# L2/AOT tests: graph shapes, fused-flags variant, HLO text emission and
# manifest consistency.
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref
from compile.kernels.md5 import pack_segments
from compile.kernels.rolling import DEFAULT_P, DEFAULT_WINDOW, pack_bytes


def rand_bytes(n, seed):
    return bytes(np.random.default_rng(seed).integers(0, 256, n, dtype=np.uint8))


class TestModelGraphs:
    def test_direct_hash_tuple(self):
        segs = [rand_bytes(256, seed=i) for i in range(4)]
        x, nblk = pack_segments(segs)
        (out,) = model.direct_hash(x, nblk, n_blocks=x.shape[1] // 16)
        assert out.shape == (4, 4)
        assert np.array_equal(np.asarray(out), ref.md5_batch_ref(segs))

    def test_sliding_window_tuple(self):
        data = rand_bytes(1024, seed=3)
        (h,) = model.sliding_window(pack_bytes(data))
        assert h.shape == (1024 - DEFAULT_WINDOW + 1,)

    def test_fused_flags_consistent(self):
        data = rand_bytes(4096, seed=4)
        h, flags = model.sliding_window_flags(pack_bytes(data), mask=0xFF, magic=0x12)
        h, flags = np.asarray(h), np.asarray(flags)
        assert np.array_equal(flags, ((h & 0xFF) == 0x12).astype(np.uint32))


class TestAot:
    def test_padded_words(self):
        # 256-byte msg -> 320 padded bytes -> 80 words -> 5 blocks
        assert aot.padded_words(256) == 80
        assert aot.padded_words(4096) == 1040

    def test_manifest_complete(self):
        arts = aot.build_manifest()
        names = {a["name"] for a in arts}
        assert len(names) == len(arts), "duplicate artifact names"
        kinds = {a["kind"] for a in arts}
        assert kinds == {"direct", "sliding"}
        for a in arts:
            if a["kind"] == "direct":
                assert a["in_words"] == [a["lanes"], a["n_blocks"] * 16]
            else:
                assert a["out_len"] == a["n_bytes"] - a["window"] + 1

    def test_lower_one_emits_hlo_text(self):
        art = dict(
            name="t", kind="direct", seg_bytes=64, lanes=2,
            n_blocks=aot.padded_words(64) // 16, in_words=[2, aot.padded_words(64)],
        )
        text = aot.lower_one(art)
        assert "HloModule" in text
        assert "u32[2,32]" in text.replace(" ", "") or "u32[2,32]" in text

    def test_lower_sliding_emits_hlo_text(self):
        art = dict(
            name="t", kind="sliding", n_bytes=256, window=DEFAULT_WINDOW,
            p=DEFAULT_P, in_words=[64], out_len=256 - DEFAULT_WINDOW + 1,
        )
        text = aot.lower_one(art)
        assert "HloModule" in text

    def test_built_artifacts_match_manifest(self):
        """If `make artifacts` has run, every manifest entry must exist."""
        mpath = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
        if not os.path.exists(mpath):
            pytest.skip("artifacts not built")
        with open(mpath) as f:
            manifest = json.load(f)
        base = os.path.dirname(mpath)
        for a in manifest["artifacts"]:
            assert os.path.exists(os.path.join(base, a["path"])), a["name"]
