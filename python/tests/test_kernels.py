# Kernel-vs-oracle correctness: the CORE L1 signal.
#
# md5_batch   must be bit-exact vs hashlib for every lane.
# rolling_hash must be bit-exact vs the Horner oracle for every offset.
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.md5 import md5_batch, pack_segments, pad_message
from compile.kernels.rolling import (
    DEFAULT_P,
    DEFAULT_WINDOW,
    mod_inverse_pow2,
    pack_bytes,
    rolling_hash,
)

RNG = np.random.default_rng(0xC0FFEE)


def rand_bytes(n, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    return bytes(rng.integers(0, 256, n, dtype=np.uint8))


# ---------------------------------------------------------------- MD5 ----
class TestMd5Padding:
    def test_pad_length_multiple_of_64(self):
        for n in [0, 1, 55, 56, 57, 63, 64, 65, 256, 4096]:
            assert len(pad_message(b"x" * n)) % 64 == 0

    def test_pad_appends_0x80(self):
        p = pad_message(b"abc")
        assert p[3] == 0x80

    def test_pad_encodes_bit_length(self):
        p = pad_message(b"a" * 10)
        assert int.from_bytes(p[-8:], "little") == 80


class TestMd5Kernel:
    @pytest.mark.parametrize("seg_bytes", [64, 256, 4096])
    @pytest.mark.parametrize("lanes", [1, 3, 16])
    def test_matches_hashlib(self, seg_bytes, lanes):
        segs = [rand_bytes(seg_bytes, seed=1000 + i) for i in range(lanes)]
        x, nblk = pack_segments(segs)
        got = np.asarray(md5_batch(x, nblk, n_blocks=x.shape[1] // 16))
        assert np.array_equal(got, ref.md5_batch_ref(segs))

    def test_known_vector_empty_block(self):
        # md5("") through the padded path: a single all-padding segment.
        segs = [b""]
        x, nblk = pack_segments(segs)
        got = np.asarray(md5_batch(x, nblk, n_blocks=x.shape[1] // 16))
        want = np.frombuffer(
            bytes.fromhex("d41d8cd98f00b204e9800998ecf8427e"), dtype="<u4"
        )
        assert np.array_equal(got[0], want)

    def test_known_vector_abc(self):
        x, nblk = pack_segments([b"abc"])
        got = np.asarray(md5_batch(x, nblk, n_blocks=x.shape[1] // 16))
        want = np.frombuffer(ref.md5_ref(b"abc"), dtype="<u4")
        assert np.array_equal(got[0], want)

    def test_lanes_independent(self):
        """Digest of lane i must not depend on other lanes."""
        segs = [rand_bytes(256, seed=i) for i in range(8)]
        x_all, nblk_all = pack_segments(segs)
        full = np.asarray(md5_batch(x_all, nblk_all, n_blocks=x_all.shape[1] // 16))
        for i in [0, 3, 7]:
            x1, nblk1 = pack_segments([segs[i]])
            one = np.asarray(md5_batch(x1, nblk1, n_blocks=x1.shape[1] // 16))
            assert np.array_equal(full[i], one[0])

    @settings(max_examples=20, deadline=None)
    @given(st.binary(min_size=0, max_size=200), st.integers(1, 4))
    def test_hypothesis_sweep(self, blob, lanes):
        segs = [blob for _ in range(lanes)]
        x, nblk = pack_segments(segs)
        got = np.asarray(md5_batch(x, nblk, n_blocks=x.shape[1] // 16))
        assert np.array_equal(got, ref.md5_batch_ref(segs))


# ------------------------------------------------------------ rolling ----
class TestModInverse:
    @settings(max_examples=50, deadline=None)
    @given(st.integers(1, 2**32 - 1).filter(lambda p: p % 2 == 1))
    def test_inverse(self, p):
        assert (p * mod_inverse_pow2(p)) % (1 << 32) == 1


class TestRollingKernel:
    @pytest.mark.parametrize("n", [64, 256, 4096])
    def test_matches_oracle(self, n):
        data = rand_bytes(n, seed=n)
        got = np.asarray(rolling_hash(pack_bytes(data)))
        assert np.array_equal(got, ref.rolling_ref_fast(data))

    def test_slow_and_fast_oracles_agree(self):
        data = rand_bytes(128, seed=5)
        assert np.array_equal(ref.rolling_ref(data), ref.rolling_ref_fast(data))

    @pytest.mark.parametrize("window", [16, 32, 48, 64])
    def test_window_sizes(self, window):
        data = rand_bytes(512, seed=window)
        got = np.asarray(rolling_hash(pack_bytes(data), window=window))
        assert np.array_equal(got, ref.rolling_ref_fast(data, window=window))

    def test_nonstandard_p(self):
        p = 0x9E3779B1  # odd
        data = rand_bytes(256, seed=9)
        got = np.asarray(rolling_hash(pack_bytes(data), p=p))
        assert np.array_equal(got, ref.rolling_ref_fast(data, p=p))

    def test_shift_invariance(self):
        """H over data[k:] must equal the tail of H over data (window
        hashes depend only on window content)."""
        data = rand_bytes(512, seed=11)
        full = np.asarray(rolling_hash(pack_bytes(data)))
        shifted = np.asarray(rolling_hash(pack_bytes(data[4:])))
        assert np.array_equal(full[4:], shifted)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.integers(16, 96))
    def test_hypothesis_sweep(self, seed, nwords):
        data = rand_bytes(4 * nwords, seed=seed)
        got = np.asarray(rolling_hash(pack_bytes(data)))
        assert np.array_equal(got, ref.rolling_ref_fast(data))

    def test_boundary_rate_statistics(self):
        """(h & mask) == magic should fire ~ 1/(mask+1) of the time on
        random data -- the property that sets the expected chunk size."""
        data = rand_bytes(1 << 18, seed=42)
        h = np.asarray(rolling_hash(pack_bytes(data)))
        mask = 0x0FFF
        rate = float(np.mean((h & mask) == 0x78))
        expected = 1.0 / (mask + 1)
        assert 0.5 * expected < rate < 2.0 * expected

    def test_unequal_segment_lengths(self):
        """One artifact shape hashes variable-length segments via the
        per-lane active-block-count input (rust planner relies on this:
        the last segment of a data block is usually short)."""
        segs = [rand_bytes(4096, seed=1), rand_bytes(100, seed=2), b"", rand_bytes(257, seed=3)]
        x, nblk = pack_segments(segs, n_blocks=65)
        got = np.asarray(md5_batch(x, nblk, n_blocks=65))
        assert np.array_equal(got, ref.md5_batch_ref(segs))
