# L2: the HashGPU compute graphs, as jax functions over the L1 Pallas
# kernels.  These are what aot.py lowers to HLO; the rust coordinator
# executes one compiled artifact per (graph, shape-bucket).
#
# Mirroring the paper's design, the graphs stop where the GPU stage stops:
#   * direct_hash   — per-segment MD5 digests; the *final* hash of the
#                     concatenated digests is computed on the host (rust),
#                     exactly as HashGPU uses the CPU for its last stage.
#   * sliding_window— per-offset rolling fingerprints; boundary selection
#                     (mask/magic, min/max, leftover carry) is host-side.
#   * sliding_window_flags — fused variant that also folds the boundary
#                     predicate into the device graph (ablation: moves the
#                     compare off the host at the cost of a fixed mask).
import jax
import jax.numpy as jnp

from .kernels.md5 import md5_batch
from .kernels.rolling import DEFAULT_P, DEFAULT_WINDOW, rolling_hash


def direct_hash(x, nblk, *, n_blocks):
    """(u32[lanes, n_blocks*16] padded segments, u32[lanes] active block
    counts) -> u32[lanes, 4] digests."""
    return (md5_batch(x, nblk, n_blocks=n_blocks),)


def sliding_window(x, *, window=DEFAULT_WINDOW, p=DEFAULT_P):
    """u32[n_words] packed bytes -> u32[4*n_words - window + 1] hashes."""
    return (rolling_hash(x, window=window, p=p),)


def sliding_window_flags(x, *, window=DEFAULT_WINDOW, p=DEFAULT_P,
                         mask=0x0FFF, magic=0x78):
    """Fused boundary predicate: returns hashes AND a u32 0/1 flag vector.

    The paper keeps the compare on the CPU; this fused variant is the
    ablation bench `ablate-fused-flags` (the flags output compresses the
    host-side scan to a flag sweep but pins mask/magic at compile time).
    """
    h = rolling_hash(x, window=window, p=p)
    flags = ((h & jnp.uint32(mask)) == jnp.uint32(magic)).astype(jnp.uint32)
    return (h, flags)


def lower_to_hlo_text(fn, *specs) -> str:
    """jit(fn).lower(specs) -> HLO text via the stablehlo->XlaComputation
    bridge.  Text (NOT .serialize()) is the interchange format: jax>=0.5
    emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
    text parser reassigns ids (see /opt/xla-example/README.md)."""
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the rolling kernel embeds its power tables
    # as constants; the default printer elides them as "{...}", which
    # does not round-trip through the rust-side text parser.
    return comp.as_hlo_text(True)
