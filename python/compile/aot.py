# AOT compile path: lower every (graph, shape-bucket) variant to HLO TEXT
# and write artifacts/ + manifest.json.  Runs once at build time
# (`make artifacts`); the rust runtime (rust/src/runtime/) loads the text
# via HloModuleProto::from_text_file and compiles it on the PJRT CPU
# client.  Python is never on the request path.
#
# Emit HLO text, NOT .serialize(): the image's xla_extension 0.5.1
# rejects jax>=0.5's 64-bit-id protos (see /opt/xla-example/README.md).
import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp

from . import model
from .kernels.md5 import pad_message
from .kernels.rolling import DEFAULT_P, DEFAULT_WINDOW

# ---------------------------------------------------------------------------
# Shape buckets.  A direct-hash job of B bytes with segment size S uses the
# smallest lane bucket >= ceil(B/S); a sliding-window job uses the smallest
# n_bytes bucket >= buffer size.  The rust side (runtime/artifacts.rs)
# mirrors this bucketing logic and splits oversized jobs.
# ---------------------------------------------------------------------------
SEGMENT_BUCKETS = {
    256: [16, 64, 256],          # small blocks: 4 KB .. 64 KB per job
    4096: [16, 64, 256, 1024],   # large blocks: 64 KB .. 4 MB per job
}
ROLLING_BYTES = [65536, 262144, 1048576, 4194304]


def padded_words(seg_bytes: int) -> int:
    """Words per segment after RFC1321 padding (host pre-pads)."""
    return len(pad_message(b"\x00" * seg_bytes)) // 4


def build_manifest():
    arts = []
    for seg, lane_list in SEGMENT_BUCKETS.items():
        words = padded_words(seg)
        n_blocks = words // 16
        for lanes in lane_list:
            arts.append(
                dict(
                    name=f"md5_seg{seg}_l{lanes}",
                    kind="direct",
                    seg_bytes=seg,
                    lanes=lanes,
                    n_blocks=n_blocks,
                    in_words=[lanes, words],
                )
            )
    for n in ROLLING_BYTES:
        arts.append(
            dict(
                name=f"roll_{n}_w{DEFAULT_WINDOW}",
                kind="sliding",
                n_bytes=n,
                window=DEFAULT_WINDOW,
                p=DEFAULT_P,
                in_words=[n // 4],
                out_len=n - DEFAULT_WINDOW + 1,
            )
        )
    return arts


def lower_one(art: dict) -> str:
    u32 = jnp.uint32
    if art["kind"] == "direct":
        spec = jax.ShapeDtypeStruct(tuple(art["in_words"]), u32)
        nblk_spec = jax.ShapeDtypeStruct((art["lanes"],), u32)
        fn = functools.partial(model.direct_hash, n_blocks=art["n_blocks"])
        return model.lower_to_hlo_text(fn, spec, nblk_spec)
    elif art["kind"] == "sliding":
        spec = jax.ShapeDtypeStruct(tuple(art["in_words"]), u32)
        fn = functools.partial(
            model.sliding_window, window=art["window"], p=art["p"]
        )
        return model.lower_to_hlo_text(fn, spec)
    raise ValueError(art["kind"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    arts = build_manifest()
    only = set(args.only.split(",")) if args.only else None

    for art in arts:
        if only is not None and art["name"] not in only:
            art["path"] = art["name"] + ".hlo.txt"  # keep manifest complete
            continue
        path = os.path.join(args.outdir, art["name"] + ".hlo.txt")
        text = lower_one(art)
        with open(path, "w") as f:
            f.write(text)
        art["path"] = art["name"] + ".hlo.txt"
        print(f"wrote {path} ({len(text)} chars)")

    manifest = dict(
        version=1,
        window=DEFAULT_WINDOW,
        p=DEFAULT_P,
        segment_buckets={str(k): v for k, v in SEGMENT_BUCKETS.items()},
        rolling_bytes=ROLLING_BYTES,
        artifacts=arts,
    )
    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(arts)} artifacts")


if __name__ == "__main__":
    main()
