# L1 Pallas kernel: sliding-window rolling fingerprint (content-based
# chunking's hot loop).
#
# The paper's HashGPU "sliding window hashing" hashes every overlapping
# W-byte window of a buffer and declares a chunk boundary where
# (hash & mask) == magic (the LBFS construction).  CUDA's formulation is
# one thread per window with shared-memory staging; the TPU-natural
# formulation (DESIGN.md par.4 Hardware-Adaptation) is a prefix-scan
# polynomial fingerprint:
#
#     H(i) = sum_{j=0..W-1} b[i+j] * p^(W-1-j)            (mod 2^32)
#          = p^(i+W-1) * (S(i+W) - S(i))                  (mod 2^32)
#     S(k) = sum_{j<k} b[j] * p^(-j)                      (mod 2^32)
#
# with p odd so p^(-1) mod 2^32 exists.  One cumsum + two elementwise
# passes replace the paper's ~100K scalar GPU threads; all arithmetic is
# natural wrapping u32.  Boundary selection (mask/magic compare, min/max
# chunk bounds, leftover carry) stays on the host — exactly like the
# paper, where "the CPU is used to check the hash values and decide on
# block boundaries".
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default polynomial base: odd, randomly-chosen constant, shared with
# rust/src/hash/rolling.rs (must match bit-for-bit).
DEFAULT_P = 0x01000193  # FNV prime; odd => invertible mod 2^32
DEFAULT_WINDOW = 48


def mod_inverse_pow2(p: int, bits: int = 32) -> int:
    """Inverse of odd p modulo 2^bits (Newton iteration)."""
    assert p % 2 == 1, "p must be odd"
    x = p  # correct to 3 bits
    for _ in range(6):  # doubles correct bits each round: 3->6->...->96
        x = (x * (2 - p * x)) % (1 << bits)
    return x % (1 << bits)


def _unpack_bytes(words):
    """u32[n] little-endian words -> u32[4n] byte values (still u32)."""
    shifts = jnp.uint32(8) * jnp.arange(4, dtype=jnp.uint32)
    b = (words[:, None] >> shifts[None, :]) & jnp.uint32(0xFF)
    return b.reshape(-1)


def _pow_table(base: int, n: int):
    """u32[n] with out[j] = base^j mod 2^32, computed with numpy at TRACE
    time so it lowers to an HLO *constant* (zero runtime cost).  NOT
    jnp.cumprod: that lowers to an O(n^2) reduce-window on the
    xla_extension 0.5.1 CPU backend the rust runtime executes; and not an
    on-device pow-by-index either — its log2(n) select passes were ~40%
    of kernel runtime (EXPERIMENTS.md section Perf)."""
    import numpy as np

    out = np.empty(n, dtype=np.uint32)
    acc = 1
    b = base & 0xFFFFFFFF
    for j in range(n):
        out[j] = acc
        acc = (acc * b) & 0xFFFFFFFF
    return out


def _prefix_sum_2level(x, row=512):
    """Inclusive prefix sum via a two-level (blocked) Hillis–Steele scan:
    log2(row) full-width passes + a tiny scan over row totals, instead of
    log2(n) full-width passes.  On the unfused xla_extension 0.5.1 CPU
    backend every pass materializes, so pass count ~ runtime."""
    n = x.shape[0]
    if n <= row:
        k = 1
        while k < n:
            x = x + jnp.concatenate([jnp.zeros((k,), x.dtype), x[:-k]])
            k *= 2
        return x
    assert n % row == 0, "bucket sizes are row-aligned"
    rows = n // row
    m = x.reshape(rows, row)
    # Intra-row scan: log2(row) passes over the full array.
    k = 1
    while k < row:
        shifted = jnp.pad(m[:, :-k], ((0, 0), (k, 0)))
        m = m + shifted
        k *= 2
    # Row offsets: exclusive scan of row totals (tiny: n/row elements).
    totals = m[:, -1]
    k = 1
    t = totals
    while k < rows:
        t = t + jnp.concatenate([jnp.zeros((k,), t.dtype), t[:-k]])
        k *= 2
    offsets = jnp.concatenate([jnp.zeros((1,), t.dtype), t[:-1]])
    return (m + offsets[:, None]).reshape(n)


def _rolling_kernel(x_ref, pinvpow_ref, ppow_ref, o_ref, *, window):
    b = _unpack_bytes(x_ref[...])  # u32[n_bytes]
    n = b.shape[0]
    # pinvpow[j] = p^-j ; ppow[k] = p^k (compile-time constant tables,
    # passed as inputs: pallas forbids captured array constants).
    pinvpow = pinvpow_ref[...]
    ppow = ppow_ref[...]
    # S[k] = sum_{j<k} b[j] * p^-j, with S[0] = 0 (exclusive prefix).
    s = jnp.concatenate(
        [jnp.zeros((1,), jnp.uint32), _prefix_sum_2level(b * pinvpow)]
    )
    n_out = n - window + 1
    win = s[window : window + n_out] - s[:n_out]  # S(i+W) - S(i)
    o_ref[...] = ppow[window - 1 : window - 1 + n_out] * win


@functools.partial(jax.jit, static_argnames=("window", "p"))
def rolling_hash(x, *, window=DEFAULT_WINDOW, p=DEFAULT_P):
    """Fingerprints of every overlapping `window`-byte window.

    x: u32[n_words] little-endian packed bytes (n_bytes = 4 * n_words).
    Returns u32[n_bytes - window + 1]: H(i) for each window start i.
    """
    n_bytes = 4 * x.shape[0]
    assert n_bytes >= window
    pinvpow = jnp.asarray(_pow_table(mod_inverse_pow2(p), n_bytes))
    ppow = jnp.asarray(_pow_table(p, n_bytes))
    return pl.pallas_call(
        functools.partial(_rolling_kernel, window=window),
        out_shape=jax.ShapeDtypeStruct((n_bytes - window + 1,), jnp.uint32),
        interpret=True,
    )(x, pinvpow, ppow)


def pack_bytes(data: bytes):
    """bytes -> u32[n/4] little-endian words (len must be 4-aligned)."""
    import numpy as np

    assert len(data) % 4 == 0, "pad to 4-byte multiple on the host"
    return jnp.asarray(np.frombuffer(data, dtype="<u4"))
