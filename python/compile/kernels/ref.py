# Pure-python/numpy correctness oracles for the Pallas kernels.
#
# md5_ref      — hashlib, the ground truth for kernels/md5.py.
# rolling_ref  — direct O(n*W) wrapping-u32 evaluation of the polynomial
#                fingerprint, ground truth for kernels/rolling.py (and for
#                rust/src/hash/rolling.rs via shared test vectors).
import hashlib

import numpy as np

from .rolling import DEFAULT_P, DEFAULT_WINDOW


def md5_ref(segment: bytes) -> bytes:
    return hashlib.md5(segment).digest()


def md5_batch_ref(segments) -> np.ndarray:
    """Digests as u32[lanes, 4] little-endian words (kernel layout)."""
    out = [np.frombuffer(md5_ref(s), dtype="<u4") for s in segments]
    return np.stack(out)


def rolling_ref(data: bytes, window: int = DEFAULT_WINDOW, p: int = DEFAULT_P) -> np.ndarray:
    """H(i) = sum b[i+j] * p^(W-1-j) mod 2^32 for every window start."""
    b = np.frombuffer(data, dtype=np.uint8).astype(np.uint64)
    n = len(b)
    out = np.zeros(n - window + 1, dtype=np.uint64)
    for i in range(n - window + 1):
        h = np.uint64(0)
        for j in range(window):
            h = (h * np.uint64(p) + b[i + j]) & np.uint64(0xFFFFFFFF)
        out[i] = h
    return out.astype(np.uint32)


def rolling_ref_fast(data: bytes, window: int = DEFAULT_WINDOW, p: int = DEFAULT_P) -> np.ndarray:
    """Vectorised oracle (still independent of the kernel's prefix-scan
    formulation): Horner evaluation across all windows at once."""
    b = np.frombuffer(data, dtype=np.uint8).astype(np.uint32)
    n = len(b)
    n_out = n - window + 1
    h = np.zeros(n_out, dtype=np.uint32)
    for j in range(window):
        h = h * np.uint32(p) + b[j : j + n_out]
    return h
