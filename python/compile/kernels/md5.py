# L1 Pallas kernel: batched MD5 (parallel Merkle-Damgard construction).
#
# The paper's HashGPU "direct hashing" primitive splits a large data block
# into fixed-size segments and hashes every segment concurrently (one CUDA
# thread per segment); the CPU then hashes the concatenation of the
# intermediate digests (Damgard's parallel construction).  On TPU the
# natural mapping is one *vector lane* per segment: the MD5 state
# (a, b, c, d) is a u32 vector across the segment axis and the 64 rounds
# are unrolled as lane-parallel u32 ops.  The block loop is a fori_loop,
# so the lowered HLO is a While over a fully-vectorised body.
#
# Input is HOST-PRE-PADDED: each segment is already padded per RFC 1321
# (0x80, zeros, 64-bit bit-length) to `n_blocks * 16` little-endian u32
# words.  The kernel is therefore shape-static: u32[lanes, n_blocks*16]
# -> u32[lanes, 4] (the digest words A, B, C, D, little-endian).
#
# interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call
# the CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# RFC 1321 round constants: K[i] = floor(2^32 * abs(sin(i + 1))).
K = tuple(int(abs(math.sin(i + 1)) * 2**32) & 0xFFFFFFFF for i in range(64))

# Per-round left-rotate amounts.
S = (
    7, 12, 17, 22, 7, 12, 17, 22, 7, 12, 17, 22, 7, 12, 17, 22,
    5, 9, 14, 20, 5, 9, 14, 20, 5, 9, 14, 20, 5, 9, 14, 20,
    4, 11, 16, 23, 4, 11, 16, 23, 4, 11, 16, 23, 4, 11, 16, 23,
    6, 10, 15, 21, 6, 10, 15, 21, 6, 10, 15, 21, 6, 10, 15, 21,
)

INIT = (0x67452301, 0xEFCDAB89, 0x98BADCFE, 0x10325476)


def _rotl(x, s):
    return (x << jnp.uint32(s)) | (x >> jnp.uint32(32 - s))


def _compress(state, block):
    """One MD5 compression over a [lanes, 16] u32 block. state: 4x[lanes]."""
    a0, b0, c0, d0 = state
    a, b, c, d = a0, b0, c0, d0
    for i in range(64):
        if i < 16:
            f = (b & c) | (~b & d)
            g = i
        elif i < 32:
            f = (d & b) | (~d & c)
            g = (5 * i + 1) % 16
        elif i < 48:
            f = b ^ c ^ d
            g = (3 * i + 5) % 16
        else:
            f = c ^ (b | ~d)
            g = (7 * i) % 16
        tmp = d
        d = c
        c = b
        sum_ = a + f + jnp.uint32(K[i]) + block[:, g]
        b = b + _rotl(sum_, S[i])
        a = tmp
    return (a0 + a, b0 + b, c0 + c, d0 + d)


def _md5_kernel(x_ref, nblk_ref, o_ref, *, n_blocks):
    x = x_ref[...]  # [lanes, n_blocks * 16] u32, pre-padded
    nblk = nblk_ref[...]  # u32[lanes]: active block count per lane
    lanes = x.shape[0]
    init = tuple(jnp.full((lanes,), jnp.uint32(v)) for v in INIT)

    def body(blk, state):
        block = jax.lax.dynamic_slice_in_dim(x, blk * 16, 16, axis=1)
        new = _compress(state, block)
        # Lanes whose message ended before this block keep their state:
        # this is how one fixed-shape artifact hashes variable-length
        # segments (the last segment of a data block is usually short).
        active = blk.astype(jnp.uint32) < nblk
        return tuple(jnp.where(active, n, s) for n, s in zip(new, state))

    a, b, c, d = jax.lax.fori_loop(0, n_blocks, body, init)
    o_ref[...] = jnp.stack([a, b, c, d], axis=1)


@functools.partial(jax.jit, static_argnames=("n_blocks",))
def md5_batch(x, nblk, *, n_blocks):
    """MD5 digests of a batch of pre-padded segments.

    x: u32[lanes, n_blocks * 16] little-endian words; each lane holds one
    RFC1321-padded message occupying its first `nblk[lane]` 64-byte
    blocks (the rest must be zero).  Returns u32[lanes, 4] digest words
    (A, B, C, D); serialising each word little-endian yields the standard
    16-byte MD5 digest.
    """
    lanes, words = x.shape
    assert words == n_blocks * 16, (words, n_blocks)
    assert nblk.shape == (lanes,)
    return pl.pallas_call(
        functools.partial(_md5_kernel, n_blocks=n_blocks),
        out_shape=jax.ShapeDtypeStruct((lanes, 4), jnp.uint32),
        interpret=True,
    )(x, nblk)


def pad_message(data: bytes) -> bytes:
    """RFC 1321 padding (host side; mirrors rust/src/hash/md5.rs)."""
    bit_len = 8 * len(data)
    data = data + b"\x80"
    data = data + b"\x00" * ((56 - len(data)) % 64)
    return data + bit_len.to_bytes(8, "little")


def pack_segments(segments, n_blocks=None):
    """Pad each segment and pack into (u32[lanes, n_blocks*16] words,
    u32[lanes] active block counts).  `n_blocks` defaults to the largest
    segment's padded block count (segments may have different lengths)."""
    import numpy as np

    padded = [pad_message(s) for s in segments]
    if n_blocks is None:
        n_blocks = max(len(p) for p in padded) // 64
    lanes = len(segments)
    arr = np.zeros((lanes, n_blocks * 16), dtype=np.uint32)
    nblk = np.zeros(lanes, dtype=np.uint32)
    for i, p in enumerate(padded):
        assert len(p) <= n_blocks * 64, "segment exceeds artifact capacity"
        w = np.frombuffer(p, dtype="<u4")
        arr[i, : len(w)] = w
        nblk[i] = len(p) // 64
    return jnp.asarray(arr), jnp.asarray(nblk)
