//! Shared-hash-service occupancy benchmark (PR 6): N concurrent write
//! sessions hashing through per-session engines vs handles onto one
//! shared coalescing service, on the SAME single modeled device.
//!
//!     cargo bench --bench hashsvc            # full matrix (adds 64 sessions + cpu arm)
//!     cargo bench --bench hashsvc -- quick   # CI smoke subset (1/4/16 sessions)
//!
//! Each session streams small 4-block submissions (16 KB blocks over
//! 4 KB segments — 16 segments a pop), the shallow-batch regime the
//! paper's CrystalGPU observation is about: a per-session engine turns
//! every submission into its own under-occupied device step, while the
//! shared service coalesces concurrent sessions' submissions into deep
//! batches (up to `max_batch_blocks`, held back at most `max_linger`)
//! that fill wide artifact lanes and amortize the per-step overhead.
//! The mock backend charges a fixed per-step cost, so the win measured
//! here is exactly the step-count reduction — the same quantity the
//! calibrated sim models via `GpuPipeline::shared_stream_secs`.
//!
//! Results are printed as tables and flushed to `BENCH_pr6.json` at the
//! repo root (MB/s + batch-depth curve per scenario/arm/session count;
//! CI gates on shared@16 beating per-session@16 on the mock-gpu
//! scenario).

use std::sync::Arc;
use std::time::{Duration, Instant};

use gpustore::config::ClientConfig;
use gpustore::crystal::{BackendKind, CrystalOpts, Master, MockTuning};
use gpustore::hashgpu::{CpuEngine, GpuEngine, HashEngine, WindowHashMode};
use gpustore::hashsvc::{HashService, SvcPolicy};
use gpustore::metrics::Table;
use gpustore::runtime::artifacts::Manifest;
use gpustore::util::Rng;

const MB: f64 = 1024.0 * 1024.0;
/// 16 KB blocks over the 4 KB segment size: 4 segments per block.
const BLOCK: usize = 16 * 1024;
const SEG: usize = 4096;
/// Blocks per submission — one small write-buffer's worth.
const SUB_BLOCKS: usize = 4;
/// Submissions per session.
const JOBS: usize = 8;
/// Fixed per-step device cost: the overhead deep batches amortize.
const STEP_COST: Duration = Duration::from_millis(2);

struct Record {
    scenario: &'static str,
    engine: &'static str,
    sessions: usize,
    mbps: f64,
    depth_mean: f64,
    depth_max: usize,
    speedup_vs_per_session: f64,
}

#[derive(Default, Clone, Copy)]
struct DepthAgg {
    batches: u64,
    depth_sum: u64,
    depth_max: usize,
}

impl DepthAgg {
    fn mean(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.depth_sum as f64 / self.batches as f64
        }
    }
}

fn mock_master() -> Arc<Master> {
    let opts = CrystalOpts {
        devices: 1,
        ..CrystalOpts::optimized(BackendKind::Mock {
            artifact_dir: Manifest::default_dir(),
            tuning: MockTuning {
                fixed_delay: STEP_COST,
                ..MockTuning::default()
            },
        })
    };
    Arc::new(Master::new(opts).unwrap())
}

/// Per-session payloads: `sessions` lists of `JOBS` submissions each.
fn payloads(sessions: usize) -> Vec<Vec<Arc<Vec<Vec<u8>>>>> {
    (0..sessions)
        .map(|s| {
            (0..JOBS)
                .map(|j| {
                    Arc::new(
                        (0..SUB_BLOCKS)
                            .map(|b| {
                                Rng::new((s * JOBS * SUB_BLOCKS + j * SUB_BLOCKS + b) as u64)
                                    .bytes(BLOCK)
                            })
                            .collect::<Vec<Vec<u8>>>(),
                    )
                })
                .collect()
        })
        .collect()
}

/// Drive all sessions concurrently; returns (elapsed secs, depth agg).
fn drive(engines: &[Arc<dyn HashEngine>], work: &[Vec<Arc<Vec<Vec<u8>>>>]) -> (f64, DepthAgg) {
    let t0 = Instant::now();
    let mut agg = DepthAgg::default();
    std::thread::scope(|scope| {
        let handles: Vec<_> = engines
            .iter()
            .zip(work)
            .map(|(engine, subs)| {
                scope.spawn(move || {
                    let mut out = DepthAgg::default();
                    for blocks in subs {
                        let ticket = engine.submit_direct_batch(blocks.clone()).unwrap();
                        let (digests, timing) = ticket.wait().unwrap();
                        assert_eq!(digests.len(), blocks.len());
                        out.batches += 1;
                        out.depth_sum += timing.batch_blocks as u64;
                        out.depth_max = out.depth_max.max(timing.batch_blocks);
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            let out = h.join().unwrap();
            agg.batches += out.batches;
            agg.depth_sum += out.depth_sum;
            agg.depth_max = agg.depth_max.max(out.depth_max);
        }
    });
    (t0.elapsed().as_secs_f64(), agg)
}

fn run_scenario(
    scenario: &'static str,
    session_counts: &[usize],
    build: impl Fn(usize, bool) -> Vec<Arc<dyn HashEngine>>,
    records: &mut Vec<Record>,
) {
    println!(
        "\n== hashsvc: {scenario} ({JOBS} submissions x {SUB_BLOCKS} x {} KB blocks per session) ==",
        BLOCK / 1024
    );
    let mut t = Table::new(&[
        "sessions",
        "per-session MB/s",
        "shared MB/s",
        "shared depth mean/max",
        "speedup",
    ]);
    for &n in session_counts {
        let work = payloads(n);
        let total_bytes = (n * JOBS * SUB_BLOCKS * BLOCK) as f64;

        let dedicated = build(n, false);
        check_digests(&dedicated[0], &work[0][0]);
        let (base_secs, base_agg) = drive(&dedicated, &work);
        drop(dedicated);
        let base_mbps = total_bytes / MB / base_secs;

        let shared = build(n, true);
        check_digests(&shared[0], &work[0][0]);
        let (svc_secs, svc_agg) = drive(&shared, &work);
        drop(shared);
        let svc_mbps = total_bytes / MB / svc_secs;

        let speedup = svc_mbps / base_mbps;
        t.row(vec![
            n.to_string(),
            format!("{base_mbps:.1}"),
            format!("{svc_mbps:.1}"),
            format!("{:.1} / {}", svc_agg.mean(), svc_agg.depth_max),
            format!("{speedup:.2}x"),
        ]);
        records.push(Record {
            scenario,
            engine: "per-session",
            sessions: n,
            mbps: base_mbps,
            depth_mean: base_agg.mean(),
            depth_max: base_agg.depth_max,
            speedup_vs_per_session: 1.0,
        });
        records.push(Record {
            scenario,
            engine: "shared",
            sessions: n,
            mbps: svc_mbps,
            depth_mean: svc_agg.mean(),
            depth_max: svc_agg.depth_max,
            speedup_vs_per_session: speedup,
        });
    }
    println!("{}", t.markdown());
}

/// Bit-identity spot check against the CPU reference.
fn check_digests(engine: &Arc<dyn HashEngine>, blocks: &Arc<Vec<Vec<u8>>>) {
    let cpu = CpuEngine::new(1, SEG, WindowHashMode::Rolling);
    let (got, _) = engine
        .submit_direct_batch(blocks.clone())
        .unwrap()
        .wait()
        .unwrap();
    for (blk, d) in blocks.iter().zip(&got) {
        assert_eq!(cpu.direct_hash(blk).unwrap(), *d, "digest mismatch");
    }
}

fn svc_policy() -> SvcPolicy {
    SvcPolicy {
        max_batch_blocks: ClientConfig::default().hash_batch,
        max_linger: Duration::from_micros(500),
        devices: 1,
    }
}

fn main() {
    let args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    let quick = args.iter().any(|a| a == "quick");
    let session_counts: Vec<usize> = if quick {
        vec![1, 4, 16]
    } else {
        vec![1, 4, 16, 64]
    };

    let mut records: Vec<Record> = Vec::new();

    // Mock-GPU arm: per-session GpuEngines vs service handles, all over
    // a fresh single-device master per measurement (same step cost).
    run_scenario(
        "mock-gpu",
        &session_counts,
        |n, shared| {
            if shared {
                let svc =
                    HashService::over_crystal(mock_master(), SEG, 48, svc_policy());
                (0..n).map(|_| svc.handle()).collect()
            } else {
                let master = mock_master();
                (0..n)
                    .map(|_| {
                        Arc::new(GpuEngine::new(master.clone(), SEG, 48))
                            as Arc<dyn HashEngine>
                    })
                    .collect()
            }
        },
        &mut records,
    );

    // CPU fallback arm (full mode): single-threaded engines per session
    // vs multi-lane service over one such engine — shows the batching
    // policy composing with host-side parallel lanes.
    if !quick {
        run_scenario(
            "cpu",
            &session_counts,
            |n, shared| {
                if shared {
                    let svc = HashService::over_engine(
                        Arc::new(CpuEngine::new(1, SEG, WindowHashMode::Rolling)),
                        SvcPolicy {
                            devices: 4,
                            ..svc_policy()
                        },
                    );
                    (0..n).map(|_| svc.handle()).collect()
                } else {
                    (0..n)
                        .map(|_| {
                            Arc::new(CpuEngine::new(1, SEG, WindowHashMode::Rolling))
                                as Arc<dyn HashEngine>
                        })
                        .collect()
                }
            },
            &mut records,
        );
    }

    flush(&records, quick);
}

fn flush(records: &[Record], quick: bool) {
    let mut out = String::from("{\n  \"bench\": \"hashsvc\",\n  \"unit\": \"MB/s\",\n");
    out.push_str(&format!("  \"quick\": {quick},\n"));
    out.push_str(&format!(
        "  \"block_bytes\": {BLOCK},\n  \"sub_blocks\": {SUB_BLOCKS},\n  \
         \"jobs_per_session\": {JOBS},\n  \"results\": [\n"
    ));
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"engine\": \"{}\", \"sessions\": {}, \
             \"mbps\": {:.2}, \"depth_mean\": {:.2}, \"depth_max\": {}, \
             \"speedup_vs_per_session\": {:.3}}}{}\n",
            r.scenario,
            r.engine,
            r.sessions,
            r.mbps,
            r.depth_mean,
            r.depth_max,
            r.speedup_vs_per_session,
            if i + 1 == records.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write("BENCH_pr6.json", &out) {
        Ok(()) => println!("\nwrote BENCH_pr6.json ({} results)", records.len()),
        Err(e) => eprintln!("could not write BENCH_pr6.json: {e}"),
    }
}
