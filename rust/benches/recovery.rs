//! Durable control-plane recovery benchmark (PR 7): how fast the
//! manager comes back from its write-ahead log, and what group commit
//! buys on the logging hot path.
//!
//!     cargo bench --bench recovery            # full matrix
//!     cargo bench --bench recovery -- quick   # CI smoke subset
//!
//! Two experiments, both against a bare [`ManagerState`] (no TCP, no
//! storage nodes — the WAL is the system under test):
//!
//! * **replay**: drive N logged mutations (open-lease → alloc →
//!   commit per file), kill the state, and time a cold
//!   `with_durability` recovery — replay time must scale linearly in
//!   log length.
//! * **group-commit**: the same mutation workload under
//!   `--wal-sync 0` (fsync every record, the strict baseline) vs the
//!   default 5 ms window (one fsync covers every record in the
//!   window).  Batched group commit must beat per-record fsync —
//!   CI gates on exactly that.
//!
//! Results are printed as tables and flushed to `BENCH_pr7.json` at
//! the repo root.

use std::time::{Duration, Instant};

use gpustore::store::proto::{BlockMeta, BlockSpec, Msg};
use gpustore::store::{policy_for, ManagerState};
use gpustore::util::Rng;
use gpustore::wal::DurabilityOpts;

/// Self-cleaning scratch directory (the bench has no access to the
/// crate-internal test fixture).
struct TempDir(std::path::PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        use std::sync::atomic::{AtomicU64, Ordering};
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let n = SEQ.fetch_add(1, Ordering::Relaxed);
        let name = format!("gpustore-bench-{tag}-{}-{n}", std::process::id());
        let dir = std::env::temp_dir().join(name);
        std::fs::create_dir_all(&dir).unwrap();
        TempDir(dir)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// A long-lived lease window so nothing lapses mid-bench, and a huge
/// snapshot cadence so recovery measures *pure log replay*.
const LEASE: Duration = Duration::from_secs(600);

fn opts(dir: &TempDir, sync_interval: Duration) -> DurabilityOpts {
    DurabilityOpts {
        data_dir: dir.0.clone(),
        sync_interval,
        snapshot_every: u64::MAX,
    }
}

fn state_with(o: &DurabilityOpts) -> ManagerState {
    ManagerState::with_durability(policy_for(1), LEASE, Some(o.clone())).unwrap()
}

fn join_nodes(state: &ManagerState) {
    // Root-reserved loopback ports: nothing listens, and this workload
    // never triggers GC, so no connection is ever attempted.
    for port in 1..=4 {
        let addr = format!("127.0.0.1:{port}");
        let _ = state.handle(Msg::NodeJoin { addr });
    }
}

/// Drive `files` fresh single-block files through the logged mutation
/// path: open-lease, alloc, commit — 3 WAL records per file, no
/// overwrites (so no GC network traffic pollutes the measurement).
fn drive(state: &ManagerState, rng: &mut Rng, files: usize, tag: &str) {
    join_nodes(state);
    for i in 0..files {
        if i % 256 == 0 {
            // Volatile liveness refresh (unlogged): keeps placement
            // alive through runs longer than the heartbeat window.
            join_nodes(state);
        }
        let file = format!("{tag}-{i}");
        let open = state.handle(Msg::OpenLease {
            file: file.clone(),
            write: true,
        });
        let Msg::LeaseGrant { lease, .. } = open else {
            panic!("open failed: {open:?}");
        };
        let mut hash = [0u8; 16];
        rng.fill(&mut hash);
        let alloc = state.handle(Msg::AllocPlacement {
            file: file.clone(),
            lease,
            blocks: vec![BlockSpec { hash, len: 4096 }],
        });
        let Msg::Placement { assignments } = alloc else {
            panic!("alloc failed: {alloc:?}");
        };
        let commit = state.handle(Msg::CommitBlockMap {
            file,
            lease,
            blocks: vec![BlockMeta {
                hash,
                len: 4096,
                replicas: assignments[0].replicas.clone(),
                ec: None,
            }],
        });
        assert!(matches!(commit, Msg::Ok), "commit failed: {commit:?}");
    }
}

struct Record {
    kind: &'static str,
    sync: &'static str,
    records: u64,
    millis: f64,
    records_per_sec: f64,
}

/// Experiment 1: cold-recovery time vs log length.
fn replay_case(files: usize, out: &mut Vec<Record>) {
    let dir = TempDir::new("replay");
    let o = opts(&dir, Duration::from_millis(5));
    let state = state_with(&o);
    let mut rng = Rng::new(0x5EED ^ files as u64);
    drive(&state, &mut rng, files, "r");
    let records = state.last_lsn();
    let want = state.snapshot_state();
    state.detach_wal();
    drop(state);

    let t = Instant::now();
    let recovered = state_with(&o);
    let millis = t.elapsed().as_secs_f64() * 1e3;
    assert_eq!(recovered.snapshot_state(), want, "recovery diverged");
    println!(
        "replay: {records:>7} records in {millis:>9.2} ms  \
         ({:.0} records/s)",
        records as f64 / (millis / 1e3)
    );
    out.push(Record {
        kind: "replay",
        sync: "batched-5ms",
        records,
        millis,
        records_per_sec: records as f64 / (millis / 1e3),
    });
}

/// Experiment 2: logging throughput, per-record fsync vs group commit.
fn group_commit_case(
    sync: &'static str,
    sync_interval: Duration,
    files: usize,
    out: &mut Vec<Record>,
) {
    let dir = TempDir::new("sync");
    let o = opts(&dir, sync_interval);
    let state = state_with(&o);
    let mut rng = Rng::new(0xABBA ^ files as u64);
    let t = Instant::now();
    drive(&state, &mut rng, files, "s");
    let millis = t.elapsed().as_secs_f64() * 1e3;
    let records = state.last_lsn();
    println!(
        "group-commit [{sync:>11}]: {records:>6} records in {millis:>9.2} ms  \
         ({:.0} records/s)",
        records as f64 / (millis / 1e3)
    );
    out.push(Record {
        kind: "group-commit",
        sync,
        records,
        millis,
        records_per_sec: records as f64 / (millis / 1e3),
    });
}

fn main() {
    let args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    let quick = args.iter().any(|a| a == "quick");

    // ~1k / ~10k records quick; the full run adds ~50k (3 records per
    // file plus the 4 node joins).
    let replay_files: Vec<usize> = if quick {
        vec![333, 3_333]
    } else {
        vec![333, 3_333, 16_666]
    };
    let sync_files = if quick { 700 } else { 3_500 };

    let mut records: Vec<Record> = Vec::new();
    println!("== recovery: replay time vs log length ==");
    for files in replay_files {
        replay_case(files, &mut records);
    }
    println!("\n== logging: per-record fsync vs group commit ==");
    group_commit_case("per-record", Duration::ZERO, sync_files, &mut records);
    group_commit_case("batched-5ms", Duration::from_millis(5), sync_files, &mut records);

    flush(&records, quick);
}

fn flush(records: &[Record], quick: bool) {
    let mut out = String::from("{\n  \"bench\": \"recovery\",\n  \"unit\": \"records/s\",\n");
    out.push_str(&format!("  \"quick\": {quick},\n  \"results\": [\n"));
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"kind\": \"{}\", \"sync\": \"{}\", \"records\": {}, \"millis\": {:.3}, \
             \"records_per_sec\": {:.0}}}{}\n",
            r.kind,
            r.sync,
            r.records,
            r.millis,
            r.records_per_sec,
            if i + 1 == records.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write("BENCH_pr7.json", &out) {
        Ok(()) => println!("\nwrote BENCH_pr7.json ({} results)", records.len()),
        Err(e) => eprintln!("could not write BENCH_pr7.json: {e}"),
    }
}
