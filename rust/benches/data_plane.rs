//! End-to-end data-plane throughput benchmark (data-plane v2): real
//! cluster, real TCP, real sessions — measuring what the pipelined
//! duplex protocol buys over the old lock-step one.
//!
//!     cargo bench --bench data_plane            # full matrix
//!     cargo bench --bench data_plane -- quick   # CI smoke subset
//!
//! The matrix crosses shaped (1 Gbps-model NICs + a GbE-realistic
//! 500 µs request→reply turnaround on every node) and unshaped
//! (loopback-raw) fabrics with replication 1 and 3, ablating the
//! per-node in-flight depth (`ClientConfig::node_inflight`).
//! **Depth 1 is the lock-step baseline** — one operation on the wire
//! per node, reply awaited before the next frame, exactly the
//! pre-pipelining data plane; the session's in-flight-bytes budget is
//! scaled with the depth.  Writes run non-CA so no hashing and no
//! dedup pollute the wire-path measurement.
//!
//! Results are printed as tables and flushed to `BENCH_pr5.json` at
//! the repo root (MB/s per scenario, op, depth, plus the
//! speedup-vs-lock-step column CI and the README quote).

use std::sync::Arc;
use std::time::{Duration, Instant};

use gpustore::config::{ClientConfig, ClusterConfig};
use gpustore::hashgpu::OracleEngine;
use gpustore::metrics::Table;
use gpustore::store::Cluster;
use gpustore::util::Rng;

const MB: f64 = 1024.0 * 1024.0;
/// Small blocks stress the per-request turnaround — the regime where
/// lock-step is `block_size / RTT`-bound.  32 KB is under the shaped
/// link's bandwidth-delay product (117 MB/s × 500 µs ≈ 58 KB), so a
/// lock-step sender genuinely idles each RTT instead of burning
/// banked token-bucket credit.
const BLOCK: usize = 32 * 1024;

struct Scenario {
    name: &'static str,
    shape: bool,
    rtt_us: u64,
    nodes: usize,
    replication: usize,
    file_mb: usize,
}

struct Record {
    scenario: &'static str,
    op: &'static str,
    nodes: usize,
    replication: usize,
    shaped: bool,
    rtt_us: u64,
    depth: usize,
    mbps: f64,
    speedup_vs_lockstep: f64,
}

fn main() {
    let args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    let quick = args.iter().any(|a| a == "quick");
    let depths: Vec<usize> = if quick {
        vec![1, 4]
    } else {
        vec![1, 2, 4, 8, 16]
    };
    let scenarios: Vec<Scenario> = if quick {
        vec![
            Scenario {
                name: "shaped-pernode",
                shape: true,
                rtt_us: 500,
                nodes: 1,
                replication: 1,
                file_mb: 8,
            },
            Scenario {
                name: "unshaped",
                shape: false,
                rtt_us: 0,
                nodes: 4,
                replication: 1,
                file_mb: 8,
            },
        ]
    } else {
        vec![
            // The per-node isolate: one node, so the whole write AND
            // read path ride a single duplex link.
            Scenario {
                name: "shaped-pernode",
                shape: true,
                rtt_us: 500,
                nodes: 1,
                replication: 1,
                file_mb: 16,
            },
            // The paper's stripe: 4 nodes behind one client NIC.
            Scenario {
                name: "shaped-stripe",
                shape: true,
                rtt_us: 500,
                nodes: 4,
                replication: 1,
                file_mb: 16,
            },
            Scenario {
                name: "shaped-stripe-r3",
                shape: true,
                rtt_us: 500,
                nodes: 4,
                replication: 3,
                file_mb: 16,
            },
            Scenario {
                name: "unshaped",
                shape: false,
                rtt_us: 0,
                nodes: 4,
                replication: 1,
                file_mb: 32,
            },
        ]
    };

    let mut records: Vec<Record> = Vec::new();
    for sc in &scenarios {
        let cluster = Cluster::spawn(ClusterConfig {
            nodes: sc.nodes,
            link_bps: 1e9,
            shape: sc.shape,
            replication: sc.replication,
            node_rtt: Duration::from_micros(sc.rtt_us),
            ..ClusterConfig::default()
        })
        .unwrap();
        let data = Rng::new(0xDA7A).bytes(sc.file_mb << 20);
        println!(
            "\n== data-plane: {} (nodes={}, r={}, {}, rtt={}us, {} MB files, {} KB blocks) ==",
            sc.name,
            sc.nodes,
            sc.replication,
            if sc.shape { "1 Gbps shaped" } else { "unshaped" },
            sc.rtt_us,
            sc.file_mb,
            BLOCK / 1024,
        );
        let mut t = Table::new(&[
            "depth",
            "write MB/s",
            "read MB/s",
            "write x vs lock-step",
            "read x vs lock-step",
        ]);
        let mut base = (0.0f64, 0.0f64);
        for &depth in &depths {
            let cfg = ClientConfig {
                block_size: BLOCK,
                write_buffer: 16 * BLOCK,
                node_inflight: depth,
                // The session budget scales with the requested depth so
                // it admits (and bounds) exactly that much pipeline.
                inflight_budget: BLOCK * depth * sc.nodes * sc.replication,
                ..ClientConfig::non_ca()
            };
            let sai = cluster.client(cfg, Arc::new(OracleEngine::new())).unwrap();
            // Unshaped loopback runs are noisier: best of 3.
            let runs = if sc.shape { 1 } else { 3 };
            let mut wr_mbps = 0.0f64;
            let mut rd_mbps = 0.0f64;
            // Warmup outside the measurement: node links connect lazily.
            sai.write_file(&format!("warm-{}-{depth}", sc.name), &data[..1 << 20])
                .unwrap();
            for run in 0..runs {
                let name = format!("dp-{}-{depth}-{run}", sc.name);
                let rep = sai.write_file(&name, &data).unwrap();
                assert_eq!(rep.new_blocks, data.len().div_ceil(BLOCK), "{name}");
                wr_mbps = wr_mbps.max(rep.mbps());
                let t0 = Instant::now();
                let back = sai.read_file(&name).unwrap();
                let dt = t0.elapsed().as_secs_f64();
                assert_eq!(back.len(), data.len(), "{name}");
                rd_mbps = rd_mbps.max(back.len() as f64 / MB / dt);
            }
            if depth == depths[0] {
                base = (wr_mbps, rd_mbps);
            }
            let (wx, rx) = (wr_mbps / base.0, rd_mbps / base.1);
            t.row(vec![
                if depth == 1 {
                    "1 (lock-step)".into()
                } else {
                    depth.to_string()
                },
                format!("{wr_mbps:.1}"),
                format!("{rd_mbps:.1}"),
                format!("{wx:.2}x"),
                format!("{rx:.2}x"),
            ]);
            for (op, mbps, speedup) in [("write", wr_mbps, wx), ("read", rd_mbps, rx)] {
                records.push(Record {
                    scenario: sc.name,
                    op,
                    nodes: sc.nodes,
                    replication: sc.replication,
                    shaped: sc.shape,
                    rtt_us: sc.rtt_us,
                    depth,
                    mbps,
                    speedup_vs_lockstep: speedup,
                });
            }
        }
        println!("{}", t.markdown());
    }
    flush(&records, quick);
}

fn flush(records: &[Record], quick: bool) {
    let mut out = String::from("{\n  \"bench\": \"data-plane\",\n  \"unit\": \"MB/s\",\n");
    out.push_str(&format!("  \"quick\": {quick},\n"));
    out.push_str(&format!("  \"block_bytes\": {BLOCK},\n  \"results\": [\n"));
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"op\": \"{}\", \"nodes\": {}, \"replication\": {}, \
             \"shaped\": {}, \"rtt_us\": {}, \"depth\": {}, \"mbps\": {:.2}, \
             \"speedup_vs_lockstep\": {:.3}}}{}\n",
            r.scenario,
            r.op,
            r.nodes,
            r.replication,
            r.shaped,
            r.rtt_us,
            r.depth,
            r.mbps,
            r.speedup_vs_lockstep,
            if i + 1 == records.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write("BENCH_pr5.json", &out) {
        Ok(()) => println!("\nwrote BENCH_pr5.json ({} results)", records.len()),
        Err(e) => eprintln!("could not write BENCH_pr5.json: {e}"),
    }
}
