//! Serve-loop scalability benchmark (PR 9): thousands of concurrent
//! client sessions against one manager, event-driven reactor vs the
//! legacy thread-per-connection accept loop.
//!
//!     cargo bench --bench sessions            # full matrix (up to 1k+)
//!     cargo bench --bench sessions -- quick   # CI smoke subset
//!
//! The workload models the control-plane edge the reactor was built
//! for: many short-lived sessions, each a burst of small metadata
//! round-trips (`ListFiles`) with connection churn every couple of
//! ops — exactly the pattern where thread-per-connection pays a thread
//! spawn + teardown per session while the event loop pays one `poll`
//! registration.  Every open session holds a live socket, so at 1024
//! sessions the thread-mode server carries 1024 blocked threads and
//! the event-mode server the same fixed worker pool it had at 16.
//!
//! Results (sessions-vs-throughput/latency curve, both modes) are
//! printed as a table and flushed to `BENCH_pr9.json` at the repo
//! root; CI gates on event-driven beating thread-per-connection at
//! 256 sessions.

use std::sync::Arc;
use std::time::{Duration, Instant};

use gpustore::config::ServeMode;
use gpustore::net::{Conn, Listener};
use gpustore::store::proto::Msg;
use gpustore::store::{policy_for, Manager, ManagerState};

/// Lease window: irrelevant to the workload (no leases opened), but
/// long so background expiry never logs anything mid-measurement.
const LEASE: Duration = Duration::from_secs(600);

/// Ops each session performs (quick mode halves this).
const OPS_PER_SESSION: usize = 12;

/// Reconnect every this many ops — the churn that makes the serve
/// loop's accept/teardown cost visible.
const CHURN_EVERY: usize = 2;

/// Driver threads multiplexing the sessions (the bench host has few
/// cores; the *server* is the system under test).
const MAX_DRIVERS: usize = 64;

struct Row {
    mode: &'static str,
    sessions: usize,
    ops: usize,
    ops_per_sec: f64,
    p50_us: f64,
    p99_us: f64,
}

fn mode_name(mode: ServeMode) -> &'static str {
    match mode {
        ServeMode::Event => "event",
        ServeMode::Thread => "thread",
    }
}

/// One `ListFiles` round-trip; returns the latency in microseconds.
fn one_op(conn: &mut Conn) -> f64 {
    let t = Instant::now();
    Msg::ListFiles.write_to(conn).expect("send");
    match Msg::read_from(conn).expect("recv") {
        Some(Msg::Files { .. }) => {}
        other => panic!("unexpected reply: {other:?}"),
    }
    t.elapsed().as_secs_f64() * 1e6
}

/// Run `sessions` concurrent sessions against a fresh manager serving
/// in `mode`; every session keeps a socket open for its whole life and
/// reconnects every [`CHURN_EVERY`] ops.
fn bench_case(mode: ServeMode, sessions: usize, ops_per_session: usize) -> Row {
    let state = Arc::new(
        ManagerState::with_durability(policy_for(1), LEASE, None).expect("manager state"),
    );
    let listener = Listener::bind("127.0.0.1:0").expect("bind");
    let mut mgr =
        Manager::serve_listener_opts(listener, state, mode, 0).expect("serve");
    let addr = mgr.addr().to_string();

    let drivers = sessions.min(MAX_DRIVERS);
    let per_driver = sessions / drivers;
    assert_eq!(sessions % drivers, 0, "session counts divide the driver pool");

    let t0 = Instant::now();
    let handles: Vec<_> = (0..drivers)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut conns: Vec<Conn> = (0..per_driver)
                    .map(|_| Conn::connect(&addr).expect("connect"))
                    .collect();
                let mut lat = Vec::with_capacity(per_driver * ops_per_session);
                for round in 0..ops_per_session {
                    for conn in conns.iter_mut() {
                        lat.push(one_op(conn));
                    }
                    if (round + 1) % CHURN_EVERY == 0 && round + 1 < ops_per_session {
                        for conn in conns.iter_mut() {
                            *conn = Conn::connect(&addr).expect("reconnect");
                        }
                    }
                }
                lat
            })
        })
        .collect();
    let mut lat: Vec<f64> = Vec::with_capacity(sessions * ops_per_session);
    for h in handles {
        lat.extend(h.join().expect("driver"));
    }
    let wall = t0.elapsed().as_secs_f64();
    mgr.shutdown();

    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let ops = lat.len();
    let pct = |p: f64| lat[((ops as f64 * p) as usize).min(ops - 1)];
    Row {
        mode: mode_name(mode),
        sessions,
        ops,
        ops_per_sec: ops as f64 / wall,
        p50_us: pct(0.50),
        p99_us: pct(0.99),
    }
}

fn main() {
    let args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    let quick = args.iter().any(|a| a == "quick");

    let session_counts: Vec<usize> = if quick {
        vec![64, 256]
    } else {
        vec![16, 64, 256, 1024]
    };
    let ops_per_session = if quick { OPS_PER_SESSION / 2 } else { OPS_PER_SESSION };

    println!("== serve-loop scalability: sessions vs throughput/latency ==");
    println!(
        "{:<8} {:>8} {:>8} {:>12} {:>9} {:>9}",
        "mode", "sessions", "ops", "ops/s", "p50 us", "p99 us"
    );
    let mut rows: Vec<Row> = Vec::new();
    for &sessions in &session_counts {
        for mode in [ServeMode::Thread, ServeMode::Event] {
            let row = bench_case(mode, sessions, ops_per_session);
            println!(
                "{:<8} {:>8} {:>8} {:>12.0} {:>9.0} {:>9.0}",
                row.mode, row.sessions, row.ops, row.ops_per_sec, row.p50_us, row.p99_us
            );
            rows.push(row);
        }
    }

    // The headline comparison CI gates on.
    let at = |mode: &str, sessions: usize| {
        rows.iter()
            .find(|r| r.mode == mode && r.sessions == sessions)
            .map(|r| r.ops_per_sec)
    };
    if let (Some(ev), Some(th)) = (at("event", 256), at("thread", 256)) {
        println!(
            "\n@256 sessions: event {ev:.0} ops/s vs thread {th:.0} ops/s ({:+.1}%)",
            (ev / th - 1.0) * 100.0
        );
    }

    flush(&rows, quick);
}

fn flush(rows: &[Row], quick: bool) {
    let mut out = String::from("{\n  \"bench\": \"sessions\",\n  \"unit\": \"ops/s\",\n");
    out.push_str(&format!("  \"quick\": {quick},\n  \"results\": [\n"));
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"mode\": \"{}\", \"sessions\": {}, \"ops\": {}, \"ops_per_sec\": {:.0}, \
             \"p50_us\": {:.1}, \"p99_us\": {:.1}}}{}\n",
            r.mode,
            r.sessions,
            r.ops,
            r.ops_per_sec,
            r.p50_us,
            r.p99_us,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write("BENCH_pr9.json", &out) {
        Ok(()) => println!("\nwrote BENCH_pr9.json ({} rows)", rows.len()),
        Err(e) => eprintln!("could not write BENCH_pr9.json: {e}"),
    }
}
