//! Self-healing benchmark (PR 10): what erasure coding buys over
//! replication at equal fault tolerance, and how fast one scrub pass
//! restores full redundancy after losing a node.
//!
//!     cargo bench --bench repair            # full matrix
//!     cargo bench --bench repair -- quick   # CI smoke subset
//!
//! Two placements at the same fault tolerance (any 2 node losses):
//!
//! * **ec:4,2** — 4 data + 2 parity shards per block, 1.5x storage
//! * **rep:3**  — 3 full copies per block, 3.0x storage
//!
//! For each, an 8-node loopback cluster ingests the workload, one node
//! is killed, the deterministic clock advances past the heartbeat
//! timeout, and `scrub_once` passes run until `redundancy_report` says
//! every block is fully redundant again.  Measured: storage overhead
//! (stored bytes / application bytes), repair wall time, and bytes
//! moved by the repair.
//!
//! Results are printed as a table and flushed to `BENCH_pr10.json` at
//! the repo root.  CI gates on the JSON parsing and on the erasure-
//! coded overhead coming in strictly below the replicated one.

use std::time::{Duration, Instant};

use gpustore::config::{ClientConfig, ClusterConfig, Placement};
use gpustore::hashgpu::{CpuEngine, WindowHashMode};
use gpustore::store::Cluster;
use gpustore::util::Rng;

struct CaseResult {
    placement: &'static str,
    nodes: usize,
    data_bytes: u64,
    stored_bytes: u64,
    storage_overhead: f64,
    repair_millis: f64,
    repair_bytes_moved: u64,
    scrub_passes: u32,
}

/// Ingest, kill, scrub, verify — one placement policy end to end.
fn case(name: &'static str, placement: Placement, data_bytes: usize) -> CaseResult {
    const NODES: usize = 8;
    let mut cluster = Cluster::spawn(ClusterConfig {
        nodes: NODES,
        link_bps: 1e9,
        shape: false,
        replication: 1,
        placement: Some(placement),
        lease_timeout: Duration::from_secs(600),
        ..ClusterConfig::default()
    })
    .unwrap();
    let cfg = ClientConfig {
        block_size: 64 * 1024,
        write_buffer: 256 * 1024,
        ..ClientConfig::default()
    };
    let engine = std::sync::Arc::new(CpuEngine::new(4, 4096, WindowHashMode::Rolling));
    let sai = cluster.client(cfg, engine).unwrap();

    let data = Rng::new(0xEC ^ data_bytes as u64).bytes(data_bytes);
    sai.write_file("bench.bin", &data).unwrap();
    let (_, stored_bytes) = cluster.storage_stats();
    let storage_overhead = stored_bytes as f64 / data_bytes as f64;

    // Lose one node, let the deterministic clock stale its heartbeat,
    // and wait for the survivors' next real beat so placement sees
    // exactly NODES - 1 live homes.
    cluster.kill_node(1);
    let s = cluster.manager().state();
    s.advance_clock(Duration::from_secs(4));
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let alive = sai
            .list_nodes()
            .map(|nodes| nodes.iter().filter(|e| e.alive).count())
            .unwrap_or(0);
        if alive == NODES - 1 {
            break;
        }
        assert!(Instant::now() < deadline, "survivors never re-heartbeat");
        std::thread::sleep(Duration::from_millis(10));
    }
    let rep = s.redundancy_report();
    assert!(rep.degraded > 0, "[{name}] the kill must degrade some blocks");
    assert_eq!(rep.unreadable, 0, "[{name}] every block must stay readable");

    // Time-to-restored-redundancy: unthrottled scrub passes until the
    // redundancy report is clean again.
    let t = Instant::now();
    let mut repair_bytes_moved = 0u64;
    let mut scrub_passes = 0u32;
    loop {
        let sr = s.scrub_once();
        repair_bytes_moved += sr.bytes_moved;
        scrub_passes += 1;
        let rep = s.redundancy_report();
        if rep.degraded == 0 && rep.unreadable == 0 {
            break;
        }
        assert!(scrub_passes < 64, "[{name}] scrub failed to converge: {sr:?}");
    }
    let repair_millis = t.elapsed().as_secs_f64() * 1e3;

    assert_eq!(
        sai.read_file("bench.bin").unwrap(),
        data,
        "[{name}] repaired file must read byte-exact"
    );
    println!(
        "{name:>6}: {storage_overhead:>4.2}x storage, repaired in {repair_millis:>8.2} ms \
         ({repair_bytes_moved} bytes moved, {scrub_passes} pass(es))"
    );
    CaseResult {
        placement: name,
        nodes: NODES,
        data_bytes: data_bytes as u64,
        stored_bytes,
        storage_overhead,
        repair_millis,
        repair_bytes_moved,
        scrub_passes,
    }
}

fn main() {
    let args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    let quick = args.iter().any(|a| a == "quick");
    let data_bytes = if quick { 2 << 20 } else { 8 << 20 };

    println!("== self-healing: ec:4,2 vs rep:3 (both survive any 2 losses) ==");
    let ec = case("ec:4,2", Placement::Erasure { k: 4, m: 2 }, data_bytes);
    let rep = case("rep:3", Placement::Replicated(3), data_bytes);

    assert!(
        ec.storage_overhead < rep.storage_overhead,
        "erasure coding must store less than replication at equal fault \
         tolerance ({:.2}x vs {:.2}x)",
        ec.storage_overhead,
        rep.storage_overhead
    );
    flush(&[ec, rep], quick);
}

fn flush(results: &[CaseResult], quick: bool) {
    let mut out = String::from(
        "{\n  \"bench\": \"repair\",\n  \"fault_tolerance\": \"any 2 node losses\",\n",
    );
    out.push_str(&format!("  \"quick\": {quick},\n  \"results\": [\n"));
    for (i, r) in results.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"placement\": \"{}\", \"nodes\": {}, \"data_bytes\": {}, \
             \"stored_bytes\": {}, \"storage_overhead\": {:.3}, \"repair_millis\": {:.3}, \
             \"repair_bytes_moved\": {}, \"scrub_passes\": {}}}{}\n",
            r.placement,
            r.nodes,
            r.data_bytes,
            r.stored_bytes,
            r.storage_overhead,
            r.repair_millis,
            r.repair_bytes_moved,
            r.scrub_passes,
            if i + 1 == results.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write("BENCH_pr10.json", &out) {
        Ok(()) => println!("\nwrote BENCH_pr10.json ({} results)", results.len()),
        Err(e) => eprintln!("could not write BENCH_pr10.json: {e}"),
    }
}
