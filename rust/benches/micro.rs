//! Micro-benchmarks of the rust hot paths (no criterion in this offline
//! environment; simple calibrated timing loops).  These feed the §Perf
//! iteration log in EXPERIMENTS.md.
//!
//!     cargo bench --bench micro            # everything
//!     cargo bench --bench micro -- md5 pjrt

use std::sync::Arc;
use std::time::Instant;

use gpustore::chunking::{ChunkParams, ContentChunker};
use gpustore::crystal::{BackendKind, CrystalOpts, DeviceOp, Master};
use gpustore::hash::{direct_hash_cpu_mt, md5, window_hashes, DEFAULT_P, DEFAULT_WINDOW};
use gpustore::runtime::artifacts::Manifest;
use gpustore::store::proto::Msg;
use gpustore::util::Rng;

const MB: f64 = 1024.0 * 1024.0;

/// Run `f` until ~0.5 s elapsed; return seconds per iteration.
fn time_it<F: FnMut()>(mut f: F) -> f64 {
    // Warmup.
    f();
    let mut iters = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt > 0.3 {
            return dt / iters as f64;
        }
        iters = (iters as f64 * (0.5 / dt.max(1e-9)).clamp(2.0, 64.0)) as u64;
    }
}

fn report_bw(name: &str, bytes: usize, secs: f64) {
    println!("{name:<44} {:>10.1} MB/s   ({:.3} ms)", bytes as f64 / secs / MB, secs * 1e3);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let want = |k: &str| args.is_empty() || args.iter().any(|a| a == k);
    let data1m = Rng::new(1).bytes(1 << 20);
    let data4m = Rng::new(2).bytes(4 << 20);

    println!("== micro benchmarks (release, this host) ==\n");

    if want("md5") {
        let s = time_it(|| {
            std::hint::black_box(md5(&data1m));
        });
        report_bw("md5 1MB (1 thread)", 1 << 20, s);
        for threads in [4, 8] {
            let s = time_it(|| {
                std::hint::black_box(direct_hash_cpu_mt(&data4m, 4096, threads));
            });
            report_bw(&format!("direct-hash 4MB seg4096 ({threads} threads)"), 4 << 20, s);
        }
    }

    if want("rolling") {
        let s = time_it(|| {
            std::hint::black_box(window_hashes(&data1m, DEFAULT_WINDOW, DEFAULT_P));
        });
        report_bw("rolling window-hashes 1MB", 1 << 20, s);
    }

    if want("chunker") {
        let params = ChunkParams::with_avg_size(64 << 10);
        let s = time_it(|| {
            std::hint::black_box(ContentChunker::chunk_all(params, &data4m));
        });
        report_bw("cdc chunk_all 4MB (~64KB chunks)", 4 << 20, s);
    }

    if want("proto") {
        let msg = Msg::PutBlock {
            req: 1,
            hash: [7; 16],
            data: data1m.clone(),
        };
        let s = time_it(|| {
            std::hint::black_box(msg.encode());
        });
        report_bw("proto encode PutBlock(1MB)", 1 << 20, s);
        let frame = msg.encode();
        let s = time_it(|| {
            let mut r = &frame[..];
            std::hint::black_box(Msg::read_from(&mut r).unwrap());
        });
        report_bw("proto decode PutBlock(1MB)", 1 << 20, s);
    }

    if want("pjrt") {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            let master = Master::new(CrystalOpts::optimized(BackendKind::Pjrt {
                artifact_dir: dir,
            }))
            .unwrap();
            let d = Arc::new(data1m.clone());
            // Warm the executable caches.
            master.run(DeviceOp::SlidingWindow, d.clone()).unwrap();
            master
                .run(DeviceOp::DirectHash { seg_bytes: 4096 }, d.clone())
                .unwrap();
            let s = time_it(|| {
                std::hint::black_box(master.run(DeviceOp::SlidingWindow, d.clone()).unwrap());
            });
            report_bw("pjrt sliding-window 1MB (e2e job)", 1 << 20, s);
            let s = time_it(|| {
                std::hint::black_box(
                    master
                        .run(DeviceOp::DirectHash { seg_bytes: 4096 }, d.clone())
                        .unwrap(),
                );
            });
            report_bw("pjrt direct-hash 1MB (e2e job)", 1 << 20, s);
            let stats = master.stats();
            let (hits, misses) = stats.pool;
            println!("  (staging pool: {hits} hits / {misses} misses)");
        } else {
            println!("pjrt: artifacts not built, skipping (run `make artifacts`)");
        }
    }

    if want("store") {
        // L3 end-to-end: loopback cluster, unshaped, CPU rolling engine —
        // isolates the coordinator + wire path from kernel cost.
        use gpustore::config::{CaMode, ClientConfig, ClusterConfig};
        use gpustore::hashgpu::{CpuEngine, WindowHashMode};
        use gpustore::store::Cluster;
        let cluster = Cluster::spawn(ClusterConfig {
            nodes: 4,
            link_bps: 1e9,
            shape: false,
            replication: 1,
            ..ClusterConfig::default()
        })
        .unwrap();
        let modes = [
            ("non-CA", CaMode::None),
            ("fixed", CaMode::Fixed),
            ("cdc", CaMode::Cdc),
        ];
        for (label, mode) in modes {
            let cfg = ClientConfig {
                ca_mode: mode,
                block_size: 256 * 1024,
                cdc_min: 64 * 1024,
                cdc_max: 1 << 20,
                cdc_mask: (1 << 18) - 1,
                write_buffer: 1 << 20,
                ..ClientConfig::default()
            };
            let sai = cluster
                .client(
                    cfg,
                    Arc::new(CpuEngine::new(1, 4096, WindowHashMode::Rolling)),
                )
                .unwrap();
            let mut seq = 0u64;
            let s = time_it(|| {
                seq += 1;
                // Streaming session, fed in 256 KB app-sized writes.
                let mut w = sai.create(&format!("m-{label}-{seq}")).unwrap();
                for chunk in data4m.chunks(256 * 1024) {
                    w.push_bytes(chunk).unwrap();
                }
                let r = w.close().unwrap();
                std::hint::black_box(r);
            });
            report_bw(&format!("store write 4MB ({label}, loopback)"), 4 << 20, s);
            // Read path: blocks come back as shared Arcs straight from
            // the node store (no per-block copy until the final
            // assembly) — the satellite-task verification bench.
            let name = format!("m-{label}-{seq}");
            let s = time_it(|| {
                std::hint::black_box(sai.read_file(&name).unwrap());
            });
            report_bw(&format!("store read 4MB ({label}, loopback)"), 4 << 20, s);
        }
    }

    if want("pool") {
        let pool = gpustore::crystal::BufferPool::new(true, 8);
        pool.prewarm(1 << 18, 4);
        let s = time_it(|| {
            std::hint::black_box(pool.acquire(1 << 18));
        });
        report_bw("buffer pool acquire 1MB (reuse)", 1 << 20, s);
        let pool = gpustore::crystal::BufferPool::new(false, 8);
        let s = time_it(|| {
            std::hint::black_box(pool.acquire(1 << 18));
        });
        report_bw("buffer pool acquire 1MB (alloc)", 1 << 20, s);
    }
}
