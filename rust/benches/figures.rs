//! Figure-regeneration harness: one table per figure/table in the
//! paper's evaluation (Figs 4–17 plus the §4.2 CPU-vs-GPU decision),
//! using measured functional behaviour (real chunker/workloads for
//! similarity) + the calibrated performance models (sim::*).
//!
//!     cargo bench --bench figures              # everything
//!     cargo bench --bench figures -- fig5 fig11 ablate-batch
//!
//! Shape expectations (paper vs ours) are recorded in EXPERIMENTS.md.

use std::sync::Mutex;

use gpustore::chunking::ChunkParams;
use gpustore::crystal::model::CpuModel;
use gpustore::metrics::{Stage, Table};
use gpustore::sim::{
    CompetitorKind, ContentionModel, EngineModel, GpuOpts, GpuPipeline, SystemSim, WriteConfig,
};
use gpustore::util::human_bytes;
use gpustore::workload::checkpoint::{cdc_similarity, fixed_similarity};
use gpustore::workload::{CheckpointStream, MutationProfile};

const MB: f64 = 1024.0 * 1024.0;

/// Machine-readable results accumulated by the figure harness and
/// flushed to `BENCH_pr2.json`: (figure, engine, config, MB/s).
static RECORDS: Mutex<Vec<(String, String, String, f64)>> = Mutex::new(Vec::new());

fn record(figure: &str, engine: &str, config: &str, mbps: f64) {
    RECORDS
        .lock()
        .unwrap()
        .push((figure.into(), engine.into(), config.into(), mbps));
}

/// Minimal JSON escaping for the label strings we emit (they are plain
/// ASCII, but stay defensive).
fn jstr(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn flush_records() {
    let recs = RECORDS.lock().unwrap();
    if recs.is_empty() {
        return;
    }
    let mut out = String::from("{\n  \"bench\": \"figures\",\n  \"unit\": \"MB/s\",\n  \"results\": [\n");
    for (i, (fig, engine, cfg, mbps)) in recs.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"figure\": \"{}\", \"engine\": \"{}\", \"config\": \"{}\", \"mbps\": {:.2}}}{}\n",
            jstr(fig),
            jstr(engine),
            jstr(cfg),
            mbps,
            if i + 1 == recs.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write("BENCH_pr2.json", &out) {
        Ok(()) => println!("\nwrote BENCH_pr2.json ({} results)", recs.len()),
        Err(e) => eprintln!("could not write BENCH_pr2.json: {e}"),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let want = |k: &str| args.is_empty() || args.iter().any(|a| a == k);

    if want("fig4") {
        fig4();
    }
    if want("fig5") {
        fig56(true);
    }
    if want("fig6") {
        fig56(false);
    }
    if want("cpu-vs-gpu") {
        cpu_vs_gpu();
    }
    if want("fig7") {
        fig7_10(false, false, "fig7: different workload, fixed blocks");
    }
    if want("fig8") {
        fig7_10(true, false, "fig8: different workload, content-based chunking");
    }
    if want("fig9") {
        fig7_10(false, true, "fig9: similar workload, fixed blocks (+CA-Infinite)");
    }
    if want("fig10") {
        fig7_10(true, true, "fig10: similar workload, content-based chunking (+CA-Infinite)");
    }
    if want("fig11") {
        fig11();
    }
    if want("fig12-14") || want("fig12") || want("fig13") || want("fig14") {
        contention(CompetitorKind::ComputeBound, "fig12-14: compute-bound competitor");
    }
    if want("fig15-17") || want("fig15") || want("fig16") || want("fig17") {
        contention(CompetitorKind::IoBound, "fig15-17: I/O-bound competitor");
    }
    if want("ablate-batch") {
        ablate_batch();
    }
    if want("ablate-10g") {
        ablate_10g();
    }
    if want("ablate-replication") {
        ablate_replication();
    }
    if want("ablate-window-mode") {
        ablate_window_mode();
    }
    flush_records();
}

fn block_sizes() -> Vec<usize> {
    vec![
        4 << 10,
        16 << 10,
        64 << 10,
        256 << 10,
        1 << 20,
        4 << 20,
        16 << 20,
        64 << 20,
        96 << 20,
    ]
}

/// Fig 4: % of total sliding-window execution per stage, no optimizations.
fn fig4() {
    println!("\n== fig4: HashGPU sliding-window stage breakdown (unoptimized) ==");
    println!("paper: memory allocation + copy-in = 80-96% of total\n");
    let p = GpuPipeline::default();
    let mut t = Table::new(&["block", "alloc %", "copy-in %", "kernel %", "copy-out %", "post %", "alloc+copyin %"]);
    for b in block_sizes() {
        let s = p.stages(&p.dev0, true, b, GpuOpts::ALONE);
        let f = s.fractions();
        let get = |st: Stage| {
            f.iter()
                .find(|(x, _)| *x == st)
                .map(|(_, v)| 100.0 * v)
                .unwrap_or(0.0)
        };
        t.row(vec![
            human_bytes(b as u64),
            format!("{:.1}", get(Stage::Preprocess)),
            format!("{:.1}", get(Stage::CopyIn)),
            format!("{:.1}", get(Stage::Kernel)),
            format!("{:.1}", get(Stage::CopyOut)),
            format!("{:.1}", get(Stage::Postprocess)),
            format!("{:.1}", get(Stage::Preprocess) + get(Stage::CopyIn)),
        ]);
    }
    println!("{}", t.markdown());
}

/// Figs 5/6: speedup vs one CPU core, stream of 10 jobs.
fn fig56(sliding: bool) {
    let (name, paper) = if sliding {
        ("fig5: sliding-window hashing speedup (stream of 10 jobs)",
         "paper: alone up to ~27x; +reuse ~100x; +overlap ~125x; dual ~190x; dual-socket CPU ~8x / 129 MBps")
    } else {
        ("fig6: direct hashing speedup (stream of 10 jobs)",
         "paper: alone up to ~7x; full single GPU ~28x; dual ~45x; dual-socket CPU ~8x")
    };
    println!("\n== {name} ==\n{paper}\n");
    let p = GpuPipeline::default();
    let cpu = CpuModel::xeon_2008();
    let single = if sliding {
        cpu.scaled_bps(cpu.window_md5_bps, 1)
    } else {
        cpu.scaled_bps(cpu.md5_bps, 1)
    };
    let dual = if sliding {
        cpu.scaled_bps(cpu.window_md5_bps, 16)
    } else {
        cpu.scaled_bps(cpu.md5_bps, 16)
    };
    let mut t = Table::new(&[
        "block",
        "alone x",
        "+reuse x",
        "+overlap x",
        "dual-GPU x",
        "dual-CPU x",
        "GPU MB/s",
        "dual-CPU MB/s",
    ]);
    for b in block_sizes() {
        let sp = |o: GpuOpts| p.stream_bps(sliding, b, o) / single;
        t.row(vec![
            human_bytes(b as u64),
            format!("{:.2}", sp(GpuOpts::ALONE)),
            format!("{:.1}", sp(GpuOpts::REUSE)),
            format!("{:.1}", sp(GpuOpts::OVERLAP)),
            format!("{:.1}", sp(GpuOpts::DUAL)),
            format!("{:.1}", dual / single),
            format!("{:.0}", p.stream_bps(sliding, b, GpuOpts::OVERLAP) / MB),
            format!("{:.0}", dual / MB),
        ]);
    }
    println!("{}", t.markdown());
}

/// §4.2: add a CPU or a GPU?
fn cpu_vs_gpu() {
    println!("\n== section 4.2: add a CPU or a GPU? ==");
    println!("paper: GPU wins 15x (sliding) / 3.5x (direct) over adding a second socket\n");
    let p = GpuPipeline::default();
    let cpu = CpuModel::xeon_2008();
    let b = 64 << 20;
    let mut t = Table::new(&["primitive", "dual-CPU MB/s", "single-GPU MB/s", "GPU : dual-CPU"]);
    for (nm, sliding) in [("sliding-window", true), ("direct", false)] {
        let dual = cpu.scaled_bps(
            if sliding { cpu.window_md5_bps } else { cpu.md5_bps },
            16,
        );
        let gpu = p.stream_bps(sliding, b, GpuOpts::OVERLAP);
        t.row(vec![
            nm.into(),
            format!("{:.0}", dual / MB),
            format!("{:.0}", gpu / MB),
            format!("{:.1}x", gpu / dual),
        ]);
    }
    println!("{}", t.markdown());
}

fn file_sizes() -> Vec<usize> {
    vec![
        1 << 20,
        4 << 20,
        16 << 20,
        64 << 20,
        96 << 20,
    ]
}

/// Figs 7-10: integrated-system write throughput, 40 files back-to-back.
fn fig7_10(cdc: bool, similar: bool, title: &str) {
    let fig_key = title.split(':').next().unwrap_or(title);
    println!("\n== {title} ==");
    if similar {
        println!("paper fig9: CA-GPU ~= CA-Infinite, >2x CA-CPU for >=64MB files");
        println!("paper fig10: CA-GPU >4.4x CA-CPU, >2.1x non-CA; within 25% of CA-Infinite\n");
    } else {
        println!("paper fig7/8: non-CA wins (hashing is pure overhead at 0% similarity);");
        println!("CDC-on-CPU capped at ~46 MBps regardless of file size\n");
    }
    let s = SystemSim::default();
    let files = 40;
    let engines: Vec<(&str, EngineModel)> = vec![
        ("non-CA", EngineModel::None),
        ("CA-CPU", EngineModel::Cpu { threads: 16 }),
        ("CA-GPU", EngineModel::Gpu { opts: GpuOpts::OVERLAP }),
        ("CA-Infinite", EngineModel::Infinite),
    ];
    let cols: Vec<&str> = std::iter::once("file size")
        .chain(engines.iter().map(|(n, _)| *n).map(|n| n))
        .collect();
    let mut t = Table::new(&cols.iter().map(|c| format!("{c} MB/s")).map(|s| Box::leak(s.into_boxed_str()) as &str).collect::<Vec<_>>());
    for size in file_sizes() {
        let blocks = (size / (1 << 20)).max(1);
        let mut row = vec![human_bytes(size as u64)];
        for (name, engine) in &engines {
            // non-CA never dedups; CA engines dedup repeats of the
            // similar workload (first of 40 files transfers).
            let dedup_able = *name != "non-CA";
            let mk = |sim: f64| WriteConfig {
                engine: *engine,
                cdc,
                similarity: sim,
                ..WriteConfig::default()
            };
            let secs = if similar && dedup_able {
                s.write_secs(&mk(0.0), size, blocks)
                    + (files - 1) as f64 * s.write_secs(&mk(1.0), size, blocks)
            } else {
                files as f64 * s.write_secs(&mk(0.0), size, blocks)
            };
            let bps = (files * size) as f64 / secs;
            record(
                fig_key,
                name,
                &format!("size={}", human_bytes(size as u64)),
                bps / MB,
            );
            row.push(format!("{:.0}", bps / MB));
        }
        t.row(row);
    }
    println!("{}", t.markdown());
}

/// Fig 11: checkpoint workload across block sizes; similarity is
/// MEASURED from the real generator + real chunkers at scaled size.
fn fig11() {
    println!("\n== fig11: checkpoint workload (100 images, 264.7 MB avg) ==");
    println!("paper: CBC-GPU best (up to 5x CBC-CPU, 2.3x non-CA), peak at ~1MB chunks;");
    println!("CBC-CPU worst (~49 MBps); fixed similarity 21-23%, CBC 76-90%\n");

    // Measure similarity at test scale: 16 MB images, chunk sizes scaled
    // by the same 1/16 factor keep the chunks-per-image regime.
    let scale = 16;
    let imgs: Vec<Vec<u8>> = CheckpointStream::new(
        8,
        (264 << 20) / scale,
        MutationProfile::paper_default(),
        0xF16,
    )
    .collect();
    let s = SystemSim::default();
    let size = 264 << 20; // model at paper scale
    let files = 100;

    let mut t = Table::new(&[
        "block size",
        "measured fixed sim %",
        "measured CBC sim %",
        "non-CA MB/s",
        "fixed-CPU MB/s",
        "fixed-GPU MB/s",
        "CBC-CPU MB/s",
        "CBC-GPU MB/s",
    ]);
    for paper_block in [256 << 10, 1 << 20, 4 << 20usize] {
        let test_block = paper_block / scale;
        let params = ChunkParams::with_avg_size(test_block);
        let mut fs = 0.0;
        let mut cs = 0.0;
        for w in imgs.windows(2) {
            fs += fixed_similarity(&w[0], &w[1], test_block);
            cs += cdc_similarity(&w[0], &w[1], params);
        }
        let fixed_sim = fs / (imgs.len() - 1) as f64;
        let cdc_sim = cs / (imgs.len() - 1) as f64;

        let blocks = size / paper_block;
        let bps = |engine: EngineModel, cdc: bool, sim: f64| {
            let cfg = WriteConfig {
                engine,
                cdc,
                similarity: sim,
                ..WriteConfig::default()
            };
            // First image transfers fully; the rest dedup at `sim`.
            let cfg0 = WriteConfig { similarity: 0.0, ..cfg };
            let secs = s.write_secs(&cfg0, size, blocks)
                + (files - 1) as f64 * s.write_secs(&cfg, size, blocks);
            (files * size) as f64 / secs / MB
        };
        let block_label = format!("block={}", human_bytes(paper_block as u64));
        let cells: [(&str, f64); 5] = [
            ("non-CA", bps(EngineModel::None, false, 0.0)),
            ("fixed-CPU", bps(EngineModel::Cpu { threads: 16 }, false, fixed_sim)),
            ("fixed-GPU", bps(EngineModel::Gpu { opts: GpuOpts::OVERLAP }, false, fixed_sim)),
            ("CBC-CPU", bps(EngineModel::Cpu { threads: 16 }, true, cdc_sim)),
            ("CBC-GPU", bps(EngineModel::Gpu { opts: GpuOpts::OVERLAP }, true, cdc_sim)),
        ];
        for (engine, mbps) in &cells {
            record("fig11", engine, &block_label, *mbps);
        }
        t.row(vec![
            human_bytes(paper_block as u64),
            format!("{:.1}", 100.0 * fixed_sim),
            format!("{:.1}", 100.0 * cdc_sim),
            format!("{:.0}", cells[0].1),
            format!("{:.0}", cells[1].1),
            format!("{:.0}", cells[2].1),
            format!("{:.0}", cells[3].1),
            format!("{:.0}", cells[4].1),
        ]);
    }
    println!("{}", t.markdown());
}

/// Figs 12-17: competing-application interference.
fn contention(kind: CompetitorKind, title: &str) {
    println!("\n== {title} ==");
    match kind {
        CompetitorKind::ComputeBound => println!(
            "paper: GPU halves the app slowdown vs CPU hashing (different); \
             storage loses <=18% vs dedicated; non-CA still slows the app (TCP)\n"
        ),
        CompetitorKind::IoBound => println!(
            "paper: app slowdown 5-15% lower with GPU; storage loses <=6%\n"
        ),
    }
    let m = ContentionModel::default();
    let s = SystemSim::default();
    let size = 1 << 30; // 1 GB files back-to-back (paper section 4.5)
    let blocks = 1024;

    for (wl, sim) in [("different", 0.0), ("similar", 1.0), ("checkpoint", 0.22)] {
        let mut t = Table::new(&[
            "engine",
            "storage MB/s",
            "dedicated MB/s",
            "tput loss %",
            "app slowdown %",
        ]);
        for (name, engine) in [
            ("non-CA", EngineModel::None),
            ("CA-CPU", EngineModel::Cpu { threads: 4 }),
            ("CA-GPU", EngineModel::Gpu { opts: GpuOpts::OVERLAP }),
        ] {
            let cfg = WriteConfig {
                engine,
                similarity: if name == "non-CA" { 0.0 } else { sim },
                ..WriteConfig::default()
            };
            let r = m.evaluate(&s, &cfg, size, blocks, kind);
            t.row(vec![
                name.into(),
                format!("{:.0}", r.storage_bps / MB),
                format!("{:.0}", r.storage_dedicated_bps / MB),
                format!("{:.1}", 100.0 * (1.0 - r.storage_bps / r.storage_dedicated_bps)),
                format!("{:.0}", 100.0 * r.app_slowdown),
            ]);
        }
        println!("-- workload: {wl} --\n{}\n", t.markdown());
    }
}

/// Ablation: batch size (paper: >=3 jobs reach near-max gain).
fn ablate_batch() {
    println!("\n== ablation: stream batch size (paper: >=3 jobs ~ max gain) ==\n");
    let p = GpuPipeline::default();
    let b = 16 << 20;
    let max_bps = (b * 100) as f64 / p.stream_secs(true, b, 100, GpuOpts::OVERLAP);
    let mut t = Table::new(&["jobs in stream", "MB/s", "% of asymptotic"]);
    for jobs in [1usize, 2, 3, 4, 6, 10, 20] {
        let bps = (b * jobs) as f64 / p.stream_secs(true, b, jobs, GpuOpts::OVERLAP);
        t.row(vec![
            jobs.to_string(),
            format!("{:.0}", bps / MB),
            format!("{:.1}", 100.0 * bps / max_bps),
        ]);
    }
    println!("{}", t.markdown());
}

/// Ablation: 1 Gbps vs 10 Gbps fabric (section 4.2's discussion).
fn ablate_10g() {
    println!("\n== ablation: 1 Gbps vs 10 Gbps network (different workload, fixed) ==\n");
    let mut t = Table::new(&["link", "non-CA MB/s", "CA-CPU MB/s", "CA-GPU MB/s"]);
    for (label, bps) in [("1 Gbps", 117e6), ("10 Gbps", 1.17e9)] {
        let s = SystemSim {
            net_bps: bps,
            ..SystemSim::default()
        };
        let size = 64 << 20;
        let cell = |name: &str, e: EngineModel| {
            let cfg = WriteConfig {
                engine: e,
                ..WriteConfig::default()
            };
            let mbps = s.write_bps(&cfg, size, 64, 10) / MB;
            record("ablate-10g", name, &format!("link={label}"), mbps);
            format!("{mbps:.0}")
        };
        t.row(vec![
            label.into(),
            cell("non-CA", EngineModel::None),
            cell("CA-CPU", EngineModel::Cpu { threads: 16 }),
            cell("CA-GPU", EngineModel::Gpu { opts: GpuOpts::OVERLAP }),
        ]);
    }
    println!("{}", t.markdown());
    println!("(10 Gbps: CPU hashing becomes the bottleneck everywhere; offload keeps up)");
}

/// Ablation (control-plane v2): replication factor vs write throughput.
/// Every new byte crosses the client NIC once per copy, so `different`
/// workloads pay ~1/r while fully-dedup'd `similar` workloads are free.
fn ablate_replication() {
    println!("\n== ablation: replication factor (manager-driven placement) ==\n");
    let s = SystemSim::default();
    let size = 64 << 20;
    let mut t = Table::new(&["replication", "different MB/s", "similar MB/s"]);
    for r in [1usize, 2, 3] {
        let mk = |sim: f64| WriteConfig {
            engine: EngineModel::Gpu { opts: GpuOpts::OVERLAP },
            similarity: sim,
            replication: r,
            ..WriteConfig::default()
        };
        let diff = s.write_bps(&mk(0.0), size, 64, 10) / MB;
        let simi = s.write_bps(&mk(1.0), size, 64, 10) / MB;
        record("ablate-replication", "CA-GPU", &format!("r={r} different"), diff);
        record("ablate-replication", "CA-GPU", &format!("r={r} similar"), simi);
        t.row(vec![r.to_string(), format!("{diff:.0}"), format!("{simi:.0}")]);
    }
    println!("{}", t.markdown());
    println!("(reliability costs bandwidth only for cold data; dedup'd bytes replicate for free)");
}

/// Ablation: CPU window-hash implementation (paper MD5-per-window vs a
/// modern rolling fingerprint) — measured on THIS machine's CPU.
fn ablate_window_mode() {
    println!("\n== ablation: CPU window-hash implementation (measured, this host) ==\n");
    use gpustore::hashgpu::{CpuEngine, HashEngine, WindowHashMode};
    use std::time::Instant;
    let data = gpustore::util::Rng::new(1).bytes(4 << 20);
    let mut t = Table::new(&["mode", "threads", "MB/s (measured)"]);
    for (mode, name) in [
        (WindowHashMode::PaperMd5, "MD5-per-window (paper)"),
        (WindowHashMode::Rolling, "rolling fingerprint"),
    ] {
        for threads in [1usize, 8] {
            let e = CpuEngine::new(threads, 4096, mode);
            let t0 = Instant::now();
            let h = e.window_hashes(&data).unwrap();
            let dt = t0.elapsed().as_secs_f64();
            std::hint::black_box(h);
            t.row(vec![
                name.into(),
                threads.to_string(),
                format!("{:.0}", data.len() as f64 / dt / MB),
            ]);
        }
    }
    println!("{}", t.markdown());
    println!("(the paper-faithful MD5 window hash is the cost that justifies offload)");
}
