//! # gpustore — "GPUs as Storage System Accelerators" (TPDS 2012), reproduced
//!
//! A content-addressable distributed storage system (the paper's MosaStore)
//! whose hashing hot path — direct hashing for fixed-size blocks and
//! sliding-window hashing for content-based chunking — can be offloaded to
//! an accelerator through AOT-compiled XLA executables (authored as
//! JAX/Pallas kernels, lowered once at build time, executed from rust via
//! the PJRT C API).
//!
//! Layer map (see DESIGN.md):
//! - [`store`] — MosaStore analog: metadata manager, storage nodes, client SAI.
//! - [`crystal`] — CrystalGPU analog: accelerator task runtime (queues,
//!   buffer reuse, transfer/compute overlap, multi-device).
//! - [`hashgpu`] — HashGPU analog: the two hashing primitives over crystal.
//! - [`runtime`] — PJRT artifact loading/execution (`xla` crate).
//! - [`hash`], [`chunking`] — CPU baselines + host-side final stages.
//! - [`sim`] — discrete-event performance model used by the figure benches.
//! - [`workload`] — paper workload generators (different/similar/checkpoint,
//!   competing compute- and I/O-bound applications).

pub mod chunking;
pub mod config;
pub mod crystal;
pub mod error;
pub mod hash;
pub mod hashgpu;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod sim;
pub mod store;
pub mod util;
pub mod workload;

pub use error::{Error, Result};
