//! # gpustore — "GPUs as Storage System Accelerators" (TPDS 2012), reproduced
//!
//! A content-addressable distributed storage system (the paper's MosaStore)
//! whose hashing hot path — direct hashing for fixed-size blocks and
//! sliding-window hashing for content-based chunking — can be offloaded to
//! an accelerator through AOT-compiled XLA executables (authored as
//! JAX/Pallas kernels, lowered once at build time, executed from rust via
//! the PJRT C API).
//!
//! The client-facing storage API is session-based: `Sai::create` opens a
//! streaming `FileWriter` (implements `std::io::Write`) whose incremental
//! writes feed the chunk→hash→dedup→stripe pipeline as data arrives, with
//! block digests *submitted asynchronously* to the accelerator so buffer
//! N's hashing overlaps buffer N-1's transfers; `close()` commits the
//! block-map and returns a `WriteReport` with exposed-vs-hidden hash-time
//! accounting.  `Sai::open` returns a `FileReader` (implements
//! `std::io::Read`) that prefetches striped blocks and verifies each
//! block's integrity before serving it.  Whole-buffer
//! `write_file`/`read_file` remain as thin wrappers.
//!
//! Layer map (see DESIGN.md):
//! - [`store`] — MosaStore analog: metadata manager, storage nodes, client
//!   SAI, and the streaming write/read sessions (`store::session`).
//! - [`crystal`] — CrystalGPU analog: accelerator task runtime (queues,
//!   buffer reuse, transfer/compute overlap, multi-device).
//! - [`hashgpu`] — HashGPU analog: the two hashing primitives over crystal,
//!   with blocking calls plus non-blocking submit/ticket pairs
//!   (`submit_direct_batch` / `submit_window_hashes`).
//! - [`hashsvc`] — shared cross-session hash service: one process-wide
//!   backend per configuration, a queue that coalesces concurrent
//!   sessions' submissions into deep device batches (flush on
//!   `max_batch_blocks` or `max_linger_us`), multi-device fan-out, and
//!   a multi-lane CPU fallback.
//! - [`runtime`] — PJRT artifact loading/execution (`xla` crate behind the
//!   `pjrt` feature; a synthetic manifest serves host-recompute backends).
//! - [`hash`], [`chunking`] — CPU baselines + host-side final stages.
//! - [`wal`] — durable control plane: segmented CRC-framed write-ahead
//!   log + snapshots under the manager, group-commit fsync batching,
//!   torn-tail-tolerant recovery, and the log-shipping record format.
//! - [`ec`] — pure-Rust GF(256) Reed–Solomon: systematic k+m shard
//!   encoding and reconstruct-from-any-k, backing the `ec:K,M`
//!   placement policy and the scrub/repair loop.
//! - [`sim`] — discrete-event performance model used by the figure benches
//!   (models the session pipeline's hash/transfer overlap).
//! - [`workload`] — paper workload generators (different/similar/checkpoint,
//!   competing compute- and I/O-bound applications).

pub mod chunking;
pub mod config;
pub mod crystal;
pub mod ec;
pub mod error;
pub mod hash;
pub mod hashgpu;
pub mod hashsvc;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod sim;
pub mod store;
pub mod util;
pub mod wal;
pub mod workload;

pub use error::{Error, Result};
