//! Hashing substrates: RFC 1321 MD5 (the paper's hash in all experiments),
//! the polynomial rolling fingerprint shared bit-for-bit with the Pallas
//! kernel, and the host-side final stage of the parallel Merkle–Damgård
//! construction.

pub mod md5;
pub mod merkle;
pub mod rolling;

pub use md5::{md5, Digest, Md5};
pub use merkle::{direct_hash_cpu, direct_hash_cpu_mt, finalize_digests, segment_count};
pub use rolling::{window_hashes, RollingHasher, DEFAULT_P, DEFAULT_WINDOW};
