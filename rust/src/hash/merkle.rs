//! Parallel Merkle–Damgård construction — the system's block hash.
//!
//! A data block is split into fixed-size segments; each segment is MD5'd
//! (in parallel, on the accelerator or across CPU threads) and the block
//! digest is the MD5 of the concatenated segment digests.  Damgård [26]
//! shows the construction is as strong as the underlying hash.
//!
//! Exactly as in the paper's HashGPU, the final hash-of-hashes runs on the
//! host CPU ("efficiently synchronizing all running GPU threads is not
//! possible"), so this module is the *shared last stage* of both the CPU
//! and the accelerator paths — guaranteeing they agree on block identity.

use super::md5::{md5, Digest, Md5};

/// Number of segments a block of `len` bytes splits into.
pub fn segment_count(len: usize, seg_bytes: usize) -> usize {
    len.div_ceil(seg_bytes).max(1)
}

/// Host-side final stage: MD5 over the concatenated segment digests.
///
/// A single-segment block short-circuits to its segment digest so that
/// small blocks hash identically to plain MD5 (and avoid a pointless
/// second pass).
pub fn finalize_digests(digests: &[Digest]) -> Digest {
    assert!(!digests.is_empty());
    if digests.len() == 1 {
        return digests[0];
    }
    let mut ctx = Md5::new();
    for d in digests {
        ctx.update(d);
    }
    ctx.finalize()
}

/// Reference CPU implementation of the full construction (single thread).
/// The accelerator path must produce the same digest for the same
/// `seg_bytes` — asserted by unit and integration tests.
pub fn direct_hash_cpu(data: &[u8], seg_bytes: usize) -> Digest {
    if data.is_empty() {
        return md5(data);
    }
    let digests: Vec<Digest> = data.chunks(seg_bytes).map(md5).collect();
    finalize_digests(&digests)
}

/// Multi-threaded CPU implementation — the paper's "dual socket CPU"
/// baseline.  Splits segments across `threads` OS threads.
pub fn direct_hash_cpu_mt(data: &[u8], seg_bytes: usize, threads: usize) -> Digest {
    if data.is_empty() {
        return md5(data);
    }
    let n_segs = segment_count(data.len(), seg_bytes);
    if threads <= 1 || n_segs < 2 * threads {
        return direct_hash_cpu(data, seg_bytes);
    }
    let mut digests = vec![[0u8; 16]; n_segs];
    let per = n_segs.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, out) in digests.chunks_mut(per).enumerate() {
            let start_seg = t * per;
            s.spawn(move || {
                for (k, d) in out.iter_mut().enumerate() {
                    let seg = start_seg + k;
                    let lo = seg * seg_bytes;
                    let hi = ((seg + 1) * seg_bytes).min(data.len());
                    *d = md5(&data[lo..hi]);
                }
            });
        }
    });
    finalize_digests(&digests)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn single_segment_is_plain_md5() {
        let data = Rng::new(1).bytes(100);
        assert_eq!(direct_hash_cpu(&data, 4096), md5(&data));
    }

    #[test]
    fn multi_segment_differs_from_plain() {
        let data = Rng::new(2).bytes(10_000);
        assert_ne!(direct_hash_cpu(&data, 4096), md5(&data));
    }

    #[test]
    fn deterministic() {
        let data = Rng::new(3).bytes(9_999);
        assert_eq!(direct_hash_cpu(&data, 256), direct_hash_cpu(&data, 256));
    }

    #[test]
    fn segment_size_is_part_of_identity() {
        let data = Rng::new(4).bytes(10_000);
        assert_ne!(direct_hash_cpu(&data, 256), direct_hash_cpu(&data, 4096));
    }

    #[test]
    fn mt_matches_single_thread() {
        let data = Rng::new(5).bytes(100_000);
        for threads in [1, 2, 4, 8, 16] {
            assert_eq!(
                direct_hash_cpu_mt(&data, 4096, threads),
                direct_hash_cpu(&data, 4096),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn mt_small_input_falls_back() {
        let data = Rng::new(6).bytes(300);
        assert_eq!(
            direct_hash_cpu_mt(&data, 256, 8),
            direct_hash_cpu(&data, 256)
        );
    }

    #[test]
    fn segment_count_math() {
        assert_eq!(segment_count(0, 256), 1);
        assert_eq!(segment_count(1, 256), 1);
        assert_eq!(segment_count(256, 256), 1);
        assert_eq!(segment_count(257, 256), 2);
        assert_eq!(segment_count(1 << 20, 4096), 256);
    }

    #[test]
    fn finalize_matches_manual() {
        let d1 = md5(b"one");
        let d2 = md5(b"two");
        let mut cat = Vec::new();
        cat.extend_from_slice(&d1);
        cat.extend_from_slice(&d2);
        assert_eq!(finalize_digests(&[d1, d2]), md5(&cat));
    }
}
