//! RFC 1321 MD5, incremental.  This is the CPU baseline primitive (the
//! paper uses MD5 "in all our experiments") and the host-side final stage
//! of the parallel Merkle–Damgård construction.  Bit-compatible with the
//! Pallas kernel in `python/compile/kernels/md5.py`.

/// A 16-byte MD5 digest.
pub type Digest = [u8; 16];

/// Per-round shift amounts.
const S: [u32; 64] = [
    7, 12, 17, 22, 7, 12, 17, 22, 7, 12, 17, 22, 7, 12, 17, 22, //
    5, 9, 14, 20, 5, 9, 14, 20, 5, 9, 14, 20, 5, 9, 14, 20, //
    4, 11, 16, 23, 4, 11, 16, 23, 4, 11, 16, 23, 4, 11, 16, 23, //
    6, 10, 15, 21, 6, 10, 15, 21, 6, 10, 15, 21, 6, 10, 15, 21,
];

/// K[i] = floor(2^32 * |sin(i+1)|) — generated once at first use to keep
/// the table out of the source (and provably identical to the kernel's).
fn k_table() -> &'static [u32; 64] {
    use std::sync::OnceLock;
    static K: OnceLock<[u32; 64]> = OnceLock::new();
    K.get_or_init(|| {
        let mut k = [0u32; 64];
        for (i, ki) in k.iter_mut().enumerate() {
            *ki = (((i as f64) + 1.0).sin().abs() * 4294967296.0) as u64 as u32;
        }
        k
    })
}

/// Incremental MD5 context.
#[derive(Clone)]
pub struct Md5 {
    state: [u32; 4],
    /// Total message bytes consumed so far.
    len: u64,
    /// Partially-filled block.
    buf: [u8; 64],
    buf_len: usize,
}

impl Default for Md5 {
    fn default() -> Self {
        Self::new()
    }
}

impl Md5 {
    /// Fresh context with the RFC 1321 initialization vector.
    pub fn new() -> Self {
        Md5 {
            state: [0x67452301, 0xEFCDAB89, 0x98BADCFE, 0x10325476],
            len: 0,
            buf: [0u8; 64],
            buf_len: 0,
        }
    }

    /// Absorb message bytes.
    pub fn update(&mut self, mut data: &[u8]) {
        self.len = self.len.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
            if data.is_empty() {
                return; // all input absorbed into the partial buffer
            }
        }
        let mut chunks = data.chunks_exact(64);
        for block in &mut chunks {
            let mut b = [0u8; 64];
            b.copy_from_slice(block);
            self.compress(&b);
        }
        let rem = chunks.remainder();
        self.buf[..rem.len()].copy_from_slice(rem);
        self.buf_len = rem.len();
    }

    /// Finish and produce the digest.
    pub fn finalize(mut self) -> Digest {
        let bit_len = self.len.wrapping_mul(8);
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0]);
        }
        // Length block: write directly (update would re-count it).
        self.buf[56..64].copy_from_slice(&bit_len.to_le_bytes());
        let block = self.buf;
        self.compress(&block);
        let mut out = [0u8; 16];
        for (i, s) in self.state.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&s.to_le_bytes());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let k = k_table();
        let mut m = [0u32; 16];
        for (i, mi) in m.iter_mut().enumerate() {
            *mi = u32::from_le_bytes([
                block[4 * i],
                block[4 * i + 1],
                block[4 * i + 2],
                block[4 * i + 3],
            ]);
        }
        let [mut a, mut b, mut c, mut d] = self.state;
        for i in 0..64 {
            let (f, g) = match i {
                0..=15 => ((b & c) | (!b & d), i),
                16..=31 => ((d & b) | (!d & c), (5 * i + 1) % 16),
                32..=47 => (b ^ c ^ d, (3 * i + 5) % 16),
                _ => (c ^ (b | !d), (7 * i) % 16),
            };
            let tmp = d;
            d = c;
            c = b;
            b = b.wrapping_add(
                a.wrapping_add(f)
                    .wrapping_add(k[i])
                    .wrapping_add(m[g])
                    .rotate_left(S[i]),
            );
            a = tmp;
        }
        self.state[0] = self.state[0].wrapping_add(a);
        self.state[1] = self.state[1].wrapping_add(b);
        self.state[2] = self.state[2].wrapping_add(c);
        self.state[3] = self.state[3].wrapping_add(d);
    }
}

/// One-shot MD5.
pub fn md5(data: &[u8]) -> Digest {
    let mut ctx = Md5::new();
    ctx.update(data);
    ctx.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hex;

    // RFC 1321 appendix A.5 test suite.
    #[test]
    fn rfc1321_vectors() {
        let cases: [(&[u8], &str); 7] = [
            (b"", "d41d8cd98f00b204e9800998ecf8427e"),
            (b"a", "0cc175b9c0f1b6a831c399e269772661"),
            (b"abc", "900150983cd24fb0d6963f7d28e17f72"),
            (b"message digest", "f96b697d7cb7938d525a2f31aaf161d0"),
            (
                b"abcdefghijklmnopqrstuvwxyz",
                "c3fcd3d76192e4007dfb496cca67e13b",
            ),
            (
                b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789",
                "d174ab98d277d9f5a5611c2c9f419d9f",
            ),
            (
                b"12345678901234567890123456789012345678901234567890123456789012345678901234567890",
                "57edf4a22be3c955ac49da2e2107b67a",
            ),
        ];
        for (input, want) in cases {
            assert_eq!(hex(&md5(input)), want, "input {input:?}");
        }
    }

    #[test]
    fn incremental_equals_oneshot() {
        let data: Vec<u8> = (0..1000u32).map(|i| (i * 7 + 3) as u8).collect();
        for splits in [1usize, 3, 17, 64, 999] {
            let mut ctx = Md5::new();
            for chunk in data.chunks(splits) {
                ctx.update(chunk);
            }
            assert_eq!(ctx.finalize(), md5(&data), "split {splits}");
        }
    }

    #[test]
    fn boundary_lengths() {
        // Around padding boundaries: 55/56/57, 63/64/65 bytes.
        for n in [0usize, 1, 55, 56, 57, 63, 64, 65, 119, 120, 121, 127, 128] {
            let data = vec![0xABu8; n];
            let d1 = md5(&data);
            let mut ctx = Md5::new();
            for b in &data {
                ctx.update(std::slice::from_ref(b));
            }
            assert_eq!(ctx.finalize(), d1, "len {n}");
        }
    }

    #[test]
    fn million_a() {
        // Classic long-message vector.
        let data = vec![b'a'; 1_000_000];
        assert_eq!(hex(&md5(&data)), "7707d6ae4e027c70eea2a935c2296f21");
    }
}
