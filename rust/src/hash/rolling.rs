//! Polynomial rolling fingerprint — the sliding-window hash.
//!
//! `H(i) = sum_{j=0..W-1} b[i+j] * p^(W-1-j)  (mod 2^32)`
//!
//! Must stay bit-for-bit identical to the Pallas kernel
//! (`python/compile/kernels/rolling.py`): the storage system's chunk
//! boundaries must not depend on whether the window hashes were produced
//! on the CPU or on the accelerator, otherwise CA-CPU and CA-GPU nodes
//! would disagree on block identity.

/// Default polynomial base (FNV prime; odd so it is invertible mod 2^32).
/// Shared with the Python kernel.
pub const DEFAULT_P: u32 = 0x0100_0193;

/// Default window width in bytes. Shared with the Python kernel.
pub const DEFAULT_WINDOW: usize = 48;

/// Incremental rolling hasher: O(1) per byte once primed.
///
/// `roll` maintains `H(i)` for the window ending at the last pushed byte:
/// `H' = (H - b_out * p^(W-1)) * p + b_in`.
#[derive(Debug, Clone)]
pub struct RollingHasher {
    p: u32,
    window: usize,
    /// p^(W-1) mod 2^32, precomputed.
    p_pow_w1: u32,
    /// Circular buffer of the current window's bytes.
    buf: Vec<u8>,
    pos: usize,
    filled: usize,
    h: u32,
}

impl RollingHasher {
    /// New hasher with explicit parameters.
    pub fn with_params(window: usize, p: u32) -> Self {
        assert!(window >= 1);
        assert!(p % 2 == 1, "p must be odd (invertible mod 2^32)");
        let mut p_pow_w1 = 1u32;
        for _ in 0..window - 1 {
            p_pow_w1 = p_pow_w1.wrapping_mul(p);
        }
        RollingHasher {
            p,
            window,
            p_pow_w1,
            buf: vec![0; window],
            pos: 0,
            filled: 0,
            h: 0,
        }
    }

    /// New hasher with the kernel-shared defaults.
    pub fn new() -> Self {
        Self::with_params(DEFAULT_WINDOW, DEFAULT_P)
    }

    /// Window width.
    pub fn window(&self) -> usize {
        self.window
    }

    /// Push one byte; returns `Some(H)` once a full window is present.
    #[inline]
    pub fn roll(&mut self, b: u8) -> Option<u32> {
        if self.filled == self.window {
            let out = self.buf[self.pos] as u32;
            self.h = self
                .h
                .wrapping_sub(out.wrapping_mul(self.p_pow_w1))
                .wrapping_mul(self.p)
                .wrapping_add(b as u32);
        } else {
            self.h = self.h.wrapping_mul(self.p).wrapping_add(b as u32);
            self.filled += 1;
        }
        self.buf[self.pos] = b;
        self.pos = (self.pos + 1) % self.window;
        (self.filled == self.window).then_some(self.h)
    }

    /// Reset to the empty state (reusing the allocation).
    pub fn reset(&mut self) {
        self.pos = 0;
        self.filled = 0;
        self.h = 0;
    }
}

impl Default for RollingHasher {
    fn default() -> Self {
        Self::new()
    }
}

/// All window hashes of `data`: `out[i] = H(i)` for every window start
/// `i in 0 ..= data.len() - window`.  Matches the Pallas kernel's output
/// layout exactly.  Returns an empty vec if `data.len() < window`.
pub fn window_hashes(data: &[u8], window: usize, p: u32) -> Vec<u32> {
    if data.len() < window {
        return Vec::new();
    }
    let mut out = Vec::with_capacity(data.len() - window + 1);
    let mut rh = RollingHasher::with_params(window, p);
    for &b in data {
        if let Some(h) = rh.roll(b) {
            out.push(h);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// O(W) reference for one window (independent of the rolling update).
    fn horner(win: &[u8], p: u32) -> u32 {
        win.iter()
            .fold(0u32, |h, &b| h.wrapping_mul(p).wrapping_add(b as u32))
    }

    #[test]
    fn rolling_equals_horner() {
        let data = Rng::new(1).bytes(4096);
        let hashes = window_hashes(&data, DEFAULT_WINDOW, DEFAULT_P);
        assert_eq!(hashes.len(), 4096 - DEFAULT_WINDOW + 1);
        for (i, &h) in hashes.iter().enumerate().step_by(97) {
            assert_eq!(h, horner(&data[i..i + DEFAULT_WINDOW], DEFAULT_P), "i={i}");
        }
    }

    #[test]
    fn short_input_empty() {
        assert!(window_hashes(&[1, 2, 3], 48, DEFAULT_P).is_empty());
    }

    #[test]
    fn exact_window_single_hash() {
        let data = Rng::new(2).bytes(48);
        let h = window_hashes(&data, 48, DEFAULT_P);
        assert_eq!(h.len(), 1);
        assert_eq!(h[0], horner(&data, DEFAULT_P));
    }

    #[test]
    fn window_1() {
        let data = [5u8, 6, 7];
        assert_eq!(window_hashes(&data, 1, DEFAULT_P), vec![5, 6, 7]);
    }

    #[test]
    fn reset_reuses_state() {
        let data = Rng::new(3).bytes(100);
        let mut rh = RollingHasher::new();
        let a: Vec<u32> = data.iter().filter_map(|&b| rh.roll(b)).collect();
        rh.reset();
        let b: Vec<u32> = data.iter().filter_map(|&b| rh.roll(b)).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn hash_depends_only_on_window_content() {
        // The same 48 bytes embedded at different stream positions must
        // produce the same hash (the content-defined-chunking property).
        let win = Rng::new(4).bytes(48);
        let mut s1 = Rng::new(5).bytes(100);
        s1.extend_from_slice(&win);
        let mut s2 = Rng::new(6).bytes(37);
        s2.extend_from_slice(&win);
        let h1 = window_hashes(&s1, 48, DEFAULT_P);
        let h2 = window_hashes(&s2, 48, DEFAULT_P);
        assert_eq!(h1[100], h2[37]);
    }

    /// Cross-check against the Python kernel's test vector generation:
    /// same constants, same math. (The authoritative cross-language check
    /// lives in tests/cross_language.rs using artifact execution.)
    #[test]
    fn matches_kernel_constants() {
        assert_eq!(DEFAULT_P, 0x0100_0193);
        assert_eq!(DEFAULT_WINDOW, 48);
    }
}
