//! Content-based chunking (the LBFS construction the paper adopts).
//!
//! A chunk boundary is declared after stream byte `e` when the rolling
//! hash of the window *ending* at `e` satisfies `(h & mask) == magic` and
//! the current chunk has reached `min_size`; a boundary is forced at
//! `max_size`.  Window hashes are stream-continuous (they never reset at
//! chunk cuts), which is what makes boundaries stable under insertions
//! and deletions — the property that buys CDC its 3–4x higher similarity
//! detection on the checkpoint workload (paper Fig 11).
//!
//! The chunker is *buffering-invariant*: feeding a stream in any split
//! produces identical chunks (property-tested).  To keep windows that
//! span buffer seams, it carries the last `window-1` bytes of the stream
//! and has the hash source (CPU rolling hash or the accelerator's
//! sliding-window artifact) hash `tail ++ buffer`.

use crate::hash::rolling::{window_hashes, DEFAULT_P, DEFAULT_WINDOW};

/// CDC parameters.  `mask`/`magic` set the expected chunk size
/// (`min_size + 1/(P[match]) ≈ min_size + mask+1` bytes on random data).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkParams {
    /// Rolling-hash window width (bytes).
    pub window: usize,
    /// Polynomial base; must be odd and match the compiled artifacts.
    pub p: u32,
    /// Boundary mask.
    pub mask: u32,
    /// Boundary magic value; `(h & mask) == magic`.
    pub magic: u32,
    /// Minimum chunk size (bytes); boundaries inside are ignored.
    pub min_size: usize,
    /// Maximum chunk size (bytes); a boundary is forced here.
    pub max_size: usize,
}

impl ChunkParams {
    /// The paper's content-based-chunking configuration: ~1.2 MB average
    /// chunks, 256 KB minimum, 4 MB maximum.
    pub fn paper_default() -> Self {
        ChunkParams {
            window: DEFAULT_WINDOW,
            p: DEFAULT_P,
            mask: (1 << 20) - 1, // ~1 MB expected spacing past min
            magic: 0x0007_8A1D & ((1 << 20) - 1),
            min_size: 256 * 1024,
            max_size: 4 * 1024 * 1024,
        }
    }

    /// Scale mask/min/max to target an average chunk size of roughly
    /// `avg` bytes (min = avg/4, max = 4*avg, mask = next_pow2(avg*3/4)-1).
    pub fn with_avg_size(avg: usize) -> Self {
        assert!(avg >= 1024, "avg chunk size too small");
        let spacing = (avg * 3 / 4).next_power_of_two();
        ChunkParams {
            window: DEFAULT_WINDOW,
            p: DEFAULT_P,
            mask: (spacing - 1) as u32,
            magic: 0x0007_8A1D & (spacing - 1) as u32,
            min_size: avg / 4,
            max_size: avg * 4,
        }
    }

    /// Validate invariants.
    pub fn validate(&self) -> crate::Result<()> {
        if self.window == 0 || self.p % 2 == 0 {
            return Err(crate::Error::Config("window>0 and odd p required".into()));
        }
        if self.magic & !self.mask != 0 {
            return Err(crate::Error::Config("magic must be within mask".into()));
        }
        if self.min_size == 0 || self.max_size < self.min_size {
            return Err(crate::Error::Config("need 0 < min <= max".into()));
        }
        if self.min_size < self.window {
            return Err(crate::Error::Config("min_size must cover a window".into()));
        }
        Ok(())
    }
}

/// A finished chunk and its start offset in the stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Chunk {
    /// Offset of the chunk's first byte in the overall stream.
    pub offset: u64,
    /// Chunk payload.
    pub data: Vec<u8>,
}

/// Streaming content-based chunker.
#[derive(Debug)]
pub struct ContentChunker {
    params: ChunkParams,
    /// Bytes of the current, unfinished chunk.
    cur: Vec<u8>,
    /// Stream offset of `cur`'s first byte.
    cur_offset: u64,
    /// Last `window-1` bytes of the stream (hash seam carry).
    tail: Vec<u8>,
}

impl ContentChunker {
    /// New chunker; panics on invalid params (use `params.validate()`
    /// first for recoverable handling).
    pub fn new(params: ChunkParams) -> Self {
        params.validate().expect("invalid chunk params");
        ContentChunker {
            params,
            cur: Vec::new(),
            cur_offset: 0,
            tail: Vec::new(),
        }
    }

    /// Parameters in use.
    pub fn params(&self) -> &ChunkParams {
        &self.params
    }

    /// The hash input for the next buffer: seam carry ++ data.  The hash
    /// source (CPU or accelerator) must hash exactly this byte string and
    /// hand the result to [`push_with_hashes`](Self::push_with_hashes).
    pub fn extended<'a>(&self, data: &'a [u8]) -> Vec<u8> {
        let mut ext = Vec::with_capacity(self.tail.len() + data.len());
        ext.extend_from_slice(&self.tail);
        ext.extend_from_slice(data);
        ext
    }

    /// Feed a buffer using the CPU rolling hash as the hash source.
    pub fn push(&mut self, data: &[u8]) -> Vec<Chunk> {
        let ext = self.extended(data);
        let hashes = window_hashes(&ext, self.params.window, self.params.p);
        self.push_with_hashes(data, &hashes)
    }

    /// Feed a buffer whose window hashes were computed externally over
    /// [`extended`](Self::extended)`(data)` — e.g. by the accelerator's
    /// sliding-window artifact.  `hashes[i]` is the hash of the window
    /// *starting* at ext index `i`; extra trailing entries (artifact
    /// padding) are ignored.
    pub fn push_with_hashes(&mut self, data: &[u8], hashes: &[u32]) -> Vec<Chunk> {
        let p = self.params;
        let w = p.window;
        let tail_len = self.tail.len();
        let mut out = Vec::new();

        for (j, &b) in data.iter().enumerate() {
            self.cur.push(b);
            let size = self.cur.len();
            // Window ending at this byte starts at ext index
            // tail_len + j - (w - 1); it exists once the stream has seen
            // at least w bytes.
            let end_pos = tail_len + j; // inclusive end, ext coordinates
            let cut = if size >= p.max_size {
                true
            } else if size >= p.min_size && end_pos + 1 >= w {
                let h = hashes[end_pos + 1 - w];
                (h & p.mask) == p.magic
            } else {
                false
            };
            if cut {
                let chunk = Chunk {
                    offset: self.cur_offset,
                    data: std::mem::take(&mut self.cur),
                };
                self.cur_offset += chunk.data.len() as u64;
                out.push(chunk);
            }
        }

        // Seam carry: last window-1 bytes of (tail ++ data).
        let keep = w - 1;
        if data.len() >= keep {
            self.tail.clear();
            self.tail.extend_from_slice(&data[data.len() - keep..]);
        } else {
            let mut t = std::mem::take(&mut self.tail);
            t.extend_from_slice(data);
            let excess = t.len().saturating_sub(keep);
            self.tail = t.split_off(excess);
        }
        out
    }

    /// Flush the final partial chunk at end of stream.
    pub fn finish(&mut self) -> Option<Chunk> {
        self.tail.clear();
        if self.cur.is_empty() {
            return None;
        }
        let chunk = Chunk {
            offset: self.cur_offset,
            data: std::mem::take(&mut self.cur),
        };
        self.cur_offset += chunk.data.len() as u64;
        Some(chunk)
    }

    /// Convenience: chunk a complete in-memory object.
    pub fn chunk_all(params: ChunkParams, data: &[u8]) -> Vec<Chunk> {
        let mut c = ContentChunker::new(params);
        let mut out = c.push(data);
        out.extend(c.finish());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn small_params() -> ChunkParams {
        ChunkParams {
            window: 16,
            p: DEFAULT_P,
            mask: 0x3FF, // ~1 KB expected spacing
            magic: 0x123,
            min_size: 256,
            max_size: 4096,
        }
    }

    #[test]
    fn validate_rejects_bad_params() {
        let mut p = small_params();
        p.magic = 0x1000; // outside mask
        assert!(p.validate().is_err());
        let mut p = small_params();
        p.max_size = 100; // < min
        assert!(p.validate().is_err());
        let mut p = small_params();
        p.p = 2; // even
        assert!(p.validate().is_err());
        let mut p = small_params();
        p.min_size = 8; // < window
        assert!(p.validate().is_err());
        assert!(small_params().validate().is_ok());
    }

    #[test]
    fn chunks_reassemble_stream() {
        let data = Rng::new(1).bytes(100_000);
        let chunks = ContentChunker::chunk_all(small_params(), &data);
        let cat: Vec<u8> = chunks.iter().flat_map(|c| c.data.clone()).collect();
        assert_eq!(cat, data);
        // Offsets are consistent.
        let mut off = 0u64;
        for c in &chunks {
            assert_eq!(c.offset, off);
            off += c.data.len() as u64;
        }
    }

    #[test]
    fn size_bounds_respected() {
        let p = small_params();
        let data = Rng::new(2).bytes(200_000);
        let chunks = ContentChunker::chunk_all(p, &data);
        for (i, c) in chunks.iter().enumerate() {
            assert!(c.data.len() <= p.max_size);
            if i + 1 != chunks.len() {
                assert!(c.data.len() >= p.min_size, "chunk {i}: {}", c.data.len());
            }
        }
    }

    #[test]
    fn buffering_invariance() {
        let p = small_params();
        let data = Rng::new(3).bytes(50_000);
        let whole = ContentChunker::chunk_all(p, &data);
        for bufsize in [1usize, 13, 100, 1024, 4096, 49_999] {
            let mut c = ContentChunker::new(p);
            let mut got = Vec::new();
            for buf in data.chunks(bufsize) {
                got.extend(c.push(buf));
            }
            got.extend(c.finish());
            assert_eq!(got, whole, "bufsize={bufsize}");
        }
    }

    #[test]
    fn insertion_stability() {
        // Insert bytes near the front; chunks past the disturbed region
        // must be identical (the raison d'etre of CDC).
        let p = small_params();
        let data = Rng::new(4).bytes(60_000);
        let mut mutated = data.clone();
        let insert = Rng::new(5).bytes(37);
        let at = 1000;
        mutated.splice(at..at, insert.iter().copied());

        let a: Vec<Vec<u8>> = ContentChunker::chunk_all(p, &data)
            .into_iter()
            .map(|c| c.data)
            .collect();
        let b: Vec<Vec<u8>> = ContentChunker::chunk_all(p, &mutated)
            .into_iter()
            .map(|c| c.data)
            .collect();
        let common = a.iter().filter(|c| b.contains(c)).count();
        assert!(
            common * 2 > a.len(),
            "only {common}/{} chunks survived a 37-byte insert",
            a.len()
        );
    }

    #[test]
    fn average_size_tracks_params() {
        let p = ChunkParams::with_avg_size(8192);
        let data = Rng::new(6).bytes(2_000_000);
        let chunks = ContentChunker::chunk_all(p, &data);
        let avg = data.len() / chunks.len();
        assert!(
            (2048..=32768).contains(&avg),
            "avg {avg} far from target 8192"
        );
    }

    #[test]
    fn external_hashes_match_internal() {
        // push_with_hashes with CPU-computed hashes == push.
        let p = small_params();
        let data = Rng::new(7).bytes(30_000);
        let mut c1 = ContentChunker::new(p);
        let mut c2 = ContentChunker::new(p);
        let mut out1 = Vec::new();
        let mut out2 = Vec::new();
        for buf in data.chunks(4096) {
            out1.extend(c1.push(buf));
            let ext = c2.extended(buf);
            let mut hashes = window_hashes(&ext, p.window, p.p);
            // Simulate artifact padding: extra garbage entries at the end.
            hashes.extend_from_slice(&[0xDEAD_BEEF; 7]);
            out2.extend(c2.push_with_hashes(buf, &hashes));
        }
        out1.extend(c1.finish());
        out2.extend(c2.finish());
        assert_eq!(out1, out2);
    }

    #[test]
    fn empty_stream() {
        let mut c = ContentChunker::new(small_params());
        assert!(c.push(&[]).is_empty());
        assert!(c.finish().is_none());
    }

    #[test]
    fn paper_default_avg_size() {
        let p = ChunkParams::paper_default();
        p.validate().unwrap();
        let data = Rng::new(8).bytes(24 * 1024 * 1024);
        let chunks = ContentChunker::chunk_all(p, &data);
        let avg = data.len() / chunks.len();
        // Paper: 1.2 MB average, 256 KB min, 4 MB max.
        assert!(
            (600 * 1024..=2600 * 1024).contains(&avg),
            "avg {avg} outside paper band"
        );
    }
}
