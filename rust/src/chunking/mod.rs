//! Block-boundary detection: fixed-size splitting and content-based
//! chunking (CDC).  CDC's boundary *selection* is host-side (CPU) in both
//! the CPU and accelerator configurations — only the window-hash
//! computation moves to the device — exactly mirroring the paper.

pub mod cdc;
pub mod fixed;

pub use cdc::{Chunk, ChunkParams, ContentChunker};
pub use fixed::{split_fixed, FixedChunker};
