//! Fixed-size block splitting (the paper's "fixed size blocks"
//! configuration; 1 MB is MosaStore's default block size).

use std::ops::Range;

/// Byte ranges of each block of a `len`-byte object split at `block` bytes.
/// The final block may be short. Empty input yields no blocks.
pub fn split_fixed(len: usize, block: usize) -> Vec<Range<usize>> {
    assert!(block > 0);
    let mut out = Vec::with_capacity(len.div_ceil(block));
    let mut lo = 0;
    while lo < len {
        let hi = (lo + block).min(len);
        out.push(lo..hi);
        lo = hi;
    }
    out
}

/// Streaming fixed-size chunker with the same push/finish shape as
/// [`super::ContentChunker`], so the SAI write path is chunker-agnostic.
#[derive(Debug)]
pub struct FixedChunker {
    block: usize,
    cur: Vec<u8>,
}

impl FixedChunker {
    /// New chunker emitting `block`-byte chunks.
    pub fn new(block: usize) -> Self {
        assert!(block > 0);
        FixedChunker {
            block,
            cur: Vec::with_capacity(block),
        }
    }

    /// Feed bytes; returns every completed block.
    pub fn push(&mut self, mut data: &[u8]) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        while !data.is_empty() {
            let take = (self.block - self.cur.len()).min(data.len());
            self.cur.extend_from_slice(&data[..take]);
            data = &data[take..];
            if self.cur.len() == self.block {
                out.push(std::mem::replace(
                    &mut self.cur,
                    Vec::with_capacity(self.block),
                ));
            }
        }
        out
    }

    /// Flush the trailing partial block, if any.
    pub fn finish(&mut self) -> Option<Vec<u8>> {
        (!self.cur.is_empty()).then(|| std::mem::take(&mut self.cur))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn split_exact() {
        let r = split_fixed(4096, 1024);
        assert_eq!(r.len(), 4);
        assert_eq!(r[3], 3072..4096);
    }

    #[test]
    fn split_with_remainder() {
        let r = split_fixed(4100, 1024);
        assert_eq!(r.len(), 5);
        assert_eq!(r[4], 4096..4100);
    }

    #[test]
    fn split_empty() {
        assert!(split_fixed(0, 1024).is_empty());
    }

    #[test]
    fn split_smaller_than_block() {
        assert_eq!(split_fixed(10, 1024), vec![0..10]);
    }

    #[test]
    fn streaming_matches_split() {
        let data = Rng::new(1).bytes(10_000);
        for bufsize in [1usize, 7, 1024, 4096, 10_000] {
            let mut ch = FixedChunker::new(1024);
            let mut blocks = Vec::new();
            for buf in data.chunks(bufsize) {
                blocks.extend(ch.push(buf));
            }
            blocks.extend(ch.finish());
            let want: Vec<Vec<u8>> = split_fixed(data.len(), 1024)
                .into_iter()
                .map(|r| data[r].to_vec())
                .collect();
            assert_eq!(blocks, want, "bufsize={bufsize}");
        }
    }

    #[test]
    fn finish_empty_is_none() {
        let mut ch = FixedChunker::new(8);
        assert!(ch.finish().is_none());
        ch.push(&[1, 2, 3, 4, 5, 6, 7, 8]);
        assert!(ch.finish().is_none());
    }
}
