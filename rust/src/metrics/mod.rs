//! Measurement plumbing: stage timers (Table 1 / Fig 4), throughput
//! meters, simple histograms, and table rendering for the figure benches.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// The five processing stages of an accelerator task (paper Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Stage {
    /// Device init, memory allocation, host-side preprocessing.
    Preprocess,
    /// Host -> device input transfer.
    CopyIn,
    /// Kernel execution.
    Kernel,
    /// Device -> host output transfer.
    CopyOut,
    /// Host-side postprocess (final hash, boundary scan) + release.
    Postprocess,
}

impl Stage {
    /// All stages in pipeline order.
    pub const ALL: [Stage; 5] = [
        Stage::Preprocess,
        Stage::CopyIn,
        Stage::Kernel,
        Stage::CopyOut,
        Stage::Postprocess,
    ];

    /// Short label for tables.
    pub fn label(&self) -> &'static str {
        match self {
            Stage::Preprocess => "prep/alloc",
            Stage::CopyIn => "copy-in",
            Stage::Kernel => "kernel",
            Stage::CopyOut => "copy-out",
            Stage::Postprocess => "post",
        }
    }
}

/// Accumulates per-stage durations across tasks (Fig 4's input).
#[derive(Debug, Default, Clone)]
pub struct StageBreakdown {
    totals: BTreeMap<Stage, Duration>,
    tasks: u64,
}

impl StageBreakdown {
    /// Empty breakdown.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one stage observation.
    pub fn add(&mut self, stage: Stage, d: Duration) {
        *self.totals.entry(stage).or_default() += d;
    }

    /// Mark one task complete (for averaging).
    pub fn end_task(&mut self) {
        self.tasks += 1;
    }

    /// Merge another breakdown in.
    pub fn merge(&mut self, other: &StageBreakdown) {
        for (s, d) in &other.totals {
            *self.totals.entry(*s).or_default() += *d;
        }
        self.tasks += other.tasks;
    }

    /// Total across stages.
    pub fn total(&self) -> Duration {
        self.totals.values().sum()
    }

    /// Fraction of total time spent in `stage` (0..1).
    pub fn fraction(&self, stage: Stage) -> f64 {
        let tot = self.total().as_secs_f64();
        if tot == 0.0 {
            return 0.0;
        }
        self.totals.get(&stage).copied().unwrap_or_default().as_secs_f64() / tot
    }

    /// Stage total.
    pub fn get(&self, stage: Stage) -> Duration {
        self.totals.get(&stage).copied().unwrap_or_default()
    }

    /// Number of completed tasks.
    pub fn tasks(&self) -> u64 {
        self.tasks
    }
}

/// Wall-clock throughput meter.
#[derive(Debug)]
pub struct Throughput {
    start: Instant,
    bytes: u64,
}

impl Throughput {
    /// Start measuring now.
    pub fn start() -> Self {
        Throughput {
            start: Instant::now(),
            bytes: 0,
        }
    }

    /// Record processed bytes.
    pub fn add(&mut self, bytes: u64) {
        self.bytes += bytes;
    }

    /// Bytes recorded so far.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// MB/s so far.
    pub fn mbps(&self) -> f64 {
        crate::util::mbps(self.bytes, self.secs())
    }
}

/// Fixed-bucket latency/size histogram (power-of-two buckets).
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Empty histogram (64 power-of-two buckets).
    pub fn new() -> Self {
        Histogram {
            buckets: vec![0; 64],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Record a value.
    pub fn record(&mut self, v: u64) {
        let b = (64 - v.leading_zeros()).min(63) as usize;
        self.buckets[b] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean value.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Smallest sample (0 if empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Largest sample.
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate quantile from bucket boundaries (upper bound).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (b, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                return if b == 0 { 0 } else { 1u64 << b };
            }
        }
        self.max
    }
}

/// Live gauges for an event-driven serve loop (PR 9): one instance per
/// reactor, updated lock-free by the poll thread and the workers, read
/// by `gpustore demo --verbose` and tests.  All counters are
/// monotonically written with relaxed ordering — they are observability,
/// not synchronization.
#[derive(Debug, Default)]
pub struct ServeGauges {
    /// Connections currently registered with the poll loop.
    pub open_conns: std::sync::atomic::AtomicU64,
    /// Total connections accepted since the loop started.
    pub accepted: std::sync::atomic::AtomicU64,
    /// Connections queued for a worker right now (ready-queue depth,
    /// summed across lanes).
    pub ready_depth: std::sync::atomic::AtomicU64,
    /// Workers currently inside a handler.
    pub workers_busy: std::sync::atomic::AtomicU64,
    /// Worker pool size (static after spawn).
    pub workers_total: std::sync::atomic::AtomicU64,
    /// Request frames fully served since the loop started.
    pub frames_served: std::sync::atomic::AtomicU64,
}

/// Point-in-time copy of [`ServeGauges`], for printing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServeSnapshot {
    /// Connections currently open.
    pub open_conns: u64,
    /// Connections accepted since start.
    pub accepted: u64,
    /// Ready-queue depth across lanes.
    pub ready_depth: u64,
    /// Workers currently busy.
    pub workers_busy: u64,
    /// Worker pool size.
    pub workers_total: u64,
    /// Frames served since start.
    pub frames_served: u64,
}

impl ServeGauges {
    /// Read every gauge at once.
    pub fn snapshot(&self) -> ServeSnapshot {
        use std::sync::atomic::Ordering::Relaxed;
        ServeSnapshot {
            open_conns: self.open_conns.load(Relaxed),
            accepted: self.accepted.load(Relaxed),
            ready_depth: self.ready_depth.load(Relaxed),
            workers_busy: self.workers_busy.load(Relaxed),
            workers_total: self.workers_total.load(Relaxed),
            frames_served: self.frames_served.load(Relaxed),
        }
    }
}

impl ServeSnapshot {
    /// Worker pool utilization in `[0, 1]` (busy / total).
    pub fn utilization(&self) -> f64 {
        if self.workers_total == 0 {
            0.0
        } else {
            self.workers_busy as f64 / self.workers_total as f64
        }
    }
}

impl std::fmt::Display for ServeSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "conns={} (accepted {}) ready={} workers={}/{} ({:.0}% busy) frames={}",
            self.open_conns,
            self.accepted,
            self.ready_depth,
            self.workers_busy,
            self.workers_total,
            self.utilization() * 100.0,
            self.frames_served,
        )
    }
}

/// Markdown table builder used by the figure harnesses.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    /// Render as GitHub-flavoured markdown.
    pub fn markdown(&self) -> String {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            s
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push('|');
        for wi in &w {
            out.push_str(&format!("{:-<width$}|", "", width = wi + 2));
        }
        for row in &self.rows {
            out.push('\n');
            out.push_str(&fmt_row(row));
        }
        out
    }

    /// Render as CSV.
    pub fn csv(&self) -> String {
        let mut out = self.header.join(",");
        for row in &self.rows {
            out.push('\n');
            out.push_str(&row.join(","));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_breakdown_fractions() {
        let mut b = StageBreakdown::new();
        b.add(Stage::CopyIn, Duration::from_millis(80));
        b.add(Stage::Kernel, Duration::from_millis(20));
        b.end_task();
        assert!((b.fraction(Stage::CopyIn) - 0.8).abs() < 1e-9);
        assert!((b.fraction(Stage::Kernel) - 0.2).abs() < 1e-9);
        assert_eq!(b.fraction(Stage::CopyOut), 0.0);
        assert_eq!(b.tasks(), 1);
    }

    #[test]
    fn stage_breakdown_merge() {
        let mut a = StageBreakdown::new();
        a.add(Stage::Kernel, Duration::from_millis(10));
        a.end_task();
        let mut b = StageBreakdown::new();
        b.add(Stage::Kernel, Duration::from_millis(30));
        b.end_task();
        a.merge(&b);
        assert_eq!(a.get(Stage::Kernel), Duration::from_millis(40));
        assert_eq!(a.tasks(), 2);
    }

    #[test]
    fn histogram_stats() {
        let mut h = Histogram::new();
        for v in [1u64, 2, 4, 8, 1024] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 1024);
        assert!((h.mean() - 207.8).abs() < 0.1);
        assert!(h.quantile(0.5) <= 8);
        assert!(h.quantile(1.0) >= 1024);
    }

    #[test]
    fn serve_gauges_snapshot_and_utilization() {
        use std::sync::atomic::Ordering::Relaxed;
        let g = ServeGauges::default();
        g.open_conns.store(3, Relaxed);
        g.accepted.store(7, Relaxed);
        g.workers_busy.store(2, Relaxed);
        g.workers_total.store(4, Relaxed);
        g.frames_served.store(11, Relaxed);
        let s = g.snapshot();
        assert_eq!(s.open_conns, 3);
        assert!((s.utilization() - 0.5).abs() < 1e-9);
        let text = s.to_string();
        assert!(text.contains("conns=3"), "{text}");
        assert!(text.contains("workers=2/4"), "{text}");
        // Empty pool never divides by zero.
        assert_eq!(ServeSnapshot { workers_total: 0, ..s }.utilization(), 0.0);
    }

    #[test]
    fn table_markdown_shape() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.lines().count() == 3);
    }

    #[test]
    fn table_csv() {
        let mut t = Table::new(&["x", "y"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.csv(), "x,y\n1,2");
    }
}
