//! Competing-application contention model (Figures 12–17, §4.5):
//! a proportional-share CPU plus fixed I/O-path interference terms.
//!
//! Shape anchors from the paper:
//! * non-CA imposes 80–225 % slowdown on a compute-bound app — TCP
//!   processing of a 1 Gbps write stream eats CPU even without hashing;
//! * CA-CPU adds hashing threads on top of that;
//! * CA-GPU frees the hashing CPU, halving the competitor slowdown on
//!   the `different` workload;
//! * storage throughput loses <= 18 % (compute competitor) / <= 6 %
//!   (I/O competitor) vs a dedicated client.

use super::write::{EngineModel, SystemSim, WriteConfig};

/// Competing application kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompetitorKind {
    /// Multithreaded prime search: wants every core.
    ComputeBound,
    /// Build-like file churn: disk + some CPU.
    IoBound,
}

/// Client-node contention model.
#[derive(Debug, Clone)]
pub struct ContentionModel {
    /// Client cores (paper: quad-core for §4.5).
    pub cores: f64,
    /// CPU cores consumed by TCP/kernel processing per GB/s of network
    /// traffic (drives the paper's surprising non-CA slowdown).
    pub tcp_cores_per_gbps: f64,
    /// Cores used by SAI bookkeeping (buffering, metadata).
    pub sai_cores: f64,
    /// Cores used by GPU management (crystal manager threads).
    pub gpu_mgmt_cores: f64,
    /// Compute app parallelism (threads).
    pub app_threads: f64,
    /// I/O app CPU demand (cores) — compile bursts.
    pub io_app_cores: f64,
    /// Fraction of storage write time that contends with the I/O app's
    /// disk channel (the paper's nodes are remote: only local buffering).
    pub disk_overlap: f64,
}

impl Default for ContentionModel {
    fn default() -> Self {
        ContentionModel {
            cores: 4.0,
            tcp_cores_per_gbps: 2.2,
            sai_cores: 0.3,
            gpu_mgmt_cores: 0.4,
            app_threads: 4.0,
            io_app_cores: 1.0,
            disk_overlap: 0.15,
        }
    }
}

/// Result of a contention evaluation.
#[derive(Debug, Clone, Copy)]
pub struct ContentionResult {
    /// Storage write throughput under competition (B/s).
    pub storage_bps: f64,
    /// Storage throughput on a dedicated node (B/s).
    pub storage_dedicated_bps: f64,
    /// Competitor slowdown (0.5 = 50 % longer runtime).
    pub app_slowdown: f64,
}

impl ContentionModel {
    /// Cores the storage client consumes while writing at `net_bps`,
    /// hashing with `engine` (`hash_cores` at full demand).
    fn storage_core_demand(&self, engine: &EngineModel, net_bps: f64) -> f64 {
        let tcp = self.tcp_cores_per_gbps * (net_bps * 8.0 / 1e9);
        let hash = match engine {
            EngineModel::None => 0.0,
            EngineModel::Infinite => 0.0,
            EngineModel::Cpu { threads } => *threads as f64,
            EngineModel::Gpu { .. } => self.gpu_mgmt_cores,
        };
        self.sai_cores + tcp + hash
    }

    /// Evaluate storage-vs-app interference for one configuration.
    ///
    /// `sim`/`cfg`/`size`/`blocks` describe the write stream exactly as
    /// in [`SystemSim::write_bps`]; the competitor runs continuously.
    pub fn evaluate(
        &self,
        sim: &SystemSim,
        cfg: &WriteConfig,
        size: usize,
        blocks: usize,
        kind: CompetitorKind,
    ) -> ContentionResult {
        let dedicated_bps = sim.write_bps(cfg, size, blocks, 10);
        let net_bps = dedicated_bps * (1.0 - cfg.similarity);

        let storage_demand = self.storage_core_demand(&cfg.engine, net_bps);
        let app_demand = match kind {
            CompetitorKind::ComputeBound => self.app_threads,
            CompetitorKind::IoBound => self.io_app_cores,
        };

        // Proportional share of the cores under overload.
        let total = storage_demand + app_demand;
        let (storage_share, app_share) = if total <= self.cores {
            (storage_demand, app_demand)
        } else {
            let f = self.cores / total;
            (storage_demand * f, app_demand * f)
        };

        // Storage slows with its CPU share (hash-bound configs suffer
        // most; network-bound configs barely notice).
        let storage_scale = (storage_share / storage_demand).min(1.0);
        // How CPU-bound is this storage config?  Ratio of CPU work to
        // total write time decides sensitivity.
        let hash = sim.hash_secs(cfg, size);
        let t_write = sim.write_secs(cfg, size, blocks);
        let cpu_sensitivity = match cfg.engine {
            EngineModel::Cpu { .. } => (hash / t_write).min(1.0),
            _ => 0.25, // TCP + bookkeeping only
        };
        let storage_bps =
            dedicated_bps * (1.0 - cpu_sensitivity * (1.0 - storage_scale));

        // Competitor slowdown: CPU share loss + I/O-path interference.
        let cpu_slow = app_demand / app_share - 1.0;
        let io_slow = match kind {
            CompetitorKind::IoBound => self.disk_overlap * (net_bps * 8.0 / 1e9),
            CompetitorKind::ComputeBound => 0.0,
        };
        ContentionResult {
            storage_bps,
            storage_dedicated_bps: dedicated_bps,
            app_slowdown: cpu_slow + io_slow,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::GpuOpts;

    fn cfg(engine: EngineModel, similarity: f64) -> WriteConfig {
        WriteConfig {
            engine,
            similarity,
            ..WriteConfig::default()
        }
    }

    const GB: usize = 1 << 30;

    fn model() -> (ContentionModel, SystemSim) {
        (ContentionModel::default(), SystemSim::default())
    }

    #[test]
    fn gpu_offload_halves_compute_app_slowdown_on_different() {
        // Paper Fig 12: CA-GPU reduces the competitor slowdown by ~half
        // vs CA-CPU under the `different` workload.
        let (m, s) = model();
        let cpu = m.evaluate(
            &s,
            &cfg(EngineModel::Cpu { threads: 4 }, 0.0),
            GB,
            1024,
            CompetitorKind::ComputeBound,
        );
        let gpu = m.evaluate(
            &s,
            &cfg(EngineModel::Gpu { opts: GpuOpts::OVERLAP }, 0.0),
            GB,
            1024,
            CompetitorKind::ComputeBound,
        );
        assert!(
            gpu.app_slowdown < 0.7 * cpu.app_slowdown,
            "gpu {} vs cpu {}",
            gpu.app_slowdown,
            cpu.app_slowdown
        );
    }

    #[test]
    fn nonca_still_slows_compute_app_via_tcp() {
        // Paper's surprise: non-CA imposes 80-225 % slowdown.
        let (m, s) = model();
        let non = m.evaluate(
            &s,
            &cfg(EngineModel::None, 0.0),
            GB,
            1024,
            CompetitorKind::ComputeBound,
        );
        assert!(
            non.app_slowdown > 0.3,
            "tcp processing must hurt: {}",
            non.app_slowdown
        );
    }

    #[test]
    fn gpu_storage_tput_loss_small_under_compute_competitor() {
        // Paper: <= 18 % loss vs dedicated.
        let (m, s) = model();
        let gpu = m.evaluate(
            &s,
            &cfg(EngineModel::Gpu { opts: GpuOpts::OVERLAP }, 0.5),
            GB,
            1024,
            CompetitorKind::ComputeBound,
        );
        let loss = 1.0 - gpu.storage_bps / gpu.storage_dedicated_bps;
        assert!(loss <= 0.20, "loss {loss}");
    }

    #[test]
    fn io_competitor_hurts_less_than_compute() {
        let (m, s) = model();
        let c = cfg(EngineModel::Gpu { opts: GpuOpts::OVERLAP }, 0.5);
        let comp = m.evaluate(&s, &c, GB, 1024, CompetitorKind::ComputeBound);
        let io = m.evaluate(&s, &c, GB, 1024, CompetitorKind::IoBound);
        let loss_c = 1.0 - comp.storage_bps / comp.storage_dedicated_bps;
        let loss_io = 1.0 - io.storage_bps / io.storage_dedicated_bps;
        assert!(loss_io <= loss_c + 1e-9, "io {loss_io} compute {loss_c}");
    }

    #[test]
    fn gpu_beats_cpu_storage_tput_under_similar_competition() {
        // Paper: ~2.5x better storage throughput under `similar` load.
        let (m, s) = model();
        let mut c_cpu = cfg(EngineModel::Cpu { threads: 4 }, 1.0);
        let mut c_gpu = cfg(EngineModel::Gpu { opts: GpuOpts::OVERLAP }, 1.0);
        c_cpu.cdc = false;
        c_gpu.cdc = false;
        let cpu = m.evaluate(&s, &c_cpu, GB, 1024, CompetitorKind::ComputeBound);
        let gpu = m.evaluate(&s, &c_gpu, GB, 1024, CompetitorKind::ComputeBound);
        assert!(
            gpu.storage_bps > 1.5 * cpu.storage_bps,
            "gpu {:.2e} cpu {:.2e}",
            gpu.storage_bps,
            cpu.storage_bps
        );
    }
}
