//! Write-path timing for the integrated system (Figures 7–11): one file
//! write = buffer-wise {window hashing (CDC) + direct hashing + dedup
//! compare} overlapped with {striped transfer of new blocks over the
//! client NIC}, plus manager commit — the exact structure of a
//! `store::session::FileWriter` session, evaluated in model time.
//!
//! Engine asymmetry mirrors the real session pipeline: synchronous
//! engines (CPU) block the writer while hashing, so hash time
//! serializes in front of the transfer; the GPU engine's digests are
//! *submitted* asynchronously and redeemed one buffer later, so buffer
//! N's hashing overlaps buffer N-1's transfer (steady state pays
//! `max(hash, transfer)` per buffer, with one exposed hash fill and one
//! trailing transfer drain).

use super::gpu::{GpuOpts, GpuPipeline};
use crate::crystal::model::CpuModel;

/// Which hash engine the modeled client uses.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EngineModel {
    /// No content addressability (`non-CA`).
    None,
    /// CA on the CPU with this many hashing threads.
    Cpu {
        /// Hashing threads.
        threads: usize,
    },
    /// CA offloaded through crystal.
    Gpu {
        /// Optimization level.
        opts: GpuOpts,
    },
    /// CA-Infinite: instant hashing (paper §4.4).
    Infinite,
}

/// Chunking mode + parameters of the modeled client.
#[derive(Debug, Clone, Copy)]
pub struct WriteConfig {
    /// Engine.
    pub engine: EngineModel,
    /// Content-based chunking (vs fixed blocks).
    pub cdc: bool,
    /// Write-buffer size (one GPU job per buffer).
    pub write_buffer: usize,
    /// Fraction of bytes deduplicated against the previous version
    /// (0 = `different`, 1 = `similar`; checkpoint values are measured
    /// from the real generator by the bench harness).
    pub similarity: f64,
    /// Copies per block (control-plane v2 replication): every new byte
    /// crosses the client NIC once per replica.
    pub replication: usize,
    /// Data-plane pipeline depth (data-plane v2): operations kept in
    /// flight per node link.  `1` models the old lock-step protocol —
    /// every transferred block pays the full request→reply turnaround
    /// ([`SystemSim::net_rtt`]) on top of its wire time; deeper
    /// pipelines amortize it away.
    pub inflight_depth: usize,
    /// Client-side erasure-encode cost (PR 10), seconds per new byte:
    /// the GF(256) Reed–Solomon pass every non-duplicate byte takes
    /// before its shards can ship.  Encoding gates transfer, so it adds
    /// serially like the other overheads.  `0.0` — the default — models
    /// replicated/round-robin placement and keeps every pre-PR-10
    /// figure bit-identical.
    pub ec_encode_overhead: f64,
}

impl Default for WriteConfig {
    fn default() -> Self {
        WriteConfig {
            engine: EngineModel::None,
            cdc: false,
            write_buffer: 4 << 20,
            similarity: 0.0,
            replication: 1,
            inflight_depth: 16,
            ec_encode_overhead: 0.0,
        }
    }
}

/// The modeled system: client CPU/GPU + network.
///
/// Calibration (anchored to the paper's integrated-system numbers, see
/// EXPERIMENTS.md): the client data path (FUSE crossing + SAI buffer
/// copies) floors at ~350 MB/s — this is what caps CA-Infinite in
/// Figs 9/10; CPU hashing inside the SAI runs at ~0.6x its standalone
/// rate (it shares cores with TCP, buffering and block bookkeeping) —
/// this reproduces the 46–49 MB/s CDC-on-CPU ceiling of Figs 8/10/11.
#[derive(Debug, Clone)]
pub struct SystemSim {
    /// CPU model (hash throughputs).
    pub cpu: CpuModel,
    /// GPU pipeline model.
    pub gpu: GpuPipeline,
    /// Client NIC bandwidth, bytes/s (1 Gbps link in the paper; the
    /// 4-node stripe is NIC-bound, not node-bound).
    pub net_bps: f64,
    /// Per-request round-trip residue on the storage fabric, seconds
    /// (GbE switch + kernel turnaround).  A lock-step data plane
    /// (`WriteConfig::inflight_depth == 1`) pays this once per
    /// transferred block; a pipelined one divides it by the depth.
    pub net_rtt: f64,
    /// Fixed per-file overhead: manager round-trips, open/commit (s).
    pub per_file_overhead: f64,
    /// Per-file lease overhead (control-plane v3): the extra manager
    /// round-trips a session spends on its lease — open-with-pin on
    /// read, open + commit-consume on write (renewals ride a separate
    /// heartbeat connection and cost the data path nothing).
    pub per_lease_overhead: f64,
    /// Per-block bookkeeping overhead on the client (s) — hash compare,
    /// metadata entry, request framing.
    pub per_block_overhead: f64,
    /// Per-commit durability overhead (PR 7): the group-commit fsync
    /// latency a manager running with a write-ahead log adds to the
    /// commit reply (at most one `--wal-sync` window plus the device
    /// flush).  `0.0` — the default — models the in-memory manager and
    /// keeps every pre-durability figure bit-identical.
    pub per_commit_wal_overhead: f64,
    /// Per-request serve-loop dispatch overhead (PR 9): the readiness
    /// reactor's queue→worker handoff added to every manager round-trip
    /// a file write makes (open, alloc, commit — modeled as the three
    /// requests of a minimal session).  `0.0` — the default — models an
    /// uncontended serve path and keeps every pre-PR-9 figure
    /// bit-identical; benches measure the real value from
    /// `BENCH_pr9.json` latency deltas.
    pub per_request_serve_overhead: f64,
    /// Fixed control-plane cost per repaired copy or shard (PR 10):
    /// the scrub loop's rehome record, placement decision and
    /// connection setup, paid once per repair on top of the wire time
    /// ([`SystemSim::repair_secs`]).  `0.0` by default — write-path
    /// figures never depend on it.
    pub per_repair_overhead: f64,
    /// Client data-path bandwidth: FUSE crossing + SAI write-buffer
    /// copies (B/s).  The CA-Infinite ceiling.
    pub memcpy_bps: f64,
    /// In-system CPU hashing efficiency vs standalone (cache pressure,
    /// TCP/bookkeeping sharing the cores).
    pub cpu_system_efficiency: f64,
}

impl Default for SystemSim {
    fn default() -> Self {
        SystemSim {
            cpu: CpuModel::xeon_2008(),
            gpu: GpuPipeline::default(),
            net_bps: 117e6, // 1 Gbps after TCP/IP overheads
            net_rtt: 0.2e-3,
            per_file_overhead: 2e-3,
            per_lease_overhead: 0.2e-3, // ~2 extra manager RTTs
            per_block_overhead: 15e-6,
            per_commit_wal_overhead: 0.0,
            per_request_serve_overhead: 0.0,
            per_repair_overhead: 0.0,
            memcpy_bps: 350e6,
            cpu_system_efficiency: 0.6,
        }
    }
}

impl SystemSim {
    /// Hashing seconds for one file of `size` bytes under `cfg`
    /// (window hashing for CDC + direct hashing of every block; the
    /// paper's CDC pipeline hashes all data through both kernels).
    pub fn hash_secs(&self, cfg: &WriteConfig, size: usize) -> f64 {
        let jobs = size.div_ceil(cfg.write_buffer).max(1);
        match cfg.engine {
            EngineModel::None => 0.0,
            EngineModel::Infinite => 0.0,
            EngineModel::Cpu { threads } => {
                let direct = self.cpu.direct_secs(size, threads);
                let raw = if cfg.cdc {
                    direct + self.cpu.window_secs(size, threads)
                } else {
                    direct
                };
                raw / self.cpu_system_efficiency
            }
            EngineModel::Gpu { opts } => {
                let per_job = cfg.write_buffer.min(size);
                let direct = self.gpu.stream_secs(false, per_job, jobs, opts);
                if cfg.cdc {
                    direct + self.gpu.stream_secs(true, per_job, jobs, opts)
                } else {
                    direct
                }
            }
        }
    }

    /// Transfer seconds for one file: only non-duplicate bytes cross
    /// the network, once per replica copy (the client NIC pays for
    /// replication, as in the real `FileWriter`).  Pure wire time — the
    /// `inflight_depth == ∞` asymptote of
    /// [`net_secs_pipelined`](SystemSim::net_secs_pipelined).
    pub fn net_secs(&self, cfg: &WriteConfig, size: usize) -> f64 {
        let new_bytes = size as f64 * (1.0 - cfg.similarity);
        new_bytes * cfg.replication.max(1) as f64 / self.net_bps
    }

    /// Transfer seconds including the per-block request→reply
    /// turnaround the data plane's pipeline does or does not hide
    /// (data-plane v2).  Each transferred block costs
    /// `max(wire_time, (wire_time + rtt) / depth)` — the classic
    /// sliding-window throughput bound: at depth 1 (lock-step) every
    /// block serializes behind its own acknowledgement
    /// (`block / RTT`-bound, the pre-pipelining data plane), while a
    /// depth that covers the bandwidth-delay product leaves the link
    /// wire-limited.
    pub fn net_secs_pipelined(&self, cfg: &WriteConfig, size: usize, blocks: usize) -> f64 {
        let blocks = blocks.max(1);
        let new_blocks = blocks as f64 * (1.0 - cfg.similarity);
        if new_blocks <= 0.0 {
            return 0.0;
        }
        let wire = (size as f64 / blocks as f64) * cfg.replication.max(1) as f64 / self.net_bps;
        let depth = cfg.inflight_depth.max(1) as f64;
        new_blocks * wire.max((wire + self.net_rtt) / depth)
    }

    /// Seconds to write one file of `size` bytes.
    ///
    /// Structure (matching `store::session::FileWriter`): the
    /// application's data passes through the client data path (`copy`),
    /// which overlaps with the striped network transfer of new blocks
    /// (async node workers) — `max(net, copy)`.  Hashing gates block
    /// placement (a block cannot be deduplicated or shipped before its
    /// digest is known): synchronous engines therefore serialize `hash`
    /// in front, while the GPU engine's asynchronous submission pays the
    /// per-buffer pipeline fill/drain instead
    /// ([`pipelined_secs`]).
    pub fn write_secs(&self, cfg: &WriteConfig, size: usize, blocks: usize) -> f64 {
        // A minimal write session makes three manager round-trips
        // (open, alloc, commit); each pays one serve-loop dispatch.
        const MANAGER_REQUESTS_PER_FILE: f64 = 3.0;
        let overhead = self.per_file_overhead
            + self.per_lease_overhead
            + self.per_commit_wal_overhead
            + MANAGER_REQUESTS_PER_FILE * self.per_request_serve_overhead
            + blocks as f64 * self.per_block_overhead
            + size as f64 * (1.0 - cfg.similarity) * cfg.ec_encode_overhead;
        self.gated_secs(cfg, size, blocks).0 + overhead
    }

    /// Seconds for the scrub loop to restore redundancy after losing
    /// `repairs` copies or shards totalling `bytes` (PR 10): each
    /// repair pays the fixed control-plane cost
    /// ([`per_repair_overhead`](Self::per_repair_overhead)) plus wire
    /// time at the repair budget (`repair_mbps` in Mbit/s, matching
    /// `--repair-mbps`; `<= 0` repairs at the full link rate).
    pub fn repair_secs(&self, repairs: usize, bytes: usize, repair_mbps: f64) -> f64 {
        let bps = if repair_mbps > 0.0 {
            (repair_mbps * 125_000.0).min(self.net_bps)
        } else {
            self.net_bps
        };
        repairs as f64 * self.per_repair_overhead + bytes as f64 / bps
    }

    /// Hash time *hidden* behind transfers for one file under `cfg` —
    /// the modeled counterpart of `WriteReport::hash_hidden_secs`.
    pub fn hash_hidden_secs(&self, cfg: &WriteConfig, size: usize, blocks: usize) -> f64 {
        self.gated_secs(cfg, size, blocks).1
    }

    /// Hash/transfer composition for one file, without per-file/block
    /// overheads: `(gated seconds, hash seconds hidden)`.  Single source
    /// of truth for the serial-vs-pipelined choice, so write_secs and
    /// hash_hidden_secs cannot diverge.
    fn gated_secs(&self, cfg: &WriteConfig, size: usize, blocks: usize) -> (f64, f64) {
        let hash = self.hash_secs(cfg, size);
        let net = self.net_secs_pipelined(cfg, size, blocks);
        let xfer = net.max(size as f64 / self.memcpy_bps);
        match cfg.engine {
            // Async digest submission: hash of buffer N overlaps the
            // transfer of buffer N-1.
            EngineModel::Gpu { .. } => {
                let jobs = size.div_ceil(cfg.write_buffer).max(1);
                let gated = pipelined_secs(hash, xfer, jobs);
                (gated, hash + xfer - gated)
            }
            // Sync engines (and no-op hashing): hash fully exposed.
            _ => (hash + xfer, 0.0),
        }
    }

    /// Write throughput (application bytes per second) for a stream of
    /// `files` equal writes.
    pub fn write_bps(&self, cfg: &WriteConfig, size: usize, blocks: usize, files: usize) -> f64 {
        let t = self.write_secs(cfg, size, blocks) * files as f64;
        (size * files) as f64 / t
    }
}

/// Two-stage software pipeline over `jobs` equal buffers: stage A
/// (hashing, `hash` seconds total) feeds stage B (transfer, `xfer`
/// seconds total).  Fill with one buffer's hash, run `jobs - 1` steady
/// cycles at the bottleneck stage, drain with one buffer's transfer:
/// `h + (jobs-1)·max(h, t) + t`.  Degenerates to `hash + xfer` for a
/// single buffer and is bounded by `max(hash, xfer) ≤ result ≤
/// hash + xfer` — the overlap algebra crystal's stager/executor split
/// realizes in wall-clock.
pub fn pipelined_secs(hash: f64, xfer: f64, jobs: usize) -> f64 {
    let n = jobs.max(1) as f64;
    let h = hash / n;
    let t = xfer / n;
    h + (n - 1.0) * h.max(t) + t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_algebra_bounds() {
        // One job: fully serialized.
        assert!((pipelined_secs(2.0, 3.0, 1) - 5.0).abs() < 1e-12);
        // Many jobs, transfer-bound: hash almost fully hidden.
        let t = pipelined_secs(1.0, 10.0, 10);
        assert!(t < 11.0 && t >= 10.0, "{t}");
        // Many jobs, hash-bound: transfer almost fully hidden.
        let t = pipelined_secs(10.0, 1.0, 10);
        assert!(t < 11.0 && t >= 10.0, "{t}");
        // Always within [max, sum].
        for (h, x, j) in [(0.0, 5.0, 4), (5.0, 0.0, 4), (3.0, 4.0, 7)] {
            let p = pipelined_secs(h, x, j);
            assert!(p >= h.max(x) - 1e-12 && p <= h + x + 1e-12, "{h} {x} {j}");
        }
    }

    #[test]
    fn gpu_write_overlap_bounded_by_serial() {
        let s = SystemSim::default();
        let c = cfg(EngineModel::Gpu { opts: GpuOpts::OVERLAP }, true, 0.0);
        let hash = s.hash_secs(&c, MB64);
        let net = s.net_secs(&c, MB64);
        let copy = MB64 as f64 / s.memcpy_bps;
        let overhead = s.per_file_overhead
            + s.per_lease_overhead
            + blocks_for(MB64) as f64 * s.per_block_overhead;
        let w = s.write_secs(&c, MB64, blocks_for(MB64));
        // Pipelined write is never faster than the bottleneck stage and
        // never slower than the old fully-serialized composition.
        assert!(w >= hash.max(net.max(copy)) + overhead - 1e-9);
        assert!(w <= hash + net.max(copy) + overhead + 1e-9);
        // And the hidden-hash accounting is the difference to serial.
        let hidden = s.hash_hidden_secs(&c, MB64, blocks_for(MB64));
        assert!(hidden >= 0.0);
        assert!((hash + net.max(copy) + overhead - hidden - w).abs() < 1e-9);
    }

    fn cfg(engine: EngineModel, cdc: bool, similarity: f64) -> WriteConfig {
        WriteConfig {
            engine,
            cdc,
            similarity,
            ..WriteConfig::default()
        }
    }

    #[test]
    fn lease_overhead_is_additive_per_file() {
        // The lease round-trips are a constant per-file cost on top of
        // the v2 model: zeroing them recovers the old write time
        // exactly, and the delta never depends on file size.
        let mut with = SystemSim::default();
        let mut without = SystemSim::default();
        without.per_lease_overhead = 0.0;
        with.per_lease_overhead = 0.5e-3;
        let c = cfg(EngineModel::Cpu { threads: 16 }, false, 0.0);
        for size in [1 << 20, MB64] {
            let d = with.write_secs(&c, size, 64) - without.write_secs(&c, size, 64);
            assert!((d - 0.5e-3).abs() < 1e-12, "size {size}: delta {d}");
        }
        // And it does not perturb the hidden-hash accounting.
        assert_eq!(
            with.hash_hidden_secs(&c, MB64, 64),
            without.hash_hidden_secs(&c, MB64, 64)
        );
    }

    #[test]
    fn wal_overhead_is_additive_per_commit() {
        // Durability is one group-commit window on the commit reply: a
        // constant per-file cost, independent of size and block count,
        // and zero by default (pre-durability figures stay
        // bit-identical).
        let without = SystemSim::default();
        assert_eq!(without.per_commit_wal_overhead, 0.0);
        let with = SystemSim {
            per_commit_wal_overhead: 5e-3, // the default --wal-sync window
            ..SystemSim::default()
        };
        let c = cfg(EngineModel::Cpu { threads: 16 }, false, 0.0);
        for (size, blocks) in [(1 << 20, 1), (MB64, 64), (MB64, 1024)] {
            let d = with.write_secs(&c, size, blocks) - without.write_secs(&c, size, blocks);
            assert!((d - 5e-3).abs() < 1e-12, "size {size}: delta {d}");
        }
        // And it does not perturb the hidden-hash accounting.
        assert_eq!(
            with.hash_hidden_secs(&c, MB64, 64),
            without.hash_hidden_secs(&c, MB64, 64)
        );
    }

    #[test]
    fn serve_overhead_defaults_to_zero_and_is_additive() {
        // The serve-loop dispatch knob is off by default, so every
        // pre-PR-9 figure is bit-identical; turned on, it adds exactly
        // three dispatches per file (open, alloc, commit) regardless of
        // size or block count, and never perturbs hidden-hash
        // accounting.
        let without = SystemSim::default();
        assert_eq!(without.per_request_serve_overhead, 0.0);
        let with = SystemSim {
            per_request_serve_overhead: 20e-6, // ~one queue handoff
            ..SystemSim::default()
        };
        let c = cfg(EngineModel::Cpu { threads: 16 }, false, 0.0);
        for (size, blocks) in [(1 << 20, 1), (MB64, 64), (MB64, 1024)] {
            let d = with.write_secs(&c, size, blocks) - without.write_secs(&c, size, blocks);
            assert!((d - 3.0 * 20e-6).abs() < 1e-12, "size {size}: delta {d}");
        }
        assert_eq!(
            with.hash_hidden_secs(&c, MB64, 64),
            without.hash_hidden_secs(&c, MB64, 64)
        );
    }

    #[test]
    fn ec_encode_overhead_defaults_to_zero_and_is_additive() {
        // Erasure encoding is a per-new-byte client cost serialized in
        // front of transfer: off by default (every pre-PR-10 figure is
        // bit-identical), and turned on it adds exactly
        // `new_bytes * knob` seconds for any size and block count,
        // never perturbing the hidden-hash accounting.
        let base = cfg(EngineModel::Cpu { threads: 16 }, false, 0.5);
        assert_eq!(base.ec_encode_overhead, 0.0);
        let with = WriteConfig {
            ec_encode_overhead: 2e-9, // ~500 MB/s GF(256) encode
            ..base
        };
        let s = SystemSim::default();
        for (size, blocks) in [(1 << 20, 1), (MB64, 64), (MB64, 1024)] {
            let d = s.write_secs(&with, size, blocks) - s.write_secs(&base, size, blocks);
            let want = size as f64 * (1.0 - base.similarity) * 2e-9;
            assert!((d - want).abs() < 1e-12, "size {size}: delta {d}");
        }
        assert_eq!(
            s.hash_hidden_secs(&with, MB64, 64),
            s.hash_hidden_secs(&base, MB64, 64)
        );
        // Fully-deduplicated writes encode nothing.
        let similar = WriteConfig {
            similarity: 1.0,
            ..with
        };
        let similar_base = WriteConfig {
            similarity: 1.0,
            ..base
        };
        assert_eq!(
            s.write_secs(&similar, MB64, 64),
            s.write_secs(&similar_base, MB64, 64)
        );
    }

    #[test]
    fn repair_secs_budget_and_fixed_cost() {
        // Time-to-restored-redundancy: fixed per-repair control-plane
        // cost plus wire time at the configured budget.
        let s = SystemSim {
            per_repair_overhead: 1e-3,
            ..SystemSim::default()
        };
        assert_eq!(SystemSim::default().per_repair_overhead, 0.0);
        let unthrottled = s.repair_secs(4, MB64, 0.0);
        let want = 4.0 * 1e-3 + MB64 as f64 / s.net_bps;
        assert!((unthrottled - want).abs() < 1e-9, "{unthrottled}");
        // A 100 Mbit/s budget repairs at 12.5 MB/s — slower than the
        // full link, never faster.
        let budgeted = s.repair_secs(4, MB64, 100.0);
        let want = 4.0 * 1e-3 + MB64 as f64 / 12.5e6;
        assert!((budgeted - want).abs() < 1e-9, "{budgeted}");
        assert!(budgeted > unthrottled);
        // A budget above the link rate clamps to the link.
        assert_eq!(s.repair_secs(4, MB64, 1e6), unthrottled);
    }

    #[test]
    fn depth_ablation_lock_step_is_rtt_bound() {
        // Small blocks against a realistic fabric RTT: the lock-step
        // data plane (depth 1) pays `rtt` per block and loses to the
        // pipelined one; a modest depth recovers the wire limit.
        let s = SystemSim {
            net_rtt: 0.5e-3,
            ..SystemSim::default()
        };
        let blocks = 1024; // 64 KB blocks of a 64 MB file
        let lockstep = WriteConfig {
            inflight_depth: 1,
            ..cfg(EngineModel::None, false, 0.0)
        };
        let deep = WriteConfig {
            inflight_depth: 8,
            ..lockstep
        };
        let t1 = s.write_secs(&lockstep, MB64, blocks);
        let t8 = s.write_secs(&deep, MB64, blocks);
        assert!(t1 > 1.5 * t8, "lock-step {t1:.3}s vs depth-8 {t8:.3}s");
        // Depth only ever helps, and never beats the pure wire time.
        assert!(
            s.net_secs_pipelined(&deep, MB64, blocks) >= s.net_secs(&deep, MB64) - 1e-12
        );
        // Fully-dedup'd writes transfer nothing at any depth.
        let similar = WriteConfig {
            similarity: 1.0,
            ..lockstep
        };
        assert_eq!(s.net_secs_pipelined(&similar, MB64, blocks), 0.0);
    }

    #[test]
    fn replication_scales_transfer_time() {
        let s = SystemSim::default();
        let c1 = cfg(EngineModel::None, false, 0.0);
        let c2 = WriteConfig { replication: 2, ..c1 };
        assert!((s.net_secs(&c2, MB64) - 2.0 * s.net_secs(&c1, MB64)).abs() < 1e-12);
        // Fully deduplicated writes transfer nothing regardless of r.
        let d2 = WriteConfig { similarity: 1.0, ..c2 };
        assert_eq!(s.net_secs(&d2, MB64), 0.0);
        assert!(s.write_secs(&c2, MB64, 64) > s.write_secs(&c1, MB64, 64));
    }

    fn blocks_for(size: usize) -> usize {
        size / (1 << 20)
    }

    const MB64: usize = 64 << 20;

    #[test]
    fn fig7_different_nonca_wins_fixed() {
        // With zero similarity, hashing is pure overhead: non-CA >= CA.
        let s = SystemSim::default();
        let non = s.write_bps(&cfg(EngineModel::None, false, 0.0), MB64, blocks_for(MB64), 10);
        let cpu = s.write_bps(
            &cfg(EngineModel::Cpu { threads: 16 }, false, 0.0),
            MB64,
            blocks_for(MB64),
            10,
        );
        let gpu = s.write_bps(
            &cfg(EngineModel::Gpu { opts: GpuOpts::OVERLAP }, false, 0.0),
            MB64,
            blocks_for(MB64),
            10,
        );
        assert!(non >= cpu && non >= gpu);
        // GPU tracks non-CA closely (hash hidden behind the network).
        assert!(gpu > 0.9 * non, "gpu {gpu:.2e} vs non {non:.2e}");
    }

    #[test]
    fn fig8_cdc_on_cpu_is_the_bottleneck() {
        // Paper: dual-CPU CDC capped ~46 MBps << 1 Gbps network.
        let s = SystemSim::default();
        let bps = s.write_bps(
            &cfg(EngineModel::Cpu { threads: 16 }, true, 0.0),
            MB64,
            blocks_for(MB64),
            10,
        );
        let mbps = bps / (1024.0 * 1024.0);
        assert!(mbps < 120.0, "CDC-CPU {mbps} MBps should be < network");
        // And far below what the GPU config reaches.
        let gpu = s.write_bps(
            &cfg(EngineModel::Gpu { opts: GpuOpts::OVERLAP }, true, 0.0),
            MB64,
            blocks_for(MB64),
            10,
        );
        assert!(gpu > 2.0 * bps);
    }

    #[test]
    fn fig9_similar_fixed_gpu_doubles_cpu() {
        // Paper: CA-GPU > 2x CA-CPU for similar workload, >= 64 MB files.
        let s = SystemSim::default();
        let cpu = s.write_bps(
            &cfg(EngineModel::Cpu { threads: 16 }, false, 1.0),
            MB64,
            blocks_for(MB64),
            10,
        );
        let gpu = s.write_bps(
            &cfg(EngineModel::Gpu { opts: GpuOpts::OVERLAP }, false, 1.0),
            MB64,
            blocks_for(MB64),
            10,
        );
        let inf = s.write_bps(&cfg(EngineModel::Infinite, false, 1.0), MB64, blocks_for(MB64), 10);
        // Paper claims "over two times"; our model lands at ~1.4x (the
        // modeled client data path floors both configs) — the ordering
        // and the near-optimality claim are the shape that matters.
        assert!(gpu > 1.3 * cpu, "gpu {gpu:.2e} cpu {cpu:.2e}");
        assert!(gpu > 0.8 * inf, "CA-GPU almost equivalent to optimal");
    }

    #[test]
    fn fig10_similar_cdc_gpu_beats_cpu_4x_and_nears_oracle() {
        let s = SystemSim::default();
        let cpu = s.write_bps(
            &cfg(EngineModel::Cpu { threads: 16 }, true, 1.0),
            MB64,
            blocks_for(MB64),
            10,
        );
        let gpu = s.write_bps(
            &cfg(EngineModel::Gpu { opts: GpuOpts::OVERLAP }, true, 1.0),
            MB64,
            blocks_for(MB64),
            10,
        );
        let inf = s.write_bps(&cfg(EngineModel::Infinite, true, 1.0), MB64, blocks_for(MB64), 10);
        let non = s.write_bps(&cfg(EngineModel::None, true, 0.0), MB64, blocks_for(MB64), 10);
        // Paper: CDC-CPU caps at 46-49 MBps.
        let cpu_mbps = cpu / (1024.0 * 1024.0);
        assert!((25.0..70.0).contains(&cpu_mbps), "cdc-cpu {cpu_mbps} MBps");
        assert!(gpu > 4.0 * cpu, "gpu/cpu {}", gpu / cpu);
        assert!(gpu > 2.0 * non, "gpu/non {}", gpu / non);
        assert!(gpu > 0.75 * inf, "within 25% of CA-Infinite for large files");
    }

    #[test]
    fn small_similar_files_gap_to_oracle_larger() {
        // Paper §4.4: loss vs CA-Infinite < 50 % for < 16 MB files.
        let s = SystemSim::default();
        let size = 8 << 20;
        let gpu = s.write_bps(
            &cfg(EngineModel::Gpu { opts: GpuOpts::OVERLAP }, true, 1.0),
            size,
            8,
            10,
        );
        let inf = s.write_bps(&cfg(EngineModel::Infinite, true, 1.0), size, 8, 10);
        let ratio = gpu / inf;
        assert!(
            (0.4..1.0).contains(&ratio),
            "gpu/infinite ratio {ratio}"
        );
    }

    #[test]
    fn fig11_checkpoint_ordering() {
        // CDC-GPU > fixed-GPU > fixed-CPU; CDC-CPU worst.
        let s = SystemSim::default();
        let size = 64 << 20;
        let b = blocks_for(size);
        // Paper similarity bands at ~1 MB blocks: fixed 22 %, CDC 82 %.
        let cdc_gpu = s.write_bps(
            &cfg(EngineModel::Gpu { opts: GpuOpts::OVERLAP }, true, 0.82),
            size, b, 10,
        );
        let cdc_cpu = s.write_bps(
            &cfg(EngineModel::Cpu { threads: 16 }, true, 0.82),
            size, b, 10,
        );
        let fix_gpu = s.write_bps(
            &cfg(EngineModel::Gpu { opts: GpuOpts::OVERLAP }, false, 0.22),
            size, b, 10,
        );
        let fix_cpu = s.write_bps(
            &cfg(EngineModel::Cpu { threads: 16 }, false, 0.22),
            size, b, 10,
        );
        let non = s.write_bps(&cfg(EngineModel::None, false, 0.0), size, b, 10);
        assert!(cdc_gpu > fix_gpu, "cdc-gpu {cdc_gpu:.2e} fix-gpu {fix_gpu:.2e}");
        assert!(fix_gpu >= fix_cpu * 0.99);
        assert!(cdc_cpu < fix_cpu, "cdc-cpu is the worst CA config");
        assert!(cdc_gpu > 1.5 * non, "dedup pays off vs non-CA");
        // Paper: CDC-GPU up to 5x CDC-CPU.
        assert!(cdc_gpu > 3.0 * cdc_cpu, "ratio {}", cdc_gpu / cdc_cpu);
    }
}
