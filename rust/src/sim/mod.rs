//! sim — the performance model that regenerates the paper's figures.
//!
//! We cannot time a 2010 GTX 480 + 22-node 1 Gbps cluster in wall-clock;
//! instead the figure harnesses combine
//!
//! * **measured functional behaviour** from the real implementation
//!   (chunk layouts, dedup ratios from the actual chunker/workloads), and
//! * **modeled stage timing** from [`crate::crystal::model`]
//!   (calibrated to the paper's anchor numbers, DESIGN.md §Substitutions),
//!
//! composed through the same pipeline structure the real crystal/SAI
//! code uses.  The CrystalGPU optimization *gains* (buffer reuse,
//! overlap, dual-GPU) are emergent from the pipeline algebra, not
//! hard-coded; the workloads are deterministic back-to-back streams, so
//! closed-form pipeline composition is exact (no event queue needed).

pub mod contention;
pub mod gpu;
pub mod write;

pub use contention::{CompetitorKind, ContentionModel};
pub use gpu::{GpuOpts, GpuPipeline};
pub use write::{pipelined_secs, EngineModel, SystemSim, WriteConfig};
