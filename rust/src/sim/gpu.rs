//! GPU stream-pipeline timing: how long a stream of hashing jobs takes
//! on the modeled device(s) under each CrystalGPU optimization level —
//! the engine behind Figures 4, 5 and 6.

use crate::crystal::model::DeviceModel;
use crate::metrics::Stage;

/// Optimization toggles (the paper's ladder in Figs 5/6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GpuOpts {
    /// Reuse pinned staging buffers (skip per-job allocation, pinned DMA).
    pub buffer_reuse: bool,
    /// Overlap transfers with kernels across the job stream.
    pub overlap: bool,
    /// Use the second device (GTX 480 + Tesla C2050, round-robin).
    pub dual_gpu: bool,
}

impl GpuOpts {
    /// HashGPU alone (paper's unoptimized baseline).
    pub const ALONE: GpuOpts = GpuOpts {
        buffer_reuse: false,
        overlap: false,
        dual_gpu: false,
    };
    /// + buffer reuse.
    pub const REUSE: GpuOpts = GpuOpts {
        buffer_reuse: true,
        overlap: false,
        dual_gpu: false,
    };
    /// + overlap (the full single-GPU CrystalGPU stack).
    pub const OVERLAP: GpuOpts = GpuOpts {
        buffer_reuse: true,
        overlap: true,
        dual_gpu: false,
    };
    /// + second GPU.
    pub const DUAL: GpuOpts = GpuOpts {
        buffer_reuse: true,
        overlap: true,
        dual_gpu: true,
    };
}

/// Per-job stage seconds on one device.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageSecs {
    /// Stage 1: pinned allocation (zero when buffers are reused).
    pub alloc: f64,
    /// Stage 2: host->device copy.
    pub h2d: f64,
    /// Stage 3: kernel.
    pub kernel: f64,
    /// Stage 4: device->host copy.
    pub d2h: f64,
    /// Stage 5: host post-processing (boundary scan / final hash).
    pub post: f64,
}

impl StageSecs {
    /// Serial total.
    pub fn total(&self) -> f64 {
        self.alloc + self.h2d + self.kernel + self.d2h + self.post
    }

    /// Largest pipelineable stage (alloc is gone under reuse; post runs
    /// on the host concurrently with the next job's device stages).
    pub fn bottleneck(&self) -> f64 {
        self.h2d.max(self.kernel).max(self.d2h).max(self.post)
    }

    /// Stage fractions of the serial total, in paper-Table-1 order.
    pub fn fractions(&self) -> [(Stage, f64); 5] {
        let t = self.total().max(1e-30);
        [
            (Stage::Preprocess, self.alloc / t),
            (Stage::CopyIn, self.h2d / t),
            (Stage::Kernel, self.kernel / t),
            (Stage::CopyOut, self.d2h / t),
            (Stage::Postprocess, self.post / t),
        ]
    }
}

/// Stream-of-jobs pipeline over one or two modeled devices.
#[derive(Debug, Clone)]
pub struct GpuPipeline {
    /// Primary device (GTX 480).
    pub dev0: DeviceModel,
    /// Secondary device (Tesla C2050), used when `dual_gpu`.
    pub dev1: DeviceModel,
    /// Host scan rate over returned window hashes (B/s of *hash* data;
    /// sliding-window stage 5 scans 4 B per input byte).
    pub scan_bps: f64,
    /// Host hash-of-hashes rate for direct hashing, expressed per input
    /// byte (digests are 16 B per 4 KB segment, so this is huge).
    pub direct_post_bps: f64,
}

impl Default for GpuPipeline {
    fn default() -> Self {
        GpuPipeline {
            dev0: DeviceModel::gtx480(),
            dev1: DeviceModel::tesla_c2050(),
            scan_bps: 10e9,
            direct_post_bps: 4e10,
        }
    }
}

impl GpuPipeline {
    /// Per-job stage seconds for a `bytes` job on `dev`.
    pub fn stages(&self, dev: &DeviceModel, sliding: bool, bytes: usize, opts: GpuOpts) -> StageSecs {
        let post = if sliding {
            bytes as f64 * dev.sliding_out_ratio / self.scan_bps
        } else {
            bytes as f64 / self.direct_post_bps
        };
        StageSecs {
            alloc: if opts.buffer_reuse {
                0.0
            } else {
                dev.alloc_secs_op(sliding, bytes)
            },
            h2d: dev.h2d_secs(bytes, opts.buffer_reuse),
            kernel: dev.kernel_secs(sliding, bytes),
            d2h: dev.d2h_secs(sliding, bytes),
            post,
        }
    }

    /// Seconds for a stream of `jobs` jobs of `bytes` each on one device.
    fn stream_one(&self, dev: &DeviceModel, sliding: bool, bytes: usize, jobs: usize, opts: GpuOpts) -> f64 {
        if jobs == 0 {
            return 0.0;
        }
        let s = self.stages(dev, sliding, bytes, opts);
        if opts.overlap {
            // Fill + steady state at the bottleneck stage.
            s.total() + (jobs - 1) as f64 * s.bottleneck()
        } else {
            jobs as f64 * s.total()
        }
    }

    /// Seconds for a stream of `jobs` jobs of `bytes` each under `opts`.
    /// Dual-GPU splits the stream round-robin (the paper's scheme).
    pub fn stream_secs(&self, sliding: bool, bytes: usize, jobs: usize, opts: GpuOpts) -> f64 {
        if opts.dual_gpu {
            let j0 = jobs.div_ceil(2);
            let j1 = jobs / 2;
            self.stream_one(&self.dev0, sliding, bytes, j0, opts)
                .max(self.stream_one(&self.dev1, sliding, bytes, j1, opts))
        } else {
            self.stream_one(&self.dev0, sliding, bytes, jobs, opts)
        }
    }

    /// Throughput (input B/s) for the standard 10-job stream.
    pub fn stream_bps(&self, sliding: bool, bytes: usize, opts: GpuOpts) -> f64 {
        let jobs = 10;
        (bytes * jobs) as f64 / self.stream_secs(sliding, bytes, jobs, opts)
    }

    /// Shared-hash-service mirror (PR 6): `sessions` concurrent clients
    /// each stream `jobs` jobs of `bytes`, submitted through a service
    /// that coalesces up to `batch` jobs into one device job and holds
    /// an under-filled batch back at most `linger_secs` (the
    /// `hash_batch` / `hash_linger_us` knobs).
    ///
    /// With one submission in flight per session, the queue depth the
    /// flush sees is the session count, so batches dispatch at
    /// `min(batch, sessions)` deep; a batch that reaches the depth
    /// bound flushes immediately, while shallower ones are released by
    /// the linger timer (modeled as exposed wait per dispatched batch —
    /// conservative, since a busy device hides part of it).
    ///
    /// `batch == 1` degenerates to exactly [`GpuPipeline::stream_secs`]
    /// on the per-session stream — the calibrated figures are
    /// reproduced bit-identically when the service is configured off.
    #[allow(clippy::too_many_arguments)]
    pub fn shared_stream_secs(
        &self,
        sliding: bool,
        bytes: usize,
        sessions: usize,
        jobs: usize,
        batch: usize,
        linger_secs: f64,
        opts: GpuOpts,
    ) -> f64 {
        let total = sessions * jobs;
        if total == 0 {
            return 0.0;
        }
        let depth = batch.min(sessions).max(1);
        let dev_jobs = total.div_ceil(depth);
        let base = self.stream_secs(sliding, bytes * depth, dev_jobs, opts);
        if depth < batch {
            base + linger_secs * dev_jobs as f64
        } else {
            base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimization_ladder_is_monotonic() {
        let p = GpuPipeline::default();
        for sliding in [true, false] {
            for bytes in [1 << 20, 16 << 20, 64 << 20] {
                let alone = p.stream_bps(sliding, bytes, GpuOpts::ALONE);
                let reuse = p.stream_bps(sliding, bytes, GpuOpts::REUSE);
                let over = p.stream_bps(sliding, bytes, GpuOpts::OVERLAP);
                let dual = p.stream_bps(sliding, bytes, GpuOpts::DUAL);
                assert!(
                    alone < reuse && reuse < over && over < dual,
                    "ladder violated at sliding={sliding} bytes={bytes}: \
                     {alone:.2e} {reuse:.2e} {over:.2e} {dual:.2e}"
                );
            }
        }
    }

    #[test]
    fn fig4_alloc_copyin_share_grows_large() {
        // Unoptimized sliding-window: alloc+copy-in dominates (80-96 %).
        let p = GpuPipeline::default();
        let s = p.stages(&p.dev0, true, 64 << 20, GpuOpts::ALONE);
        let f = s.fractions();
        let share = f[0].1 + f[1].1;
        assert!(share > 0.7, "share {share}");
    }

    #[test]
    fn fig5_single_gpu_speedup_band() {
        // Fully-optimized single GPU vs one CPU core: paper ~125x for
        // large sliding-window blocks.
        let p = GpuPipeline::default();
        let cpu = crate::crystal::model::CpuModel::xeon_2008();
        let bytes = 64 << 20;
        let gpu_bps = p.stream_bps(true, bytes, GpuOpts::OVERLAP);
        let speedup = gpu_bps / cpu.scaled_bps(cpu.window_md5_bps, 1);
        assert!(
            (80.0..200.0).contains(&speedup),
            "sliding speedup {speedup}"
        );
    }

    #[test]
    fn fig6_direct_speedup_band() {
        // Paper: ~28x single-GPU direct hashing vs one core.
        let p = GpuPipeline::default();
        let cpu = crate::crystal::model::CpuModel::xeon_2008();
        let bytes = 64 << 20;
        let gpu_bps = p.stream_bps(false, bytes, GpuOpts::OVERLAP);
        let speedup = gpu_bps / cpu.scaled_bps(cpu.md5_bps, 1);
        assert!((15.0..45.0).contains(&speedup), "direct speedup {speedup}");
    }

    #[test]
    fn small_blocks_slower_than_cpu() {
        // Fig 5: below ~64 KB the unoptimized GPU loses to the CPU.
        let p = GpuPipeline::default();
        let cpu = crate::crystal::model::CpuModel::xeon_2008();
        let bytes = 4 << 10;
        let gpu_bps = p.stream_bps(true, bytes, GpuOpts::ALONE);
        assert!(gpu_bps < cpu.scaled_bps(cpu.window_md5_bps, 1));
    }

    #[test]
    fn dual_gpu_sublinear() {
        // Round-robin over asymmetric devices: > 1.2x, < 2x.
        let p = GpuPipeline::default();
        let b = 64 << 20;
        let one = p.stream_bps(true, b, GpuOpts::OVERLAP);
        let two = p.stream_bps(true, b, GpuOpts::DUAL);
        let gain = two / one;
        assert!((1.2..2.0).contains(&gain), "dual gain {gain}");
    }

    #[test]
    fn zero_jobs_zero_time() {
        let p = GpuPipeline::default();
        assert_eq!(p.stream_secs(true, 1 << 20, 0, GpuOpts::DUAL), 0.0);
        assert_eq!(
            p.shared_stream_secs(true, 1 << 20, 4, 0, 64, 200e-6, GpuOpts::DUAL),
            0.0
        );
    }

    #[test]
    fn shared_batch_one_is_identity() {
        // batch == 1 must reproduce the per-session stream exactly
        // (bit-identical), whatever the linger: the calibrated figure
        // benches are untouched by the service model.
        let p = GpuPipeline::default();
        for sliding in [true, false] {
            for (sessions, jobs) in [(1, 10), (4, 3), (16, 2)] {
                assert_eq!(
                    p.shared_stream_secs(
                        sliding,
                        1 << 20,
                        sessions,
                        jobs,
                        1,
                        200e-6,
                        GpuOpts::OVERLAP
                    ),
                    p.stream_secs(sliding, 1 << 20, sessions * jobs, GpuOpts::OVERLAP)
                );
            }
        }
    }

    #[test]
    fn shared_service_beats_per_session_at_16() {
        // 16 sessions of small (64 KB) jobs on one shared device: the
        // coalesced batches amortize per-job launch/staging overhead
        // that per-session shallow submissions pay in full.
        let p = GpuPipeline::default();
        let (bytes, sessions, jobs) = (64 << 10, 16, 8);
        let per_session = p.stream_secs(false, bytes, sessions * jobs, GpuOpts::OVERLAP);
        let shared =
            p.shared_stream_secs(false, bytes, sessions, jobs, 64, 200e-6, GpuOpts::OVERLAP);
        assert!(
            shared < per_session,
            "shared {shared:.6} >= per-session {per_session:.6}"
        );
    }

    #[test]
    fn shared_deeper_batch_is_monotonic() {
        // More coalescing never hurts (at fixed tiny linger): each
        // doubling of the batch bound amortizes more per-job overhead.
        let p = GpuPipeline::default();
        let (bytes, sessions, jobs) = (64 << 10, 16, 8);
        let t = |batch| {
            p.shared_stream_secs(false, bytes, sessions, jobs, batch, 50e-6, GpuOpts::OVERLAP)
        };
        assert!(t(16) <= t(4) && t(4) <= t(1), "{} {} {}", t(16), t(4), t(1));
    }
}
