//! gpustore CLI — launcher for the distributed storage system.
//!
//! Components (multi-process deployment, control-plane v2: nodes join
//! the manager and clients bootstrap from the manager alone):
//!   gpustore manager --listen 0.0.0.0:7070 [--replication 2]
//!   gpustore node    --listen 0.0.0.0:7071 --manager H:7070 [--advertise H:7071]
//!   gpustore write   --manager H:P --mode cdc --engine gpu \
//!                    --file f --size 64M --count 10
//!   gpustore read    --manager H:P --file f --out path
//!   gpustore demo    (single-process cluster + one write/read cycle)
//!
//! Benchmarks regenerating the paper's figures live in `cargo bench`
//! (rust/benches/figures.rs); runnable scenarios in examples/.

use std::collections::HashMap;
use std::io::{Read as _, Write as _};
use std::time::Duration;

use gpustore::config::{CaMode, ClientConfig, ClusterConfig, HashEngineKind, Placement, ServeMode};
use gpustore::hashsvc::session_engine;
use gpustore::net::Listener;
use gpustore::store::manager::DEFAULT_LEASE_TIMEOUT;
use gpustore::store::proto::MAX_REPLICAS;
use gpustore::store::{
    policy_for, Cluster, ErasureCoded, Follower, Manager, ManagerState, NodeOpts, PlacementPolicy,
    Sai, StorageNode,
};
use gpustore::util::{human_bytes, Rng};
use gpustore::wal::DurabilityOpts;
use gpustore::{Error, Result};

/// Application-side streaming granularity for the CLI's writes: the
/// session API re-buffers internally, so this only shapes how the CLI
/// feeds data in (like an app issuing 1 MB `write(2)` calls).
const CLI_IO_CHUNK: usize = 1 << 20;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "manager" => cmd_manager(&flags),
        "node" => cmd_node(&flags),
        "write" => cmd_write(&flags),
        "read" => cmd_read(&flags),
        "verify" => cmd_verify(&flags),
        "ls" => cmd_ls(&flags),
        "trace" => cmd_trace(&flags),
        "demo" => cmd_demo(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(Error::Config(format!("unknown command `{other}`"))),
    }
}

fn print_usage() {
    println!(
        "gpustore — GPU-accelerated content-addressable storage \
         (TPDS'12 reproduction)\n\n\
         USAGE:\n  gpustore manager --listen ADDR [--replication N] [--lease-timeout SECS]\n\
         \x20                [--placement rr|rep:R|ec:K,M]\n\
         \x20                [--scrub-interval SECS [--repair-mbps MBPS]]\n\
         \x20                [--serve-threads N]\n\
         \x20                [--data-dir DIR [--wal-sync MS] [--snapshot-every N]]\n\
         \x20                [--peers A,B[,..] [--advertise ADDR] [--initial-leader]]\n\
         \x20                [--follow ADDR [--peers A,B[,..]]]\n  \
         gpustore node --listen ADDR --manager ADDR [--advertise ADDR] [--disk DIR]\n\
         \x20             [--serve-threads N]\n  \
         gpustore write --manager ADDR [--mode fixed|cdc|none]\n\
         \x20                [--engine cpu|gpu|oracle] [--threads N]\n\
         \x20                [--inflight-mb MB] [--node-inflight N]\n\
         \x20                [--hash-batch N] [--hash-linger-us US] [--hash-devices N]\n\
         \x20                [--file NAME] [--size BYTES|K|M|G] [--count N] [--seed N]\n\
         \x20                [--verbose]\n  \
         gpustore read --manager ADDR --file NAME [--out PATH]\n  \
         gpustore verify --manager ADDR --file NAME\n  \
         gpustore ls --manager ADDR\n  \
         gpustore trace --manager ADDR --trace FILE [--seed N]\n  \
         gpustore demo [--replication N] [--placement rr|rep:R|ec:K,M]\n\
         \x20             [--scrub-interval SECS [--repair-mbps MBPS]]\n\
         \x20             [--lease-timeout SECS] [--data-dir DIR]\n\
         \x20             [--hash-batch N] [--hash-linger-us US] [--hash-devices N]\n\
         \x20             [--serve-threads N] [--verbose]\n\n\
         Nodes register with the manager; clients discover them from it\n\
         (no --nodes flag).  `make artifacts` must have produced\n\
         artifacts/ for --engine gpu."
    );
}

fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        let Some(key) = a.strip_prefix("--") else {
            return Err(Error::Config(format!("unexpected argument `{a}`")));
        };
        let val = args
            .get(i + 1)
            .filter(|v| !v.starts_with("--"))
            .cloned()
            .unwrap_or_else(|| "true".into());
        let next_is_flag = args.get(i + 1).map(|v| v.starts_with("--")).unwrap_or(true);
        let consumed = if val == "true" && next_is_flag { 1 } else { 2 };
        m.insert(key.to_string(), val);
        i += consumed;
    }
    Ok(m)
}

fn parse_size(s: &str) -> Result<usize> {
    let s = s.trim();
    let (num, mult) = match s.chars().last() {
        Some('K') | Some('k') => (&s[..s.len() - 1], 1024),
        Some('M') | Some('m') => (&s[..s.len() - 1], 1024 * 1024),
        Some('G') | Some('g') => (&s[..s.len() - 1], 1024 * 1024 * 1024),
        _ => (s, 1),
    };
    num.parse::<usize>()
        .map(|n| n * mult)
        .map_err(|_| Error::Config(format!("bad size `{s}`")))
}

fn client_config(flags: &HashMap<String, String>) -> Result<ClientConfig> {
    let mode = match flags.get("mode").map(String::as_str).unwrap_or("fixed") {
        "fixed" => CaMode::Fixed,
        "cdc" | "cbc" => CaMode::Cdc,
        "none" | "non-ca" => CaMode::None,
        m => return Err(Error::Config(format!("bad --mode `{m}`"))),
    };
    let threads: usize = flags
        .get("threads")
        .map(|t| t.parse().unwrap_or(1))
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        });
    let engine = match flags.get("engine").map(String::as_str).unwrap_or("cpu") {
        "cpu" => HashEngineKind::Cpu { threads },
        "gpu" => HashEngineKind::gpu_default(),
        "oracle" | "infinite" => HashEngineKind::Oracle,
        e => return Err(Error::Config(format!("bad --engine `{e}`"))),
    };
    let mut cfg = ClientConfig {
        ca_mode: mode,
        engine,
        ..ClientConfig::default()
    };
    // Data-plane knobs: the per-session in-flight-bytes budget and the
    // per-node pipeline depth.  Parsed strictly — a malformed value
    // must fail loudly, not silently run with a default.
    if let Some(v) = flags.get("inflight-mb") {
        cfg.inflight_budget = match v
            .parse::<usize>()
            .ok()
            .filter(|&mb| mb >= 1)
            .and_then(|mb| mb.checked_mul(1024 * 1024))
        {
            Some(bytes) => bytes,
            None => {
                return Err(Error::Config(format!(
                    "bad --inflight-mb `{v}` (need an integer >= 1, in-range)"
                )))
            }
        };
    }
    if let Some(v) = flags.get("node-inflight") {
        cfg.node_inflight = match v.parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                return Err(Error::Config(format!(
                    "bad --node-inflight `{v}` (need an integer >= 1)"
                )))
            }
        };
    }
    apply_hash_flags(flags, &mut cfg)?;
    cfg.validate()?;
    Ok(cfg)
}

/// Parse the shared-hash-service knobs strictly (same rule as the other
/// data-plane flags: malformed values fail loudly).  `--hash-batch` and
/// `--hash-devices` need integers >= 1; `--hash-linger-us 0` is valid —
/// it disables lingering so every flush is immediate.
fn apply_hash_flags(flags: &HashMap<String, String>, cfg: &mut ClientConfig) -> Result<()> {
    if let Some(v) = flags.get("hash-batch") {
        cfg.hash_batch = match v.parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                return Err(Error::Config(format!(
                    "bad --hash-batch `{v}` (need an integer >= 1)"
                )))
            }
        };
    }
    if let Some(v) = flags.get("hash-linger-us") {
        cfg.hash_linger_us = match v.parse::<u64>() {
            Ok(n) => n,
            Err(_) => {
                return Err(Error::Config(format!(
                    "bad --hash-linger-us `{v}` (need a non-negative integer)"
                )))
            }
        };
    }
    if let Some(v) = flags.get("hash-devices") {
        cfg.hash_devices = match v.parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                return Err(Error::Config(format!(
                    "bad --hash-devices `{v}` (need an integer >= 1)"
                )))
            }
        };
    }
    Ok(())
}

fn connect_sai(flags: &HashMap<String, String>) -> Result<Sai> {
    let manager = flags
        .get("manager")
        .ok_or_else(|| Error::Config("--manager required".into()))?;
    if flags.contains_key("nodes") {
        eprintln!("note: --nodes is obsolete; storage nodes are discovered via the manager");
    }
    let cfg = client_config(flags)?;
    // Engines are handles onto the process-wide shared hash service:
    // every client in this process with the same engine/policy
    // coalesces its hashing into one backend (see `gpustore::hashsvc`).
    let engine = session_engine(&cfg, None)?;
    Sai::connect(manager, cfg, engine, None)
}

/// Parse `--replication` strictly: a malformed or out-of-range value
/// must fail loudly, not be silently coerced.
fn parse_replication(flags: &HashMap<String, String>) -> Result<usize> {
    match flags.get("replication") {
        None => Ok(1),
        Some(r) => match r.parse::<usize>() {
            Ok(n) if (1..=MAX_REPLICAS).contains(&n) => Ok(n),
            _ => Err(Error::Config(format!(
                "bad --replication `{r}` (need an integer in 1..={MAX_REPLICAS})"
            ))),
        },
    }
}

/// Parse `--placement rr|rep:R|ec:K,M` (PR 10).  Absent means "derive
/// from `--replication`" (the pre-erasure-coding behavior); present, it
/// wins over `--replication` and is validated loudly by
/// [`Placement::parse`].
fn parse_placement(flags: &HashMap<String, String>) -> Result<Option<Placement>> {
    flags
        .get("placement")
        .map(|v| Placement::parse(v))
        .transpose()
}

/// The placement policy the CLI flags ask for (see
/// [`parse_placement`]); erasure-coded shard counts are re-validated by
/// [`ErasureCoded::new`].
fn policy_from_flags(
    placement: Option<Placement>,
    replication: usize,
) -> Result<Box<dyn PlacementPolicy>> {
    match placement {
        None => Ok(policy_for(replication)),
        Some(Placement::RoundRobin) => Ok(policy_for(1)),
        Some(Placement::Replicated(r)) => Ok(policy_for(r)),
        Some(Placement::Erasure { k, m }) => Ok(Box::new(ErasureCoded::new(k, m)?)),
    }
}

/// Parse the self-healing knobs (PR 10): `--scrub-interval SECS`
/// (fractional allowed; `0` or absent disables the background
/// scrub/repair + anti-entropy passes) and `--repair-mbps MBPS`
/// (repair-traffic budget in Mbit/s per scrub window; `0` or absent
/// leaves repair unthrottled).  Malformed or negative values fail
/// loudly.
fn parse_scrub(flags: &HashMap<String, String>) -> Result<(Duration, f64)> {
    let interval = match flags.get("scrub-interval") {
        None => Duration::ZERO,
        Some(v) => match v.parse::<f64>().ok().and_then(|s| {
            (s >= 0.0).then_some(())?;
            Duration::try_from_secs_f64(s).ok()
        }) {
            Some(d) => d,
            None => {
                return Err(Error::Config(format!(
                    "bad --scrub-interval `{v}` (need a non-negative number of seconds)"
                )))
            }
        },
    };
    let mbps = match flags.get("repair-mbps") {
        None => 0.0,
        Some(v) => match v.parse::<f64>() {
            Ok(m) if m >= 0.0 && m.is_finite() => m,
            _ => {
                return Err(Error::Config(format!(
                    "bad --repair-mbps `{v}` (need a non-negative number of Mbit/s)"
                )))
            }
        },
    };
    if mbps > 0.0 && interval.is_zero() {
        return Err(Error::Config(
            "--repair-mbps budgets the background scrub; it requires --scrub-interval".into(),
        ));
    }
    Ok((interval, mbps))
}

/// Parse `--lease-timeout` (whole seconds, fractional allowed, e.g.
/// `0.5`) as strictly as `--replication`: malformed, zero, or
/// out-of-range fails loudly rather than silently running with a
/// default (or panicking on Duration overflow).
fn parse_lease_timeout(flags: &HashMap<String, String>) -> Result<Duration> {
    match flags.get("lease-timeout") {
        None => Ok(DEFAULT_LEASE_TIMEOUT),
        Some(v) => match v.parse::<f64>().ok().and_then(|s| {
            (s > 0.0).then_some(())?;
            Duration::try_from_secs_f64(s).ok()
        }) {
            Some(d) => Ok(d),
            None => Err(Error::Config(format!(
                "bad --lease-timeout `{v}` (need a positive number of seconds)"
            ))),
        },
    }
}

/// Parse `--serve-threads` (manager and node commands): `N >= 1` sizes
/// the event reactor's worker pool, `0` selects the legacy
/// thread-per-connection serve path (the benchmark baseline), absent
/// means event mode with the built-in pool size.  Strict like the other
/// knobs: malformed values fail loudly.
fn parse_serve(flags: &HashMap<String, String>) -> Result<(ServeMode, usize)> {
    match flags.get("serve-threads") {
        None => Ok((ServeMode::default(), 0)),
        Some(v) => match v.parse::<usize>() {
            Ok(0) => Ok((ServeMode::Thread, 0)),
            Ok(n) => Ok((ServeMode::Event, n)),
            Err(_) => Err(Error::Config(format!(
                "bad --serve-threads `{v}` (need a non-negative integer; 0 = \
                 thread-per-connection)"
            ))),
        },
    }
}

/// Parse the durability knobs: `--data-dir DIR` turns the write-ahead
/// log on; `--wal-sync MS` (group-commit fsync interval, `0` = fsync
/// every record) and `--snapshot-every N` refine it and therefore
/// require `--data-dir`.
fn parse_durability(flags: &HashMap<String, String>) -> Result<Option<DurabilityOpts>> {
    let Some(dir) = flags.get("data-dir") else {
        for k in ["wal-sync", "snapshot-every"] {
            if flags.contains_key(k) {
                return Err(Error::Config(format!("--{k} requires --data-dir")));
            }
        }
        return Ok(None);
    };
    let mut opts = DurabilityOpts::new(dir);
    if let Some(v) = flags.get("wal-sync") {
        opts.sync_interval = match v.parse::<u64>() {
            Ok(ms) => Duration::from_millis(ms),
            Err(_) => {
                return Err(Error::Config(format!(
                    "bad --wal-sync `{v}` (need a non-negative integer of milliseconds)"
                )))
            }
        };
    }
    if let Some(v) = flags.get("snapshot-every") {
        opts.snapshot_every = match v.parse::<u64>() {
            Ok(n) if n >= 1 => n,
            _ => {
                return Err(Error::Config(format!(
                    "bad --snapshot-every `{v}` (need an integer >= 1)"
                )))
            }
        };
    }
    Ok(Some(opts))
}

/// Human-readable scrub summary for the manager banner lines.
fn scrub_note(interval: Duration, mbps: f64) -> String {
    if interval.is_zero() {
        return String::new();
    }
    if mbps > 0.0 {
        format!(", scrub every {interval:?} at {mbps} Mbit/s")
    } else {
        format!(", scrub every {interval:?}")
    }
}

/// Consecutive failed polls after which a follower assumes the primary
/// is gone and promotes itself.
const FOLLOWER_PROMOTE_AFTER: u32 = 20;

/// Follower poll cadence.
const FOLLOWER_POLL: Duration = Duration::from_millis(100);

/// Consensus timer cadence for CLI-run managers (tests tick manually).
const MANAGER_TICK: Duration = Duration::from_millis(50);

/// `--peers A,B[,..]` parsed into a peer address list.
fn parse_peers(flags: &HashMap<String, String>) -> Option<Vec<String>> {
    flags.get("peers").map(|p| {
        p.split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    })
}

fn cmd_manager(flags: &HashMap<String, String>) -> Result<()> {
    let listen = flags.get("listen").map(String::as_str).unwrap_or("0.0.0.0:7070");
    let replication = parse_replication(flags)?;
    let placement = parse_placement(flags)?;
    let (scrub_interval, repair_mbps) = parse_scrub(flags)?;
    let lease_timeout = parse_lease_timeout(flags)?;
    let durability = parse_durability(flags)?;
    let peers = parse_peers(flags);
    if let Some(primary) = flags.get("follow") {
        if durability.is_some() {
            return Err(Error::Config(
                "--follow replicates in memory from the primary's log; \
                 it cannot be combined with --data-dir"
                    .into(),
            ));
        }
        return cmd_follow(listen, primary, lease_timeout, peers);
    }
    let policy = policy_from_flags(placement, replication)?;
    let name = policy.name();
    let durable = match &durability {
        Some(o) => format!(", data dir {}", o.data_dir.display()),
        None => ", in-memory".into(),
    };
    let (serve_mode, serve_threads) = parse_serve(flags)?;
    let serving = match serve_mode {
        ServeMode::Event => "event-driven",
        ServeMode::Thread => "thread-per-conn",
    };
    let Some(peers) = peers else {
        let state = std::sync::Arc::new(ManagerState::with_durability(
            policy,
            lease_timeout,
            durability,
        )?);
        state.set_scrub(scrub_interval, repair_mbps);
        let mut mgr =
            Manager::serve_listener_opts(Listener::bind(listen)?, state, serve_mode, serve_threads)?;
        if !scrub_interval.is_zero() {
            // The scrub/repair pass rides the consensus ticker (a
            // solo manager's tick skips the election machinery).
            mgr.start_ticker(MANAGER_TICK);
        }
        println!(
            "metadata manager listening on {} (policy {name}, replication {replication}, \
             lease timeout {lease_timeout:?}, {serving}{scrub}{durable})",
            mgr.addr(),
            scrub = scrub_note(scrub_interval, repair_mbps),
        );
        loop {
            std::thread::park();
        }
    };
    // Quorum member: peers are the OTHER managers' addresses; this
    // member is known to them as --advertise (default: --listen, which
    // must then be a concrete address, not a wildcard).
    if peers.is_empty() {
        return Err(Error::Config("--peers lists no addresses".into()));
    }
    let advertise = flags
        .get("advertise")
        .map(String::as_str)
        .unwrap_or(listen)
        .to_string();
    let initial_leader = flags.get("initial-leader").is_some();
    let term_dir = durability.as_ref().map(|o| o.data_dir.clone());
    let state = std::sync::Arc::new(ManagerState::with_durability(
        policy,
        lease_timeout,
        durability,
    )?);
    state.set_scrub(scrub_interval, repair_mbps);
    state.set_consensus(
        gpustore::store::ConsensusOpts {
            self_addr: advertise.clone(),
            peers: peers.clone(),
            initial_leader,
        },
        term_dir,
    )?;
    let mut mgr =
        Manager::serve_listener_opts(Listener::bind(listen)?, state, serve_mode, serve_threads)?;
    mgr.start_ticker(MANAGER_TICK);
    println!(
        "quorum manager {} listening on {} (peers {}, {}policy {name}, replication \
         {replication}, lease timeout {lease_timeout:?}, {serving}{scrub}{durable})",
        advertise,
        mgr.addr(),
        peers.join(","),
        if initial_leader { "initial leader, " } else { "" },
        scrub = scrub_note(scrub_interval, repair_mbps),
    );
    loop {
        std::thread::park();
    }
}

/// Log-shipping follower: bootstrap from the primary's snapshot and
/// tail its WAL.  When the primary stops answering, promotion is
/// quorum-gated (PR 8): with `--peers` the follower stands for election
/// and serves only after winning a majority; without peers it refuses
/// loudly instead of risking split-brain against a
/// partitioned-but-alive primary.
fn cmd_follow(
    listen: &str,
    primary: &str,
    lease_timeout: Duration,
    peers: Option<Vec<String>>,
) -> Result<()> {
    let follower = Follower::connect(primary, lease_timeout)?;
    println!(
        "follower replicating from {primary} (lsn {}); promotion on {listen} \
         after {FOLLOWER_PROMOTE_AFTER} failed polls is {}",
        follower.last_lsn(),
        match &peers {
            Some(p) => format!("quorum-gated across {} peer(s)", p.len()),
            None => "disabled (no --peers): will refuse loudly".to_string(),
        }
    );
    let mut failures = 0u32;
    loop {
        match follower.poll() {
            Ok(n) => {
                failures = 0;
                if n == 0 {
                    std::thread::sleep(FOLLOWER_POLL);
                }
            }
            Err(e) => {
                failures += 1;
                if failures >= FOLLOWER_PROMOTE_AFTER {
                    eprintln!("follower: primary unreachable ({e})");
                    break;
                }
                std::thread::sleep(FOLLOWER_POLL);
            }
        }
    }
    let Some(peers) = peers else {
        return Err(Error::Manager(format!(
            "follower: primary {primary} unreachable after {FOLLOWER_PROMOTE_AFTER} failed \
             polls; REFUSING blind promotion (a partitioned-but-alive primary would \
             split-brain).  Configure --peers to stand for a quorum election, or restart \
             the primary."
        )));
    };
    let lsn = follower.last_lsn();
    let mut mgr = follower.promote_gated(listen, peers, None)?;
    mgr.start_ticker(MANAGER_TICK);
    println!(
        "follower won election; serving on {} (lsn {lsn})",
        mgr.addr()
    );
    loop {
        std::thread::park();
    }
}

fn cmd_node(flags: &HashMap<String, String>) -> Result<()> {
    let listen = flags.get("listen").map(String::as_str).unwrap_or("0.0.0.0:7071");
    let disk = flags.get("disk").map(std::path::PathBuf::from);
    // When binding a wildcard address, --advertise tells the manager
    // (and thus clients) how to reach this node.
    let advertise = flags.get("advertise").map(String::as_str);
    let (serve_mode, serve_threads) = parse_serve(flags)?;
    let node = StorageNode::spawn_opts(
        listen,
        NodeOpts {
            disk_dir: disk,
            manager: flags.get("manager").cloned(),
            advertise: advertise.map(str::to_string),
            serve_mode,
            serve_threads,
            ..NodeOpts::default()
        },
    )?;
    match node.node_id() {
        Some(id) => println!("storage node {id} listening on {} (joined manager)", node.addr()),
        None => println!("storage node listening on {} (standalone, no manager)", node.addr()),
    }
    loop {
        std::thread::park();
    }
}

fn cmd_write(flags: &HashMap<String, String>) -> Result<()> {
    let sai = connect_sai(flags)?;
    let size = parse_size(flags.get("size").map(String::as_str).unwrap_or("16M"))?;
    let count: usize = flags
        .get("count")
        .map(|c| c.parse().unwrap_or(1))
        .unwrap_or(1);
    let seed: u64 = flags.get("seed").map(|s| s.parse().unwrap_or(1)).unwrap_or(1);
    let name = flags.get("file").cloned().unwrap_or_else(|| "bench.bin".into());

    let mut total = 0u64;
    let mut secs = 0.0;
    for i in 0..count {
        let data = Rng::new(seed ^ i as u64).bytes(size);
        // Streaming session: feed the pipeline in app-sized chunks, then
        // commit on close.
        let mut w = sai.create(&name)?;
        for chunk in data.chunks(CLI_IO_CHUNK) {
            w.write_all(chunk)?;
        }
        let r = w.close()?;
        println!(
            "write {}/{count}: {} in {:?} -> {:.1} MB/s ({} blocks, {} new, sim {:.0}%, \
             hash {:.2}s exposed + {:.2}s hidden)",
            i + 1,
            human_bytes(r.bytes),
            r.elapsed,
            r.mbps(),
            r.blocks,
            r.new_blocks,
            100.0 * r.similarity,
            r.hash_secs,
            r.hash_hidden_secs
        );
        if flags.contains_key("verbose") {
            println!(
                "  hash batching: {} batches, depth mean {:.1} / max {}, \
                 svc linger {:.2} ms, overlap {:.0}%",
                r.hash_batches,
                r.hash_batch_depth_mean,
                r.hash_batch_depth_max,
                1e3 * r.hash_linger_secs,
                100.0 * r.overlap_fraction()
            );
        }
        total += r.bytes;
        secs += r.elapsed.as_secs_f64();
    }
    println!(
        "total: {} in {:.2}s -> {:.1} MB/s",
        human_bytes(total),
        secs,
        total as f64 / (1024.0 * 1024.0) / secs
    );
    Ok(())
}

fn cmd_read(flags: &HashMap<String, String>) -> Result<()> {
    let sai = connect_sai(flags)?;
    let name = flags
        .get("file")
        .ok_or_else(|| Error::Config("--file required".into()))?;
    // Streaming session: blocks are prefetched + integrity-verified and
    // never all resident at once when writing to a file.
    let mut r = sai.open(name)?;
    match flags.get("out") {
        Some(path) => {
            let mut f = std::fs::File::create(path)?;
            let n = std::io::copy(&mut r, &mut f)?;
            println!("read {} -> {path}", human_bytes(n));
        }
        None => {
            let mut data = Vec::with_capacity(r.len() as usize);
            r.read_to_end(&mut data)?;
            println!("read {} (integrity-verified)", human_bytes(data.len() as u64));
        }
    }
    Ok(())
}

fn cmd_verify(flags: &HashMap<String, String>) -> Result<()> {
    let sai = connect_sai(flags)?;
    let name = flags
        .get("file")
        .ok_or_else(|| Error::Config("--file required".into()))?;
    let (ok, bad) = sai.verify_file(name)?;
    println!("{name}: {ok} blocks ok, {bad} corrupt");
    if bad > 0 {
        return Err(Error::Node(format!("{bad} corrupt blocks")));
    }
    Ok(())
}

fn cmd_ls(flags: &HashMap<String, String>) -> Result<()> {
    let sai = connect_sai(flags)?;
    for (name, version) in sai.list_files()? {
        let (_, blocks) = sai.get_block_map(&name)?;
        let bytes: u64 = blocks.iter().map(|b| b.len as u64).sum();
        println!("{name}\tv{version}\t{} blocks\t{}", blocks.len(), human_bytes(bytes));
    }
    Ok(())
}

fn cmd_trace(flags: &HashMap<String, String>) -> Result<()> {
    let sai = connect_sai(flags)?;
    let path = flags
        .get("trace")
        .ok_or_else(|| Error::Config("--trace FILE required".into()))?;
    let seed: u64 = flags.get("seed").map(|s| s.parse().unwrap_or(1)).unwrap_or(1);
    let text = std::fs::read_to_string(path)?;
    let trace = gpustore::workload::Trace::parse(&text)?;
    let reports = trace.replay(&sai, seed)?;
    let mut total = 0u64;
    let mut secs = 0.0;
    for (i, r) in reports.iter().enumerate() {
        println!(
            "write {}: {} -> {:.1} MB/s (sim {:.0}%)",
            i + 1,
            human_bytes(r.bytes),
            r.mbps(),
            100.0 * r.similarity
        );
        total += r.bytes;
        secs += r.elapsed.as_secs_f64();
    }
    println!(
        "trace done: {} in {:.2}s -> {:.1} MB/s",
        human_bytes(total),
        secs,
        total as f64 / (1024.0 * 1024.0) / secs.max(1e-9)
    );
    Ok(())
}

fn cmd_demo(flags: &HashMap<String, String>) -> Result<()> {
    // Cluster::spawn validates replication against the node count.
    let replication = parse_replication(flags)?;
    let lease_timeout = parse_lease_timeout(flags)?;
    let placement = parse_placement(flags)?;
    let (scrub_interval, repair_mbps) = parse_scrub(flags)?;
    let durability = parse_durability(flags)?;
    // The hash-service knobs ride through the cluster config so every
    // client connected via `service_client` shares one policy.
    let mut knobs = ClientConfig::default();
    apply_hash_flags(flags, &mut knobs)?;
    let (serve_mode, serve_threads) = parse_serve(flags)?;
    let cluster = Cluster::spawn(ClusterConfig {
        replication,
        placement,
        scrub_interval,
        repair_mbps,
        lease_timeout,
        hash_batch: knobs.hash_batch,
        hash_linger_us: knobs.hash_linger_us,
        hash_devices: knobs.hash_devices,
        durability: durability.clone(),
        serve_mode,
        serve_threads,
        ..ClusterConfig::default()
    })?;
    let durable = match &durability {
        Some(o) => format!(", data dir {}", o.data_dir.display()),
        None => String::new(),
    };
    let placed = match placement {
        None => format!("replication {replication}"),
        Some(Placement::RoundRobin) => "placement rr".into(),
        Some(Placement::Replicated(r)) => format!("placement rep:{r}"),
        Some(Placement::Erasure { k, m }) => format!("placement ec:{k},{m}"),
    };
    println!(
        "demo cluster: manager {} nodes {:?} ({placed}, \
         lease timeout {lease_timeout:?}{}{durable})",
        cluster.manager_addr(),
        cluster.node_addrs(),
        scrub_note(scrub_interval, repair_mbps),
    );
    let sai = cluster.service_client(ClientConfig::ca_cpu_fixed(4))?;
    let data = Rng::new(1).bytes(8 << 20);
    let write_streaming = |name: &str| -> Result<gpustore::store::WriteReport> {
        let mut w = sai.create(name)?;
        for chunk in data.chunks(CLI_IO_CHUNK) {
            w.write_all(chunk)?;
        }
        w.close()
    };
    let r = write_streaming("demo")?;
    println!("write: {:.1} MB/s", r.mbps());
    let r = write_streaming("demo")?;
    println!("rewrite: {:.1} MB/s, similarity {:.0}%", r.mbps(), 100.0 * r.similarity);
    let mut back = Vec::with_capacity(data.len());
    sai.open("demo")?.read_to_end(&mut back)?;
    assert_eq!(back, data);
    println!("read-back OK");
    if flags.contains_key("verbose") {
        // Per-loop serve gauges (PR 9): open connections, ready-queue
        // depth, worker-pool utilization, frames served.
        for (who, g) in cluster.serve_gauges() {
            println!("  serve {who}: {}", g.snapshot());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_size_suffixes() {
        assert_eq!(parse_size("10").unwrap(), 10);
        assert_eq!(parse_size("4K").unwrap(), 4096);
        assert_eq!(parse_size("2M").unwrap(), 2 << 20);
        assert_eq!(parse_size("1G").unwrap(), 1 << 30);
        assert!(parse_size("x").is_err());
    }

    #[test]
    fn parse_flags_pairs_and_bools() {
        let args: Vec<String> = ["--a", "1", "--flag", "--b", "x"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let f = parse_flags(&args).unwrap();
        assert_eq!(f.get("a").unwrap(), "1");
        assert_eq!(f.get("flag").unwrap(), "true");
        assert_eq!(f.get("b").unwrap(), "x");
    }

    #[test]
    fn parse_lease_timeout_flag() {
        let mut flags = HashMap::new();
        assert_eq!(parse_lease_timeout(&flags).unwrap(), DEFAULT_LEASE_TIMEOUT);
        flags.insert("lease-timeout".into(), "2".into());
        assert_eq!(parse_lease_timeout(&flags).unwrap(), Duration::from_secs(2));
        flags.insert("lease-timeout".into(), "0.5".into());
        assert_eq!(
            parse_lease_timeout(&flags).unwrap(),
            Duration::from_millis(500)
        );
        for bad in ["0", "-1", "x", "inf", "nan", "1e20"] {
            flags.insert("lease-timeout".into(), bad.into());
            assert!(parse_lease_timeout(&flags).is_err(), "{bad}");
        }
    }

    #[test]
    fn parse_serve_threads_flag() {
        let mut flags = HashMap::new();
        assert_eq!(parse_serve(&flags).unwrap(), (ServeMode::Event, 0));
        flags.insert("serve-threads".into(), "8".into());
        assert_eq!(parse_serve(&flags).unwrap(), (ServeMode::Event, 8));
        // 0 selects the legacy thread-per-connection baseline.
        flags.insert("serve-threads".into(), "0".into());
        assert_eq!(parse_serve(&flags).unwrap(), (ServeMode::Thread, 0));
        for bad in ["x", "-1", "1.5", ""] {
            flags.insert("serve-threads".into(), bad.into());
            assert!(parse_serve(&flags).is_err(), "{bad}");
        }
    }

    #[test]
    fn parse_placement_flag() {
        let mut flags = HashMap::new();
        // Absent: derive from --replication, as before PR 10.
        assert_eq!(parse_placement(&flags).unwrap(), None);
        flags.insert("placement".into(), "rr".into());
        assert_eq!(
            parse_placement(&flags).unwrap(),
            Some(Placement::RoundRobin)
        );
        flags.insert("placement".into(), "rep:3".into());
        assert_eq!(
            parse_placement(&flags).unwrap(),
            Some(Placement::Replicated(3))
        );
        flags.insert("placement".into(), "ec:4,2".into());
        assert_eq!(
            parse_placement(&flags).unwrap(),
            Some(Placement::Erasure { k: 4, m: 2 })
        );
        for bad in ["", "rep:0", "ec:0,2", "ec:4", "raid5", "true"] {
            flags.insert("placement".into(), bad.into());
            assert!(parse_placement(&flags).is_err(), "{bad:?}");
        }
        // The policy constructor re-validates the wire bound.
        assert!(policy_from_flags(Some(Placement::Erasure { k: 60, m: 10 }), 1).is_err());
        assert_eq!(
            policy_from_flags(Some(Placement::Erasure { k: 2, m: 1 }), 1)
                .unwrap()
                .name(),
            "erasure-coded"
        );
    }

    #[test]
    fn parse_scrub_flags() {
        let mut flags = HashMap::new();
        // Absent: background scrub disabled, repair unthrottled.
        assert_eq!(parse_scrub(&flags).unwrap(), (Duration::ZERO, 0.0));
        flags.insert("scrub-interval".into(), "1.5".into());
        assert_eq!(
            parse_scrub(&flags).unwrap(),
            (Duration::from_millis(1500), 0.0)
        );
        flags.insert("repair-mbps".into(), "40".into());
        assert_eq!(
            parse_scrub(&flags).unwrap(),
            (Duration::from_millis(1500), 40.0)
        );
        for bad in ["x", "-1", "nan", "inf"] {
            let mut f = flags.clone();
            f.insert("scrub-interval".into(), bad.into());
            assert!(parse_scrub(&f).is_err(), "scrub-interval={bad}");
            let mut f = flags.clone();
            f.insert("repair-mbps".into(), bad.into());
            assert!(parse_scrub(&f).is_err(), "repair-mbps={bad}");
        }
        // A repair budget without a scrub loop budgets nothing.
        let mut f = HashMap::new();
        f.insert("repair-mbps".into(), "40".into());
        assert!(parse_scrub(&f).is_err());
    }

    #[test]
    fn parse_durability_flags() {
        let mut flags = HashMap::new();
        assert!(parse_durability(&flags).unwrap().is_none());
        // The refining knobs are meaningless without a data dir.
        flags.insert("wal-sync".into(), "5".into());
        assert!(parse_durability(&flags).is_err());
        flags.insert("data-dir".into(), "/tmp/d".into());
        flags.insert("snapshot-every".into(), "100".into());
        let opts = parse_durability(&flags).unwrap().unwrap();
        assert_eq!(opts.data_dir, std::path::PathBuf::from("/tmp/d"));
        assert_eq!(opts.sync_interval, Duration::from_millis(5));
        assert_eq!(opts.snapshot_every, 100);
        // `--wal-sync 0` is valid: fsync every record.
        flags.insert("wal-sync".into(), "0".into());
        let opts = parse_durability(&flags).unwrap().unwrap();
        assert_eq!(opts.sync_interval, Duration::ZERO);
        for (k, bad) in [
            ("wal-sync", "x"),
            ("wal-sync", "-1"),
            ("snapshot-every", "0"),
            ("snapshot-every", "y"),
        ] {
            let mut f = flags.clone();
            f.insert(k.into(), bad.into());
            assert!(parse_durability(&f).is_err(), "{k}={bad}");
        }
    }

    #[test]
    fn client_config_modes() {
        let mut flags = HashMap::new();
        flags.insert("mode".into(), "cdc".into());
        flags.insert("engine".into(), "oracle".into());
        let cfg = client_config(&flags).unwrap();
        assert_eq!(cfg.ca_mode, CaMode::Cdc);
        assert_eq!(cfg.engine, HashEngineKind::Oracle);
        flags.insert("mode".into(), "bogus".into());
        assert!(client_config(&flags).is_err());
    }

    #[test]
    fn client_config_data_plane_flags() {
        let mut flags = HashMap::new();
        flags.insert("inflight-mb".into(), "64".into());
        flags.insert("node-inflight".into(), "4".into());
        let cfg = client_config(&flags).unwrap();
        assert_eq!(cfg.inflight_budget, 64 * 1024 * 1024);
        assert_eq!(cfg.node_inflight, 4);
        for (k, bad) in [
            ("inflight-mb", "0"),
            ("inflight-mb", "x"),
            // 2^44 + 1 MB: parses as usize but overflows the byte
            // conversion — must fail loudly, not wrap.
            ("inflight-mb", "17592186044417"),
            ("node-inflight", "0"),
        ] {
            let mut f = HashMap::new();
            f.insert(k.to_string(), bad.to_string());
            assert!(client_config(&f).is_err(), "{k}={bad}");
        }
    }

    #[test]
    fn client_config_hash_service_flags() {
        let mut flags = HashMap::new();
        flags.insert("hash-batch".into(), "128".into());
        flags.insert("hash-linger-us".into(), "0".into());
        flags.insert("hash-devices".into(), "2".into());
        let cfg = client_config(&flags).unwrap();
        assert_eq!(cfg.hash_batch, 128);
        assert_eq!(cfg.hash_linger_us, 0);
        assert_eq!(cfg.hash_devices, 2);
        for (k, bad) in [
            ("hash-batch", "0"),
            ("hash-batch", "x"),
            ("hash-linger-us", "-5"),
            ("hash-linger-us", "y"),
            ("hash-devices", "0"),
        ] {
            let mut f = HashMap::new();
            f.insert(k.to_string(), bad.to_string());
            assert!(client_config(&f).is_err(), "{k}={bad}");
        }
    }
}
