//! Reed–Solomon erasure coding over GF(256) (PR 10, ROADMAP open
//! item 1).  Pure-Rust and dependency-free like the rest of the crate:
//! log/exp tables over the conventional Reed–Solomon polynomial
//! `0x11d`, a systematic
//! Vandermonde-derived encode matrix, and Gauss–Jordan inversion for
//! reconstruction.
//!
//! A block of `len` bytes is split into `k` data shards of
//! `ceil(len / k)` bytes (the last one zero-padded) and extended with
//! `m` parity shards of the same length.  The code is **systematic**:
//! shards `0..k` are the data itself, so the healthy read path is a
//! plain concatenation with no field arithmetic.  Any `k` of the
//! `k + m` shards reconstruct the block byte-exact; fewer than `k`
//! cannot (property-tested against random erasures below).
//!
//! The encode matrix is the (k+m)×k Vandermonde matrix over the
//! evaluation points `0, 1, .., k+m-1` normalized by the inverse of its
//! top k×k square.  Any k rows of a Vandermonde matrix with distinct
//! points are linearly independent, and normalizing (multiplying every
//! row on the right by one fixed invertible matrix) preserves that — so
//! every k-subset of shards yields an invertible decode matrix.

use std::sync::OnceLock;

/// The field polynomial: x^8 + x^4 + x^3 + x^2 + 1.
const POLY: u16 = 0x11d;

/// Largest supported `k + m` (the field has 255 usable evaluation
/// points; the wire protocol caps replica sets well below this).
pub const MAX_SHARDS: usize = 255;

struct Tables {
    log: [u8; 256],
    exp: [u8; 512],
}

fn tables() -> &'static Tables {
    static TABLES: OnceLock<Tables> = OnceLock::new();
    TABLES.get_or_init(|| {
        let mut log = [0u8; 256];
        let mut exp = [0u8; 512];
        let mut x: u16 = 1;
        for i in 0..255 {
            exp[i] = x as u8;
            log[x as usize] = i as u8;
            x <<= 1;
            if x & 0x100 != 0 {
                x ^= POLY;
            }
        }
        // Doubled exp table: mul can index log[a] + log[b] without a
        // modular reduction.
        for i in 255..512 {
            exp[i] = exp[i - 255];
        }
        Tables { log, exp }
    })
}

/// GF(256) multiplication.
#[inline]
pub fn gf_mul(a: u8, b: u8) -> u8 {
    if a == 0 || b == 0 {
        return 0;
    }
    let t = tables();
    t.exp[t.log[a as usize] as usize + t.log[b as usize] as usize]
}

/// GF(256) multiplicative inverse (panics on 0, which has none).
#[inline]
pub fn gf_inv(a: u8) -> u8 {
    assert_ne!(a, 0, "gf_inv(0)");
    let t = tables();
    t.exp[255 - t.log[a as usize] as usize]
}

/// GF(256) exponentiation by a small non-negative power.
fn gf_pow(a: u8, e: usize) -> u8 {
    if e == 0 {
        return 1;
    }
    if a == 0 {
        return 0;
    }
    let t = tables();
    let le = (t.log[a as usize] as usize * e) % 255;
    t.exp[le]
}

/// The systematic (k+m)×k encode matrix: rows `0..k` are the identity,
/// rows `k..k+m` are the parity combinations.
fn encode_matrix(k: usize, m: usize) -> Vec<Vec<u8>> {
    assert!(k >= 1, "ec: k must be >= 1");
    assert!(k + m <= MAX_SHARDS, "ec: k + m must be <= {MAX_SHARDS}");
    // Vandermonde over distinct points 0..k+m (row i = [i^0, i^1, ..]).
    let rows = k + m;
    let mut v: Vec<Vec<u8>> = (0..rows)
        .map(|i| (0..k).map(|j| gf_pow(i as u8, j)).collect())
        .collect();
    // Normalize by the inverse of the top square so the code is
    // systematic; every k-row subset stays invertible.
    let top: Vec<Vec<u8>> = v[..k].to_vec();
    let inv = invert(top).expect("vandermonde top square is invertible");
    for row in v.iter_mut() {
        let old = row.clone();
        for (j, out) in row.iter_mut().enumerate() {
            let mut acc = 0u8;
            for (c, &o) in old.iter().enumerate() {
                acc ^= gf_mul(o, inv[c][j]);
            }
            *out = acc;
        }
    }
    v
}

/// Gauss–Jordan inversion in GF(256); `None` if singular.
fn invert(mut a: Vec<Vec<u8>>) -> Option<Vec<Vec<u8>>> {
    let n = a.len();
    let mut inv: Vec<Vec<u8>> = (0..n)
        .map(|i| (0..n).map(|j| u8::from(i == j)).collect())
        .collect();
    for col in 0..n {
        // Find a pivot row at or below `col`.
        let pivot = (col..n).find(|&r| a[r][col] != 0)?;
        a.swap(col, pivot);
        inv.swap(col, pivot);
        // Scale the pivot row to 1.
        let p = gf_inv(a[col][col]);
        for j in 0..n {
            a[col][j] = gf_mul(a[col][j], p);
            inv[col][j] = gf_mul(inv[col][j], p);
        }
        // Eliminate the column from every other row.
        for r in 0..n {
            if r == col || a[r][col] == 0 {
                continue;
            }
            let f = a[r][col];
            for j in 0..n {
                let ac = gf_mul(f, a[col][j]);
                a[r][j] ^= ac;
                let ic = gf_mul(f, inv[col][j]);
                inv[r][j] ^= ic;
            }
        }
    }
    Some(inv)
}

/// Shard length for a block of `len` bytes under `k` data shards.
pub fn shard_len(len: usize, k: usize) -> usize {
    len.div_ceil(k)
}

/// Split `data` into `k` data shards (zero-padded to equal length) and
/// append `m` parity shards.  Returns `k + m` shards of
/// [`shard_len`]`(data.len(), k)` bytes each.
pub fn encode(k: usize, m: usize, data: &[u8]) -> Vec<Vec<u8>> {
    let slen = shard_len(data.len(), k);
    let mut shards: Vec<Vec<u8>> = Vec::with_capacity(k + m);
    for i in 0..k {
        let start = (i * slen).min(data.len());
        let end = ((i + 1) * slen).min(data.len());
        let mut s = data[start..end].to_vec();
        s.resize(slen, 0);
        shards.push(s);
    }
    let matrix = encode_matrix(k, m);
    for row in &matrix[k..] {
        let mut p = vec![0u8; slen];
        for (c, coef) in row.iter().enumerate() {
            if *coef == 0 {
                continue;
            }
            for (j, b) in shards[c].iter().enumerate() {
                p[j] ^= gf_mul(*coef, *b);
            }
        }
        shards.push(p);
    }
    shards
}

/// Reconstruct the original `len` bytes from any `k` surviving shards.
/// `shards[i]` is shard `i` or `None` if lost; exactly `k + m` entries.
/// Fails loudly when fewer than `k` shards survive or a survivor has
/// the wrong length.
pub fn reconstruct(
    k: usize,
    m: usize,
    shards: &[Option<Vec<u8>>],
    len: usize,
) -> Result<Vec<u8>, String> {
    if shards.len() != k + m {
        return Err(format!(
            "ec: expected {} shard slots, got {}",
            k + m,
            shards.len()
        ));
    }
    let slen = shard_len(len, k);
    let mut have: Vec<(usize, &[u8])> = Vec::with_capacity(k);
    for (i, s) in shards.iter().enumerate() {
        if let Some(s) = s {
            if s.len() != slen {
                return Err(format!(
                    "ec: shard {i} has {} bytes, expected {slen}",
                    s.len()
                ));
            }
            have.push((i, s));
            if have.len() == k {
                break;
            }
        }
    }
    if have.len() < k {
        return Err(format!(
            "ec: only {} of {} shards survive, need {k}",
            shards.iter().filter(|s| s.is_some()).count(),
            k + m
        ));
    }
    // Systematic fast path: shards 0..k intact means the data needs no
    // field arithmetic at all.
    let mut out = Vec::with_capacity(k * slen);
    if have.iter().enumerate().all(|(c, (i, _))| c == *i) {
        for (_, s) in &have {
            out.extend_from_slice(s);
        }
        out.truncate(len);
        return Ok(out);
    }
    let matrix = encode_matrix(k, m);
    let sub: Vec<Vec<u8>> = have.iter().map(|(i, _)| matrix[*i].clone()).collect();
    let inv = invert(sub).ok_or_else(|| "ec: decode matrix singular".to_string())?;
    for data_row in inv.iter().take(k) {
        let mut shard = vec![0u8; slen];
        for (r, coef) in data_row.iter().enumerate() {
            if *coef == 0 {
                continue;
            }
            let src = have[r].1;
            for (j, b) in src.iter().enumerate() {
                shard[j] ^= gf_mul(*coef, *b);
            }
        }
        out.extend_from_slice(&shard);
    }
    out.truncate(len);
    Ok(out)
}

/// Rebuild one missing shard (data or parity) from any `k` survivors:
/// reconstruct the block, re-encode, and pick the requested index.  The
/// scrub/repair loop uses this to re-home a shard onto a fresh node.
pub fn rebuild_shard(
    k: usize,
    m: usize,
    shards: &[Option<Vec<u8>>],
    len: usize,
    idx: usize,
) -> Result<Vec<u8>, String> {
    if idx >= k + m {
        return Err(format!("ec: shard index {idx} out of range {}", k + m));
    }
    let data = reconstruct(k, m, shards, len)?;
    Ok(encode(k, m, &data).swap_remove(idx))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn field_axioms_hold() {
        // Spot-check the table construction against schoolbook GF math.
        for a in 1..=255u8 {
            assert_eq!(gf_mul(a, gf_inv(a)), 1, "a={a}");
            assert_eq!(gf_mul(a, 1), a);
            assert_eq!(gf_mul(a, 0), 0);
        }
        // Known inverse pair under 0x11d: 0x53 * 0x8C = 0x01 (under
        // AES's 0x11b the pair would be 0x53/0xCA — not this field).
        assert_eq!(gf_mul(0x53, 0x8C), 0x01);
        // Commutativity + distributivity samples.
        let mut rng = Rng::new(7);
        for _ in 0..200 {
            let (a, b, c) = (
                rng.range(0, 256) as u8,
                rng.range(0, 256) as u8,
                rng.range(0, 256) as u8,
            );
            assert_eq!(gf_mul(a, b), gf_mul(b, a));
            assert_eq!(gf_mul(a, b ^ c), gf_mul(a, b) ^ gf_mul(a, c));
        }
    }

    #[test]
    fn systematic_layout() {
        let data: Vec<u8> = (0..100u8).collect();
        let shards = encode(4, 2, &data);
        assert_eq!(shards.len(), 6);
        let slen = shard_len(data.len(), 4);
        assert_eq!(slen, 25);
        let cat: Vec<u8> = shards[..4].concat();
        assert_eq!(&cat[..data.len()], &data[..]);
        // Deterministic: same input, same shards.
        assert_eq!(encode(4, 2, &data), shards);
    }

    /// PROPERTY: any ≤m random erasures reconstruct byte-exact, for
    /// random (k, m), lengths (including non-divisible and tiny), and
    /// data.
    #[test]
    fn prop_reconstruct_under_random_erasures() {
        for seed in 0..60u64 {
            let mut rng = Rng::new(0xEC ^ (seed << 4));
            let k = rng.range(1, 7);
            let m = rng.range(1, 5);
            let len = rng.range(1, 5000);
            let data = rng.bytes(len);
            let shards = encode(k, m, &data);
            assert_eq!(shards.len(), k + m, "seed={seed}");

            let mut have: Vec<Option<Vec<u8>>> = shards.iter().cloned().map(Some).collect();
            let erase = rng.range(0, m + 1);
            for _ in 0..erase {
                let i = rng.range(0, k + m);
                have[i] = None; // may repeat: erases ≤ `erase` shards
            }
            let got = reconstruct(k, m, &have, len)
                .unwrap_or_else(|e| panic!("seed={seed} k={k} m={m}: {e}"));
            assert_eq!(got, data, "seed={seed} k={k} m={m} len={len}");
        }
    }

    /// PROPERTY: every single missing shard (data or parity) can be
    /// rebuilt bit-identical to the original encoding.
    #[test]
    fn prop_rebuild_any_single_shard() {
        for seed in 0..20u64 {
            let mut rng = Rng::new(0x5EC ^ seed);
            let k = rng.range(1, 6);
            let m = rng.range(1, 4);
            let len = rng.range(1, 2000);
            let data = rng.bytes(len);
            let shards = encode(k, m, &data);
            for lost in 0..k + m {
                let mut have: Vec<Option<Vec<u8>>> =
                    shards.iter().cloned().map(Some).collect();
                have[lost] = None;
                let rebuilt = rebuild_shard(k, m, &have, len, lost).unwrap();
                assert_eq!(rebuilt, shards[lost], "seed={seed} lost={lost}");
            }
        }
    }

    /// PROPERTY: strictly more than m erasures must fail loudly, never
    /// return wrong bytes.
    #[test]
    fn prop_too_many_erasures_fail() {
        for seed in 0..20u64 {
            let mut rng = Rng::new(0xDEAD ^ (seed << 3));
            let k = rng.range(2, 6);
            let m = rng.range(1, 4);
            let len = rng.range(1, 1000);
            let data = rng.bytes(len);
            let shards = encode(k, m, &data);
            let mut have: Vec<Option<Vec<u8>>> = shards.into_iter().map(Some).collect();
            // Erase m+1 distinct shards.
            for i in 0..m + 1 {
                have[i * (k + m) / (m + 1)] = None;
            }
            let left = have.iter().filter(|s| s.is_some()).count();
            assert!(left < k + m);
            if left < k {
                assert!(reconstruct(k, m, &have, len).is_err(), "seed={seed}");
            } else {
                // Still ≥ k survivors: must succeed exactly.
                assert_eq!(reconstruct(k, m, &have, len).unwrap(), data);
            }
        }
    }

    #[test]
    fn wrong_shard_length_rejected() {
        let data = vec![1u8; 64];
        let shards = encode(2, 1, &data);
        let mut have: Vec<Option<Vec<u8>>> = shards.into_iter().map(Some).collect();
        have[0].as_mut().unwrap().push(0);
        assert!(reconstruct(2, 1, &have, 64).is_err());
        assert!(reconstruct(2, 1, &have[..2], 64).is_err(), "slot count");
    }
}
