//! PJRT runtime: load the AOT-compiled HLO artifacts (built once by
//! `make artifacts` from the JAX/Pallas sources) and execute them from
//! the rust hot path.  Python is never on the request path.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::{ArtifactSpec, Manifest};
pub use pjrt::{pjrt_available, Executable, PjrtContext};
