//! Artifact registry: parses `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) and answers "which compiled executable do I
//! run for this job?" — mirroring the shape-bucketing logic in aot.py.

use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::{Error, Result};

/// Kind of compute graph an artifact implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Batched per-segment MD5 (`md5_seg{S}_l{L}`): u32[lanes, words] ->
    /// u32[lanes, 4].
    Direct,
    /// Sliding-window rolling fingerprint (`roll_{N}_w{W}`):
    /// u32[N/4] -> u32[N - W + 1].
    Sliding,
}

/// One manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Unique name (also the HLO file stem).
    pub name: String,
    /// Graph kind.
    pub kind: ArtifactKind,
    /// Absolute path to the HLO text.
    pub path: PathBuf,
    /// Direct: segment size in bytes (pre-padding).
    pub seg_bytes: usize,
    /// Direct: number of parallel lanes (segments per execution).
    pub lanes: usize,
    /// Direct: MD5 blocks per padded segment.
    pub n_blocks: usize,
    /// Sliding: input size in bytes.
    pub n_bytes: usize,
    /// Sliding: window width.
    pub window: usize,
    /// Input element count (u32 words).
    pub in_words: usize,
    /// Input dims.
    pub in_dims: Vec<usize>,
}

impl ArtifactSpec {
    /// Payload capacity in bytes: how much raw data one execution covers.
    pub fn capacity(&self) -> usize {
        match self.kind {
            ArtifactKind::Direct => self.seg_bytes * self.lanes,
            ArtifactKind::Sliding => self.n_bytes,
        }
    }
}

/// Parsed manifest with bucket-selection logic.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// All artifacts.
    pub artifacts: Vec<ArtifactSpec>,
    /// CDC window width shared by all sliding artifacts.
    pub window: usize,
    /// Rolling-hash base.
    pub p: u32,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .map_err(|e| Error::Artifact(format!("read {}: {e}", mpath.display())))?;
        let j = Json::parse(&text)?;
        let window = j.req_usize("window")?;
        let p = j.req_usize("p")? as u32;
        let mut artifacts = Vec::new();
        for a in j
            .req("artifacts")?
            .as_arr()
            .ok_or_else(|| Error::Artifact("artifacts not an array".into()))?
        {
            let kind = match a.req_str("kind")? {
                "direct" => ArtifactKind::Direct,
                "sliding" => ArtifactKind::Sliding,
                k => return Err(Error::Artifact(format!("unknown kind {k}"))),
            };
            let in_dims: Vec<usize> = a
                .req("in_words")?
                .as_arr()
                .ok_or_else(|| Error::Artifact("in_words not an array".into()))?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect();
            artifacts.push(ArtifactSpec {
                name: a.req_str("name")?.to_string(),
                kind,
                path: dir.join(a.req_str("path")?),
                seg_bytes: a.get("seg_bytes").and_then(Json::as_usize).unwrap_or(0),
                lanes: a.get("lanes").and_then(Json::as_usize).unwrap_or(0),
                n_blocks: a.get("n_blocks").and_then(Json::as_usize).unwrap_or(0),
                n_bytes: a.get("n_bytes").and_then(Json::as_usize).unwrap_or(0),
                window: a.get("window").and_then(Json::as_usize).unwrap_or(window),
                in_words: in_dims.iter().product(),
                in_dims,
            });
        }
        if artifacts.is_empty() {
            return Err(Error::Artifact("empty manifest".into()));
        }
        Ok(Manifest {
            artifacts,
            window,
            p,
        })
    }

    /// Default artifact directory: `$GPUSTORE_ARTIFACTS` or
    /// `<crate root>/artifacts`.
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("GPUSTORE_ARTIFACTS") {
            return PathBuf::from(d);
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// A built-in manifest mirroring aot.py's shape buckets exactly
    /// (same names, lane/segment buckets and rolling sizes).  Backends
    /// that recompute on the host — the Mock executor — need only the
    /// *shapes*, not the compiled HLO, so they can run in environments
    /// where `make artifacts` has never been invoked.
    pub fn synthetic() -> Manifest {
        use crate::hash::{DEFAULT_P, DEFAULT_WINDOW};
        let mut artifacts = Vec::new();
        let buckets: [(usize, &[usize]); 2] =
            [(256, &[16, 64, 256]), (4096, &[16, 64, 256, 1024])];
        for (seg, lane_list) in buckets {
            let words = crate::runtime::pjrt::padded_words(seg);
            for &lanes in lane_list {
                artifacts.push(ArtifactSpec {
                    name: format!("md5_seg{seg}_l{lanes}"),
                    kind: ArtifactKind::Direct,
                    path: PathBuf::new(),
                    seg_bytes: seg,
                    lanes,
                    n_blocks: words / 16,
                    n_bytes: 0,
                    window: DEFAULT_WINDOW,
                    in_words: lanes * words,
                    in_dims: vec![lanes, words],
                });
            }
        }
        for n in [65536usize, 262144, 1048576, 4194304] {
            artifacts.push(ArtifactSpec {
                name: format!("roll_{n}_w{DEFAULT_WINDOW}"),
                kind: ArtifactKind::Sliding,
                path: PathBuf::new(),
                seg_bytes: 0,
                lanes: 0,
                n_blocks: 0,
                n_bytes: n,
                window: DEFAULT_WINDOW,
                in_words: n / 4,
                in_dims: vec![n / 4],
            });
        }
        Manifest {
            artifacts,
            window: DEFAULT_WINDOW,
            p: DEFAULT_P,
        }
    }

    /// Load `dir/manifest.json` if it exists, otherwise fall back to the
    /// [`synthetic`](Self::synthetic) manifest.  Used by host-recompute
    /// backends; the PJRT backend always requires real artifacts.
    pub fn load_or_synthetic(dir: &Path) -> Result<Manifest> {
        if dir.join("manifest.json").exists() {
            Self::load(dir)
        } else {
            Ok(Self::synthetic())
        }
    }

    /// Smallest direct-hash artifact with `seg_bytes` segments that fits
    /// `data_len` bytes in one execution; falls back to the
    /// largest-capacity bucket (caller splits the job).
    pub fn pick_direct(&self, seg_bytes: usize, data_len: usize) -> Result<&ArtifactSpec> {
        let need_lanes = data_len.div_ceil(seg_bytes).max(1);
        self.artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Direct && a.seg_bytes == seg_bytes)
            .filter(|a| a.lanes >= need_lanes)
            .min_by_key(|a| a.lanes)
            .or_else(|| {
                self.artifacts
                    .iter()
                    .filter(|a| a.kind == ArtifactKind::Direct && a.seg_bytes == seg_bytes)
                    .max_by_key(|a| a.lanes)
            })
            .ok_or_else(|| {
                Error::Artifact(format!("no direct artifact for seg_bytes={seg_bytes}"))
            })
    }

    /// Sliding-window artifact for the next step over `data_len`
    /// remaining bytes.  Work-minimizing policy: an exactly-covering
    /// bucket is used only if it wastes < 50 % of its capacity;
    /// otherwise the largest bucket <= data_len is used and the caller
    /// iterates (splitting costs only a window-1 overlap, while padding
    /// a 1 MB+eps job into a 4 MB bucket costs 4x the kernel work —
    /// EXPERIMENTS.md section Perf).
    pub fn pick_sliding(&self, data_len: usize) -> Result<&ArtifactSpec> {
        let sliding = || {
            self.artifacts
                .iter()
                .filter(|a| a.kind == ArtifactKind::Sliding)
        };
        if let Some(tight) = sliding()
            .filter(|a| a.n_bytes >= data_len && data_len * 2 > a.n_bytes)
            .min_by_key(|a| a.n_bytes)
        {
            return Ok(tight);
        }
        sliding()
            .filter(|a| a.n_bytes <= data_len)
            .max_by_key(|a| a.n_bytes)
            .or_else(|| sliding().min_by_key(|a| a.n_bytes))
            .ok_or_else(|| Error::Artifact("no sliding artifacts".into()))
    }

    /// Segment sizes available for direct hashing.
    pub fn direct_seg_sizes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Direct)
            .map(|a| a.seg_bytes)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_manifest() -> Manifest {
        // A synthetic manifest mirroring aot.py's bucket structure.
        let dir = std::env::temp_dir().join(format!("gpustore-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let json = r#"{
            "version": 1, "window": 48, "p": 16777619,
            "artifacts": [
stub
            ]
        }"#;
        let mut entries = Vec::new();
        for (seg, lanes, blocks) in [(256, 16, 5), (256, 64, 5), (4096, 16, 65), (4096, 256, 65)] {
            entries.push(format!(
                r#"{{"name":"md5_seg{seg}_l{lanes}","kind":"direct","seg_bytes":{seg},"lanes":{lanes},"n_blocks":{blocks},"in_words":[{lanes},{w}],"path":"x.hlo.txt"}}"#,
                w = blocks * 16
            ));
        }
        for n in [65536usize, 262144] {
            entries.push(format!(
                r#"{{"name":"roll_{n}_w48","kind":"sliding","n_bytes":{n},"window":48,"p":16777619,"in_words":[{}],"out_len":{},"path":"y.hlo.txt"}}"#,
                n / 4,
                n - 47
            ));
        }
        let json = json.replace("stub", &entries.join(",\n"));
        std::fs::write(dir.join("manifest.json"), json).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn parses_synthetic_manifest() {
        let m = test_manifest();
        assert_eq!(m.window, 48);
        assert_eq!(m.p, 16777619);
        assert_eq!(m.artifacts.len(), 6);
        assert_eq!(m.direct_seg_sizes(), vec![256, 4096]);
    }

    #[test]
    fn pick_direct_smallest_fit() {
        let m = test_manifest();
        // 4 KB over 256-byte segments -> 16 lanes.
        let a = m.pick_direct(256, 4096).unwrap();
        assert_eq!(a.lanes, 16);
        // 5 KB -> needs 20 segments -> 64-lane bucket.
        let a = m.pick_direct(256, 5 * 1024).unwrap();
        assert_eq!(a.lanes, 64);
    }

    #[test]
    fn pick_direct_oversized_falls_back_to_largest() {
        let m = test_manifest();
        let a = m.pick_direct(4096, 64 << 20).unwrap();
        assert_eq!(a.lanes, 256);
    }

    #[test]
    fn pick_direct_unknown_seg_errors() {
        let m = test_manifest();
        assert!(m.pick_direct(1024, 4096).is_err());
    }

    #[test]
    fn pick_sliding_buckets() {
        let m = test_manifest();
        // Below the smallest bucket: pad into it.
        assert_eq!(m.pick_sliding(10_000).unwrap().n_bytes, 65536);
        assert_eq!(m.pick_sliding(65536).unwrap().n_bytes, 65536);
        // Slightly over a bucket: SPLIT (fill the smaller bucket and
        // iterate) rather than waste 3/4 of the next one.
        assert_eq!(m.pick_sliding(65537).unwrap().n_bytes, 65536);
        // Over half of the next bucket: use it in one shot.
        assert_eq!(m.pick_sliding(200_000).unwrap().n_bytes, 262144);
        // Oversized -> largest (caller iterates).
        assert_eq!(m.pick_sliding(1 << 24).unwrap().n_bytes, 262144);
    }

    #[test]
    fn capacity() {
        let m = test_manifest();
        let a = m.pick_direct(4096, 1).unwrap();
        assert_eq!(a.capacity(), 4096 * 16);
    }

    #[test]
    fn synthetic_manifest_mirrors_aot_buckets() {
        let m = Manifest::synthetic();
        assert_eq!(m.window, crate::hash::DEFAULT_WINDOW);
        assert_eq!(m.p, crate::hash::DEFAULT_P);
        assert_eq!(m.direct_seg_sizes(), vec![256, 4096]);
        // Largest 4096-seg bucket is 1024 lanes = 4 MB per execution.
        assert_eq!(m.pick_direct(4096, 64 << 20).unwrap().lanes, 1024);
        assert_eq!(m.pick_direct(256, 4096).unwrap().lanes, 16);
        assert_eq!(m.pick_sliding(65536).unwrap().n_bytes, 65536);
        assert_eq!(m.pick_sliding(1 << 30).unwrap().n_bytes, 4194304);
        // Direct specs carry consistent packing geometry.
        for a in m.artifacts.iter().filter(|a| a.kind == ArtifactKind::Direct) {
            assert_eq!(a.in_words, a.lanes * a.n_blocks * 16);
        }
    }

    #[test]
    fn load_or_synthetic_falls_back() {
        let dir = std::env::temp_dir().join("gpustore-definitely-missing");
        let m = Manifest::load_or_synthetic(&dir).unwrap();
        assert!(!m.artifacts.is_empty());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.artifacts.is_empty());
            for a in &m.artifacts {
                assert!(a.path.exists(), "missing {}", a.path.display());
            }
        }
    }
}
