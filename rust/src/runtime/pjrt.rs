//! PJRT execution: compile an HLO-text artifact once, run it many times.
//!
//! Interchange is HLO *text* (not serialized protos): jax >= 0.5 emits
//! 64-bit instruction ids that the crate's xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Threading: the `xla` wrapper types hold raw pointers and are not
//! `Send`, so a [`PjrtContext`] (client + its compiled executables) is
//! owned by exactly one thread — crystal's per-device manager thread,
//! mirroring the paper's one-manager-thread-per-GPU design.
//!
//! Feature gating: real execution needs the `xla` crate, which is only
//! available where it has been vendored.  Without the `pjrt` cargo
//! feature this module compiles a stub [`PjrtContext`] whose
//! constructor reports the missing backend — the Mock backend and every
//! CPU path stay fully functional, and crystal surfaces the error as a
//! per-device init failure.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::time::Duration;
#[cfg(feature = "pjrt")]
use std::time::Instant;

#[cfg(feature = "pjrt")]
use super::artifacts::ArtifactKind;
use super::artifacts::{ArtifactSpec, Manifest};
use crate::metrics::{Stage, StageBreakdown};
use crate::{Error, Result};

/// A compiled artifact plus its spec.
#[cfg(feature = "pjrt")]
pub struct Executable {
    /// Manifest entry this was compiled from.
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// Stub of the compiled-artifact handle (built without the `pjrt`
/// feature; never constructed).
#[cfg(not(feature = "pjrt"))]
pub struct Executable {
    /// Manifest entry this was compiled from.
    pub spec: ArtifactSpec,
}

/// Whether this build can execute PJRT artifacts at all.
pub fn pjrt_available() -> bool {
    cfg!(feature = "pjrt")
}

/// Timing for one execution, split per paper-Table-1 stage.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecTiming {
    /// Host buffer prep (pack/pad) — stage 1.
    pub preprocess: Duration,
    /// Host->device transfer — stage 2.
    pub copy_in: Duration,
    /// Kernel execution — stage 3.
    pub kernel: Duration,
    /// Device->host transfer — stage 4.
    pub copy_out: Duration,
}

impl ExecTiming {
    /// Fold into a [`StageBreakdown`].
    pub fn record(&self, b: &mut StageBreakdown) {
        b.add(Stage::Preprocess, self.preprocess);
        b.add(Stage::CopyIn, self.copy_in);
        b.add(Stage::Kernel, self.kernel);
        b.add(Stage::CopyOut, self.copy_out);
    }
}

/// One thread's PJRT client and executable cache.
#[cfg(feature = "pjrt")]
pub struct PjrtContext {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, Executable>,
}

/// Stub PJRT context (built without the `pjrt` feature): construction
/// fails with a clear error, so the Pjrt backend degrades to a
/// per-device init failure while everything else keeps working.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtContext {
    manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtContext {
    /// Always fails: this build has no PJRT runtime.
    pub fn new(dir: &std::path::Path) -> Result<PjrtContext> {
        // Validate the manifest anyway so errors stay informative.
        let _ = Manifest::load(dir)?;
        Err(Error::Xla(
            "built without the `pjrt` feature: PJRT execution unavailable \
             (rebuild with --features pjrt and the vendored xla crate)"
            .into(),
        ))
    }

    /// Create with the default artifact directory.
    pub fn with_default_dir() -> Result<PjrtContext> {
        Self::new(&Manifest::default_dir())
    }

    /// The manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        "pjrt-unavailable".into()
    }

    /// Unavailable in this build.
    pub fn run_direct(
        &mut self,
        _name: &str,
        _words: &[u32],
        _nblk: &[u32],
    ) -> Result<(Vec<u32>, ExecTiming)> {
        Err(Error::Xla("PJRT execution unavailable".into()))
    }

    /// Unavailable in this build.
    pub fn run_sliding(&mut self, _name: &str, _words: &[u32]) -> Result<(Vec<u32>, ExecTiming)> {
        Err(Error::Xla("PJRT execution unavailable".into()))
    }
}

#[cfg(feature = "pjrt")]
impl PjrtContext {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn new(dir: &std::path::Path) -> Result<PjrtContext> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtContext {
            client,
            manifest,
            cache: HashMap::new(),
        })
    }

    /// Create with the default artifact directory.
    pub fn with_default_dir() -> Result<PjrtContext> {
        Self::new(&Manifest::default_dir())
    }

    /// The manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn executable(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let spec = self
                .manifest
                .artifacts
                .iter()
                .find(|a| a.name == name)
                .ok_or_else(|| Error::Artifact(format!("unknown artifact {name}")))?
                .clone();
            let path = spec.path.to_str().ok_or_else(|| {
                Error::Artifact(format!("non-utf8 path {}", spec.path.display()))
            })?;
            let proto = xla::HloModuleProto::from_text_file(path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(spec.name.clone(), Executable { spec, exe });
        }
        Ok(&self.cache[name])
    }

    /// Run a direct-hash artifact over pre-padded u32 words
    /// (`lanes * n_blocks * 16` of them) plus the per-lane active block
    /// counts (`lanes` of them).  Returns `lanes * 4` digest words and
    /// per-stage timing.
    pub fn run_direct(
        &mut self,
        name: &str,
        words: &[u32],
        nblk: &[u32],
    ) -> Result<(Vec<u32>, ExecTiming)> {
        // Borrow dance: fetch raw parts before mutable self use.
        self.executable(name)?;
        let client = self.client.clone();
        let exe = &self.cache[name];
        if exe.spec.kind != ArtifactKind::Direct {
            return Err(Error::Artifact(format!("{name} is not a direct artifact")));
        }
        if nblk.len() != exe.spec.lanes {
            return Err(Error::Artifact(format!(
                "{name}: nblk has {} lanes, artifact expects {}",
                nblk.len(),
                exe.spec.lanes
            )));
        }
        let dims = exe.spec.in_dims.clone();
        let out_elems = exe.spec.lanes * 4;
        Self::run_u32(&client, exe, words, Some(nblk), &dims, out_elems)
    }

    /// Run a sliding-window artifact over packed u32 words (`n_bytes/4`).
    /// Returns `n_bytes - window + 1` hashes and per-stage timing.
    pub fn run_sliding(
        &mut self,
        name: &str,
        words: &[u32],
    ) -> Result<(Vec<u32>, ExecTiming)> {
        self.executable(name)?;
        let client = self.client.clone();
        let exe = &self.cache[name];
        if exe.spec.kind != ArtifactKind::Sliding {
            return Err(Error::Artifact(format!("{name} is not a sliding artifact")));
        }
        let dims = exe.spec.in_dims.clone();
        let out_elems = exe.spec.n_bytes - exe.spec.window + 1;
        Self::run_u32(&client, exe, words, None, &dims, out_elems)
    }

    fn run_u32(
        client: &xla::PjRtClient,
        exe: &Executable,
        words: &[u32],
        aux: Option<&[u32]>,
        dims: &[usize],
        out_elems: usize,
    ) -> Result<(Vec<u32>, ExecTiming)> {
        if words.len() != exe.spec.in_words {
            return Err(Error::Artifact(format!(
                "{}: input has {} words, artifact expects {}",
                exe.spec.name,
                words.len(),
                exe.spec.in_words
            )));
        }
        let mut t = ExecTiming::default();

        // Stage 2: host -> device.
        let t0 = Instant::now();
        let mut bufs = vec![client.buffer_from_host_buffer::<u32>(words, dims, None)?];
        if let Some(aux) = aux {
            bufs.push(client.buffer_from_host_buffer::<u32>(aux, &[aux.len()], None)?);
        }
        t.copy_in = t0.elapsed();

        // Stage 3: kernel.
        let t0 = Instant::now();
        let outs = exe.exe.execute_b::<xla::PjRtBuffer>(&bufs)?;
        let out_buf = &outs[0][0];
        t.kernel = t0.elapsed();

        // Stage 4: device -> host.  Lowered with return_tuple=True, so
        // the output is a 1-tuple literal.
        let t0 = Instant::now();
        let lit = out_buf.to_literal_sync()?.to_tuple1()?;
        let out = lit.to_vec::<u32>()?;
        t.copy_out = t0.elapsed();

        if out.len() != out_elems {
            return Err(Error::Artifact(format!(
                "{}: output has {} elems, expected {}",
                exe.spec.name,
                out.len(),
                out_elems
            )));
        }
        Ok((out, t))
    }
}

/// Pack a byte slice into little-endian u32 words, zero-padding the tail
/// to `target_words` (artifact input width).
pub fn pack_words(data: &[u8], target_words: usize) -> Vec<u32> {
    assert!(data.len().div_ceil(4) <= target_words, "data exceeds artifact");
    let mut out = vec![0u32; target_words];
    let mut chunks = data.chunks_exact(4);
    let mut i = 0;
    for c in &mut chunks {
        out[i] = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        i += 1;
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut b = [0u8; 4];
        b[..rem.len()].copy_from_slice(rem);
        out[i] = u32::from_le_bytes(b);
    }
    out
}

/// RFC 1321 padding of one segment into a caller-provided word buffer
/// (an artifact lane of `n_blocks * 16` words).  The padded message
/// occupies the first `padded_words(seg.len())` words; the rest of the
/// lane is zeroed.  Returns the active 64-byte block count for the
/// lane — the artifact's second input.
/// (Mirrors `pack_segments` in python/compile/kernels/md5.py.)
pub fn pad_segment_into(seg: &[u8], lane_words: &mut [u32]) -> u32 {
    let used = padded_words(seg.len());
    assert!(used <= lane_words.len(), "segment exceeds artifact lane");
    // Zero the lane, then write data words, 0x80 terminator, bit length.
    for w in lane_words.iter_mut() {
        *w = 0;
    }
    let mut chunks = seg.chunks_exact(4);
    let mut i = 0;
    for c in &mut chunks {
        lane_words[i] = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        i += 1;
    }
    let rem = chunks.remainder();
    let mut b = [0u8; 4];
    b[..rem.len()].copy_from_slice(rem);
    b[rem.len()] = 0x80;
    lane_words[i] = u32::from_le_bytes(b);
    // (When rem is empty the 0x80 terminator is the low byte of word i.)
    let bit_len = (seg.len() as u64).wrapping_mul(8);
    lane_words[used - 2] = (bit_len & 0xFFFF_FFFF) as u32;
    lane_words[used - 1] = (bit_len >> 32) as u32;
    (used / 16) as u32
}

/// Number of padded words a segment of `seg_bytes` occupies (must match
/// aot.py's `padded_words`).
pub fn padded_words(seg_bytes: usize) -> usize {
    // data + 1 (0x80) + pad to 56 mod 64 + 8 length bytes
    let with_term = seg_bytes + 1;
    let padded = with_term + ((56usize.wrapping_sub(with_term)) % 64) + 8;
    padded / 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::md5;

    #[test]
    fn pack_words_le() {
        let w = pack_words(&[1, 0, 0, 0, 2, 0, 0], 3);
        assert_eq!(w, vec![1, 2, 0]);
    }

    #[test]
    #[should_panic]
    fn pack_words_overflow_panics() {
        pack_words(&[0u8; 16], 3);
    }

    #[test]
    fn padded_words_matches_python() {
        // From aot.py's test: 256 -> 80 words, 4096 -> 1040 words.
        assert_eq!(padded_words(256), 80);
        assert_eq!(padded_words(4096), 1040);
        assert_eq!(padded_words(0), 16);
        assert_eq!(padded_words(55), 16);
        assert_eq!(padded_words(56), 32);
        assert_eq!(padded_words(64), 32);
    }

    /// pad_segment_into must produce the exact byte stream MD5 would
    /// compress — verified by running the *scalar* MD5 over the padded
    /// words with a no-finalize compress loop.
    #[test]
    fn pad_segment_matches_md5_padding() {
        for n in [0usize, 1, 3, 4, 55, 56, 63, 64, 100, 256] {
            let seg: Vec<u8> = (0..n).map(|i| (i * 13 + 7) as u8).collect();
            let words = padded_words(n.max(1).min(256).max(n)); // exact-size lane
            let mut lane = vec![0u32; padded_words(n)];
            pad_segment_into(&seg, &mut lane);
            // Rebuild bytes from words and feed MD5's compress via a
            // reference: digest of padded bytes interpreted as raw blocks
            // must equal md5(seg).  We verify by re-deriving the digest
            // through the same construction the kernel uses.
            let bytes: Vec<u8> = lane.iter().flat_map(|w| w.to_le_bytes()).collect();
            // Padding correctness: 0x80 right after data, length at end.
            assert_eq!(bytes[n], 0x80, "n={n}");
            let bit_len = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
            assert_eq!(bit_len, 8 * n as u64, "n={n}");
            // All padding bytes between are zero.
            for (i, &b) in bytes[n + 1..bytes.len() - 8].iter().enumerate() {
                assert_eq!(b, 0, "n={n} pad byte {i}");
            }
            let _ = words;
            let _ = md5(&seg); // digest correctness is covered by the
                               // artifact-execution integration test
        }
    }
}
