//! Hash engine implementations.
//!
//! Besides the blocking primitives, every engine offers *non-blocking
//! submission* ([`HashEngine::submit_direct_batch`] /
//! [`HashEngine::submit_window_hashes`]): the caller gets a ticket
//! immediately and redeems it later, so hashing of write-buffer N can
//! overlap the transfers of buffer N-1 — the paper's pipeline, surfaced
//! in the API.  CPU and oracle engines default to the synchronous path
//! (the work happens at submit time, nothing is hidden); the GPU engine
//! rides the crystal `submit*`/[`JobHandle`] machinery so the device
//! works while the client keeps moving data.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::{ClientConfig, HashEngineKind};
use crate::crystal::{BackendKind, CrystalOpts, DeviceOp, JobHandle, Master};
use crate::crystal::task::JobOut;
use crate::hash::{finalize_digests, window_hashes, Digest, Md5};
use crate::metrics::{Stage, StageBreakdown};
use crate::{Error, Result};

// ------------------------------------------------------------- tickets ----

/// How much hash-engine time a redeemed ticket cost the caller, split
/// into the part that stalled the pipeline and the part that ran while
/// the caller was doing something else (the paper's hidden hashing).
#[derive(Debug, Clone, Copy, Default)]
pub struct HashTiming {
    /// Engine time the caller actually blocked on (submit-side compute
    /// for sync engines, `wait` + host postprocess for async ones).
    pub exposed: Duration,
    /// Engine time that overlapped the caller's other work.  Always zero
    /// for synchronous engines.
    pub hidden: Duration,
    /// Depth (blocks) of the device batch that served this ticket: the
    /// submission size on dedicated engines, the coalesced cross-session
    /// batch size on the shared hash service.  Zero for window tickets.
    pub batch_blocks: usize,
    /// Time the submission lingered in a shared-service queue before
    /// dispatch.  Zero on dedicated engines.
    pub svc_wait: Duration,
}

impl HashTiming {
    fn sync(cost: Duration) -> Self {
        HashTiming {
            exposed: cost,
            ..HashTiming::default()
        }
    }

    /// Split `engine_time` between exposed (`blocked`) and hidden.
    fn split(engine_time: Duration, blocked: Duration) -> Self {
        HashTiming {
            exposed: blocked,
            hidden: engine_time.saturating_sub(blocked),
            ..HashTiming::default()
        }
    }
}

enum DigestsInner {
    /// Result computed at submit time (sync engines).
    Ready(Result<Vec<Digest>>),
    /// In-flight crystal batch job; finalized on redeem.
    Crystal {
        handle: JobHandle,
        n_blocks: usize,
        breakdown: Arc<Mutex<StageBreakdown>>,
    },
    /// Work in flight somewhere else (e.g. the shared hash service);
    /// the closure blocks until it resolves and reports its own timing.
    Deferred(Box<dyn FnOnce() -> Result<(Vec<Digest>, HashTiming)> + Send>),
}

/// In-flight batch of block digests (from
/// [`HashEngine::submit_direct_batch`]).
pub struct DigestsTicket {
    inner: DigestsInner,
    sync_cost: Duration,
}

impl DigestsTicket {
    /// A ticket whose work already happened at submit time.
    pub fn ready(result: Result<Vec<Digest>>, cost: Duration) -> Self {
        DigestsTicket {
            inner: DigestsInner::Ready(result),
            sync_cost: cost,
        }
    }

    /// A ticket backed by a blocking resolver (used by engines — like the
    /// shared hash service — whose in-flight state lives outside the
    /// crystal runtime).  The closure runs once, on `wait`.
    pub fn deferred<F>(resolve: F) -> Self
    where
        F: FnOnce() -> Result<(Vec<Digest>, HashTiming)> + Send + 'static,
    {
        DigestsTicket {
            inner: DigestsInner::Deferred(Box::new(resolve)),
            sync_cost: Duration::ZERO,
        }
    }

    /// Block until the digests are available.
    pub fn wait(self) -> Result<(Vec<Digest>, HashTiming)> {
        match self.inner {
            DigestsInner::Ready(r) => {
                let digests = r?;
                let mut t = HashTiming::sync(self.sync_cost);
                t.batch_blocks = digests.len();
                Ok((digests, t))
            }
            DigestsInner::Deferred(resolve) => resolve(),
            DigestsInner::Crystal {
                handle,
                n_blocks,
                breakdown,
            } => {
                let t0 = Instant::now();
                let r = handle.wait()?;
                let blocked = t0.elapsed();
                let JobOut::DigestGroups(groups) = &r.out else {
                    return Err(Error::Crystal("wrong output kind".into()));
                };
                if groups.len() != n_blocks {
                    return Err(Error::Crystal(format!(
                        "batch returned {} groups for {} blocks",
                        groups.len(),
                        n_blocks
                    )));
                }
                // Host-side final stage (paper: the CPU computes the
                // final hash of the intermediate hashes).
                let t1 = Instant::now();
                let out: Vec<Digest> = groups.iter().map(|g| finalize_digests(g)).collect();
                let post = t1.elapsed();
                {
                    let mut b = breakdown.lock().unwrap();
                    r.timing.record(&mut b);
                    b.add(Stage::Postprocess, post);
                }
                let mut t = HashTiming::split(r.timing.total() + post, blocked + post);
                t.batch_blocks = n_blocks;
                Ok((out, t))
            }
        }
    }
}

enum WindowInner {
    Ready(Result<Vec<u32>>),
    Crystal {
        handle: JobHandle,
        breakdown: Arc<Mutex<StageBreakdown>>,
    },
    Deferred(Box<dyn FnOnce() -> Result<(Vec<u32>, HashTiming)> + Send>),
}

/// In-flight sliding-window hash job (from
/// [`HashEngine::submit_window_hashes`]).
pub struct WindowTicket {
    inner: WindowInner,
    sync_cost: Duration,
}

impl WindowTicket {
    /// A ticket whose work already happened at submit time.
    pub fn ready(result: Result<Vec<u32>>, cost: Duration) -> Self {
        WindowTicket {
            inner: WindowInner::Ready(result),
            sync_cost: cost,
        }
    }

    /// A ticket backed by a blocking resolver (see
    /// [`DigestsTicket::deferred`]).
    pub fn deferred<F>(resolve: F) -> Self
    where
        F: FnOnce() -> Result<(Vec<u32>, HashTiming)> + Send + 'static,
    {
        WindowTicket {
            inner: WindowInner::Deferred(Box::new(resolve)),
            sync_cost: Duration::ZERO,
        }
    }

    /// Block until the window hashes are available.
    pub fn wait(self) -> Result<(Vec<u32>, HashTiming)> {
        match self.inner {
            WindowInner::Ready(r) => Ok((r?, HashTiming::sync(self.sync_cost))),
            WindowInner::Deferred(resolve) => resolve(),
            WindowInner::Crystal { handle, breakdown } => {
                let t0 = Instant::now();
                let r = handle.wait()?;
                let blocked = t0.elapsed();
                let JobOut::Hashes(h) = r.out else {
                    return Err(Error::Crystal("wrong output kind".into()));
                };
                r.timing.record(&mut breakdown.lock().unwrap());
                Ok((h, HashTiming::split(r.timing.total(), blocked)))
            }
        }
    }
}

/// How a CPU engine computes window hashes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WindowHashMode {
    /// MD5 of every overlapping window, low 4 digest bytes as the hash —
    /// the paper's CPU implementation (7–51 MBps on 2008 hardware, the
    /// bottleneck that motivates GPU offloading).
    PaperMd5,
    /// The rolling polynomial fingerprint (what the accelerator runs).
    /// Ablation mode: shows how a modern CPU CDC implementation shifts
    /// the crossover points.
    Rolling,
}

/// A provider of the two hashing primitives.
pub trait HashEngine: Send + Sync {
    /// Block digest via the parallel Merkle–Damgård construction.
    fn direct_hash(&self, data: &[u8]) -> Result<Digest>;

    /// Digest a batch of blocks (the SAI submits one write-buffer's
    /// blocks at once — the batching the paper adds for GPU offload).
    fn direct_hash_batch(&self, blocks: &[&[u8]]) -> Result<Vec<Digest>> {
        blocks.iter().map(|b| self.direct_hash(b)).collect()
    }

    /// Hashes of every overlapping window of `data` (window width is the
    /// engine's compiled/configured width).
    fn window_hashes(&self, data: &[u8]) -> Result<Vec<u32>>;

    /// Non-blocking digest submission: hash a batch of blocks, returning
    /// a ticket the caller redeems later.  The default implementation is
    /// the synchronous path (the work happens here and the ticket is
    /// already resolved); async engines override it so the caller can
    /// overlap hashing with transfers.
    fn submit_direct_batch(&self, blocks: Arc<Vec<Vec<u8>>>) -> Result<DigestsTicket> {
        let t0 = Instant::now();
        let refs: Vec<&[u8]> = blocks.iter().map(|b| b.as_slice()).collect();
        let r = self.direct_hash_batch(&refs);
        Ok(DigestsTicket::ready(r, t0.elapsed()))
    }

    /// Non-blocking window-hash submission (see
    /// [`submit_direct_batch`](Self::submit_direct_batch)).
    fn submit_window_hashes(&self, data: Vec<u8>) -> Result<WindowTicket> {
        let t0 = Instant::now();
        let r = self.window_hashes(&data);
        Ok(WindowTicket::ready(r, t0.elapsed()))
    }

    /// Window width used by [`window_hashes`](Self::window_hashes).
    fn window(&self) -> usize;

    /// Engine label ("cpu", "gpu", "oracle").
    fn name(&self) -> &'static str;

    /// Per-stage timing breakdown accumulated so far (GPU engines).
    fn stage_breakdown(&self) -> Option<StageBreakdown> {
        None
    }
}

// ---------------------------------------------------------------- CPU ----

/// CPU engine: the paper's CA-CPU configuration.
pub struct CpuEngine {
    threads: usize,
    seg_bytes: usize,
    window: usize,
    p: u32,
    mode: WindowHashMode,
}

impl CpuEngine {
    /// `threads` hashing threads; `seg_bytes` is the Merkle–Damgård
    /// segment size (must match the accelerator artifacts for identity).
    pub fn new(threads: usize, seg_bytes: usize, mode: WindowHashMode) -> Self {
        CpuEngine {
            threads: threads.max(1),
            seg_bytes,
            window: crate::hash::DEFAULT_WINDOW,
            p: crate::hash::DEFAULT_P,
            mode,
        }
    }

    fn window_md5(&self, data: &[u8]) -> Vec<u32> {
        let w = self.window;
        if data.len() < w {
            return Vec::new();
        }
        let n_out = data.len() - w + 1;
        let mut out = vec![0u32; n_out];
        let threads = self.threads.min(n_out.max(1));
        if threads <= 1 {
            for (i, o) in out.iter_mut().enumerate() {
                *o = md5_window_value(&data[i..i + w]);
            }
            return out;
        }
        let per = n_out.div_ceil(threads);
        std::thread::scope(|s| {
            for (t, chunk) in out.chunks_mut(per).enumerate() {
                let start = t * per;
                s.spawn(move || {
                    for (k, o) in chunk.iter_mut().enumerate() {
                        let i = start + k;
                        *o = md5_window_value(&data[i..i + w]);
                    }
                });
            }
        });
        out
    }
}

/// Low 4 bytes (LE) of the window's MD5 — the paper's window hash value.
fn md5_window_value(win: &[u8]) -> u32 {
    let mut ctx = Md5::new();
    ctx.update(win);
    let d = ctx.finalize();
    u32::from_le_bytes([d[0], d[1], d[2], d[3]])
}

impl HashEngine for CpuEngine {
    fn direct_hash(&self, data: &[u8]) -> Result<Digest> {
        Ok(crate::hash::merkle::direct_hash_cpu_mt(
            data,
            self.seg_bytes,
            self.threads,
        ))
    }

    fn window_hashes(&self, data: &[u8]) -> Result<Vec<u32>> {
        Ok(match self.mode {
            WindowHashMode::PaperMd5 => self.window_md5(data),
            WindowHashMode::Rolling => window_hashes(data, self.window, self.p),
        })
    }

    fn window(&self) -> usize {
        self.window
    }

    fn name(&self) -> &'static str {
        "cpu"
    }
}

// ---------------------------------------------------------------- GPU ----

/// Accelerator engine: submits jobs to the crystal runtime and finishes
/// the host-side stage (hash-of-hashes) itself — CA-GPU.
pub struct GpuEngine {
    master: Arc<Master>,
    seg_bytes: usize,
    window: usize,
    breakdown: Arc<Mutex<StageBreakdown>>,
}

impl GpuEngine {
    /// Wrap an existing crystal runtime.
    pub fn new(master: Arc<Master>, seg_bytes: usize, window: usize) -> Self {
        GpuEngine {
            master,
            seg_bytes,
            window,
            breakdown: Arc::new(Mutex::new(StageBreakdown::new())),
        }
    }

    /// The underlying crystal runtime (stats, drain).
    pub fn master(&self) -> &Arc<Master> {
        &self.master
    }
}

impl HashEngine for GpuEngine {
    fn direct_hash(&self, data: &[u8]) -> Result<Digest> {
        Ok(self.direct_hash_batch(&[data])?[0])
    }

    fn direct_hash_batch(&self, blocks: &[&[u8]]) -> Result<Vec<Digest>> {
        let owned: Arc<Vec<Vec<u8>>> = Arc::new(blocks.iter().map(|b| b.to_vec()).collect());
        let (digests, _) = self.submit_direct_batch(owned)?.wait()?;
        Ok(digests)
    }

    fn window_hashes(&self, data: &[u8]) -> Result<Vec<u32>> {
        let (hashes, _) = self.submit_window_hashes(data.to_vec())?.wait()?;
        Ok(hashes)
    }

    fn submit_direct_batch(&self, blocks: Arc<Vec<Vec<u8>>>) -> Result<DigestsTicket> {
        if blocks.is_empty() {
            return Ok(DigestsTicket::ready(Ok(Vec::new()), Duration::ZERO));
        }
        // One crystal job for the whole batch: the planner packs every
        // block's segments into as few device executions as possible
        // (per-block submission paid one execution per block —
        // EXPERIMENTS.md section Perf).  Submission is non-blocking; the
        // device hashes while the caller keeps chunking/transferring.
        let n_blocks = blocks.len();
        let handle = self.master.submit_batch(self.seg_bytes, blocks);
        Ok(DigestsTicket {
            inner: DigestsInner::Crystal {
                handle,
                n_blocks,
                breakdown: self.breakdown.clone(),
            },
            sync_cost: Duration::ZERO,
        })
    }

    fn submit_window_hashes(&self, data: Vec<u8>) -> Result<WindowTicket> {
        let handle = self.master.submit(DeviceOp::SlidingWindow, Arc::new(data));
        Ok(WindowTicket {
            inner: WindowInner::Crystal {
                handle,
                breakdown: self.breakdown.clone(),
            },
            sync_cost: Duration::ZERO,
        })
    }

    fn window(&self) -> usize {
        self.window
    }

    fn name(&self) -> &'static str {
        "gpu"
    }

    fn stage_breakdown(&self) -> Option<StageBreakdown> {
        Some(self.breakdown.lock().unwrap().clone())
    }
}

// ------------------------------------------------------------- Oracle ----

/// CA-Infinite: "computes the hash function instantly" (paper §4.4).
/// Uses a cheap 128-bit mixing fingerprint instead of MD5 — collision-
/// safe for dedup experiments, near-free to compute — and the rolling
/// fingerprint for windows.
pub struct OracleEngine {
    window: usize,
    p: u32,
}

impl OracleEngine {
    /// Default-window oracle.
    pub fn new() -> Self {
        OracleEngine {
            window: crate::hash::DEFAULT_WINDOW,
            p: crate::hash::DEFAULT_P,
        }
    }
}

impl Default for OracleEngine {
    fn default() -> Self {
        Self::new()
    }
}

/// Fast 128-bit fingerprint (two independent 64-bit lanes of
/// multiply-xor mixing over 8-byte words).
fn oracle_fingerprint(data: &[u8]) -> Digest {
    const M1: u64 = 0x9E37_79B9_7F4A_7C15;
    const M2: u64 = 0xC2B2_AE3D_27D4_EB4F;
    let mut h1 = 0x8422_2325_CBF2_9CE4u64 ^ (data.len() as u64).wrapping_mul(M1);
    let mut h2 = 0xCBF2_9CE4_8422_2325u64 ^ (data.len() as u64).wrapping_mul(M2);
    let mut chunks = data.chunks_exact(8);
    for c in &mut chunks {
        let w = u64::from_le_bytes(c.try_into().unwrap());
        h1 = (h1 ^ w).wrapping_mul(M1).rotate_left(31);
        h2 = (h2 ^ w.rotate_left(17)).wrapping_mul(M2).rotate_left(29);
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut b = [0u8; 8];
        b[..rem.len()].copy_from_slice(rem);
        let w = u64::from_le_bytes(b);
        h1 = (h1 ^ w).wrapping_mul(M1).rotate_left(31);
        h2 = (h2 ^ w.rotate_left(17)).wrapping_mul(M2).rotate_left(29);
    }
    h1 ^= h1 >> 33;
    h1 = h1.wrapping_mul(M2);
    h1 ^= h1 >> 29;
    h2 ^= h2 >> 31;
    h2 = h2.wrapping_mul(M1);
    h2 ^= h2 >> 27;
    let mut out = [0u8; 16];
    out[..8].copy_from_slice(&h1.to_le_bytes());
    out[8..].copy_from_slice(&h2.to_le_bytes());
    out
}

impl HashEngine for OracleEngine {
    fn direct_hash(&self, data: &[u8]) -> Result<Digest> {
        Ok(oracle_fingerprint(data))
    }

    fn window_hashes(&self, data: &[u8]) -> Result<Vec<u32>> {
        Ok(window_hashes(data, self.window, self.p))
    }

    fn window(&self) -> usize {
        self.window
    }

    fn name(&self) -> &'static str {
        "oracle"
    }
}

// ------------------------------------------------------------ factory ----

/// Build the engine a [`ClientConfig`] asks for.  GPU engines get a
/// dedicated crystal runtime over the PJRT backend with artifacts from
/// `artifact_dir` (None = default directory).
pub fn build_engine(
    cfg: &ClientConfig,
    artifact_dir: Option<std::path::PathBuf>,
) -> Result<Arc<dyn HashEngine>> {
    let dir =
        artifact_dir.unwrap_or_else(crate::runtime::artifacts::Manifest::default_dir);
    Ok(match cfg.engine {
        HashEngineKind::Cpu { threads } => Arc::new(CpuEngine::new(
            threads,
            cfg.segment_bytes,
            WindowHashMode::PaperMd5,
        )),
        HashEngineKind::Gpu {
            devices,
            buffer_reuse,
            overlap,
        } => {
            let opts = CrystalOpts {
                devices,
                buffer_reuse,
                overlap,
                ..CrystalOpts::optimized(BackendKind::Pjrt { artifact_dir: dir })
            };
            let master = Arc::new(Master::new(opts)?);
            Arc::new(GpuEngine::new(
                master,
                cfg.segment_bytes,
                crate::hash::DEFAULT_WINDOW,
            ))
        }
        HashEngineKind::Oracle => Arc::new(OracleEngine::new()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crystal::MockTuning;
    use crate::hash::direct_hash_cpu;
    use crate::runtime::artifacts::Manifest;
    use crate::util::Rng;

    fn gpu_engine_mock() -> GpuEngine {
        let opts = CrystalOpts::optimized(BackendKind::Mock {
            artifact_dir: Manifest::default_dir(),
            tuning: MockTuning::default(),
        });
        GpuEngine::new(Arc::new(Master::new(opts).unwrap()), 4096, 48)
    }

    #[test]
    fn cpu_direct_uses_construction() {
        let e = CpuEngine::new(2, 4096, WindowHashMode::Rolling);
        let data = Rng::new(1).bytes(50_000);
        assert_eq!(e.direct_hash(&data).unwrap(), direct_hash_cpu(&data, 4096));
    }

    #[test]
    fn gpu_and_cpu_direct_agree() {
        let gpu = gpu_engine_mock();
        let cpu = CpuEngine::new(1, 4096, WindowHashMode::Rolling);
        for len in [0usize, 100, 4096, 70_000] {
            let data = Rng::new(len as u64).bytes(len);
            assert_eq!(
                gpu.direct_hash(&data).unwrap(),
                cpu.direct_hash(&data).unwrap(),
                "len={len}"
            );
        }
    }

    #[test]
    fn gpu_batch_matches_individual() {
        let gpu = gpu_engine_mock();
        let blocks: Vec<Vec<u8>> = (0..5).map(|i| Rng::new(i).bytes(10_000)).collect();
        let refs: Vec<&[u8]> = blocks.iter().map(|b| b.as_slice()).collect();
        let batch = gpu.direct_hash_batch(&refs).unwrap();
        for (b, d) in blocks.iter().zip(&batch) {
            assert_eq!(gpu.direct_hash(b).unwrap(), *d);
        }
    }

    #[test]
    fn gpu_window_hashes_match_rolling_cpu() {
        let gpu = gpu_engine_mock();
        let cpu = CpuEngine::new(1, 4096, WindowHashMode::Rolling);
        let data = Rng::new(3).bytes(70_000);
        assert_eq!(
            gpu.window_hashes(&data).unwrap(),
            cpu.window_hashes(&data).unwrap()
        );
    }

    #[test]
    fn paper_md5_window_mode_differs_but_same_len() {
        let md5e = CpuEngine::new(2, 4096, WindowHashMode::PaperMd5);
        let rolle = CpuEngine::new(2, 4096, WindowHashMode::Rolling);
        let data = Rng::new(4).bytes(1000);
        let a = md5e.window_hashes(&data).unwrap();
        let b = rolle.window_hashes(&data).unwrap();
        assert_eq!(a.len(), b.len());
        assert_ne!(a, b);
    }

    #[test]
    fn md5_window_mode_thread_invariant() {
        let e1 = CpuEngine::new(1, 4096, WindowHashMode::PaperMd5);
        let e8 = CpuEngine::new(8, 4096, WindowHashMode::PaperMd5);
        let data = Rng::new(5).bytes(2000);
        assert_eq!(
            e1.window_hashes(&data).unwrap(),
            e8.window_hashes(&data).unwrap()
        );
    }

    #[test]
    fn oracle_deterministic_and_distinct() {
        let o = OracleEngine::new();
        let a = Rng::new(6).bytes(1000);
        let b = Rng::new(7).bytes(1000);
        assert_eq!(o.direct_hash(&a).unwrap(), o.direct_hash(&a).unwrap());
        assert_ne!(o.direct_hash(&a).unwrap(), o.direct_hash(&b).unwrap());
    }

    #[test]
    fn oracle_fingerprint_avalanche() {
        // Flipping one bit should change the fingerprint.
        let mut data = Rng::new(8).bytes(256);
        let d1 = oracle_fingerprint(&data);
        data[100] ^= 1;
        let d2 = oracle_fingerprint(&data);
        assert_ne!(d1, d2);
        let diff: u32 = d1
            .iter()
            .zip(&d2)
            .map(|(a, b)| (a ^ b).count_ones())
            .sum();
        assert!(diff > 20, "weak avalanche: {diff} bits");
    }

    #[test]
    fn stage_breakdown_accumulates() {
        let gpu = gpu_engine_mock();
        let data = Rng::new(9).bytes(10_000);
        gpu.direct_hash(&data).unwrap();
        gpu.window_hashes(&data).unwrap();
        let b = gpu.stage_breakdown().unwrap();
        assert_eq!(b.tasks(), 2);
    }

    #[test]
    fn sync_tickets_match_blocking_path() {
        let e = CpuEngine::new(2, 4096, WindowHashMode::Rolling);
        let blocks: Vec<Vec<u8>> = (0..4).map(|i| Rng::new(i).bytes(5000)).collect();
        let refs: Vec<&[u8]> = blocks.iter().map(|b| b.as_slice()).collect();
        let want = e.direct_hash_batch(&refs).unwrap();
        let (got, t) = e
            .submit_direct_batch(Arc::new(blocks.clone()))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(got, want);
        // Sync path: all engine time is exposed, nothing hidden.
        assert_eq!(t.hidden, Duration::ZERO);

        let data = Rng::new(11).bytes(20_000);
        let want = e.window_hashes(&data).unwrap();
        let (got, t) = e.submit_window_hashes(data).unwrap().wait().unwrap();
        assert_eq!(got, want);
        assert_eq!(t.hidden, Duration::ZERO);
    }

    #[test]
    fn gpu_tickets_match_blocking_path() {
        let gpu = gpu_engine_mock();
        let cpu = CpuEngine::new(1, 4096, WindowHashMode::Rolling);
        let blocks: Vec<Vec<u8>> = (0..3).map(|i| Rng::new(i + 50).bytes(9000)).collect();
        let (got, _) = gpu
            .submit_direct_batch(Arc::new(blocks.clone()))
            .unwrap()
            .wait()
            .unwrap();
        for (b, d) in blocks.iter().zip(&got) {
            assert_eq!(cpu.direct_hash(b).unwrap(), *d);
        }
        let data = Rng::new(60).bytes(70_000);
        let (got, _) = gpu
            .submit_window_hashes(data.clone())
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(got, cpu.window_hashes(&data).unwrap());
    }

    #[test]
    fn gpu_ticket_hides_hash_time_behind_other_work() {
        // A mock device with a fixed per-step delay: if the caller does
        // 20 ms of "other work" between submit and wait, the ~5 ms of
        // device time must show up as hidden, not exposed.
        let opts = CrystalOpts::optimized(BackendKind::Mock {
            artifact_dir: Manifest::default_dir(),
            tuning: MockTuning {
                fixed_delay: std::time::Duration::from_millis(5),
                ..Default::default()
            },
        });
        let gpu = GpuEngine::new(Arc::new(Master::new(opts).unwrap()), 4096, 48);
        let blocks: Vec<Vec<u8>> = vec![Rng::new(1).bytes(8192)];
        let ticket = gpu.submit_direct_batch(Arc::new(blocks)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        let (_, t) = ticket.wait().unwrap();
        assert!(
            t.hidden >= Duration::from_millis(2),
            "hidden {:?} should cover the device delay",
            t.hidden
        );
    }

    #[test]
    fn factory_builds_cpu_and_oracle() {
        let cfg = ClientConfig::ca_cpu_fixed(2);
        assert_eq!(build_engine(&cfg, None).unwrap().name(), "cpu");
        let cfg = ClientConfig::ca_infinite(crate::config::CaMode::Fixed);
        assert_eq!(build_engine(&cfg, None).unwrap().name(), "oracle");
    }
}
