//! hashgpu — the HashGPU analog: the two hashing primitives storage
//! systems need (direct hashing, sliding-window hashing) behind a common
//! [`HashEngine`] trait, with CPU, accelerator (crystal), and oracle
//! implementations — the paper's CA-CPU / CA-GPU / CA-Infinite configs.
//!
//! Identity guarantees:
//! * **Direct hashing** uses the parallel Merkle–Damgård construction on
//!   *every* engine (CPU and GPU paths produce identical block digests,
//!   so mixed deployments agree on block identity).  The final
//!   hash-of-hashes always runs on the host CPU, as in the paper.
//! * **Window hashing** is engine-specific by design: the CPU baseline
//!   reproduces the paper's implementation (MD5 of every overlapping
//!   window — the cost that motivates offloading), while the
//!   accelerator runs the TPU-adapted rolling fingerprint
//!   (DESIGN.md §Hardware-Adaptation).  Each configuration is
//!   self-consistent; expected chunk-size statistics are identical.

pub mod engine;

pub use engine::{
    build_engine, CpuEngine, DigestsTicket, GpuEngine, HashEngine, HashTiming,
    OracleEngine, WindowHashMode, WindowTicket,
};
