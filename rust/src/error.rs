//! Unified error type for the crate.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified error enumeration across all subsystems.
#[derive(Debug)]
pub enum Error {
    /// I/O error (files, sockets).
    Io(std::io::Error),
    /// XLA / PJRT error from the `xla` crate.
    Xla(String),
    /// Artifact registry problems (missing manifest, no bucket fits, ...).
    Artifact(String),
    /// Wire-protocol violations.
    Proto(String),
    /// Metadata-manager level errors (unknown file, version conflict, ...).
    Manager(String),
    /// Storage-node level errors (unknown block, ...).
    Node(String),
    /// Accelerator runtime errors (queue shut down, device failure, ...).
    Crystal(String),
    /// Configuration errors.
    Config(String),
    /// Anything else.
    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Xla(e) => write!(f, "xla: {e}"),
            Error::Artifact(e) => write!(f, "artifact: {e}"),
            Error::Proto(e) => write!(f, "proto: {e}"),
            Error::Manager(e) => write!(f, "manager: {e}"),
            Error::Node(e) => write!(f, "node: {e}"),
            Error::Crystal(e) => write!(f, "crystal: {e}"),
            Error::Config(e) => write!(f, "config: {e}"),
            Error::Other(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Storage sessions implement `std::io::{Read, Write}`; their internal
/// errors cross the trait boundary as `io::Error` (the original
/// [`Error`] is preserved as the source, or unwrapped if it was I/O).
impl From<Error> for std::io::Error {
    fn from(e: Error) -> Self {
        match e {
            Error::Io(io) => io,
            other => std::io::Error::other(other),
        }
    }
}

impl Error {
    /// Shorthand constructor for ad-hoc errors.
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Other(s.into())
    }
}
