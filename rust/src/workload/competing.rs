//! Competing applications for the §4.5 interference study.
//!
//! * [`ComputeBoundApp`] — multithreaded prime search (the paper's
//!   compute-bound competitor).
//! * [`IoBoundApp`] — metadata-heavy file churn standing in for the
//!   Apache httpd compile (the paper's I/O-bound competitor): bursts of
//!   small reads/writes interleaved with short compute.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::Rng;

/// Multithreaded prime counting by trial division.
#[derive(Debug, Clone)]
pub struct ComputeBoundApp {
    /// Search numbers in `[2, limit)`.
    pub limit: u64,
    /// Worker threads.
    pub threads: usize,
}

impl ComputeBoundApp {
    /// Default sizing: a few hundred ms of work on one core.
    pub fn new(limit: u64, threads: usize) -> Self {
        ComputeBoundApp { limit, threads }
    }

    /// Run to completion; returns (elapsed, primes found).
    pub fn run(&self) -> (Duration, u64) {
        let t0 = Instant::now();
        let count = Arc::new(AtomicU64::new(0));
        let next = Arc::new(AtomicU64::new(2));
        std::thread::scope(|s| {
            for _ in 0..self.threads.max(1) {
                let count = count.clone();
                let next = next.clone();
                let limit = self.limit;
                s.spawn(move || {
                    const STRIDE: u64 = 256;
                    loop {
                        let lo = next.fetch_add(STRIDE, Ordering::Relaxed);
                        if lo >= limit {
                            break;
                        }
                        let hi = (lo + STRIDE).min(limit);
                        let mut local = 0;
                        for n in lo..hi {
                            if is_prime(n) {
                                local += 1;
                            }
                        }
                        count.fetch_add(local, Ordering::Relaxed);
                    }
                });
            }
        });
        (t0.elapsed(), count.load(Ordering::Relaxed))
    }

    /// Run repeatedly until `stop` flips; returns completed iterations
    /// and total elapsed (for slowdown-under-load measurements).
    pub fn run_until(&self, stop: &AtomicBool) -> (u64, Duration) {
        let t0 = Instant::now();
        let mut iters = 0;
        while !stop.load(Ordering::Relaxed) {
            self.run();
            iters += 1;
        }
        (iters, t0.elapsed())
    }
}

fn is_prime(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    if n % 2 == 0 {
        return n == 2;
    }
    let mut d = 3;
    while d * d <= n {
        if n % d == 0 {
            return false;
        }
        d += 2;
    }
    true
}

/// File-churn workload emulating a software build: create, read, rewrite
/// and delete many small files under a scratch directory.
#[derive(Debug)]
pub struct IoBoundApp {
    /// Scratch directory (caller-owned; created if missing).
    pub dir: PathBuf,
    /// Number of files per pass.
    pub files: usize,
    /// Bytes per file.
    pub file_size: usize,
    /// Passes per run.
    pub passes: usize,
}

impl IoBoundApp {
    /// Default sizing comparable to a small compile tree.
    pub fn new(dir: PathBuf) -> Self {
        IoBoundApp {
            dir,
            files: 128,
            file_size: 64 * 1024,
            passes: 2,
        }
    }

    /// Run to completion; returns elapsed.
    pub fn run(&self) -> std::io::Result<Duration> {
        let t0 = Instant::now();
        std::fs::create_dir_all(&self.dir)?;
        let mut rng = Rng::new(0x10B0);
        for pass in 0..self.passes {
            // "Compile": write object files.
            for i in 0..self.files {
                let path = self.dir.join(format!("obj_{pass}_{i}.o"));
                std::fs::write(&path, rng.bytes(self.file_size))?;
            }
            // "Link": read everything back.
            let mut total = 0usize;
            for i in 0..self.files {
                let path = self.dir.join(format!("obj_{pass}_{i}.o"));
                total += std::fs::read(&path)?.len();
            }
            assert_eq!(total, self.files * self.file_size);
            // "Clean": remove.
            for i in 0..self.files {
                let path = self.dir.join(format!("obj_{pass}_{i}.o"));
                std::fs::remove_file(&path)?;
            }
        }
        Ok(t0.elapsed())
    }

    /// Run repeatedly until `stop` flips; returns completed passes and
    /// elapsed.
    pub fn run_until(&self, stop: &AtomicBool) -> std::io::Result<(u64, Duration)> {
        let t0 = Instant::now();
        let mut iters = 0;
        while !stop.load(Ordering::Relaxed) {
            self.run()?;
            iters += 1;
        }
        Ok((iters, t0.elapsed()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prime_counts_correct() {
        assert!(is_prime(2));
        assert!(is_prime(97));
        assert!(!is_prime(1));
        assert!(!is_prime(91)); // 7*13
        let (_, n) = ComputeBoundApp::new(100, 2).run();
        assert_eq!(n, 25); // pi(100)
    }

    #[test]
    fn compute_app_thread_invariant() {
        let (_, a) = ComputeBoundApp::new(10_000, 1).run();
        let (_, b) = ComputeBoundApp::new(10_000, 4).run();
        assert_eq!(a, b);
    }

    #[test]
    fn run_until_stops() {
        let app = ComputeBoundApp::new(1_000, 2);
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            let h = s.spawn(|| app.run_until(&stop));
            std::thread::sleep(Duration::from_millis(20));
            stop.store(true, Ordering::Relaxed);
            let (iters, _) = h.join().unwrap();
            assert!(iters > 0);
        });
    }

    #[test]
    fn io_app_runs_and_cleans() {
        let dir = std::env::temp_dir().join(format!("gpustore-io-test-{}", std::process::id()));
        let app = IoBoundApp {
            dir: dir.clone(),
            files: 8,
            file_size: 1024,
            passes: 1,
        };
        app.run().unwrap();
        // All files deleted.
        let left = std::fs::read_dir(&dir).unwrap().count();
        assert_eq!(left, 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
