//! Checkpoint-stream workload: the analog of the paper's 100 successive
//! BLAST/BLCR checkpoint images (avg 264.7 MB, 5-minute interval).
//!
//! We do not have the proprietary trace, so successive images are derived
//! by mutating the previous image with a mix chosen to land in the
//! paper's measured similarity bands (DESIGN.md §Substitutions):
//!
//! * a few **insertions/deletions** — these shift alignment, so they
//!   destroy fixed-block matches downstream of the first edit while CDC
//!   boundaries resynchronise: this is what pins fixed-block similarity
//!   near `E[min of k uniforms] = 1/(k+1)` (~21–25 % for k=3);
//! * scattered **in-place overwrites** — these cost both schemes about
//!   one chunk each, pulling CDC similarity down into the 76–90 % band.

use crate::util::Rng;

use super::synthetic::{Workload, WorkloadKind};

/// Mutation mix applied between successive checkpoint images.
#[derive(Debug, Clone, Copy)]
pub struct MutationProfile {
    /// Number of byte-range insertions per step.
    pub insertions: usize,
    /// Bytes per insertion (uniform 1..=this).
    pub insert_max: usize,
    /// Number of byte-range deletions per step.
    pub deletions: usize,
    /// Bytes per deletion (uniform 1..=this).
    pub delete_max: usize,
    /// Number of in-place overwrite spots per step.
    pub overwrites: usize,
    /// Overwrite spot size as a fraction of the image (so the profile is
    /// scale-free: the same profile works for 8 MB tests and 264 MB runs).
    pub overwrite_frac: f64,
}

impl MutationProfile {
    /// Tuned to reproduce the paper's bands: 21–23 % fixed-block
    /// similarity and 76–90 % CDC similarity between successive images.
    pub fn paper_default() -> Self {
        MutationProfile {
            insertions: 1,
            insert_max: 512,
            deletions: 1,
            delete_max: 512,
            overwrites: 12,
            overwrite_frac: 0.002,
        }
    }

    /// A heavier mix (lower similarity) for sensitivity studies.
    pub fn heavy() -> Self {
        MutationProfile {
            insertions: 6,
            insert_max: 4096,
            deletions: 3,
            delete_max: 4096,
            overwrites: 60,
            overwrite_frac: 0.004,
        }
    }
}

/// Iterator over successive checkpoint images.
#[derive(Debug)]
pub struct CheckpointStream {
    rng: Rng,
    profile: MutationProfile,
    current: Vec<u8>,
    emitted: usize,
    count: usize,
}

impl CheckpointStream {
    /// Stream of `count` images of roughly `size` bytes (images drift a
    /// little as insertions/deletions accumulate, like real checkpoints).
    pub fn new(count: usize, size: usize, profile: MutationProfile, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let current = rng.bytes(size);
        CheckpointStream {
            rng,
            profile,
            current,
            emitted: 0,
            count,
        }
    }

    /// Full workload materialised up front (small experiments only).
    pub fn materialize(count: usize, size: usize, profile: MutationProfile, seed: u64) -> Workload {
        let files: Vec<Vec<u8>> = CheckpointStream::new(count, size, profile, seed).collect();
        Workload {
            kind: WorkloadKind::Checkpoint,
            files,
        }
    }

    fn mutate(&mut self) {
        let p = self.profile;
        let n0 = self.current.len();
        // In-place overwrites.
        let spot = ((n0 as f64 * p.overwrite_frac) as usize).max(64);
        for _ in 0..p.overwrites {
            let n = self.current.len();
            if n <= spot {
                break;
            }
            let at = self.rng.range(0, n - spot);
            let mut patch = vec![0u8; spot];
            self.rng.fill(&mut patch);
            self.current[at..at + spot].copy_from_slice(&patch);
        }
        // Deletions.
        for _ in 0..p.deletions {
            let n = self.current.len();
            let len = self.rng.range(1, p.delete_max + 1).min(n / 2);
            let at = self.rng.range(0, n - len);
            self.current.drain(at..at + len);
        }
        // Insertions.
        for _ in 0..p.insertions {
            let n = self.current.len();
            let len = self.rng.range(1, p.insert_max + 1);
            let at = self.rng.range(0, n);
            let ins = self.rng.bytes(len);
            self.current.splice(at..at, ins);
        }
    }
}

impl Iterator for CheckpointStream {
    type Item = Vec<u8>;

    fn next(&mut self) -> Option<Vec<u8>> {
        if self.emitted >= self.count {
            return None;
        }
        if self.emitted > 0 {
            self.mutate();
        }
        self.emitted += 1;
        Some(self.current.clone())
    }
}

/// Fraction of `new`'s fixed-size blocks already present among `old`'s
/// (by block hash) — the similarity metric the paper reports.
pub fn fixed_similarity(old: &[u8], new: &[u8], block: usize) -> f64 {
    use crate::hash::md5;
    use std::collections::HashSet;
    let old_hashes: HashSet<_> = old.chunks(block).map(md5).collect();
    let blocks: Vec<_> = new.chunks(block).collect();
    if blocks.is_empty() {
        return 0.0;
    }
    let hit = blocks.iter().filter(|b| old_hashes.contains(&md5(b))).count();
    hit as f64 / blocks.len() as f64
}

/// CDC similarity: fraction of `new`'s *bytes* covered by chunks whose
/// hash already appears among `old`'s chunks.
pub fn cdc_similarity(old: &[u8], new: &[u8], params: crate::chunking::ChunkParams) -> f64 {
    use crate::chunking::ContentChunker;
    use crate::hash::md5;
    use std::collections::HashSet;
    let old_hashes: HashSet<_> = ContentChunker::chunk_all(params, old)
        .iter()
        .map(|c| md5(&c.data))
        .collect();
    let new_chunks = ContentChunker::chunk_all(params, new);
    let total: usize = new_chunks.iter().map(|c| c.data.len()).sum();
    if total == 0 {
        return 0.0;
    }
    let hit: usize = new_chunks
        .iter()
        .filter(|c| old_hashes.contains(&md5(&c.data)))
        .map(|c| c.data.len())
        .sum();
    hit as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunking::ChunkParams;

    #[test]
    fn stream_emits_count() {
        let imgs: Vec<_> =
            CheckpointStream::new(5, 1 << 16, MutationProfile::paper_default(), 1).collect();
        assert_eq!(imgs.len(), 5);
    }

    #[test]
    fn successive_images_differ_but_drift_slowly() {
        let imgs: Vec<_> =
            CheckpointStream::new(3, 1 << 18, MutationProfile::paper_default(), 2).collect();
        assert_ne!(imgs[0], imgs[1]);
        // Size drift is small relative to the image.
        let d = (imgs[2].len() as i64 - imgs[0].len() as i64).unsigned_abs() as usize;
        assert!(d < imgs[0].len() / 10);
    }

    #[test]
    fn deterministic() {
        let a: Vec<_> =
            CheckpointStream::new(3, 1 << 14, MutationProfile::paper_default(), 3).collect();
        let b: Vec<_> =
            CheckpointStream::new(3, 1 << 14, MutationProfile::paper_default(), 3).collect();
        assert_eq!(a, b);
    }

    /// The headline property: CDC detects several times more similarity
    /// than fixed blocks on checkpoint-style streams (paper: 21–23 % vs
    /// 76–90 %, i.e. 3–4x).
    #[test]
    fn similarity_bands_match_paper() {
        let size = 4 << 20; // 4 MB test-scale image
        // ~32 KB avg chunks -> ~128 chunks per image: same chunk-count
        // regime as 264 MB images with 1.2 MB chunks.
        let params = ChunkParams::with_avg_size(32 << 10);
        let block = 32 << 10;
        let mut fixed = Vec::new();
        let mut cdc = Vec::new();
        for seed in [4u64, 5, 6] {
            let imgs: Vec<_> =
                CheckpointStream::new(3, size, MutationProfile::paper_default(), seed).collect();
            for w in imgs.windows(2) {
                fixed.push(fixed_similarity(&w[0], &w[1], block));
                cdc.push(cdc_similarity(&w[0], &w[1], params));
            }
        }
        let favg = fixed.iter().sum::<f64>() / fixed.len() as f64;
        let cavg = cdc.iter().sum::<f64>() / cdc.len() as f64;
        assert!(
            (0.10..=0.45).contains(&favg),
            "fixed similarity {favg} outside band"
        );
        assert!(
            (0.65..=0.95).contains(&cavg),
            "cdc similarity {cavg} outside band"
        );
        assert!(cavg > 2.0 * favg, "cdc {cavg} not >2x fixed {favg}");
    }

    #[test]
    fn identical_images_full_similarity() {
        let img = crate::util::Rng::new(5).bytes(1 << 18);
        assert_eq!(fixed_similarity(&img, &img, 4096), 1.0);
        let p = ChunkParams::with_avg_size(16 << 10);
        assert_eq!(cdc_similarity(&img, &img, p), 1.0);
    }

    #[test]
    fn unrelated_images_near_zero_similarity() {
        let a = crate::util::Rng::new(6).bytes(1 << 18);
        let b = crate::util::Rng::new(7).bytes(1 << 18);
        assert!(fixed_similarity(&a, &b, 4096) < 0.01);
    }
}
