//! Workload generators reproducing the paper's evaluation inputs:
//! the `different` / `similar` synthetic spectra (§4.3), the BLAST+BLCR
//! checkpoint trace analog, and the competing compute-/IO-bound
//! applications of §4.5.

pub mod checkpoint;
pub mod competing;
pub mod synthetic;
pub mod trace;

pub use checkpoint::{CheckpointStream, MutationProfile};
pub use competing::{ComputeBoundApp, IoBoundApp};
pub use synthetic::{different_files, similar_files, Workload, WorkloadKind};
pub use trace::{Trace, TraceOp};
