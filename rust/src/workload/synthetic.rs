//! The two ends of the similarity spectrum (§4.3):
//!
//! * `different` — every file completely distinct: exposes all hashing
//!   overheads, zero dedup opportunity (also proxies integrity-only use).
//! * `similar`   — the same file written repeatedly: upper-bounds the
//!   gains of content addressability; only hashing + lookup remain.

use crate::util::Rng;

/// Which end of the spectrum a generated stream represents.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadKind {
    /// All-distinct files.
    Different,
    /// Identical files.
    Similar,
    /// Successive checkpoint images (see [`super::checkpoint`]).
    Checkpoint,
}

/// A generated sequence of file contents to write back-to-back.
#[derive(Debug)]
pub struct Workload {
    /// Kind tag (for reports).
    pub kind: WorkloadKind,
    /// File payloads in write order.
    pub files: Vec<Vec<u8>>,
}

impl Workload {
    /// Total bytes across all files.
    pub fn total_bytes(&self) -> u64 {
        self.files.iter().map(|f| f.len() as u64).sum()
    }
}

/// `count` completely different files of `size` bytes (seeded).
pub fn different_files(count: usize, size: usize, seed: u64) -> Workload {
    let mut files = Vec::with_capacity(count);
    for i in 0..count {
        let mut rng = Rng::new(seed ^ (0xD1F + i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        files.push(rng.bytes(size));
    }
    Workload {
        kind: WorkloadKind::Different,
        files,
    }
}

/// `count` copies of one random `size`-byte file (seeded).
pub fn similar_files(count: usize, size: usize, seed: u64) -> Workload {
    let mut rng = Rng::new(seed);
    let f = rng.bytes(size);
    Workload {
        kind: WorkloadKind::Similar,
        files: vec![f; count],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn different_are_different() {
        let w = different_files(4, 1024, 7);
        assert_eq!(w.files.len(), 4);
        for i in 0..4 {
            for j in i + 1..4 {
                assert_ne!(w.files[i], w.files[j]);
            }
        }
    }

    #[test]
    fn similar_are_identical() {
        let w = similar_files(5, 2048, 7);
        for f in &w.files[1..] {
            assert_eq!(f, &w.files[0]);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        assert_eq!(different_files(2, 128, 9).files, different_files(2, 128, 9).files);
        assert_ne!(different_files(2, 128, 9).files, different_files(2, 128, 10).files);
    }

    #[test]
    fn total_bytes() {
        assert_eq!(similar_files(3, 100, 1).total_bytes(), 300);
    }
}
