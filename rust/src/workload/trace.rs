//! Workload traces: record real write streams and replay them — the
//! mechanism a downstream user needs to run *their* workload through the
//! system (the paper's checkpoint trace was exactly such a recording).
//!
//! Format: one op per line, `#` comments.
//!
//! ```text
//! # op  file        size_or_src
//! write ckpt.img    26214400      # synthetic random payload of N bytes
//! mutate ckpt.img   overwrite=12,insert=1,delete=1   # next version
//! write ckpt.img    -             # re-write current version buffer
//! read  ckpt.img    -
//! ```

use std::collections::HashMap;

use crate::util::Rng;
use crate::{Error, Result};

/// One trace operation.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceOp {
    /// Write `file` with `size` fresh random bytes (or the current
    /// buffer if `size` is None).
    Write {
        /// Target file.
        file: String,
        /// Payload size; None = current buffer.
        size: Option<usize>,
    },
    /// Mutate `file`'s buffer in place (checkpoint-style evolution).
    Mutate {
        /// Target file.
        file: String,
        /// In-place overwrite spots.
        overwrites: usize,
        /// Insertions.
        inserts: usize,
        /// Deletions.
        deletes: usize,
    },
    /// Read `file` back (and verify length).
    Read {
        /// Target file.
        file: String,
    },
}

/// A parsed trace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    /// Operations in order.
    pub ops: Vec<TraceOp>,
}

impl Trace {
    /// Parse the text format above.
    pub fn parse(text: &str) -> Result<Trace> {
        let mut ops = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let op = parts.next().unwrap();
            let file = parts
                .next()
                .ok_or_else(|| Error::Config(format!("trace line {}: missing file", ln + 1)))?
                .to_string();
            let arg = parts.next().unwrap_or("-");
            match op {
                "write" => {
                    let size = if arg == "-" {
                        None
                    } else {
                        Some(parse_size(arg).ok_or_else(|| {
                            Error::Config(format!("trace line {}: bad size `{arg}`", ln + 1))
                        })?)
                    };
                    ops.push(TraceOp::Write { file, size });
                }
                "mutate" => {
                    let mut overwrites = 0;
                    let mut inserts = 0;
                    let mut deletes = 0;
                    for kv in arg.split(',') {
                        let (k, v) = kv.split_once('=').ok_or_else(|| {
                            Error::Config(format!("trace line {}: bad kv", ln + 1))
                        })?;
                        let v: usize = v.parse().map_err(|_| {
                            Error::Config(format!("trace line {}: bad count", ln + 1))
                        })?;
                        match k {
                            "overwrite" => overwrites = v,
                            "insert" => inserts = v,
                            "delete" => deletes = v,
                            _ => {
                                return Err(Error::Config(format!(
                                    "trace line {}: unknown key `{k}`",
                                    ln + 1
                                )))
                            }
                        }
                    }
                    ops.push(TraceOp::Mutate {
                        file,
                        overwrites,
                        inserts,
                        deletes,
                    });
                }
                "read" => ops.push(TraceOp::Read { file }),
                other => {
                    return Err(Error::Config(format!(
                        "trace line {}: unknown op `{other}`",
                        ln + 1
                    )))
                }
            }
        }
        Ok(Trace { ops })
    }

    /// Replay against a SAI client; returns per-op write reports.
    /// Writes stream through a [`crate::store::FileWriter`] session in
    /// application-sized chunks (a recorded trace replays the way the
    /// original application wrote: incrementally, not as one giant
    /// buffer); reads stream back through a
    /// [`crate::store::FileReader`].
    pub fn replay(
        &self,
        sai: &crate::store::Sai,
        seed: u64,
    ) -> Result<Vec<crate::store::WriteReport>> {
        use std::io::Read as _;
        /// Replay granularity of one application write call.
        const REPLAY_IO_CHUNK: usize = 1 << 20;
        let mut rng = Rng::new(seed);
        let mut buffers: HashMap<String, Vec<u8>> = HashMap::new();
        let mut reports = Vec::new();
        for op in &self.ops {
            match op {
                TraceOp::Write { file, size } => {
                    if let Some(n) = size {
                        let data = rng.bytes(*n);
                        buffers.insert(file.clone(), data);
                    }
                    let data = buffers
                        .get(file)
                        .ok_or_else(|| Error::Config(format!("write {file}: no buffer")))?;
                    let mut w = sai.create(file)?;
                    for chunk in data.chunks(REPLAY_IO_CHUNK) {
                        w.push_bytes(chunk)?;
                    }
                    reports.push(w.close()?);
                }
                TraceOp::Mutate {
                    file,
                    overwrites,
                    inserts,
                    deletes,
                } => {
                    let buf = buffers
                        .get_mut(file)
                        .ok_or_else(|| Error::Config(format!("mutate {file}: no buffer")))?;
                    let profile = super::MutationProfile {
                        insertions: *inserts,
                        insert_max: 512,
                        deletions: *deletes,
                        delete_max: 512,
                        overwrites: *overwrites,
                        overwrite_frac: 0.002,
                    };
                    mutate_buffer(buf, profile, &mut rng);
                }
                TraceOp::Read { file } => {
                    let mut r = sai.open(file)?;
                    let mut data = Vec::with_capacity(r.len() as usize);
                    r.read_to_end(&mut data).map_err(Error::Io)?;
                    if let Some(expect) = buffers.get(file) {
                        if &data != expect {
                            return Err(Error::Other(format!(
                                "trace read {file}: payload mismatch"
                            )));
                        }
                    }
                }
            }
        }
        Ok(reports)
    }
}

fn parse_size(s: &str) -> Option<usize> {
    let (num, mult) = match s.chars().last()? {
        'K' | 'k' => (&s[..s.len() - 1], 1024),
        'M' | 'm' => (&s[..s.len() - 1], 1024 * 1024),
        'G' | 'g' => (&s[..s.len() - 1], 1024 * 1024 * 1024),
        _ => (s, 1),
    };
    num.parse::<usize>().ok().map(|n| n * mult)
}

/// Apply a mutation profile to a buffer in place (shared with the
/// checkpoint generator's semantics).
pub fn mutate_buffer(buf: &mut Vec<u8>, p: super::MutationProfile, rng: &mut Rng) {
    let n0 = buf.len();
    let spot = ((n0 as f64 * p.overwrite_frac) as usize).max(64);
    for _ in 0..p.overwrites {
        let n = buf.len();
        if n <= spot {
            break;
        }
        let at = rng.range(0, n - spot);
        let mut patch = vec![0u8; spot];
        rng.fill(&mut patch);
        buf[at..at + spot].copy_from_slice(&patch);
    }
    for _ in 0..p.deletions {
        let n = buf.len();
        if n < 4 {
            break;
        }
        let len = rng.range(1, p.delete_max + 1).min(n / 2);
        let at = rng.range(0, n - len);
        buf.drain(at..at + len);
    }
    for _ in 0..p.insertions {
        let n = buf.len();
        let len = rng.range(1, p.insert_max + 1);
        let at = rng.range(0, n + 1).min(n);
        let ins = rng.bytes(len);
        buf.splice(at..at, ins);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# checkpoint-style trace
write ckpt 256K
mutate ckpt overwrite=4,insert=1,delete=1
write ckpt -
read ckpt -
"#;

    #[test]
    fn parse_sample() {
        let t = Trace::parse(SAMPLE).unwrap();
        assert_eq!(t.ops.len(), 4);
        assert_eq!(
            t.ops[0],
            TraceOp::Write {
                file: "ckpt".into(),
                size: Some(256 * 1024)
            }
        );
        assert_eq!(
            t.ops[1],
            TraceOp::Mutate {
                file: "ckpt".into(),
                overwrites: 4,
                inserts: 1,
                deletes: 1
            }
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Trace::parse("frobnicate x y").is_err());
        assert!(Trace::parse("write").is_err());
        assert!(Trace::parse("mutate f overwrite?4").is_err());
        assert!(Trace::parse("write f 12Q").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let t = Trace::parse("# nothing\n\n  \n").unwrap();
        assert!(t.ops.is_empty());
    }

    #[test]
    fn replay_against_cluster() {
        use crate::config::{CaMode, ClientConfig, ClusterConfig};
        use crate::hashgpu::{CpuEngine, WindowHashMode};
        use std::sync::Arc;
        let cluster = crate::store::Cluster::spawn(ClusterConfig {
            nodes: 2,
            link_bps: 1e9,
            shape: false,
            replication: 1,
            ..ClusterConfig::default()
        })
        .unwrap();
        let cfg = ClientConfig {
            ca_mode: CaMode::Fixed,
            block_size: 16 * 1024,
            write_buffer: 64 * 1024,
            ..ClientConfig::default()
        };
        let sai = cluster
            .client(cfg, Arc::new(CpuEngine::new(2, 4096, WindowHashMode::Rolling)))
            .unwrap();
        let t = Trace::parse(SAMPLE).unwrap();
        let reports = t.replay(&sai, 7).unwrap();
        assert_eq!(reports.len(), 2); // two writes
        // The second (mutated) write dedups the aligned prefix (fixed
        // blocks: everything past the first indel re-transfers).
        assert!(reports[1].similarity > 0.05, "{}", reports[1].similarity);
    }

    #[test]
    fn mutate_buffer_changes_content() {
        let mut rng = Rng::new(1);
        let mut buf = rng.bytes(10_000);
        let orig = buf.clone();
        mutate_buffer(
            &mut buf,
            crate::workload::MutationProfile::paper_default(),
            &mut rng,
        );
        assert_ne!(buf, orig);
    }
}
