//! Minimal JSON parser — just enough to read `artifacts/manifest.json`
//! and config files.  Supports the full JSON value grammar (objects,
//! arrays, strings with escapes, numbers, booleans, null); no serializer
//! beyond what the figure harnesses need.

use std::collections::BTreeMap;
use std::fmt;

use crate::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (stored as f64, as in JavaScript).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(Error::Proto(format!("trailing bytes at {}", p.i)));
        }
        Ok(v)
    }

    /// Object field accessor.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer value (rejects non-integral numbers).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// usize value.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    /// Array items.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field helpers with descriptive errors.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Proto(format!("missing field `{key}`")))
    }

    /// Required string field.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::Proto(format!("field `{key}` not a string")))
    }

    /// Required integer field.
    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| Error::Proto(format!("field `{key}` not an integer")))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| Error::Proto("unexpected end of json".into()))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::Proto(format!(
                "expected `{}` at {}, found `{}`",
                c as char, self.i, self.b[self.i] as char
            )))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(Error::Proto(format!("bad literal at {}", self.i)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(Error::Proto(format!(
                "unexpected `{}` at {}",
                c as char, self.i
            ))),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => {
                    return Err(Error::Proto(format!(
                        "expected , or }} at {}, found `{}`",
                        self.i, c as char
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => {
                    return Err(Error::Proto(format!(
                        "expected , or ] at {}, found `{}`",
                        self.i, c as char
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(Error::Proto("truncated \\u escape".into()));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| Error::Proto("bad \\u escape".into()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Proto("bad \\u escape".into()))?;
                            self.i += 4;
                            // Surrogate pairs are not needed by our
                            // manifests; map unpaired surrogates to U+FFFD.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        c => {
                            return Err(Error::Proto(format!("bad escape `\\{}`", c as char)))
                        }
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Multi-byte UTF-8: copy the full sequence.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.i - 1;
                    if start + len > self.b.len() {
                        return Err(Error::Proto("truncated utf-8".into()));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| Error::Proto("bad utf-8".into()))?;
                    s.push_str(chunk);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| Error::Proto("bad number".into()))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Proto(format!("bad number `{s}`")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].req_str("b").unwrap(),
            "c"
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".into())
        );
    }

    #[test]
    fn parse_utf8_passthrough() {
        assert_eq!(
            Json::parse("\"héllo\"").unwrap(),
            Json::Str("héllo".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn accessors() {
        let j = Json::parse(r#"{"n": 42, "s": "x"}"#).unwrap();
        assert_eq!(j.req_usize("n").unwrap(), 42);
        assert_eq!(j.req_str("s").unwrap(), "x");
        assert!(j.req("missing").is_err());
        assert!(j.req_usize("s").is_err());
    }

    #[test]
    fn display_roundtrip() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(s) = std::fs::read_to_string(p) {
            let j = Json::parse(&s).unwrap();
            assert!(j.get("artifacts").unwrap().as_arr().unwrap().len() >= 4);
        }
    }
}
