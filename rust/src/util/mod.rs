//! Small shared utilities: deterministic PRNG, byte-size formatting, and
//! a minimal JSON parser (the environment is offline; no serde).

pub mod json;

/// xoshiro256** — deterministic, dependency-free PRNG used by every
/// workload generator and test so runs are reproducible from a seed.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next 64 uniformly random bits.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's debiased multiply-shift.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fill a buffer with random bytes.
    pub fn fill(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Allocate `n` random bytes.
    pub fn bytes(&mut self, n: usize) -> Vec<u8> {
        let mut v = vec![0u8; n];
        self.fill(&mut v);
        v
    }
}

/// Render a byte count as a human-readable string ("64KB", "1.5MB").
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if v.fract() == 0.0 {
        format!("{}{}", v as u64, UNITS[u])
    } else {
        format!("{:.1}{}", v, UNITS[u])
    }
}

/// Render a rate in MB/s.  Zero (or negative, or NaN) elapsed time
/// yields 0.0 rather than a division blow-up: an instantaneous
/// measurement carries no rate information, and 0.0 keeps report
/// aggregation (sums, averages, tables) finite.
pub fn mbps(bytes: u64, seconds: f64) -> f64 {
    if seconds.is_nan() || seconds <= 0.0 {
        return 0.0;
    }
    bytes as f64 / (1024.0 * 1024.0) / seconds
}

/// Hex-encode a byte slice.
pub fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn below_covers_values() {
        let mut r = Rng::new(4);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn fill_handles_unaligned() {
        let mut r = Rng::new(6);
        let v = r.bytes(13);
        assert_eq!(v.len(), 13);
        assert!(v.iter().any(|&b| b != 0));
    }

    #[test]
    fn human_bytes_format() {
        assert_eq!(human_bytes(512), "512B");
        assert_eq!(human_bytes(65536), "64KB");
        assert_eq!(human_bytes(1536), "1.5KB");
        assert_eq!(human_bytes(96 * 1024 * 1024), "96MB");
    }

    #[test]
    fn hex_roundtrip() {
        assert_eq!(hex(&[0xde, 0xad, 0x01]), "dead01");
    }

    #[test]
    fn mbps_guards_degenerate_elapsed() {
        assert_eq!(mbps(1 << 20, 0.0), 0.0);
        assert_eq!(mbps(1 << 20, -1.0), 0.0);
        assert_eq!(mbps(1 << 20, f64::NAN), 0.0);
        assert!((mbps(1 << 20, 1.0) - 1.0).abs() < 1e-12);
    }
}
