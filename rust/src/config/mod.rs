//! Typed configuration for the storage system and its experiments,
//! mirroring the paper's evaluated setups (§4).

use std::time::Duration;

use crate::chunking::ChunkParams;

/// Content-addressability mode of the client SAI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CaMode {
    /// `non-CA`: no hashing, data written straight to storage nodes.
    None,
    /// Fixed-size blocks + direct hashing (MosaStore default: 1 MB).
    Fixed,
    /// Content-based chunking via sliding-window hashing.
    Cdc,
}

/// Where the hashing work runs — the paper's CPU / GPU / oracle configs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HashEngineKind {
    /// Single- or multi-threaded host CPU ("CA-CPU"; 16 threads on the
    /// dual-socket machine is the paper's best CPU config).
    Cpu {
        /// Hashing worker threads.
        threads: usize,
    },
    /// Accelerator offload through crystal ("CA-GPU").
    Gpu {
        /// Number of devices (the paper evaluates 1 and 2).
        devices: usize,
        /// Reuse pinned buffers (CrystalGPU optimization 1).
        buffer_reuse: bool,
        /// Overlap transfer with compute (CrystalGPU optimization 2).
        overlap: bool,
    },
    /// "CA-Infinite": instant hashing oracle, the upper bound of §4.4.
    Oracle,
}

impl HashEngineKind {
    /// The paper's single-GPU fully-optimized configuration.
    pub fn gpu_default() -> Self {
        HashEngineKind::Gpu {
            devices: 1,
            buffer_reuse: true,
            overlap: true,
        }
    }
}

/// How a node's / manager's serve path multiplexes connections (PR 9).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ServeMode {
    /// Event-driven readiness loop + fixed worker pool (the default):
    /// thousands of connections on a handful of threads.
    #[default]
    Event,
    /// Legacy thread-per-connection serving, kept as the benchmark
    /// baseline (`cargo bench --bench sessions` compares both).
    Thread,
}

/// How the manager places a block's bytes across storage nodes
/// (PR 10).  Parsed from the CLI's `--placement` (`rr`, `rep:R`,
/// `ec:K,M`); [`ClusterConfig::placement`] carries it cluster-wide.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Classic single-copy round-robin striping (`rr`).
    RoundRobin,
    /// `R` whole copies per block (`rep:R`).
    Replicated(usize),
    /// `K` data + `M` parity shards per block, GF(256) Reed–Solomon
    /// (`ec:K,M`) — readable from any `K`, tolerating `M` losses at
    /// `(K+M)/K`× storage overhead.
    Erasure { k: u8, m: u8 },
}

impl Placement {
    /// Parse the CLI syntax: `rr`, `rep:R`, or `ec:K,M`.  Malformed or
    /// degenerate values (zero copies/shards) fail loudly — silently
    /// weakening a redundancy request is worse than refusing it.
    pub fn parse(s: &str) -> crate::Result<Placement> {
        let s = s.trim();
        if s == "rr" {
            return Ok(Placement::RoundRobin);
        }
        if let Some(r) = s.strip_prefix("rep:") {
            let r: usize = r
                .trim()
                .parse()
                .map_err(|_| crate::Error::Config(format!("bad replication factor in {s:?}")))?;
            if r == 0 {
                return Err(crate::Error::Config("rep:R needs R >= 1".into()));
            }
            return Ok(Placement::Replicated(r));
        }
        if let Some(km) = s.strip_prefix("ec:") {
            let (k, m) = km.split_once(',').ok_or_else(|| {
                crate::Error::Config(format!("ec placement needs ec:K,M (got {s:?})"))
            })?;
            let k: u8 = k
                .trim()
                .parse()
                .map_err(|_| crate::Error::Config(format!("bad data-shard count in {s:?}")))?;
            let m: u8 = m
                .trim()
                .parse()
                .map_err(|_| crate::Error::Config(format!("bad parity-shard count in {s:?}")))?;
            if k == 0 || m == 0 {
                return Err(crate::Error::Config("ec:K,M needs K >= 1 and M >= 1".into()));
            }
            return Ok(Placement::Erasure { k, m });
        }
        Err(crate::Error::Config(format!(
            "unknown placement {s:?} (expected rr, rep:R or ec:K,M)"
        )))
    }

    /// Homes (whole copies or shards) each block occupies.
    pub fn replication(&self) -> usize {
        match self {
            Placement::RoundRobin => 1,
            Placement::Replicated(r) => *r,
            Placement::Erasure { k, m } => *k as usize + *m as usize,
        }
    }
}

/// Client (SAI) configuration.
#[derive(Debug, Clone)]
pub struct ClientConfig {
    /// Content addressability mode.
    pub ca_mode: CaMode,
    /// Hash engine selection.
    pub engine: HashEngineKind,
    /// Fixed-block size (CaMode::Fixed). Paper default: 1 MB.
    pub block_size: usize,
    /// CDC parameters (CaMode::Cdc).
    pub cdc_min: usize,
    /// CDC maximum chunk size.
    pub cdc_max: usize,
    /// CDC boundary mask (expected spacing = mask+1 past min).
    pub cdc_mask: u32,
    /// Write-buffer size: data accumulated before a chunk+hash batch is
    /// submitted (the batching the paper adds for CBC offload).
    pub write_buffer: usize,
    /// Direct-hash segment size for the parallel Merkle–Damgård split.
    pub segment_bytes: usize,
    /// Maximum operations in flight per node connection (data-plane
    /// v2).  The duplex node links pipeline up to this many puts/gets
    /// on one socket; `1` degenerates to the old lock-step protocol
    /// (one op on the wire per node, reply awaited before the next
    /// frame) and is the benchmark baseline.
    pub node_inflight: usize,
    /// Per-session in-flight payload budget in **bytes** (data-plane
    /// v2): a write session stops accepting new batches once this many
    /// put bytes are unacknowledged, and a read session prefetches
    /// ahead of the consumer only up to this many bytes.  One knob
    /// bounds the memory of arbitrarily deep pipelines (CLI:
    /// `--inflight-mb`).
    pub inflight_budget: usize,
    /// Shared hash service: flush a coalesced device batch once this
    /// many blocks are queued across sessions (the occupancy bound;
    /// CLI: `--hash-batch`).
    pub hash_batch: usize,
    /// Shared hash service: flush once the oldest queued submission has
    /// waited this many microseconds (the latency bound; CLI:
    /// `--hash-linger-us`).  `0` flushes every submission immediately.
    pub hash_linger_us: u64,
    /// Shared hash service fan-out: crystal devices on the GPU backend,
    /// parallel hashing lanes on the CPU fallback (CLI:
    /// `--hash-devices`).
    pub hash_devices: usize,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            ca_mode: CaMode::Fixed,
            engine: HashEngineKind::Cpu { threads: 1 },
            block_size: 1024 * 1024,
            cdc_min: 256 * 1024,
            cdc_max: 4 * 1024 * 1024,
            cdc_mask: (1 << 20) - 1,
            write_buffer: 4 * 1024 * 1024,
            segment_bytes: 4096,
            node_inflight: 16,
            inflight_budget: 32 * 1024 * 1024,
            hash_batch: 64,
            hash_linger_us: 200,
            hash_devices: 1,
        }
    }
}

impl ClientConfig {
    /// CDC parameters derived from this config.
    pub fn chunk_params(&self) -> ChunkParams {
        ChunkParams {
            window: crate::hash::DEFAULT_WINDOW,
            p: crate::hash::DEFAULT_P,
            mask: self.cdc_mask,
            magic: 0x0007_8A1D & self.cdc_mask,
            min_size: self.cdc_min,
            max_size: self.cdc_max,
        }
    }

    /// Validate cross-field invariants.
    pub fn validate(&self) -> crate::Result<()> {
        if self.block_size == 0
            || self.write_buffer == 0
            || self.node_inflight == 0
            || self.inflight_budget == 0
            || self.hash_batch == 0
            || self.hash_devices == 0
        {
            return Err(crate::Error::Config("zero-sized config field".into()));
        }
        if self.ca_mode == CaMode::Cdc {
            self.chunk_params().validate()?;
            if self.write_buffer < self.cdc_max {
                return Err(crate::Error::Config(
                    "write_buffer must be >= cdc_max so a chunk fits a batch".into(),
                ));
            }
        }
        if let HashEngineKind::Cpu { threads } = self.engine {
            if threads == 0 {
                return Err(crate::Error::Config("cpu threads must be > 0".into()));
            }
        }
        if let HashEngineKind::Gpu { devices, .. } = self.engine {
            if devices == 0 {
                return Err(crate::Error::Config("gpu devices must be > 0".into()));
            }
        }
        Ok(())
    }

    /// Paper preset: `non-CA`.
    pub fn non_ca() -> Self {
        ClientConfig {
            ca_mode: CaMode::None,
            ..Default::default()
        }
    }

    /// Paper preset: `CA-CPU` fixed blocks, `threads` hashing threads.
    pub fn ca_cpu_fixed(threads: usize) -> Self {
        ClientConfig {
            ca_mode: CaMode::Fixed,
            engine: HashEngineKind::Cpu { threads },
            ..Default::default()
        }
    }

    /// Paper preset: `CA-GPU` fixed blocks.
    pub fn ca_gpu_fixed() -> Self {
        ClientConfig {
            ca_mode: CaMode::Fixed,
            engine: HashEngineKind::gpu_default(),
            ..Default::default()
        }
    }

    /// Paper preset: `CA-CPU` content-based chunking.
    pub fn ca_cpu_cdc(threads: usize) -> Self {
        ClientConfig {
            ca_mode: CaMode::Cdc,
            engine: HashEngineKind::Cpu { threads },
            ..Default::default()
        }
    }

    /// Paper preset: `CA-GPU` content-based chunking.
    pub fn ca_gpu_cdc() -> Self {
        ClientConfig {
            ca_mode: CaMode::Cdc,
            engine: HashEngineKind::gpu_default(),
            ..Default::default()
        }
    }

    /// Paper preset: `CA-Infinite` (oracle hashing).
    pub fn ca_infinite(ca_mode: CaMode) -> Self {
        ClientConfig {
            ca_mode,
            engine: HashEngineKind::Oracle,
            ..Default::default()
        }
    }
}

/// Cluster-wide experiment configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of storage nodes (paper testbed: up to 22-node cluster,
    /// stripes of 4).
    pub nodes: usize,
    /// Link bandwidth in bits/sec (paper: 1 Gbps; §4.2 discusses 10 Gbps).
    pub link_bps: f64,
    /// Whether to shape in-proc links at `link_bps`.
    pub shape: bool,
    /// Copies per block placed by the manager (control-plane v2:
    /// `ReplicatedStripe` when > 1, classic round-robin when 1).
    /// Must be `1 <= replication <= nodes`.
    pub replication: usize,
    /// Manager lease timeout (control-plane v3): how long a read
    /// session's version pins and a write session's claims survive
    /// without a renewal.  Surfaced like `replication`
    /// (`--lease-timeout` in the CLI); must be non-zero.
    pub lease_timeout: Duration,
    /// Modeled fabric round-trip residue applied to every storage-node
    /// reply (data-plane v2, single-host experiments): each reply is
    /// released this long after its request arrived, through a delay
    /// line that lets pipelined requests overlap their latencies the
    /// way real in-flight packets do.  `ZERO` (the default) disables
    /// the model; benchmarks set it to a GbE-realistic few hundred
    /// microseconds to expose the lock-step `block_size / RTT` bound.
    pub node_rtt: Duration,
    /// Cluster-wide shared-hash-service occupancy bound, stamped onto
    /// every client built through
    /// [`Cluster::service_client`](crate::store::Cluster::service_client)
    /// so co-located sessions agree on one batching policy (and hence
    /// share one service).  See [`ClientConfig::hash_batch`].
    pub hash_batch: usize,
    /// Cluster-wide latency bound (see [`ClientConfig::hash_linger_us`]).
    pub hash_linger_us: u64,
    /// Cluster-wide service fan-out (see [`ClientConfig::hash_devices`]).
    pub hash_devices: usize,
    /// Manager durability (PR 7): `Some` gives the manager a data dir
    /// with a write-ahead log + snapshots, so
    /// [`Cluster::restart_manager`](crate::store::Cluster::restart_manager)
    /// recovers the control plane after a crash.  `None` (the default)
    /// keeps the pre-durability in-memory manager.
    pub durability: Option<crate::wal::DurabilityOpts>,
    /// Manager replicas forming a quorum group (PR 8).  `1` (the
    /// default) is the classic single manager; `>= 2` spawns that many
    /// managers wired as consensus peers (member 0 starts as leader,
    /// the rest as followers; with durability each member gets its own
    /// subdirectory under the configured data dir).  Elections need a
    /// majority, so 3 is the smallest count that survives losing a
    /// member.
    pub managers: usize,
    /// Serve-path architecture for every node and manager in the
    /// cluster (PR 9).  [`ServeMode::Event`] (the default) multiplexes
    /// all connections over a reactor + worker pool; `Thread` keeps the
    /// legacy thread-per-connection loops for baseline benchmarks.
    pub serve_mode: ServeMode,
    /// Worker threads per serve loop (`--serve-threads`); `0` picks the
    /// built-in default.  Ignored in [`ServeMode::Thread`].
    pub serve_threads: usize,
    /// Placement policy override (PR 10, `--placement`).  `None` (the
    /// default) derives the policy from [`replication`](Self::replication)
    /// as before; `Some` wins over `replication` and unlocks
    /// [`Placement::Erasure`] placement.
    pub placement: Option<Placement>,
    /// How often each manager's background scrub/repair pass and
    /// anti-entropy sweep run (PR 10, `--scrub-interval`).  `ZERO` (the
    /// default) disables them; tests drive the passes directly through
    /// the deterministic clock instead.
    pub scrub_interval: Duration,
    /// Repair-traffic budget in Mbit/s, spent per scrub window (PR 10,
    /// `--repair-mbps`); `0.0` (the default) leaves repair unthrottled.
    pub repair_mbps: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            nodes: 4,
            link_bps: 1e9,
            shape: true,
            replication: 1,
            lease_timeout: Duration::from_secs(30),
            node_rtt: Duration::ZERO,
            hash_batch: 64,
            hash_linger_us: 200,
            hash_devices: 1,
            durability: None,
            managers: 1,
            serve_mode: ServeMode::default(),
            serve_threads: 0,
            placement: None,
            scrub_interval: Duration::ZERO,
            repair_mbps: 0.0,
        }
    }
}

impl ClusterConfig {
    /// Default cluster with an n-way replication factor.
    pub fn replicated(replication: usize) -> Self {
        ClusterConfig {
            replication,
            ..Default::default()
        }
    }

    /// Homes (copies or shards) each block occupies under the effective
    /// placement — what must fit within `nodes`.
    pub fn homes_per_block(&self) -> usize {
        match self.placement {
            Some(p) => p.replication(),
            None => self.replication,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        ClientConfig::default().validate().unwrap();
        ClientConfig::non_ca().validate().unwrap();
        ClientConfig::ca_cpu_fixed(16).validate().unwrap();
        ClientConfig::ca_gpu_fixed().validate().unwrap();
        ClientConfig::ca_cpu_cdc(8).validate().unwrap();
        ClientConfig::ca_gpu_cdc().validate().unwrap();
        ClientConfig::ca_infinite(CaMode::Cdc).validate().unwrap();
    }

    #[test]
    fn zero_threads_rejected() {
        assert!(ClientConfig::ca_cpu_fixed(0).validate().is_err());
    }

    #[test]
    fn zero_data_plane_knobs_rejected() {
        let c = ClientConfig {
            node_inflight: 0,
            ..ClientConfig::default()
        };
        assert!(c.validate().is_err());
        let c = ClientConfig {
            inflight_budget: 0,
            ..ClientConfig::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn zero_hash_service_knobs_rejected() {
        let c = ClientConfig {
            hash_batch: 0,
            ..ClientConfig::default()
        };
        assert!(c.validate().is_err());
        let c = ClientConfig {
            hash_devices: 0,
            ..ClientConfig::default()
        };
        assert!(c.validate().is_err());
        // Zero linger is legal: flush every submission immediately.
        let c = ClientConfig {
            hash_linger_us: 0,
            ..ClientConfig::default()
        };
        c.validate().unwrap();
    }

    #[test]
    fn small_write_buffer_rejected_for_cdc() {
        let mut c = ClientConfig::ca_cpu_cdc(1);
        c.write_buffer = 1024 * 1024; // < cdc_max
        assert!(c.validate().is_err());
    }

    #[test]
    fn chunk_params_coherent() {
        let c = ClientConfig::ca_gpu_cdc();
        let p = c.chunk_params();
        assert_eq!(p.min_size, c.cdc_min);
        assert_eq!(p.max_size, c.cdc_max);
        p.validate().unwrap();
    }

    #[test]
    fn serve_mode_defaults_to_event() {
        let c = ClusterConfig::default();
        assert_eq!(c.serve_mode, ServeMode::Event);
        assert_eq!(c.serve_threads, 0);
    }

    #[test]
    fn placement_parses_all_three_forms() {
        assert_eq!(Placement::parse("rr").unwrap(), Placement::RoundRobin);
        assert_eq!(
            Placement::parse("rep:3").unwrap(),
            Placement::Replicated(3)
        );
        assert_eq!(
            Placement::parse("ec:4,2").unwrap(),
            Placement::Erasure { k: 4, m: 2 }
        );
        // Whitespace tolerated around tokens.
        assert_eq!(
            Placement::parse(" ec: 4 , 2 ").unwrap(),
            Placement::Erasure { k: 4, m: 2 }
        );
        assert_eq!(Placement::parse("rr").unwrap().replication(), 1);
        assert_eq!(Placement::parse("rep:3").unwrap().replication(), 3);
        assert_eq!(Placement::parse("ec:4,2").unwrap().replication(), 6);
    }

    #[test]
    fn malformed_placement_fails_loudly() {
        for bad in [
            "", "rep", "rep:", "rep:0", "rep:x", "ec", "ec:", "ec:4", "ec:4,", "ec:0,2", "ec:4,0",
            "ec:a,b", "ec:4;2", "raid5", "rr2",
        ] {
            assert!(Placement::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn scrub_knobs_default_off() {
        let c = ClusterConfig::default();
        assert_eq!(c.placement, None);
        assert_eq!(c.scrub_interval, Duration::ZERO);
        assert_eq!(c.repair_mbps, 0.0);
        assert_eq!(c.homes_per_block(), 1);
        let c = ClusterConfig {
            placement: Some(Placement::Erasure { k: 2, m: 1 }),
            ..ClusterConfig::default()
        };
        assert_eq!(c.homes_per_block(), 3);
    }

    #[test]
    fn presets_differ_where_expected() {
        assert_eq!(ClientConfig::non_ca().ca_mode, CaMode::None);
        assert_eq!(ClientConfig::ca_gpu_cdc().ca_mode, CaMode::Cdc);
        assert_eq!(
            ClientConfig::ca_gpu_fixed().engine,
            HashEngineKind::gpu_default()
        );
        assert_eq!(
            ClientConfig::ca_infinite(CaMode::Fixed).engine,
            HashEngineKind::Oracle
        );
    }
}
