//! Durable control plane: a segmented, CRC-framed write-ahead log plus
//! periodic snapshots for the manager's metadata (block table, leases,
//! file maps, node registry).
//!
//! Everything the manager mutates is first serialized as a typed
//! [`Record`] and appended here; the same `apply()` path in
//! `store::manager` consumes records both live and during replay, so
//! recovery is not a separate (and separately-buggy) code path.
//!
//! ## On-disk layout (`--data-dir`)
//!
//! ```text
//! <data-dir>/
//!   wal/seg-<first_lsn:020>.log     append-only record segments
//!   snap/snap-<lsn:020>.snap        full-state snapshots
//! ```
//!
//! Each log frame is `u32 body_len (LE) | u32 crc32 (LE) | body`, where
//! `body = u64 lsn (LE) | record bytes` and the CRC covers the body.
//! LSNs are dense (each record's lsn is its predecessor's + 1), which
//! recovery verifies — a gap means a lost segment and fails loudly.
//!
//! ## Torn tails vs. corruption
//!
//! A crash can tear the **final** record of the **final** segment: an
//! incomplete frame at EOF there is expected, truncated away, and
//! replay proceeds (the record was never acknowledged — group commit's
//! documented loss window).  Everything else is corruption and fails
//! loudly: a short frame mid-log, a complete frame whose CRC
//! mismatches, an LSN gap, or an undecodable snapshot.  The WAL never
//! silently drops interior history.
//!
//! ## Group commit
//!
//! `sync_interval == 0` fsyncs every append (strict durability, used by
//! the recovery tests and the bench baseline).  A non-zero interval
//! fsyncs at most once per interval — the classic group-commit trade:
//! an unacknowledged tail of at most one interval's records can be lost
//! on power failure, in exchange for not paying an fsync per mutation.
//! `Wal::sync` (and drop) force the tail down.
//!
//! ## Snapshots
//!
//! Every `snapshot_every` records the manager serializes its entire
//! state ([`SnapshotState`]) through a temp-file + fsync + rename
//! sequence, rotates the log so the new segment starts after the
//! snapshot's lsn, and prunes segments and snapshots the new snapshot
//! covers.  Recovery loads the latest snapshot and replays only the
//! tail.

use std::fs::{self, File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::hash::Digest;
use crate::store::proto::{put_blocks, put_ec, put_replicas, put_str, BlockMeta, Cursor, MAX_FRAME};
use crate::{Error, Result};

/// Durability knobs for a manager (`--data-dir`, `--wal-sync`,
/// `--snapshot-every`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DurabilityOpts {
    /// Root directory for the WAL segments and snapshots.
    pub data_dir: PathBuf,
    /// Group-commit window: fsync at most once per this interval
    /// (`0` = fsync every record).
    pub sync_interval: Duration,
    /// Snapshot after this many records since the last snapshot.
    pub snapshot_every: u64,
}

impl DurabilityOpts {
    /// Options with the default group-commit window (5 ms) and snapshot
    /// cadence (4096 records).
    pub fn new(data_dir: impl Into<PathBuf>) -> DurabilityOpts {
        DurabilityOpts {
            data_dir: data_dir.into(),
            sync_interval: Duration::from_millis(5),
            snapshot_every: 4096,
        }
    }
}

/// Rotate the live segment when it crosses this size.
const SEG_BYTES: u64 = 8 * 1024 * 1024;

/// Magic prefix of a snapshot file.
const SNAP_MAGIC: &[u8; 4] = b"GSNP";
/// Snapshot format version.  v2 adds the per-block erasure-coding
/// descriptor (two bytes per block-map entry and snapshot block).
const SNAP_VERSION: u32 = 2;

/// One typed manager mutation.  Every state change the manager makes —
/// live or during replay — is one of these, applied through the single
/// `ManagerState::apply` path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Record {
    /// Commit a file version (claims redeemed, old map released).
    Commit {
        /// File name.
        file: String,
        /// Write lease consumed by the commit (`0` = untracked).
        lease: u64,
        /// The committed block-map.
        blocks: Vec<BlockMeta>,
    },
    /// Release provisional claims (aborted writer occurrences).
    Release {
        /// One entry per released claim occurrence.
        hashes: Vec<Digest>,
    },
    /// Grant a lease (read: pins the listed occurrences; write: empty).
    OpenLease {
        /// The granted lease id.
        id: u64,
        /// Read lease: file name.  Write lease: session claim token.
        tag: String,
        /// Writer claim lease vs. read-pin lease.
        write: bool,
        /// Pinned hash occurrences (read leases; empty for write).
        hashes: Vec<Digest>,
    },
    /// Extend a lease's expiry (client heartbeat).
    RenewLease {
        /// Lease id.
        id: u64,
    },
    /// Release a lease early (client drop).
    DropLease {
        /// Lease id.
        id: u64,
    },
    /// Lapse an overdue lease (manager expiry sweep).
    ExpireLease {
        /// Lease id.
        id: u64,
    },
    /// Place a batch of claims: each block's replica set was decided by
    /// the placement policy at log time, so replay never re-runs
    /// placement (the policy cursor is volatile).
    Alloc {
        /// Claim tag of the allocating session.
        tag: String,
        /// Write lease the claims are held under (`0` = untracked).
        lease: u64,
        /// Placed blocks with their decided replica sets.
        blocks: Vec<BlockMeta>,
    },
    /// A new node joined the registry (re-joins of a known address only
    /// touch the volatile liveness clock and are not logged).
    NodeJoin {
        /// Assigned node id (the registry index).
        id: u32,
        /// Address the node serves blocks on.
        addr: String,
    },
    /// Replace a block's replica set (scrub/repair re-homed a lost or
    /// corrupt copy onto a live node).  The new set was decided at log
    /// time — replay installs it verbatim, like `Alloc`.  Applies to
    /// the block table and every committed file map referencing the
    /// block; a no-op if the block has since been released.
    Rehome {
        /// The repaired block.
        hash: Digest,
        /// The full new replica set (shard positions preserved under
        /// erasure coding).
        replicas: Vec<u32>,
    },
}

impl Record {
    fn tag(&self) -> u8 {
        match self {
            Record::Commit { .. } => 1,
            Record::Release { .. } => 2,
            Record::OpenLease { .. } => 3,
            Record::RenewLease { .. } => 4,
            Record::DropLease { .. } => 5,
            Record::ExpireLease { .. } => 6,
            Record::Alloc { .. } => 7,
            Record::NodeJoin { .. } => 8,
            Record::Rehome { .. } => 9,
        }
    }

    /// Serialize to record bytes (tag + fields; no frame).
    pub fn encode(&self) -> Vec<u8> {
        let mut p = vec![self.tag()];
        match self {
            Record::Commit { file, lease, blocks } | Record::Alloc { tag: file, lease, blocks } => {
                put_str(&mut p, file);
                p.extend_from_slice(&lease.to_le_bytes());
                put_blocks(&mut p, blocks);
            }
            Record::Release { hashes } => put_hashes(&mut p, hashes),
            Record::OpenLease { id, tag, write, hashes } => {
                p.extend_from_slice(&id.to_le_bytes());
                put_str(&mut p, tag);
                p.push(*write as u8);
                put_hashes(&mut p, hashes);
            }
            Record::RenewLease { id } | Record::DropLease { id } | Record::ExpireLease { id } => {
                p.extend_from_slice(&id.to_le_bytes())
            }
            Record::NodeJoin { id, addr } => {
                p.extend_from_slice(&id.to_le_bytes());
                put_str(&mut p, addr);
            }
            Record::Rehome { hash, replicas } => {
                p.extend_from_slice(hash);
                put_replicas(&mut p, replicas);
            }
        }
        p
    }

    /// Deserialize record bytes (strict: trailing bytes are an error).
    pub fn decode(b: &[u8]) -> Result<Record> {
        let mut c = Cursor::new(b);
        let tag = c.u8()?;
        let rec = match tag {
            1 => Record::Commit {
                file: c.str()?,
                lease: c.u64()?,
                blocks: c.blocks()?,
            },
            2 => Record::Release { hashes: c.hashes()? },
            3 => Record::OpenLease {
                id: c.u64()?,
                tag: c.str()?,
                write: c.u8()? != 0,
                hashes: c.hashes()?,
            },
            4 => Record::RenewLease { id: c.u64()? },
            5 => Record::DropLease { id: c.u64()? },
            6 => Record::ExpireLease { id: c.u64()? },
            7 => Record::Alloc {
                tag: c.str()?,
                lease: c.u64()?,
                blocks: c.blocks()?,
            },
            8 => Record::NodeJoin {
                id: c.u32()?,
                addr: c.str()?,
            },
            9 => Record::Rehome {
                hash: c.digest()?,
                replicas: c.replicas()?,
            },
            t => return Err(Error::Proto(format!("wal: unknown record tag {t}"))),
        };
        c.finish(&format!("wal record {tag}"))?;
        Ok(rec)
    }
}

fn put_hashes(p: &mut Vec<u8>, hashes: &[Digest]) {
    p.extend_from_slice(&(hashes.len() as u32).to_le_bytes());
    for h in hashes {
        p.extend_from_slice(h);
    }
}

/// One stored block's full bookkeeping in a snapshot (mirrors the
/// manager's `BlockInfo`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SnapBlock {
    /// Content hash.
    pub hash: Digest,
    /// Payload length.
    pub len: u32,
    /// Assigned replica set.
    pub replicas: Vec<u32>,
    /// Committed references.
    pub refs: u64,
    /// Provisional claim occurrences.
    pub pending: u64,
    /// Read-lease pins.
    pub pins: u64,
    /// Claim tag of the first allocator while uncommitted.
    pub placed_by: String,
    /// Erasure coding: `Some((k, m))` → `replicas[i]` holds shard `i`;
    /// `None` → full copies.
    pub ec: Option<(u8, u8)>,
}

/// One live lease in a snapshot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SnapLease {
    /// Lease id.
    pub id: u64,
    /// File name (read) or claim token (write).
    pub tag: String,
    /// Writer claim lease vs. read-pin lease.
    pub write: bool,
    /// Held hash occurrences.
    pub hashes: Vec<Digest>,
}

/// A complete, serializable image of the manager's durable state at one
/// LSN.  Volatile fields (lease expiry clocks, node liveness beats, the
/// placement cursor, GC-in-flight marks) are deliberately absent: lease
/// clocks resume conservatively at a full TTL, nodes resume "alive"
/// until the heartbeat timeout re-judges them, and `Alloc` records
/// carry their decided replica sets so the cursor never needs replay.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct SnapshotState {
    /// LSN of the last record folded into this image.
    pub lsn: u64,
    /// Files, sorted by name: `(name, version, block-map)`.
    pub files: Vec<(String, u64, Vec<BlockMeta>)>,
    /// Block table, sorted by hash.
    pub blocks: Vec<SnapBlock>,
    /// Node registry addresses, by id.
    pub nodes: Vec<String>,
    /// Live leases, sorted by id.
    pub leases: Vec<SnapLease>,
    /// Next lease id to grant.
    pub next_lease: u64,
}

impl SnapshotState {
    /// Serialize: `magic | crc32(body) | body`.
    pub fn encode(&self) -> Vec<u8> {
        let mut p = Vec::new();
        p.extend_from_slice(&SNAP_VERSION.to_le_bytes());
        p.extend_from_slice(&self.lsn.to_le_bytes());
        p.extend_from_slice(&self.next_lease.to_le_bytes());
        p.extend_from_slice(&(self.files.len() as u32).to_le_bytes());
        for (name, version, blocks) in &self.files {
            put_str(&mut p, name);
            p.extend_from_slice(&version.to_le_bytes());
            put_blocks(&mut p, blocks);
        }
        p.extend_from_slice(&(self.blocks.len() as u32).to_le_bytes());
        for b in &self.blocks {
            p.extend_from_slice(&b.hash);
            p.extend_from_slice(&b.len.to_le_bytes());
            put_replicas(&mut p, &b.replicas);
            p.extend_from_slice(&b.refs.to_le_bytes());
            p.extend_from_slice(&b.pending.to_le_bytes());
            p.extend_from_slice(&b.pins.to_le_bytes());
            put_str(&mut p, &b.placed_by);
            put_ec(&mut p, b.ec);
        }
        p.extend_from_slice(&(self.nodes.len() as u32).to_le_bytes());
        for addr in &self.nodes {
            put_str(&mut p, addr);
        }
        p.extend_from_slice(&(self.leases.len() as u32).to_le_bytes());
        for l in &self.leases {
            p.extend_from_slice(&l.id.to_le_bytes());
            put_str(&mut p, &l.tag);
            p.push(l.write as u8);
            put_hashes(&mut p, &l.hashes);
        }
        let mut out = Vec::with_capacity(8 + p.len());
        out.extend_from_slice(SNAP_MAGIC);
        out.extend_from_slice(&crc32(&p).to_le_bytes());
        out.extend_from_slice(&p);
        out
    }

    /// Deserialize, verifying magic, CRC, version and exact length.
    pub fn decode(b: &[u8]) -> Result<SnapshotState> {
        if b.len() < 8 || &b[..4] != SNAP_MAGIC {
            return Err(Error::Proto("snapshot: bad magic".into()));
        }
        let crc = u32::from_le_bytes(b[4..8].try_into().unwrap());
        let body = &b[8..];
        if crc32(body) != crc {
            return Err(Error::Proto("snapshot: crc mismatch".into()));
        }
        let mut c = Cursor::new(body);
        let version = c.u32()?;
        if version != SNAP_VERSION {
            return Err(Error::Proto(format!("snapshot: unknown version {version}")));
        }
        let lsn = c.u64()?;
        let next_lease = c.u64()?;
        let nf = c.list_len(16, "snapshot files")?;
        let mut files = Vec::with_capacity(nf.min(4096));
        for _ in 0..nf {
            let name = c.str()?;
            let v = c.u64()?;
            files.push((name, v, c.blocks()?));
        }
        let nb = c.list_len(51, "snapshot blocks")?;
        let mut blocks = Vec::with_capacity(nb.min(4096));
        for _ in 0..nb {
            blocks.push(SnapBlock {
                hash: c.digest()?,
                len: c.u32()?,
                replicas: c.replicas()?,
                refs: c.u64()?,
                pending: c.u64()?,
                pins: c.u64()?,
                placed_by: c.str()?,
                ec: c.ec()?,
            });
        }
        let nn = c.list_len(4, "snapshot nodes")?;
        let mut nodes = Vec::with_capacity(nn.min(4096));
        for _ in 0..nn {
            nodes.push(c.str()?);
        }
        let nl = c.list_len(17, "snapshot leases")?;
        let mut leases = Vec::with_capacity(nl.min(4096));
        for _ in 0..nl {
            leases.push(SnapLease {
                id: c.u64()?,
                tag: c.str()?,
                write: c.u8()? != 0,
                hashes: c.hashes()?,
            });
        }
        c.finish("snapshot")?;
        Ok(SnapshotState {
            lsn,
            files,
            blocks,
            nodes,
            leases,
            next_lease,
        })
    }
}

/// An open write-ahead log: the manager's append handle.
#[derive(Debug)]
pub struct Wal {
    opts: DurabilityOpts,
    /// The live (last) segment, opened for append.
    seg: File,
    seg_bytes: u64,
    /// LSN the next append must carry.
    next_lsn: u64,
    /// Group-commit clock: last time the live segment was fsynced.
    last_sync: Instant,
    /// Records appended since the last snapshot.
    since_snapshot: u64,
}

/// The result of opening a data dir: the state image to install, the
/// log tail to replay on top of it, and the continuing append handle.
#[derive(Debug)]
pub struct Recovery {
    /// Latest valid snapshot, if any.
    pub snapshot: Option<SnapshotState>,
    /// Records after the snapshot, in LSN order.
    pub records: Vec<(u64, Record)>,
    /// The log, positioned to append the next record.
    pub wal: Wal,
}

impl Wal {
    /// Append one record as `lsn` (must be the next dense LSN) and
    /// apply the group-commit sync policy.
    pub fn append(&mut self, lsn: u64, record: &[u8]) -> Result<()> {
        debug_assert_eq!(lsn, self.next_lsn, "wal appends must be dense");
        let mut frame = Vec::with_capacity(16 + record.len());
        frame.extend_from_slice(&((8 + record.len()) as u32).to_le_bytes());
        frame.extend_from_slice(&[0; 4]); // crc placeholder
        frame.extend_from_slice(&lsn.to_le_bytes());
        frame.extend_from_slice(record);
        let crc = crc32(&frame[8..]);
        frame[4..8].copy_from_slice(&crc.to_le_bytes());
        self.seg.write_all(&frame)?;
        self.seg_bytes += frame.len() as u64;
        self.next_lsn = lsn + 1;
        self.since_snapshot += 1;
        if self.opts.sync_interval.is_zero() {
            self.seg.sync_data()?;
        } else {
            let now = Instant::now();
            if now.duration_since(self.last_sync) >= self.opts.sync_interval {
                self.seg.sync_data()?;
                self.last_sync = now;
            }
        }
        if self.seg_bytes >= SEG_BYTES {
            self.rotate()?;
        }
        Ok(())
    }

    /// Force any unsynced tail to disk (the group-commit window ends
    /// here; also runs on drop).
    pub fn sync(&mut self) -> Result<()> {
        self.seg.sync_data()?;
        self.last_sync = Instant::now();
        Ok(())
    }

    /// LSN the next append must carry.
    pub fn next_lsn(&self) -> u64 {
        self.next_lsn
    }

    /// True once `snapshot_every` records accumulated since the last
    /// snapshot — the manager should cut one.
    pub fn wants_snapshot(&self) -> bool {
        self.since_snapshot >= self.opts.snapshot_every.max(1)
    }

    /// Durably write a snapshot covering everything up to
    /// `snap.lsn == next_lsn - 1`, rotate the log, and prune segments
    /// and snapshots the new image covers.
    pub fn snapshot(&mut self, snap: &SnapshotState) -> Result<()> {
        debug_assert_eq!(snap.lsn + 1, self.next_lsn, "snapshot must cover the log");
        let snap_dir = self.opts.data_dir.join("snap");
        let tmp = snap_dir.join("snap.tmp");
        let finali = snap_dir.join(format!("snap-{:020}.snap", snap.lsn));
        {
            let mut f = File::create(&tmp)?;
            f.write_all(&snap.encode())?;
            f.sync_all()?;
        }
        fs::rename(&tmp, &finali)?;
        sync_dir(&snap_dir)?;
        // Rotate so the live segment starts after the snapshot: every
        // older segment is then fully covered and prunable.
        self.rotate()?;
        self.since_snapshot = 0;
        prune(&self.opts.data_dir, snap.lsn, self.segment_path())?;
        Ok(())
    }

    /// Replace the log wholesale with `snap`: delete every existing
    /// snapshot and segment (including any with LSNs beyond the
    /// snapshot — the divergent-tail case on a demoted leader), durably
    /// write `snap`, and start a fresh segment at `snap.lsn + 1`.  Used
    /// when a replica re-bootstraps from a new quorum leader whose
    /// history supersedes the local one.
    ///
    /// Deletion happens first so a crash mid-reset can only leave a
    /// blank node (which re-bootstraps again on the leader's next
    /// contact), never a stale higher-LSN snapshot that recovery would
    /// prefer over the installed one.
    pub fn reset_to(&mut self, snap: &SnapshotState) -> Result<()> {
        let snap_dir = self.opts.data_dir.join("snap");
        let wal_dir = self.opts.data_dir.join("wal");
        for (_, path) in list(&snap_dir, "snap-", ".snap")? {
            let _ = fs::remove_file(path);
        }
        for (_, path) in list(&wal_dir, "seg-", ".log")? {
            let _ = fs::remove_file(path);
        }
        sync_dir(&snap_dir)?;
        sync_dir(&wal_dir)?;
        let tmp = snap_dir.join("snap.tmp");
        let finali = snap_dir.join(format!("snap-{:020}.snap", snap.lsn));
        {
            let mut f = File::create(&tmp)?;
            f.write_all(&snap.encode())?;
            f.sync_all()?;
        }
        fs::rename(&tmp, &finali)?;
        sync_dir(&snap_dir)?;
        self.next_lsn = snap.lsn + 1;
        let fresh = wal_dir.join(format!("seg-{:020}.log", self.next_lsn));
        self.seg = OpenOptions::new().create(true).append(true).open(&fresh)?;
        self.seg_bytes = 0;
        self.last_sync = Instant::now();
        self.since_snapshot = 0;
        sync_dir(&wal_dir)?;
        Ok(())
    }

    fn segment_path(&self) -> PathBuf {
        // The live segment's first lsn is next_lsn minus what it holds;
        // after a rotate it is exactly next_lsn.  We only need this
        // right after rotation (for prune), where it is exact.
        self.opts
            .data_dir
            .join("wal")
            .join(format!("seg-{:020}.log", self.next_lsn))
    }

    /// Sync and close the live segment, then start a fresh one at
    /// `next_lsn`.
    fn rotate(&mut self) -> Result<()> {
        self.seg.sync_data()?;
        let wal_dir = self.opts.data_dir.join("wal");
        let path = wal_dir.join(format!("seg-{:020}.log", self.next_lsn));
        self.seg = OpenOptions::new().create(true).append(true).open(&path)?;
        self.seg_bytes = 0;
        self.last_sync = Instant::now();
        sync_dir(&wal_dir)?;
        Ok(())
    }
}

impl Drop for Wal {
    fn drop(&mut self) {
        let _ = self.seg.sync_data();
    }
}

/// Open (or initialize) a data dir: load the latest snapshot, replay
/// and validate the log tail, truncate a torn final record, and return
/// the continuing append handle.  Interior corruption — a short frame
/// mid-log, a CRC mismatch on a complete frame, an LSN gap, an
/// unreadable snapshot — fails loudly; this function never guesses.
pub fn recover(opts: &DurabilityOpts) -> Result<Recovery> {
    let wal_dir = opts.data_dir.join("wal");
    let snap_dir = opts.data_dir.join("snap");
    fs::create_dir_all(&wal_dir)?;
    fs::create_dir_all(&snap_dir)?;
    // A crash between tmp-write and rename leaves a .tmp: never valid.
    let _ = fs::remove_file(snap_dir.join("snap.tmp"));

    let snapshot = match latest(&snap_dir, "snap-", ".snap")? {
        Some((_, path)) => {
            let bytes = fs::read(&path)?;
            // A snapshot that exists but does not decode is corruption,
            // not absence: fail loudly rather than silently replaying
            // from an older base and resurrecting deleted state.
            Some(SnapshotState::decode(&bytes).map_err(|e| {
                Error::Proto(format!("snapshot {}: {e}", path.display()))
            })?)
        }
        None => None,
    };
    let snap_lsn = snapshot.as_ref().map(|s| s.lsn).unwrap_or(0);

    let mut seg_paths: Vec<(u64, PathBuf)> = list(&wal_dir, "seg-", ".log")?;
    seg_paths.sort();
    let mut records: Vec<(u64, Record)> = Vec::new();
    let mut expected: Option<u64> = None;
    for (i, (first_lsn, path)) in seg_paths.iter().enumerate() {
        let last = i + 1 == seg_paths.len();
        let bytes = fs::read(path)?;
        let mut off = 0usize;
        while off < bytes.len() {
            let frame_start = off;
            if bytes.len() - off < 8 {
                torn(path, frame_start, last, &bytes)?;
                break;
            }
            let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
            let crc = u32::from_le_bytes(bytes[off + 4..off + 8].try_into().unwrap());
            if len < 9 || len > MAX_FRAME {
                return Err(Error::Proto(format!(
                    "wal {}: bad frame length {len} at offset {frame_start}",
                    path.display()
                )));
            }
            if bytes.len() - off - 8 < len {
                torn(path, frame_start, last, &bytes)?;
                break;
            }
            let body = &bytes[off + 8..off + 8 + len];
            if crc32(body) != crc {
                return Err(Error::Proto(format!(
                    "wal {}: crc mismatch at offset {frame_start} (lsn area {})",
                    path.display(),
                    expected.unwrap_or(*first_lsn),
                )));
            }
            let lsn = u64::from_le_bytes(body[..8].try_into().unwrap());
            if off == 0 && lsn != *first_lsn {
                return Err(Error::Proto(format!(
                    "wal {}: first record lsn {lsn} does not match segment name",
                    path.display()
                )));
            }
            if let Some(e) = expected {
                if lsn != e {
                    return Err(Error::Proto(format!(
                        "wal {}: lsn gap (expected {e}, found {lsn}) — a segment is missing",
                        path.display()
                    )));
                }
            }
            expected = Some(lsn + 1);
            if lsn > snap_lsn {
                records.push((lsn, Record::decode(&body[8..])?));
            }
            off += 8 + len;
        }
    }
    if let Some((lsn, _)) = records.first() {
        if snapshot.is_some() && *lsn > snap_lsn + 1 {
            return Err(Error::Proto(format!(
                "wal: first replay record lsn {lsn} leaves a gap after snapshot lsn {snap_lsn}"
            )));
        }
    }
    let last_lsn = expected.map(|e| e - 1).unwrap_or(0).max(snap_lsn);
    let next_lsn = last_lsn + 1;

    // Continue the last segment when it ends exactly at last_lsn;
    // otherwise (fresh dir, or a snapshot newer than the whole log)
    // start a clean segment at next_lsn so density holds.
    let continue_last = expected.map(|e| e - 1) == Some(last_lsn) && !seg_paths.is_empty();
    let (seg, seg_bytes) = if continue_last {
        let path = &seg_paths.last().unwrap().1;
        let f = OpenOptions::new().append(true).open(path)?;
        let len = f.metadata()?.len();
        (f, len)
    } else {
        let path = wal_dir.join(format!("seg-{:020}.log", next_lsn));
        let f = OpenOptions::new().create(true).append(true).open(&path)?;
        sync_dir(&wal_dir)?;
        (f, 0)
    };
    Ok(Recovery {
        snapshot,
        records,
        wal: Wal {
            opts: opts.clone(),
            seg,
            seg_bytes,
            next_lsn,
            last_sync: Instant::now(),
            since_snapshot: 0,
        },
    })
}

/// Handle an incomplete frame at `frame_start`: in the final segment it
/// is a torn tail (a crash mid-append of a record that was never
/// acknowledged) — truncate it away, note it on stderr, and let the
/// same recovery pass continue with everything before it; anywhere else
/// it is a lost chunk of history — fail loudly.
fn torn(path: &Path, frame_start: usize, last_segment: bool, bytes: &[u8]) -> Result<()> {
    if !last_segment {
        return Err(Error::Proto(format!(
            "wal {}: truncated record mid-log at offset {frame_start}",
            path.display()
        )));
    }
    let f = OpenOptions::new().write(true).open(path)?;
    f.set_len(frame_start as u64)?;
    f.sync_all()?;
    eprintln!(
        "gpustore wal: torn tail truncated at {} bytes of {} ({} trailing bytes discarded)",
        frame_start,
        path.display(),
        bytes.len() - frame_start
    );
    Ok(())
}

/// Files in `dir` named `<prefix><u64><suffix>`, with the parsed
/// number.
fn list(dir: &Path, prefix: &str, suffix: &str) -> Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(num) = name
            .strip_prefix(prefix)
            .and_then(|s| s.strip_suffix(suffix))
        else {
            continue;
        };
        let Ok(n) = num.parse::<u64>() else { continue };
        out.push((n, entry.path()));
    }
    Ok(out)
}

fn latest(dir: &Path, prefix: &str, suffix: &str) -> Result<Option<(u64, PathBuf)>> {
    Ok(list(dir, prefix, suffix)?.into_iter().max())
}

/// Delete snapshots older than `snap_lsn` and segments other than the
/// live one (all fully covered after the post-snapshot rotation).
fn prune(data_dir: &Path, snap_lsn: u64, live_segment: PathBuf) -> Result<()> {
    for (lsn, path) in list(&data_dir.join("snap"), "snap-", ".snap")? {
        if lsn < snap_lsn {
            let _ = fs::remove_file(path);
        }
    }
    for (_, path) in list(&data_dir.join("wal"), "seg-", ".log")? {
        if path != live_segment {
            let _ = fs::remove_file(path);
        }
    }
    Ok(())
}

/// Durably persist election state: the manager's current term, who it
/// voted for in that term, and the term under which its log head was
/// accepted (the Raft "last log term", used for election
/// up-to-dateness).  A CRC-framed sidecar (`<data_dir>/term`) next to
/// the WAL, written tmp + fsync + rename: forgetting a vote across a
/// crash would let a node vote twice in one term and elect two leaders,
/// and forgetting the accepted term would let a long stale-term log
/// outvote a shorter log holding newer commits.
pub fn save_term(
    data_dir: &Path,
    term: u64,
    voted_for: Option<&str>,
    accepted_term: u64,
) -> Result<()> {
    fs::create_dir_all(data_dir)?;
    let voted = voted_for.unwrap_or("");
    let mut body = Vec::with_capacity(20 + voted.len());
    body.extend_from_slice(&term.to_le_bytes());
    body.extend_from_slice(&accepted_term.to_le_bytes());
    body.extend_from_slice(&(voted.len() as u32).to_le_bytes());
    body.extend_from_slice(voted.as_bytes());
    let mut buf = Vec::with_capacity(8 + body.len());
    buf.extend_from_slice(b"GTRM");
    buf.extend_from_slice(&crc32(&body).to_le_bytes());
    buf.extend_from_slice(&body);
    let tmp = data_dir.join("term.tmp");
    let finali = data_dir.join("term");
    {
        let mut f = File::create(&tmp)?;
        f.write_all(&buf)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, &finali)?;
    sync_dir(data_dir)
}

/// Load the persisted `(term, voted_for, accepted_term)`: `Ok(None)`
/// when no term file exists (a fresh node), loud `Err` on any
/// corruption — guessing at election state risks a double vote.
#[allow(clippy::type_complexity)]
pub fn load_term(data_dir: &Path) -> Result<Option<(u64, Option<String>, u64)>> {
    let path = data_dir.join("term");
    let bytes = match fs::read(&path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    let fail = |why: &str| Error::Proto(format!("term file {}: {why}", path.display()));
    if bytes.len() < 28 || &bytes[..4] != b"GTRM" {
        return Err(fail("bad magic or truncated"));
    }
    let crc = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    let body = &bytes[8..];
    if crc32(body) != crc {
        return Err(fail("crc mismatch"));
    }
    let term = u64::from_le_bytes(body[..8].try_into().unwrap());
    let accepted = u64::from_le_bytes(body[8..16].try_into().unwrap());
    let len = u32::from_le_bytes(body[16..20].try_into().unwrap()) as usize;
    if body.len() != 20 + len {
        return Err(fail("bad voted-for length"));
    }
    let voted = if len == 0 {
        None
    } else {
        Some(
            String::from_utf8(body[20..].to_vec()).map_err(|_| fail("voted-for not utf-8"))?,
        )
    };
    Ok(Some((term, voted, accepted)))
}

fn sync_dir(dir: &Path) -> Result<()> {
    // Durability of creates/renames needs the directory fsynced on
    // POSIX; best-effort on platforms where opening a dir fails.
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32 (IEEE, reflected) — the zlib polynomial, hand-rolled for the
/// zero-dependency constraint.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Unit-test fixtures shared by this module's tests and the manager's
/// durability tests.
#[cfg(test)]
pub(crate) mod testutil {
    use std::fs;
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Unique throwaway data dir (removed on drop, best effort).
    pub(crate) struct TempDir(pub PathBuf);

    impl TempDir {
        pub(crate) fn new(tag: &str) -> TempDir {
            static N: AtomicU64 = AtomicU64::new(0);
            let p = std::env::temp_dir().join(format!(
                "gpustore-{tag}-{}-{}",
                std::process::id(),
                N.fetch_add(1, Ordering::Relaxed)
            ));
            fs::create_dir_all(&p).unwrap();
            TempDir(p)
        }
    }

    impl Drop for TempDir {
        fn drop(&mut self) {
            let _ = fs::remove_dir_all(&self.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::TempDir;
    use super::*;

    fn strict(dir: &Path) -> DurabilityOpts {
        DurabilityOpts {
            data_dir: dir.to_path_buf(),
            sync_interval: Duration::ZERO,
            snapshot_every: 1_000_000,
        }
    }

    fn rec(i: u8) -> Record {
        Record::Release {
            hashes: vec![[i; 16]],
        }
    }

    fn append_n(w: &mut Wal, from: u64, n: u64) {
        for k in 0..n {
            let lsn = from + k;
            w.append(lsn, &rec((lsn % 251) as u8).encode()).unwrap();
        }
    }

    #[test]
    fn crc32_known_vectors() {
        // The zlib/IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn record_roundtrip_all_variants() {
        let meta = BlockMeta {
            hash: [7; 16],
            len: 123,
            replicas: vec![0, 2],
            ec: None,
        };
        let coded = BlockMeta {
            hash: [8; 16],
            len: 4096,
            replicas: vec![0, 1, 2, 3, 4, 5],
            ec: Some((4, 2)),
        };
        let all = vec![
            Record::Commit {
                file: "f".into(),
                lease: 9,
                blocks: vec![meta.clone(), coded.clone()],
            },
            Record::Release {
                hashes: vec![[1; 16], [2; 16]],
            },
            Record::OpenLease {
                id: 3,
                tag: "t#1.2.abc".into(),
                write: true,
                hashes: vec![],
            },
            Record::OpenLease {
                id: 4,
                tag: "file.bin".into(),
                write: false,
                hashes: vec![[5; 16], [5; 16]],
            },
            Record::RenewLease { id: u64::MAX },
            Record::DropLease { id: 1 },
            Record::ExpireLease { id: 2 },
            Record::Alloc {
                tag: "sess".into(),
                lease: 0,
                blocks: vec![meta, coded],
            },
            Record::NodeJoin {
                id: 3,
                addr: "127.0.0.1:7071".into(),
            },
            Record::Rehome {
                hash: [9; 16],
                replicas: vec![2, 1, 5],
            },
            Record::Rehome {
                hash: [0; 16],
                replicas: vec![],
            },
        ];
        for r in all {
            let b = r.encode();
            assert_eq!(Record::decode(&b).unwrap(), r, "{r:?}");
            // Trailing garbage is rejected.
            let mut long = b.clone();
            long.push(0xEE);
            assert!(Record::decode(&long).is_err(), "{r:?}");
        }
    }

    #[test]
    fn snapshot_roundtrip_and_corruption() {
        let snap = SnapshotState {
            lsn: 42,
            files: vec![(
                "a".into(),
                3,
                vec![BlockMeta {
                    hash: [1; 16],
                    len: 10,
                    replicas: vec![0],
                    ec: None,
                }],
            )],
            blocks: vec![
                SnapBlock {
                    hash: [1; 16],
                    len: 10,
                    replicas: vec![0],
                    refs: 1,
                    pending: 2,
                    pins: 3,
                    placed_by: "s".into(),
                    ec: None,
                },
                SnapBlock {
                    hash: [2; 16],
                    len: 9000,
                    replicas: vec![0, 1, 2],
                    refs: 1,
                    pending: 0,
                    pins: 0,
                    placed_by: String::new(),
                    ec: Some((2, 1)),
                },
            ],
            nodes: vec!["a:1".into(), "b:2".into()],
            leases: vec![SnapLease {
                id: 7,
                tag: "a".into(),
                write: false,
                hashes: vec![[1; 16]],
            }],
            next_lease: 8,
        };
        let mut b = snap.encode();
        assert_eq!(SnapshotState::decode(&b).unwrap(), snap);
        // One flipped byte in the body fails the CRC, loudly.
        let mid = b.len() - 3;
        b[mid] ^= 0xFF;
        assert!(SnapshotState::decode(&b).is_err());
        assert!(SnapshotState::decode(b"GSNPxxxx").is_err());
        assert!(SnapshotState::decode(b"XXXX").is_err());
    }

    #[test]
    fn append_and_recover_roundtrip() {
        let t = TempDir::new("wal-roundtrip");
        let opts = strict(&t.0);
        {
            let mut r = recover(&opts).unwrap();
            assert!(r.snapshot.is_none());
            assert!(r.records.is_empty());
            assert_eq!(r.wal.next_lsn(), 1);
            append_n(&mut r.wal, 1, 5);
        }
        let r = recover(&opts).unwrap();
        assert!(r.snapshot.is_none());
        assert_eq!(r.records.len(), 5);
        assert_eq!(r.records.first().unwrap().0, 1);
        assert_eq!(r.records.last().unwrap().0, 5);
        assert_eq!(r.records[2].1, rec(3));
        assert_eq!(r.wal.next_lsn(), 6);
    }

    #[test]
    fn torn_final_record_truncated_then_recovers() {
        let t = TempDir::new("wal-torn");
        let opts = strict(&t.0);
        {
            let mut r = recover(&opts).unwrap();
            append_n(&mut r.wal, 1, 3);
        }
        // Tear the tail: append half a frame to the live segment.
        let seg = list(&t.0.join("wal"), "seg-", ".log").unwrap().pop().unwrap().1;
        let whole = fs::read(&seg).unwrap();
        let mut f = OpenOptions::new().append(true).open(&seg).unwrap();
        f.write_all(&99u32.to_le_bytes()).unwrap(); // length of a frame that never arrived
        f.write_all(&[1, 2, 3]).unwrap();
        drop(f);
        // One recovery pass truncates the torn tail and carries on with
        // every complete record — a crashed manager restarts in one go.
        let r = recover(&opts).unwrap();
        assert_eq!(fs::read(&seg).unwrap(), whole, "tail truncated exactly");
        assert_eq!(r.records.len(), 3);
        assert_eq!(r.wal.next_lsn(), 4);
        drop(r);
        // Idempotent: a second recovery sees a clean log.
        let r = recover(&opts).unwrap();
        assert_eq!(r.records.len(), 3);
        assert_eq!(r.wal.next_lsn(), 4);
    }

    #[test]
    fn corrupt_crc_mid_segment_fails_loudly() {
        let t = TempDir::new("wal-crc");
        let opts = strict(&t.0);
        {
            let mut r = recover(&opts).unwrap();
            append_n(&mut r.wal, 1, 3);
        }
        let seg = list(&t.0.join("wal"), "seg-", ".log").unwrap().pop().unwrap().1;
        let mut bytes = fs::read(&seg).unwrap();
        // Flip one payload byte of the FIRST frame: a complete interior
        // record with a bad CRC is corruption, never a torn tail.
        let len = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
        bytes[8 + len - 1] ^= 0xFF;
        fs::write(&seg, &bytes).unwrap();
        let err = recover(&opts).unwrap_err();
        assert!(format!("{err}").contains("crc mismatch"), "{err}");
        // And it stays loud on retry: nothing was silently truncated.
        assert!(recover(&opts).is_err());
    }

    #[test]
    fn empty_log_with_valid_snapshot() {
        let t = TempDir::new("wal-snap-only");
        let opts = strict(&t.0);
        {
            let mut r = recover(&opts).unwrap();
            append_n(&mut r.wal, 1, 4);
            let snap = SnapshotState {
                lsn: 4,
                next_lease: 1,
                ..SnapshotState::default()
            };
            r.wal.snapshot(&snap).unwrap();
        }
        // The snapshot pruned all older segments; the live one is empty.
        let r = recover(&opts).unwrap();
        assert_eq!(r.snapshot.as_ref().unwrap().lsn, 4);
        assert!(r.records.is_empty(), "{:?}", r.records);
        assert_eq!(r.wal.next_lsn(), 5);
        assert_eq!(
            list(&t.0.join("snap"), "snap-", ".snap").unwrap().len(),
            1,
            "older snapshots pruned"
        );
    }

    #[test]
    fn snapshot_newer_than_log_recovers_from_snapshot() {
        let t = TempDir::new("wal-snap-newer");
        let opts = strict(&t.0);
        {
            let mut r = recover(&opts).unwrap();
            append_n(&mut r.wal, 1, 3);
        }
        // Hand-write a snapshot claiming lsn 10 (beyond the log): the
        // log is fully covered, replays nothing, and appends continue
        // at 11 in a fresh segment.
        let snap = SnapshotState {
            lsn: 10,
            next_lease: 1,
            ..SnapshotState::default()
        };
        fs::write(
            t.0.join("snap").join(format!("snap-{:020}.snap", 10)),
            snap.encode(),
        )
        .unwrap();
        let mut r = recover(&opts).unwrap();
        assert_eq!(r.snapshot.as_ref().unwrap().lsn, 10);
        assert!(r.records.is_empty());
        assert_eq!(r.wal.next_lsn(), 11);
        append_n(&mut r.wal, 11, 2);
        drop(r);
        let r = recover(&opts).unwrap();
        assert_eq!(r.records.iter().map(|(l, _)| *l).collect::<Vec<_>>(), [11, 12]);
    }

    #[test]
    fn corrupt_snapshot_fails_loudly() {
        let t = TempDir::new("wal-snap-corrupt");
        let opts = strict(&t.0);
        {
            let mut r = recover(&opts).unwrap();
            append_n(&mut r.wal, 1, 2);
            r.wal
                .snapshot(&SnapshotState {
                    lsn: 2,
                    next_lease: 1,
                    ..SnapshotState::default()
                })
                .unwrap();
        }
        let snap = list(&t.0.join("snap"), "snap-", ".snap").unwrap().pop().unwrap().1;
        let mut b = fs::read(&snap).unwrap();
        let last = b.len() - 1;
        b[last] ^= 0x55;
        fs::write(&snap, &b).unwrap();
        assert!(recover(&opts).is_err(), "corrupt snapshot must not be skipped");
    }

    #[test]
    fn leftover_tmp_snapshot_is_removed() {
        let t = TempDir::new("wal-tmp");
        let opts = strict(&t.0);
        fs::create_dir_all(t.0.join("snap")).unwrap();
        fs::write(t.0.join("snap").join("snap.tmp"), b"half-written").unwrap();
        let r = recover(&opts).unwrap();
        assert!(r.snapshot.is_none());
        assert!(!t.0.join("snap").join("snap.tmp").exists());
    }

    #[test]
    fn lsn_gap_between_segments_fails_loudly() {
        let t = TempDir::new("wal-gap");
        let opts = strict(&t.0);
        {
            let mut r = recover(&opts).unwrap();
            append_n(&mut r.wal, 1, 2);
        }
        // Forge a second segment that skips lsn 3.
        let mut w = Wal {
            opts: opts.clone(),
            seg: OpenOptions::new()
                .create(true)
                .append(true)
                .open(t.0.join("wal").join(format!("seg-{:020}.log", 4)))
                .unwrap(),
            seg_bytes: 0,
            next_lsn: 4,
            last_sync: Instant::now(),
            since_snapshot: 0,
        };
        w.append(4, &rec(4).encode()).unwrap();
        drop(w);
        let err = recover(&opts).unwrap_err();
        assert!(format!("{err}").contains("lsn gap"), "{err}");
    }

    #[test]
    fn group_commit_interval_skips_syncs() {
        // Behavioural, not timing-based: with a huge interval, appends
        // must not sync each record (we can only observe this as "no
        // error" + data still recovered, since the OS page cache holds
        // the bytes) — and an explicit sync() flushes the tail.
        let t = TempDir::new("wal-group");
        let opts = DurabilityOpts {
            data_dir: t.0.clone(),
            sync_interval: Duration::from_secs(3600),
            snapshot_every: 1_000_000,
        };
        {
            let mut r = recover(&opts).unwrap();
            append_n(&mut r.wal, 1, 100);
            r.wal.sync().unwrap();
        }
        assert_eq!(recover(&opts).unwrap().records.len(), 100);
    }

    #[test]
    fn segment_rotation_preserves_history() {
        let t = TempDir::new("wal-rotate");
        let opts = strict(&t.0);
        {
            let mut r = recover(&opts).unwrap();
            // Big records force several rotations past SEG_BYTES.
            let big = Record::Release {
                hashes: vec![[9; 16]; 40_000],
            }
            .encode();
            for lsn in 1..=30u64 {
                r.wal.append(lsn, &big).unwrap();
            }
        }
        assert!(
            list(&t.0.join("wal"), "seg-", ".log").unwrap().len() > 1,
            "rotation happened"
        );
        let r = recover(&opts).unwrap();
        assert_eq!(r.records.len(), 30);
        assert_eq!(r.wal.next_lsn(), 31);
    }

    #[test]
    fn term_roundtrip_absent_and_corrupt() {
        let t = TempDir::new("wal-term");
        // Absent: a fresh node has no election state.
        assert_eq!(load_term(&t.0).unwrap(), None);
        save_term(&t.0, 3, Some("127.0.0.1:7100"), 2).unwrap();
        assert_eq!(
            load_term(&t.0).unwrap(),
            Some((3, Some("127.0.0.1:7100".into()), 2))
        );
        // Overwrite with a bare term (vote cleared on term bump).
        save_term(&t.0, 4, None, 2).unwrap();
        assert_eq!(load_term(&t.0).unwrap(), Some((4, None, 2)));
        // Corruption fails loudly, never guesses.
        let path = t.0.join("term");
        let mut bytes = fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        fs::write(&path, &bytes).unwrap();
        assert!(load_term(&t.0).is_err());
        fs::write(&path, b"XX").unwrap();
        assert!(load_term(&t.0).is_err());
    }

    #[test]
    fn reset_to_discards_divergent_higher_lsn_tail() {
        let t = TempDir::new("wal-reset");
        let opts = strict(&t.0);
        let mut r = recover(&opts).unwrap();
        // A local tail 1..=10 that a new leader's history supersedes.
        append_n(&mut r.wal, 1, 10);
        // The leader's snapshot covers only lsn 4: lower than our tail.
        let snap = SnapshotState {
            lsn: 4,
            files: vec![],
            blocks: vec![],
            nodes: vec!["n:1".into()],
            leases: vec![],
            next_lease: 7,
        };
        r.wal.reset_to(&snap).unwrap();
        assert_eq!(r.wal.next_lsn(), 5);
        // Appends continue densely from the snapshot.
        append_n(&mut r.wal, 5, 3);
        drop(r);
        let rec = recover(&opts).unwrap();
        let got = rec.snapshot.unwrap();
        assert_eq!(got.lsn, 4);
        assert_eq!(got.next_lease, 7);
        // Only the post-reset records survive — lsns 5..=7, nothing
        // from the divergent 10-record tail.
        assert_eq!(
            rec.records.iter().map(|(l, _)| *l).collect::<Vec<_>>(),
            vec![5, 6, 7]
        );
        assert_eq!(rec.wal.next_lsn(), 8);
    }
}
