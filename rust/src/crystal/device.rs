//! Device backends: planning (host-side staging, `Send`) and execution
//! (device-side, thread-confined), split so the overlap optimization can
//! stage job *i+1* while job *i* runs — the paper's CUDA-stream overlap.
//!
//! * [`Planner`] — picks shape buckets from the artifact manifest and
//!   packs/pads input bytes into pooled staging buffers.
//! * [`PjrtExecutor`] — runs the AOT artifacts on the PJRT CPU client
//!   (one instance per manager thread; the xla wrappers are not `Send`).
//! * [`MockExecutor`] — recomputes the kernels' results on the host from
//!   the *packed representation* (so it also validates the packing),
//!   with injectable delays and failures for queue tests.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use super::buffers::{BufferPool, PooledBuf};
use super::task::{DeviceOp, JobOut};
use crate::hash::{md5, Digest};
use crate::runtime::artifacts::{ArtifactKind, Manifest};
use crate::runtime::pjrt::{pad_segment_into, ExecTiming, PjrtContext};
use crate::{Error, Result};

/// Alias: a device operation's output (re-exported as crystal::DeviceOut).
pub type DeviceOut = JobOut;

/// One packed execution step of a job.
pub struct PlanStep {
    /// Artifact to run.
    pub artifact: String,
    /// Packed input words (artifact's exact input width).
    pub buf: PooledBuf,
    /// Auxiliary input (direct: per-lane active block counts).
    pub aux: Vec<u32>,
    /// How to interpret the output.
    pub meta: StepMeta,
}

/// Output interpretation of a step.
#[derive(Debug, Clone)]
pub enum StepMeta {
    /// Direct hash: first `n_segs` lane digests are valid.
    Direct {
        /// Valid lanes.
        n_segs: usize,
    },
    /// Batched direct hash: consecutive lane runs belong to blocks
    /// `(block_index, n_segs)`.
    DirectBatch {
        /// Lane runs in order.
        groups: Vec<(usize, usize)>,
    },
    /// Sliding window: first `valid` hashes are valid.
    Sliding {
        /// Valid output count.
        valid: usize,
    },
}

/// A fully staged job.
pub struct Plan {
    /// Execution steps in order.
    pub steps: Vec<PlanStep>,
    /// The operation (for assembly).
    pub op: DeviceOp,
    /// Input length in bytes.
    pub input_len: usize,
    /// Time spent packing (stage 1 part 2; buffer acquisition included).
    pub prep: Duration,
}

/// Shape-bucket selection + packing.  `Send + Sync`; shared by stagers.
#[derive(Clone)]
pub struct Planner {
    manifest: Manifest,
}

impl Planner {
    /// Build from a loaded manifest.
    pub fn new(manifest: Manifest) -> Self {
        Planner { manifest }
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Stage `data` for `op`, drawing staging buffers from `pool`.
    pub fn plan(&self, op: DeviceOp, data: &[u8], pool: &BufferPool) -> Result<Plan> {
        let t0 = Instant::now();
        let steps = match op {
            DeviceOp::DirectHash { seg_bytes } => self.plan_direct(seg_bytes, data, pool)?,
            DeviceOp::SlidingWindow => self.plan_sliding(data, pool)?,
        };
        Ok(Plan {
            steps,
            op,
            input_len: data.len(),
            prep: t0.elapsed(),
        })
    }

    fn plan_direct(
        &self,
        seg_bytes: usize,
        data: &[u8],
        pool: &BufferPool,
    ) -> Result<Vec<PlanStep>> {
        let mut steps = Vec::new();
        let mut rest = data;
        // Empty input still hashes one (empty) segment.
        loop {
            let art = self.manifest.pick_direct(seg_bytes, rest.len())?;
            let take = rest.len().min(art.capacity());
            let (cur, next) = rest.split_at(take);
            let n_segs = crate::hash::segment_count(cur.len(), seg_bytes);
            let lane_words = art.n_blocks * 16;
            let mut buf = pool.acquire(art.in_words);
            let mut aux = vec![0u32; art.lanes];
            {
                let words = buf.as_mut_slice();
                for (lane, seg) in cur.chunks(seg_bytes.max(1)).enumerate() {
                    aux[lane] = pad_segment_into(
                        seg,
                        &mut words[lane * lane_words..(lane + 1) * lane_words],
                    );
                }
                if cur.is_empty() {
                    aux[0] = pad_segment_into(&[], &mut words[..lane_words]);
                }
                // Unused lanes stay zero (nblk 0: the kernel never
                // touches their state); their digests are discarded.
            }
            steps.push(PlanStep {
                artifact: art.name.clone(),
                buf,
                aux,
                meta: StepMeta::Direct { n_segs },
            });
            if next.is_empty() {
                break;
            }
            rest = next;
        }
        Ok(steps)
    }

    /// Stage a batch of blocks for direct hashing: blocks' segments are
    /// packed back-to-back into as few artifact executions as possible
    /// (vs one execution per block), which is what makes small-block
    /// workloads (1 MB fixed blocks = 256 segments) amortize the
    /// per-execution overhead.
    pub fn plan_direct_batch(
        &self,
        seg_bytes: usize,
        blocks: &[Vec<u8>],
        pool: &BufferPool,
    ) -> Result<Plan> {
        let refs: Vec<&[u8]> = blocks.iter().map(|b| b.as_slice()).collect();
        self.plan_direct_refs(seg_bytes, &refs, pool)
    }

    /// Stage several sessions' block batches as ONE batched direct-hash
    /// job (the shared hash service's coalescing path).  Blocks are
    /// indexed flat across groups, in order — the output is
    /// `JobOut::DigestGroups` with one entry per block, exactly as if the
    /// concatenated batch had been submitted by a single caller.
    pub fn plan_direct_batch_groups(
        &self,
        seg_bytes: usize,
        groups: &[std::sync::Arc<Vec<Vec<u8>>>],
        pool: &BufferPool,
    ) -> Result<Plan> {
        let refs: Vec<&[u8]> = groups
            .iter()
            .flat_map(|g| g.iter().map(|b| b.as_slice()))
            .collect();
        self.plan_direct_refs(seg_bytes, &refs, pool)
    }

    fn plan_direct_refs(
        &self,
        seg_bytes: usize,
        blocks: &[&[u8]],
        pool: &BufferPool,
    ) -> Result<Plan> {
        let t0 = Instant::now();
        let total: usize = blocks.iter().map(|b| b.len()).sum();
        // Per-block segment slices, in order.
        struct SegRef<'a> {
            block: usize,
            seg: &'a [u8],
        }
        let mut segs: Vec<SegRef> = Vec::new();
        for (bi, b) in blocks.iter().enumerate() {
            if b.is_empty() {
                segs.push(SegRef { block: bi, seg: &[] });
                continue;
            }
            for seg in b.chunks(seg_bytes.max(1)) {
                segs.push(SegRef { block: bi, seg });
            }
        }
        let mut steps = Vec::new();
        let mut i = 0;
        while i < segs.len() {
            let remaining_bytes = total.min((segs.len() - i) * seg_bytes);
            let art = self.manifest.pick_direct(seg_bytes, remaining_bytes)?;
            let lane_words = art.n_blocks * 16;
            let take = (segs.len() - i).min(art.lanes);
            let mut buf = pool.acquire(art.in_words);
            let mut aux = vec![0u32; art.lanes];
            let mut groups: Vec<(usize, usize)> = Vec::new();
            {
                let words = buf.as_mut_slice();
                for (lane, sr) in segs[i..i + take].iter().enumerate() {
                    aux[lane] = pad_segment_into(
                        sr.seg,
                        &mut words[lane * lane_words..(lane + 1) * lane_words],
                    );
                    match groups.last_mut() {
                        Some((b, n)) if *b == sr.block => *n += 1,
                        _ => groups.push((sr.block, 1)),
                    }
                }
            }
            steps.push(PlanStep {
                artifact: art.name.clone(),
                buf,
                aux,
                meta: StepMeta::DirectBatch { groups },
            });
            i += take;
        }
        Ok(Plan {
            steps,
            op: DeviceOp::DirectHash { seg_bytes },
            input_len: total,
            prep: t0.elapsed(),
        })
    }

    fn plan_sliding(&self, data: &[u8], pool: &BufferPool) -> Result<Vec<PlanStep>> {
        let window = self.manifest.window;
        if data.len() < window {
            return Ok(Vec::new()); // no full window: nothing to run
        }
        let mut steps = Vec::new();
        let mut off = 0usize;
        while off + window <= data.len() {
            let art = self.manifest.pick_sliding(data.len() - off)?;
            let take = (data.len() - off).min(art.n_bytes);
            let chunk = &data[off..off + take];
            let valid = take - window + 1;
            let mut buf = pool.acquire(art.in_words);
            {
                let words = buf.as_mut_slice();
                let mut it = chunk.chunks_exact(4);
                let mut i = 0;
                for c in &mut it {
                    words[i] = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                    i += 1;
                }
                let rem = it.remainder();
                if !rem.is_empty() {
                    let mut b = [0u8; 4];
                    b[..rem.len()].copy_from_slice(rem);
                    words[i] = u32::from_le_bytes(b);
                }
                // Tail words beyond the chunk stay zero; outputs past
                // `valid` are discarded.
            }
            steps.push(PlanStep {
                artifact: art.name.clone(),
                buf,
                aux: Vec::new(),
                meta: StepMeta::Sliding { valid },
            });
            // Next chunk re-covers the last window-1 bytes.
            off += valid;
        }
        Ok(steps)
    }
}

fn lane_digest(words: &[u32], lane: usize) -> Digest {
    let mut d = [0u8; 16];
    for w in 0..4 {
        d[4 * w..4 * w + 4].copy_from_slice(&words[lane * 4 + w].to_le_bytes());
    }
    d
}

/// Assemble step outputs into the job's output.
pub fn assemble(op: DeviceOp, steps: &[(StepMeta, Vec<u32>)]) -> JobOut {
    // Batched plans are detected by their step metadata.
    if steps
        .iter()
        .any(|(m, _)| matches!(m, StepMeta::DirectBatch { .. }))
    {
        let n_blocks = steps
            .iter()
            .filter_map(|(m, _)| match m {
                StepMeta::DirectBatch { groups } => groups.iter().map(|(b, _)| b + 1).max(),
                _ => None,
            })
            .max()
            .unwrap_or(0);
        let mut out: Vec<Vec<Digest>> = vec![Vec::new(); n_blocks];
        for (meta, words) in steps {
            let StepMeta::DirectBatch { groups } = meta else {
                continue;
            };
            let mut lane = 0;
            for (block, n) in groups {
                for _ in 0..*n {
                    out[*block].push(lane_digest(words, lane));
                    lane += 1;
                }
            }
        }
        return JobOut::DigestGroups(out);
    }
    match op {
        DeviceOp::DirectHash { .. } => {
            let mut digests: Vec<Digest> = Vec::new();
            for (meta, words) in steps {
                let StepMeta::Direct { n_segs } = meta else {
                    continue;
                };
                for lane in 0..*n_segs {
                    digests.push(lane_digest(words, lane));
                }
            }
            JobOut::Digests(digests)
        }
        DeviceOp::SlidingWindow => {
            let mut hashes = Vec::new();
            for (meta, words) in steps {
                let StepMeta::Sliding { valid } = meta else {
                    continue;
                };
                hashes.extend_from_slice(&words[..*valid]);
            }
            JobOut::Hashes(hashes)
        }
    }
}

/// Executes planned steps on a concrete device.  NOT `Send`: built on
/// the manager thread via [`BackendKind::build_executor`].
pub trait Executor {
    /// Run one artifact over packed words (plus the direct-hash aux
    /// lane-count input); returns raw output words and per-stage timing.
    fn run_step(
        &mut self,
        artifact: &str,
        words: &[u32],
        aux: &[u32],
    ) -> Result<(Vec<u32>, ExecTiming)>;

    /// Device label for diagnostics.
    fn label(&self) -> String;
}

/// Executor selection, sendable to manager threads.
#[derive(Debug, Clone)]
pub enum BackendKind {
    /// Real PJRT execution of the AOT artifacts.
    Pjrt {
        /// Artifact directory (manifest + HLO text files).
        artifact_dir: PathBuf,
    },
    /// Host recomputation with injectable behaviour.
    Mock {
        /// Artifact directory (for the manifest; HLO not needed).
        artifact_dir: PathBuf,
        /// Delay/failure tuning.
        tuning: MockTuning,
    },
}

/// Mock behaviour knobs.
#[derive(Debug, Clone, Copy, Default)]
pub struct MockTuning {
    /// Fixed delay per step.
    pub fixed_delay: Duration,
    /// Additional delay per input byte (ns/B).
    pub ns_per_byte: f64,
    /// Fail every Nth step (1-based) with a Crystal error, if set.
    pub fail_every: Option<u64>,
}

impl BackendKind {
    /// Load the manifest this backend will use (for the shared planner).
    /// The Mock backend recomputes on the host and needs only shapes, so
    /// it falls back to the built-in synthetic manifest when
    /// `make artifacts` has not been run; real PJRT execution always
    /// requires the compiled artifacts.
    pub fn load_manifest(&self) -> Result<Manifest> {
        match self {
            BackendKind::Pjrt { artifact_dir } => Manifest::load(artifact_dir),
            BackendKind::Mock { artifact_dir, .. } => {
                Manifest::load_or_synthetic(artifact_dir)
            }
        }
    }

    /// Construct the thread-confined executor (call on the manager
    /// thread).
    pub fn build_executor(&self, device_id: usize) -> Result<Box<dyn Executor>> {
        match self {
            BackendKind::Pjrt { artifact_dir } => Ok(Box::new(PjrtExecutor {
                ctx: PjrtContext::new(artifact_dir)?,
                device_id,
            })),
            BackendKind::Mock {
                artifact_dir,
                tuning,
            } => Ok(Box::new(MockExecutor {
                manifest: Manifest::load_or_synthetic(artifact_dir)?,
                tuning: *tuning,
                device_id,
                steps_run: 0,
            })),
        }
    }
}

/// PJRT-backed executor.
pub struct PjrtExecutor {
    ctx: PjrtContext,
    device_id: usize,
}

impl Executor for PjrtExecutor {
    fn run_step(
        &mut self,
        artifact: &str,
        words: &[u32],
        aux: &[u32],
    ) -> Result<(Vec<u32>, ExecTiming)> {
        let kind = self
            .ctx
            .manifest()
            .artifacts
            .iter()
            .find(|a| a.name == artifact)
            .map(|a| a.kind)
            .ok_or_else(|| Error::Artifact(format!("unknown artifact {artifact}")))?;
        match kind {
            ArtifactKind::Direct => self.ctx.run_direct(artifact, words, aux),
            ArtifactKind::Sliding => self.ctx.run_sliding(artifact, words),
        }
    }

    fn label(&self) -> String {
        format!("pjrt:{} dev{}", self.ctx.platform(), self.device_id)
    }
}

/// Host-recompute executor used by queue/integration tests.
pub struct MockExecutor {
    manifest: Manifest,
    tuning: MockTuning,
    device_id: usize,
    steps_run: u64,
}

impl Executor for MockExecutor {
    fn run_step(
        &mut self,
        artifact: &str,
        words: &[u32],
        aux: &[u32],
    ) -> Result<(Vec<u32>, ExecTiming)> {
        self.steps_run += 1;
        if let Some(n) = self.tuning.fail_every {
            if self.steps_run % n == 0 {
                return Err(Error::Crystal(format!(
                    "injected failure on step {}",
                    self.steps_run
                )));
            }
        }
        let spec = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.name == artifact)
            .ok_or_else(|| Error::Artifact(format!("unknown artifact {artifact}")))?;
        let t0 = Instant::now();
        let out = match spec.kind {
            ArtifactKind::Direct => {
                // Recompute per-lane MD5 from the packed+padded lanes:
                // the active block count (aux) locates the length words.
                let lane_words = spec.n_blocks * 16;
                let mut out = Vec::with_capacity(spec.lanes * 4);
                for lane in 0..spec.lanes {
                    let lw = &words[lane * lane_words..(lane + 1) * lane_words];
                    let used = (aux.get(lane).copied().unwrap_or(0) as usize) * 16;
                    let d = if used == 0 {
                        // Inactive lane: digest is discarded; emit zeros.
                        [0u8; 16]
                    } else {
                        let bit_len = (lw[used - 2] as u64) | ((lw[used - 1] as u64) << 32);
                        let n = (bit_len / 8) as usize;
                        let bytes: Vec<u8> =
                            lw.iter().flat_map(|w| w.to_le_bytes()).collect();
                        md5(&bytes[..n.min(bytes.len())])
                    };
                    for w in 0..4 {
                        out.push(u32::from_le_bytes(d[4 * w..4 * w + 4].try_into().unwrap()));
                    }
                }
                out
            }
            ArtifactKind::Sliding => {
                let bytes: Vec<u8> = words.iter().flat_map(|w| w.to_le_bytes()).collect();
                crate::hash::window_hashes(&bytes, spec.window, self.manifest.p)
            }
        };
        let kernel = t0.elapsed();
        let delay = self.tuning.fixed_delay
            + Duration::from_nanos((self.tuning.ns_per_byte * (words.len() * 4) as f64) as u64);
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
        Ok((
            out,
            ExecTiming {
                kernel: kernel + delay,
                ..Default::default()
            },
        ))
    }

    fn label(&self) -> String {
        format!("mock dev{}", self.device_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mock_setup() -> (Planner, MockExecutor, BufferPool) {
        // Real manifest when built, synthetic (same shapes) otherwise.
        let manifest = Manifest::load_or_synthetic(&Manifest::default_dir()).unwrap();
        let planner = Planner::new(manifest.clone());
        let exec = MockExecutor {
            manifest,
            tuning: MockTuning::default(),
            device_id: 0,
            steps_run: 0,
        };
        (planner, exec, BufferPool::new(true, 16))
    }

    fn run_plan(plan: Plan, exec: &mut MockExecutor) -> JobOut {
        let mut outs = Vec::new();
        for step in &plan.steps {
            let (words, _) = exec
                .run_step(&step.artifact, step.buf.as_slice(), &step.aux)
                .unwrap();
            outs.push((step.meta.clone(), words));
        }
        assemble(plan.op, &outs)
    }

    #[test]
    fn direct_plan_matches_cpu_construction() {
        let (planner, mut exec, pool) = mock_setup();
        for len in [100usize, 4096, 5000, 70_000] {
            let data = Rng::new(len as u64).bytes(len);
            let plan = planner
                .plan(DeviceOp::DirectHash { seg_bytes: 4096 }, &data, &pool)
                .unwrap();
            let JobOut::Digests(digests) = run_plan(plan, &mut exec) else {
                panic!("wrong out kind");
            };
            let want: Vec<Digest> = data.chunks(4096).map(md5).collect();
            assert_eq!(digests, want, "len={len}");
        }
    }

    #[test]
    fn sliding_plan_matches_cpu_hashes() {
        let (planner, mut exec, pool) = mock_setup();
        let w = planner.manifest().window;
        let p = planner.manifest().p;
        for len in [64usize, 4096, 70_000, 200_000] {
            let data = Rng::new(len as u64).bytes(len);
            let plan = planner.plan(DeviceOp::SlidingWindow, &data, &pool).unwrap();
            let JobOut::Hashes(hashes) = run_plan(plan, &mut exec) else {
                panic!("wrong out kind");
            };
            let want = crate::hash::window_hashes(&data, w, p);
            assert_eq!(hashes.len(), want.len(), "len={len}");
            assert_eq!(hashes, want, "len={len}");
        }
    }

    #[test]
    fn sliding_short_input_empty_plan() {
        let (planner, _, pool) = mock_setup();
        let plan = planner
            .plan(DeviceOp::SlidingWindow, &[1, 2, 3], &pool)
            .unwrap();
        assert!(plan.steps.is_empty());
        assert!(matches!(
            assemble(DeviceOp::SlidingWindow, &[]),
            JobOut::Hashes(h) if h.is_empty()
        ));
    }

    #[test]
    fn direct_empty_input_single_empty_digest() {
        let (planner, mut exec, pool) = mock_setup();
        let plan = planner
            .plan(DeviceOp::DirectHash { seg_bytes: 4096 }, &[], &pool)
            .unwrap();
        let JobOut::Digests(d) = run_plan(plan, &mut exec) else {
            panic!()
        };
        assert_eq!(d, vec![md5(&[])]);
    }

    #[test]
    fn mock_failure_injection() {
        let (planner, _, pool) = mock_setup();
        let mut exec = MockExecutor {
            manifest: planner.manifest().clone(),
            tuning: MockTuning {
                fail_every: Some(2),
                ..Default::default()
            },
            device_id: 0,
            steps_run: 0,
        };
        let data = Rng::new(1).bytes(4096);
        let plan = planner
            .plan(DeviceOp::DirectHash { seg_bytes: 4096 }, &data, &pool)
            .unwrap();
        let step = &plan.steps[0];
        assert!(exec
            .run_step(&step.artifact, step.buf.as_slice(), &step.aux)
            .is_ok());
        assert!(exec
            .run_step(&step.artifact, step.buf.as_slice(), &step.aux)
            .is_err());
    }

    #[test]
    fn oversized_direct_job_splits() {
        let (planner, _, pool) = mock_setup();
        // Largest 4096-seg artifact is 1024 lanes = 4 MB; 10 MB splits.
        let data = vec![7u8; 10 << 20];
        let plan = planner
            .plan(DeviceOp::DirectHash { seg_bytes: 4096 }, &data, &pool)
            .unwrap();
        assert!(plan.steps.len() >= 3, "steps={}", plan.steps.len());
    }
}
