//! crystal — the CrystalGPU analog: a task-management runtime between the
//! storage system and the accelerator(s).
//!
//! The metaphor is the paper's (§3.2.3): the application submits *jobs*
//! to a shared **outstanding queue** and waits for callbacks; a
//! **manager thread per device** pulls jobs, executes the device
//! operation, and notifies the submitter.  The runtime transparently
//! provides the paper's three optimizations:
//!
//! 1. **buffer reuse** — staging buffers come from a recycling pool
//!    instead of being allocated per job (the paper's non-pageable
//!    memory reuse);
//! 2. **transfer/compute overlap** — each device gets a *stager* thread
//!    that packs/pads the next job's input while the executor thread
//!    runs the current kernel (the paper's CUDA-stream overlap);
//! 3. **transparent multi-device** — one manager (stager+executor pair)
//!    per device, all pulling from the shared outstanding queue.
//!
//! The backend is pluggable: [`device::PjrtBackend`] runs the real
//! AOT-compiled artifacts through PJRT; [`device::MockBackend`] computes
//! the same results on the CPU with injectable delays/failures for
//! deterministic queue testing.

pub mod buffers;
pub mod device;
pub mod master;
pub mod model;
pub mod task;

pub use buffers::BufferPool;
pub use device::{BackendKind, DeviceOut, MockTuning};
pub use master::{CrystalOpts, CrystalStats, JobHandle, Master};
pub use task::{DeviceOp, JobOut, JobResult, StageTimings};
