//! Staging-buffer pool — the paper's non-pageable (pinned) memory reuse.
//!
//! Allocating pinned memory per job dominated HashGPU-alone's runtime
//! (Fig 4: up to 80–96 % together with copy-in); CrystalGPU pre-allocates
//! and recycles.  Our stand-in for "pinned alloc" is the u32 staging
//! vector a job packs its input into: with reuse on, buffers are
//! recycled through a free list; with reuse off, every acquisition
//! allocates *and touches* fresh memory (so the cost is physical, not
//! just allocator bookkeeping) — letting the Fig 4/5/6 harnesses measure
//! the optimization the way the paper did.

use std::collections::HashMap;
use std::sync::Mutex;

/// A recyclable u32 staging buffer.  Returns itself to the pool on drop.
pub struct PooledBuf {
    buf: Option<Vec<u32>>,
    home: Option<std::sync::Arc<PoolShared>>,
}

impl PooledBuf {
    /// Read access.
    pub fn as_slice(&self) -> &[u32] {
        self.buf.as_ref().unwrap()
    }

    /// Write access.
    pub fn as_mut_slice(&mut self) -> &mut [u32] {
        self.buf.as_mut().unwrap()
    }

    /// Length in words.
    pub fn len(&self) -> usize {
        self.buf.as_ref().unwrap().len()
    }

    /// True if zero-length.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        if let (Some(buf), Some(home)) = (self.buf.take(), self.home.take()) {
            let mut free = home.free.lock().unwrap();
            let list = free.entry(buf.len()).or_default();
            if list.len() < home.max_per_size {
                list.push(buf);
            }
        }
    }
}

struct PoolShared {
    free: Mutex<HashMap<usize, Vec<Vec<u32>>>>,
    max_per_size: usize,
}

/// Size-keyed buffer pool.
pub struct BufferPool {
    shared: std::sync::Arc<PoolShared>,
    reuse: bool,
    hits: std::sync::atomic::AtomicU64,
    misses: std::sync::atomic::AtomicU64,
}

impl BufferPool {
    /// `reuse = false` reproduces the unoptimized HashGPU-alone behaviour
    /// (fresh allocation per job).
    pub fn new(reuse: bool, max_per_size: usize) -> Self {
        BufferPool {
            shared: std::sync::Arc::new(PoolShared {
                free: Mutex::new(HashMap::new()),
                max_per_size,
            }),
            reuse,
            hits: Default::default(),
            misses: Default::default(),
        }
    }

    /// Acquire a zeroed buffer of exactly `words` words.
    pub fn acquire(&self, words: usize) -> PooledBuf {
        use std::sync::atomic::Ordering;
        if self.reuse {
            let recycled = {
                let mut free = self.shared.free.lock().unwrap();
                free.get_mut(&words).and_then(Vec::pop)
            };
            if let Some(mut buf) = recycled {
                self.hits.fetch_add(1, Ordering::Relaxed);
                buf.iter_mut().for_each(|w| *w = 0);
                return PooledBuf {
                    buf: Some(buf),
                    home: Some(self.shared.clone()),
                };
            }
            self.misses.fetch_add(1, Ordering::Relaxed);
            return PooledBuf {
                buf: Some(vec![0u32; words]),
                home: Some(self.shared.clone()),
            };
        }
        // No reuse: fresh allocation, touched page-by-page so the cost
        // (page faults + zeroing) is paid like a pinned cudaMallocHost.
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut buf = vec![0u32; words];
        for w in buf.iter_mut().step_by(1024) {
            std::hint::black_box(*w);
        }
        PooledBuf {
            buf: Some(buf),
            home: None, // dropped, not recycled
        }
    }

    /// Pre-populate the pool (the paper allocates at init).
    pub fn prewarm(&self, words: usize, count: usize) {
        if !self.reuse {
            return;
        }
        let mut free = self.shared.free.lock().unwrap();
        let list = free.entry(words).or_default();
        while list.len() < count.min(self.shared.max_per_size) {
            list.push(vec![0u32; words]);
        }
    }

    /// (hits, misses) so far.
    pub fn stats(&self) -> (u64, u64) {
        use std::sync::atomic::Ordering;
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_recycles() {
        let pool = BufferPool::new(true, 8);
        {
            let mut b = pool.acquire(100);
            b.as_mut_slice()[0] = 42;
        } // returned
        let b = pool.acquire(100);
        assert_eq!(b.as_slice()[0], 0, "recycled buffer must be zeroed");
        let (hits, misses) = pool.stats();
        assert_eq!((hits, misses), (1, 1));
    }

    #[test]
    fn no_reuse_never_recycles() {
        let pool = BufferPool::new(false, 8);
        drop(pool.acquire(64));
        drop(pool.acquire(64));
        let (hits, misses) = pool.stats();
        assert_eq!(hits, 0);
        assert_eq!(misses, 2);
    }

    #[test]
    fn sizes_are_segregated() {
        let pool = BufferPool::new(true, 8);
        drop(pool.acquire(10));
        let _a = pool.acquire(20); // different size: miss
        let (hits, misses) = pool.stats();
        assert_eq!(hits, 0);
        assert_eq!(misses, 2);
    }

    #[test]
    fn prewarm_gives_hits() {
        let pool = BufferPool::new(true, 8);
        pool.prewarm(256, 4);
        drop(pool.acquire(256));
        let (hits, _) = pool.stats();
        assert_eq!(hits, 1);
    }

    #[test]
    fn pool_caps_retained_buffers() {
        let pool = BufferPool::new(true, 2);
        let bufs: Vec<_> = (0..4).map(|_| pool.acquire(8)).collect();
        drop(bufs); // only 2 retained
        for _ in 0..2 {
            drop(pool.acquire(8));
        }
        let (hits, misses) = pool.stats();
        // 4 initial misses; then 2 hits (retained) is the best case.
        assert_eq!(misses, 4);
        assert_eq!(hits, 2);
    }
}
