//! The crystal master: shared outstanding queue, one manager per device
//! (stager + executor threads when overlap is on), callback delivery,
//! and runtime statistics — the paper's §3.2.3 design.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::buffers::BufferPool;
use super::device::{assemble, BackendKind, Plan, Planner};
use super::task::{DeviceOp, JobResult, StageTimings};
use crate::metrics::StageBreakdown;
use crate::{Error, Result};

/// Crystal runtime options (the paper's optimization toggles).
#[derive(Debug, Clone)]
pub struct CrystalOpts {
    /// Number of devices (manager pairs).
    pub devices: usize,
    /// Executor backend.
    pub backend: BackendKind,
    /// Recycle staging buffers (CrystalGPU optimization 1).
    pub buffer_reuse: bool,
    /// Stage next job while current executes (optimization 2).
    pub overlap: bool,
    /// Max staged-but-unexecuted jobs per device (pipeline depth).
    pub pipeline_depth: usize,
    /// Outstanding-queue bound; submit blocks when full (backpressure).
    /// 0 = unbounded.
    pub queue_cap: usize,
    /// Buffers retained per size class in the pool.
    pub pool_max_per_size: usize,
}

impl CrystalOpts {
    /// Fully-optimized single-device configuration over the given backend.
    pub fn optimized(backend: BackendKind) -> Self {
        CrystalOpts {
            devices: 1,
            backend,
            buffer_reuse: true,
            overlap: true,
            pipeline_depth: 2,
            queue_cap: 64,
            pool_max_per_size: 8,
        }
    }

    /// HashGPU-alone configuration: no reuse, no overlap.
    pub fn unoptimized(backend: BackendKind) -> Self {
        CrystalOpts {
            buffer_reuse: false,
            overlap: false,
            ..Self::optimized(backend)
        }
    }
}

/// Aggregate runtime statistics.
#[derive(Debug, Clone, Default)]
pub struct CrystalStats {
    /// Jobs completed per device.
    pub per_device: Vec<u64>,
    /// Stage breakdown across all completed jobs.
    pub stages: StageBreakdown,
    /// Staging-pool (hits, misses).
    pub pool: (u64, u64),
    /// Jobs that failed.
    pub failures: u64,
}

/// Handle to an in-flight job.
pub struct JobHandle {
    rx: Receiver<Result<JobResult>>,
}

impl JobHandle {
    /// Block until the job completes.
    pub fn wait(self) -> Result<JobResult> {
        self.rx
            .recv()
            .map_err(|_| Error::Crystal("runtime shut down".into()))?
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<Result<JobResult>> {
        self.rx.try_recv().ok()
    }
}

enum Payload {
    /// One input buffer.
    Single(Arc<Vec<u8>>),
    /// A batch of blocks for packed direct hashing.
    Batch {
        seg_bytes: usize,
        blocks: Arc<Vec<Vec<u8>>>,
    },
    /// Several callers' block batches coalesced into one packed job
    /// (the shared hash service's deep cross-session batches).  Blocks
    /// are indexed flat across groups in submission order.
    BatchGroups {
        seg_bytes: usize,
        groups: Vec<Arc<Vec<Vec<u8>>>>,
    },
}

struct QueueItem {
    op: DeviceOp,
    payload: Payload,
    submitted: Instant,
    reply: Sender<Result<JobResult>>,
}

struct Shared {
    queue: Mutex<VecDeque<QueueItem>>,
    nonempty: Condvar,
    space: Condvar,
    shutdown: AtomicBool,
    pool: BufferPool,
    planner: Planner,
    stats: Mutex<CrystalStats>,
    inflight: AtomicU64,
    idle: Condvar,
    queue_cap: usize,
}

/// The crystal runtime.
pub struct Master {
    shared: Arc<Shared>,
    managers: Vec<JoinHandle<()>>,
}

impl Master {
    /// Start manager threads per `opts`.
    pub fn new(opts: CrystalOpts) -> Result<Master> {
        if opts.devices == 0 {
            return Err(Error::Crystal("need at least one device".into()));
        }
        let manifest = opts.backend.load_manifest()?;
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            nonempty: Condvar::new(),
            space: Condvar::new(),
            shutdown: AtomicBool::new(false),
            pool: BufferPool::new(opts.buffer_reuse, opts.pool_max_per_size),
            planner: Planner::new(manifest),
            stats: Mutex::new(CrystalStats {
                per_device: vec![0; opts.devices],
                ..Default::default()
            }),
            inflight: AtomicU64::new(0),
            idle: Condvar::new(),
            queue_cap: opts.queue_cap,
        });

        let mut managers = Vec::new();
        for dev in 0..opts.devices {
            let sh = shared.clone();
            let backend = opts.backend.clone();
            let overlap = opts.overlap;
            let depth = opts.pipeline_depth.max(1);
            managers.push(
                std::thread::Builder::new()
                    .name(format!("crystal-mgr-{dev}"))
                    .spawn(move || manager_loop(sh, backend, dev, overlap, depth))
                    .map_err(|e| Error::Crystal(format!("spawn manager: {e}")))?,
            );
        }
        Ok(Master { shared, managers })
    }

    /// Submit a job; returns a handle for the callback.
    pub fn submit(&self, op: DeviceOp, data: Arc<Vec<u8>>) -> JobHandle {
        self.enqueue(op, Payload::Single(data))
    }

    /// Submit a batch of blocks for packed direct hashing: the planner
    /// packs all blocks' segments into as few device executions as
    /// possible and the result groups digests per block.
    pub fn submit_batch(&self, seg_bytes: usize, blocks: Arc<Vec<Vec<u8>>>) -> JobHandle {
        self.enqueue(
            DeviceOp::DirectHash { seg_bytes },
            Payload::Batch { seg_bytes, blocks },
        )
    }

    /// Submit several callers' block batches as ONE packed direct-hash
    /// job without concatenating (or copying) their payloads.  Digest
    /// groups come back indexed flat across `groups` in order — the
    /// shared hash service splits them back out per caller.
    pub fn submit_batch_groups(
        &self,
        seg_bytes: usize,
        groups: Vec<Arc<Vec<Vec<u8>>>>,
    ) -> JobHandle {
        self.enqueue(
            DeviceOp::DirectHash { seg_bytes },
            Payload::BatchGroups { seg_bytes, groups },
        )
    }

    fn enqueue(&self, op: DeviceOp, payload: Payload) -> JobHandle {
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            while self.shared.queue_cap > 0
                && q.len() >= self.shared.queue_cap
                && !self.shared.shutdown.load(Ordering::Relaxed)
            {
                q = self.shared.space.wait(q).unwrap();
            }
            self.shared.inflight.fetch_add(1, Ordering::Relaxed);
            q.push_back(QueueItem {
                op,
                payload,
                submitted: Instant::now(),
                reply: tx,
            });
        }
        self.shared.nonempty.notify_one();
        JobHandle { rx }
    }

    /// Convenience: submit and wait.
    pub fn run(&self, op: DeviceOp, data: Arc<Vec<u8>>) -> Result<JobResult> {
        self.submit(op, data).wait()
    }

    /// Block until every submitted job has completed.
    pub fn drain(&self) {
        let mut q = self.shared.queue.lock().unwrap();
        while self.shared.inflight.load(Ordering::Relaxed) > 0 {
            let (guard, _) = self
                .shared
                .idle
                .wait_timeout(q, std::time::Duration::from_millis(50))
                .unwrap();
            q = guard;
        }
    }

    /// Snapshot of runtime statistics.
    pub fn stats(&self) -> CrystalStats {
        let mut s = self.shared.stats.lock().unwrap().clone();
        s.pool = self.shared.pool.stats();
        s
    }

    /// Number of devices.
    pub fn devices(&self) -> usize {
        self.managers.len()
    }
}

impl Drop for Master {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        self.shared.nonempty.notify_all();
        self.shared.space.notify_all();
        for m in self.managers.drain(..) {
            let _ = m.join();
        }
    }
}

/// Pull the next queue item, or None on shutdown.
fn next_item(sh: &Shared) -> Option<QueueItem> {
    let mut q = sh.queue.lock().unwrap();
    loop {
        if let Some(item) = q.pop_front() {
            sh.space.notify_one();
            return Some(item);
        }
        if sh.shutdown.load(Ordering::Relaxed) {
            return None;
        }
        q = sh.nonempty.wait(q).unwrap();
    }
}

struct Staged {
    plan: Plan,
    queued: std::time::Duration,
    reply: Sender<Result<JobResult>>,
}

/// Stage (pack) one queue item via the shared planner.
fn stage(sh: &Shared, item: &QueueItem) -> Result<Plan> {
    match &item.payload {
        Payload::Single(data) => sh.planner.plan(item.op, data, &sh.pool),
        Payload::Batch { seg_bytes, blocks } => {
            sh.planner.plan_direct_batch(*seg_bytes, blocks, &sh.pool)
        }
        Payload::BatchGroups { seg_bytes, groups } => {
            sh.planner
                .plan_direct_batch_groups(*seg_bytes, groups, &sh.pool)
        }
    }
}

fn manager_loop(
    sh: Arc<Shared>,
    backend: BackendKind,
    dev: usize,
    overlap: bool,
    depth: usize,
) {
    let mut executor = match backend.build_executor(dev) {
        Ok(e) => e,
        Err(e) => {
            // Device failed to initialize: fail jobs as they arrive.
            while let Some(item) = next_item(&sh) {
                let _ = item
                    .reply
                    .send(Err(Error::Crystal(format!("device {dev} init failed: {e}"))));
                job_done(&sh);
            }
            return;
        }
    };

    if overlap {
        // Stager thread: plan (pack/pad) while the executor runs.
        let (tx, rx): (SyncSender<Staged>, _) = mpsc::sync_channel(depth);
        let sh2 = sh.clone();
        let stager = std::thread::Builder::new()
            .name(format!("crystal-stage-{dev}"))
            .spawn(move || {
                while let Some(item) = next_item(&sh2) {
                    let queued = item.submitted.elapsed();
                    match stage(&sh2, &item) {
                        Ok(plan) => {
                            if tx
                                .send(Staged {
                                    plan,
                                    queued,
                                    reply: item.reply,
                                })
                                .is_err()
                            {
                                return;
                            }
                        }
                        Err(e) => {
                            let _ = item.reply.send(Err(e));
                            job_done(&sh2);
                        }
                    }
                }
            })
            .expect("spawn stager");

        while let Ok(staged) = rx.recv() {
            execute_staged(&sh, &mut *executor, dev, staged);
        }
        let _ = stager.join();
    } else {
        while let Some(item) = next_item(&sh) {
            let queued = item.submitted.elapsed();
            match stage(&sh, &item) {
                Ok(plan) => execute_staged(
                    &sh,
                    &mut *executor,
                    dev,
                    Staged {
                        plan,
                        queued,
                        reply: item.reply,
                    },
                ),
                Err(e) => {
                    let _ = item.reply.send(Err(e));
                    job_done(&sh);
                }
            }
        }
    }
}

fn execute_staged(
    sh: &Shared,
    executor: &mut dyn super::device::Executor,
    dev: usize,
    staged: Staged,
) {
    let Staged {
        plan,
        queued,
        reply,
    } = staged;
    let mut timing = StageTimings {
        preprocess: plan.prep,
        queued,
        ..Default::default()
    };
    let mut outs = Vec::with_capacity(plan.steps.len());
    let mut failed = None;
    for step in &plan.steps {
        match executor.run_step(&step.artifact, step.buf.as_slice(), &step.aux) {
            Ok((words, t)) => {
                timing.copy_in += t.copy_in;
                timing.kernel += t.kernel;
                timing.copy_out += t.copy_out;
                outs.push((step.meta.clone(), words));
            }
            Err(e) => {
                failed = Some(e);
                break;
            }
        }
    }
    let result = match failed {
        Some(e) => Err(e),
        None => {
            let out = assemble(plan.op, &outs);
            Ok(JobResult {
                out,
                timing,
                device: dev,
                input_len: plan.input_len,
            })
        }
    };
    {
        let mut stats = sh.stats.lock().unwrap();
        match &result {
            Ok(r) => {
                stats.per_device[dev] += 1;
                r.timing.record(&mut stats.stages);
            }
            Err(_) => stats.failures += 1,
        }
    }
    let _ = reply.send(result);
    job_done(sh);
}

fn job_done(sh: &Shared) {
    sh.inflight.fetch_sub(1, Ordering::Relaxed);
    sh.idle.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crystal::device::MockTuning;
    use crate::crystal::task::JobOut;
    use crate::hash::md5;
    use crate::runtime::artifacts::Manifest;
    use crate::util::Rng;

    fn mock_backend(tuning: MockTuning) -> BackendKind {
        BackendKind::Mock {
            artifact_dir: Manifest::default_dir(),
            tuning,
        }
    }

    #[test]
    fn submit_and_wait_direct() {
        let m = Master::new(CrystalOpts::optimized(mock_backend(Default::default()))).unwrap();
        let data = Arc::new(Rng::new(1).bytes(10_000));
        let r = m
            .run(DeviceOp::DirectHash { seg_bytes: 4096 }, data.clone())
            .unwrap();
        let JobOut::Digests(d) = r.out else { panic!() };
        let want: Vec<_> = data.chunks(4096).map(md5).collect();
        assert_eq!(d, want);
        assert_eq!(r.input_len, 10_000);
    }

    #[test]
    fn stream_of_jobs_all_complete() {
        let m = Master::new(CrystalOpts::optimized(mock_backend(Default::default()))).unwrap();
        let handles: Vec<_> = (0..20)
            .map(|i| {
                let data = Arc::new(Rng::new(i).bytes(4096 + i as usize * 100));
                m.submit(DeviceOp::SlidingWindow, data)
            })
            .collect();
        for h in handles {
            let r = h.wait().unwrap();
            let JobOut::Hashes(h) = r.out else { panic!() };
            assert_eq!(h.len(), r.input_len - 48 + 1);
        }
        let stats = m.stats();
        assert_eq!(stats.per_device.iter().sum::<u64>(), 20);
        assert_eq!(stats.failures, 0);
    }

    #[test]
    fn multi_device_balances() {
        let opts = CrystalOpts {
            devices: 2,
            ..CrystalOpts::optimized(mock_backend(MockTuning {
                fixed_delay: std::time::Duration::from_millis(2),
                ..Default::default()
            }))
        };
        let m = Master::new(opts).unwrap();
        let handles: Vec<_> = (0..16)
            .map(|i| m.submit(DeviceOp::SlidingWindow, Arc::new(Rng::new(i).bytes(4096))))
            .collect();
        for h in handles {
            h.wait().unwrap();
        }
        let stats = m.stats();
        assert_eq!(stats.per_device.len(), 2);
        // Both devices did work (shared queue balances under delay).
        assert!(stats.per_device[0] > 0, "{:?}", stats.per_device);
        assert!(stats.per_device[1] > 0, "{:?}", stats.per_device);
    }

    #[test]
    fn batch_groups_match_concatenated_batch() {
        let m = Master::new(CrystalOpts::optimized(mock_backend(Default::default()))).unwrap();
        let g1: Arc<Vec<Vec<u8>>> = Arc::new(
            (0..3)
                .map(|i| Rng::new(i).bytes(5000 + i as usize * 111))
                .collect(),
        );
        let g2: Arc<Vec<Vec<u8>>> = Arc::new(vec![Rng::new(9).bytes(12_000), Vec::new()]);
        let all: Arc<Vec<Vec<u8>>> = Arc::new(g1.iter().chain(g2.iter()).cloned().collect());
        let grouped = m
            .submit_batch_groups(4096, vec![g1.clone(), g2.clone()])
            .wait()
            .unwrap();
        let flat = m.submit_batch(4096, all).wait().unwrap();
        let (JobOut::DigestGroups(a), JobOut::DigestGroups(b)) = (grouped.out, flat.out) else {
            panic!("wrong output kinds");
        };
        assert_eq!(a, b);
        assert_eq!(a.len(), 5);
    }

    #[test]
    fn failure_injection_reported() {
        let m = Master::new(CrystalOpts::optimized(mock_backend(MockTuning {
            fail_every: Some(2),
            ..Default::default()
        })))
        .unwrap();
        let mut errs = 0;
        for i in 0..6 {
            let r = m.run(
                DeviceOp::SlidingWindow,
                Arc::new(Rng::new(i).bytes(4096)),
            );
            if r.is_err() {
                errs += 1;
            }
        }
        assert!(errs >= 2, "errs={errs}");
        assert_eq!(m.stats().failures as usize, errs);
    }

    #[test]
    fn drain_waits_for_inflight() {
        let m = Master::new(CrystalOpts::optimized(mock_backend(MockTuning {
            fixed_delay: std::time::Duration::from_millis(5),
            ..Default::default()
        })))
        .unwrap();
        let handles: Vec<_> = (0..4)
            .map(|i| m.submit(DeviceOp::SlidingWindow, Arc::new(Rng::new(i).bytes(4096))))
            .collect();
        m.drain();
        for h in handles {
            assert!(h.try_wait().is_some(), "job not finished after drain");
        }
    }

    #[test]
    fn backpressure_blocks_at_cap() {
        let opts = CrystalOpts {
            queue_cap: 2,
            ..CrystalOpts::optimized(mock_backend(MockTuning {
                fixed_delay: std::time::Duration::from_millis(10),
                ..Default::default()
            }))
        };
        let m = Master::new(opts).unwrap();
        let t0 = Instant::now();
        let handles: Vec<_> = (0..8)
            .map(|i| m.submit(DeviceOp::SlidingWindow, Arc::new(Rng::new(i).bytes(4096))))
            .collect();
        // With cap 2 and 10 ms jobs, submitting 8 must have blocked.
        assert!(t0.elapsed().as_millis() >= 20);
        for h in handles {
            h.wait().unwrap();
        }
    }

    #[test]
    fn overlap_off_still_correct() {
        let mut opts = CrystalOpts::optimized(mock_backend(Default::default()));
        opts.overlap = false;
        opts.buffer_reuse = false;
        let m = Master::new(opts).unwrap();
        let data = Arc::new(Rng::new(9).bytes(66_000));
        let r = m.run(DeviceOp::SlidingWindow, data.clone()).unwrap();
        let JobOut::Hashes(h) = r.out else { panic!() };
        assert_eq!(
            h,
            crate::hash::window_hashes(&data, 48, crate::hash::DEFAULT_P)
        );
    }

    #[test]
    fn queue_wait_recorded() {
        let m = Master::new(CrystalOpts::optimized(mock_backend(MockTuning {
            fixed_delay: std::time::Duration::from_millis(5),
            ..Default::default()
        })))
        .unwrap();
        let handles: Vec<_> = (0..4)
            .map(|i| m.submit(DeviceOp::SlidingWindow, Arc::new(Rng::new(i).bytes(4096))))
            .collect();
        let last = handles.into_iter().last().unwrap().wait().unwrap();
        // The last of 4 serialized 5 ms jobs waited in queue.
        assert!(last.timing.queued.as_millis() >= 5);
    }
}
