//! Job types flowing through the crystal runtime.

use std::time::Duration;

use crate::hash::Digest;
use crate::metrics::{Stage, StageBreakdown};

/// The device operations HashGPU offloads (the paper's two kernels).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceOp {
    /// Parallel Merkle–Damgård: per-segment MD5 digests of the input.
    DirectHash {
        /// Segment size in bytes (must match a compiled artifact family).
        seg_bytes: usize,
    },
    /// Sliding-window rolling fingerprints of every window of the input.
    SlidingWindow,
}

/// Per-stage wall-clock timings of one job (paper Table 1).
#[derive(Debug, Clone, Copy, Default)]
pub struct StageTimings {
    /// Stage 1: staging-buffer acquisition + pack/pad.
    pub preprocess: Duration,
    /// Stage 2: host -> device transfer.
    pub copy_in: Duration,
    /// Stage 3: kernel execution.
    pub kernel: Duration,
    /// Stage 4: device -> host transfer.
    pub copy_out: Duration,
    /// Stage 5: host post-processing (filled by the hashgpu layer).
    pub postprocess: Duration,
    /// Time spent waiting in the outstanding queue.
    pub queued: Duration,
}

impl StageTimings {
    /// Total across stages (excluding queue wait).
    pub fn total(&self) -> Duration {
        self.preprocess + self.copy_in + self.kernel + self.copy_out + self.postprocess
    }

    /// Fold into a [`StageBreakdown`].
    pub fn record(&self, b: &mut StageBreakdown) {
        b.add(Stage::Preprocess, self.preprocess);
        b.add(Stage::CopyIn, self.copy_in);
        b.add(Stage::Kernel, self.kernel);
        b.add(Stage::CopyOut, self.copy_out);
        b.add(Stage::Postprocess, self.postprocess);
        b.end_task();
    }
}

/// Output of a completed device job.
#[derive(Debug, Clone)]
pub enum JobOut {
    /// Per-segment digests (DirectHash).  The *final* hash-of-hashes is
    /// computed by the hashgpu layer on the host, per the paper.
    Digests(Vec<Digest>),
    /// Per-block groups of per-segment digests (batched direct hashing:
    /// many blocks packed into each artifact execution so a whole
    /// write-buffer costs one or two device calls instead of one per
    /// block — EXPERIMENTS.md section Perf).
    DigestGroups(Vec<Vec<Digest>>),
    /// Window fingerprints (SlidingWindow), truncated to the valid
    /// `len - window + 1` prefix.
    Hashes(Vec<u32>),
}

/// A completed job: output + accounting.
#[derive(Debug, Clone)]
pub struct JobResult {
    /// Device operation output.
    pub out: JobOut,
    /// Per-stage timings.
    pub timing: StageTimings,
    /// Device that executed the job.
    pub device: usize,
    /// Bytes of input covered.
    pub input_len: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timings_total() {
        let t = StageTimings {
            preprocess: Duration::from_millis(1),
            copy_in: Duration::from_millis(2),
            kernel: Duration::from_millis(3),
            copy_out: Duration::from_millis(4),
            postprocess: Duration::from_millis(5),
            queued: Duration::from_millis(100),
        };
        assert_eq!(t.total(), Duration::from_millis(15));
    }

    #[test]
    fn record_counts_task() {
        let mut b = StageBreakdown::new();
        StageTimings::default().record(&mut b);
        assert_eq!(b.tasks(), 1);
    }
}
