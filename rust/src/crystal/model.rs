//! Calibrated analytic device model — the performance stand-in for the
//! paper's GPUs (DESIGN.md §Substitutions).
//!
//! We cannot run a 2010 GTX 480; the figure harnesses instead use an
//! analytic cost model of the five task stages, with constants anchored
//! to the paper's *measured* end-points:
//!
//! * Fig 5 labels: dual-socket CPU (16 threads) sliding-window hashing
//!   peaks at 129 MBps => single core ~16 MBps (8x claim);
//! * Fig 5: GTX 480 full-stack sliding-window speedup ~125x on large
//!   blocks => kernel+overlapped-transfer throughput ~2 GBps;
//! * Fig 4: alloc+copy-in = 80–96 % of unoptimized time on large blocks;
//! * PCIe 2.0 x16 ~ 8 GB/s raw, ~5.5 GB/s effective for pinned DMA,
//!   ~2.5 GB/s effective for pageable (extra host copy);
//! * pinned allocation ~ 0.5 ms/MB + 0.2 ms fixed (CUDA-era numbers).
//!
//! The CrystalGPU optimization *gains* (buffer reuse, overlap, dual-GPU)
//! are NOT hard-coded: they emerge from how `sim::pipeline` composes
//! these stage costs.

/// Analytic per-stage cost model of one accelerator device.
#[derive(Debug, Clone, Copy)]
pub struct DeviceModel {
    /// Fixed per-job driver/launch overhead (s).
    pub launch_overhead: f64,
    /// Staging-buffer (pinned) allocation: fixed (s) + per-byte (s/B).
    pub alloc_fixed: f64,
    /// Per-byte allocation cost (s/B).
    pub alloc_per_byte: f64,
    /// Host->device bandwidth with pinned buffers (B/s).
    pub h2d_pinned: f64,
    /// Host->device bandwidth with pageable buffers (B/s) — the 1-copy
    /// penalty when buffer reuse is off.
    pub h2d_pageable: f64,
    /// Device->host bandwidth (B/s).
    pub d2h: f64,
    /// Sliding-window kernel throughput (input B/s).
    pub sliding_bps: f64,
    /// Direct-hash kernel throughput (input B/s).
    pub direct_bps: f64,
    /// Output bytes per input byte for sliding window (4 B hash/byte).
    pub sliding_out_ratio: f64,
    /// Output bytes per input byte for direct hash (16 B per segment).
    pub direct_out_ratio: f64,
}

impl DeviceModel {
    /// NVIDIA GeForce GTX 480 (480 cores @ 1.4 GHz), the paper's primary
    /// device, behind PCIe 2.0 x16.  Constants solved from the paper's
    /// anchor points (module docs): sliding kernel 2.0 GB/s gives the
    /// ~125x fully-optimized speedup over the 16 MBps single-core CPU;
    /// direct kernel 4.2 GB/s gives the ~28x direct-hash speedup over a
    /// 150 MBps single-core MD5.
    pub fn gtx480() -> Self {
        DeviceModel {
            launch_overhead: 60e-6,
            alloc_fixed: 0.2e-3,
            alloc_per_byte: 0.5e-3 / 1e6, // 0.5 ms per MB (pinned)
            h2d_pinned: 5.5e9,
            h2d_pageable: 2.5e9,
            d2h: 12.0e9,
            sliding_bps: 2.0e9,
            direct_bps: 4.2e9,
            sliding_out_ratio: 4.0,
            direct_out_ratio: 16.0 / 4096.0,
        }
    }

    /// NVIDIA Tesla C2050 (448 cores @ 1.1 GHz), the paper's second
    /// device in the dual-GPU experiments — ~0.73x the GTX 480's rate.
    pub fn tesla_c2050() -> Self {
        let g = Self::gtx480();
        DeviceModel {
            sliding_bps: g.sliding_bps * 0.73,
            direct_bps: g.direct_bps * 0.73,
            ..g
        }
    }

    /// Kernel seconds for `bytes` of input.
    pub fn kernel_secs(&self, op_sliding: bool, bytes: usize) -> f64 {
        let bps = if op_sliding {
            self.sliding_bps
        } else {
            self.direct_bps
        };
        self.launch_overhead + bytes as f64 / bps
    }

    /// Host->device seconds for `bytes` (pinned or pageable path).
    pub fn h2d_secs(&self, bytes: usize, pinned: bool) -> f64 {
        let bw = if pinned {
            self.h2d_pinned
        } else {
            self.h2d_pageable
        };
        bytes as f64 / bw
    }

    /// Device->host seconds for the op's output on `bytes` of input.
    pub fn d2h_secs(&self, op_sliding: bool, bytes: usize) -> f64 {
        let ratio = if op_sliding {
            self.sliding_out_ratio
        } else {
            self.direct_out_ratio
        };
        bytes as f64 * ratio / self.d2h
    }

    /// Allocation seconds for the job's pinned staging buffers.  Both
    /// the input and the output buffer must be pinned, so the cost
    /// covers `in + out` bytes (for sliding-window ops the output is 4x
    /// the input — a large part of why Fig 4's alloc share is so high).
    pub fn alloc_secs_op(&self, op_sliding: bool, in_bytes: usize) -> f64 {
        let ratio = if op_sliding {
            self.sliding_out_ratio
        } else {
            self.direct_out_ratio
        };
        let total = in_bytes as f64 * (1.0 + ratio);
        self.alloc_fixed + total * self.alloc_per_byte
    }

    /// Allocation seconds for a plain `bytes` staging buffer.
    pub fn alloc_secs(&self, bytes: usize) -> f64 {
        self.alloc_fixed + bytes as f64 * self.alloc_per_byte
    }
}

/// CPU-side hashing cost model, anchored to the paper's measured CPU
/// baselines (window hashing = MD5 per overlapping window).
#[derive(Debug, Clone, Copy)]
pub struct CpuModel {
    /// Single-core direct (MD5) hashing throughput (B/s).
    pub md5_bps: f64,
    /// Single-core sliding-window (MD5-per-window) throughput (B/s).
    pub window_md5_bps: f64,
    /// Parallel-efficiency factor per extra thread (1.0 = linear).
    pub smp_efficiency: f64,
    /// Cores available.
    pub cores: usize,
}

impl CpuModel {
    /// Intel Xeon E5345-era quad core (paper's 2.33 GHz Xeon): one core.
    /// Fig 5 labels give dual-socket (16 threads) window hashing at
    /// 129 MBps => ~16 MBps per core with their MD5-per-window code.
    pub fn xeon_2008() -> Self {
        CpuModel {
            md5_bps: 150e6,       // one-core MD5 of the era (Fig 6 anchor)
            window_md5_bps: 16e6, // Fig 5 anchor (129 MBps / 8x @ dual)
            smp_efficiency: 0.95,
            cores: 4,
        }
    }

    /// Effective throughput using `threads` threads on `self.cores`+ CPU.
    pub fn scaled_bps(&self, single: f64, threads: usize) -> f64 {
        let t = threads.max(1) as f64;
        single * t * self.smp_efficiency.powf(t - 1.0)
    }

    /// Direct hashing seconds for `bytes` with `threads` threads.
    pub fn direct_secs(&self, bytes: usize, threads: usize) -> f64 {
        bytes as f64 / self.scaled_bps(self.md5_bps, threads)
    }

    /// Window hashing seconds for `bytes` with `threads` threads.
    pub fn window_secs(&self, bytes: usize, threads: usize) -> f64 {
        bytes as f64 / self.scaled_bps(self.window_md5_bps, threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dual_socket_window_rate_matches_fig5_label() {
        // 16 threads on the dual-socket machine: paper label 129 MBps.
        let cpu = CpuModel::xeon_2008();
        let bps = cpu.scaled_bps(cpu.window_md5_bps, 16);
        let mbps = bps / (1024.0 * 1024.0);
        assert!((110.0..160.0).contains(&mbps), "{mbps} MBps");
    }

    #[test]
    fn kernel_dominates_only_for_large_blocks() {
        let m = DeviceModel::gtx480();
        // 4 KB: overheads dominate the kernel.
        let small_kernel = m.kernel_secs(true, 4096);
        let small_over = m.alloc_secs(4096) + m.h2d_secs(4096, false);
        assert!(small_over > small_kernel);
    }

    #[test]
    fn alloc_plus_copyin_dominate_unoptimized_large_blocks() {
        // Fig 4's 80-96 % claim at large block sizes.
        let m = DeviceModel::gtx480();
        let b = 64 << 20;
        let alloc = m.alloc_secs_op(true, b);
        let h2d = m.h2d_secs(b, false);
        let kernel = m.kernel_secs(true, b);
        let d2h = m.d2h_secs(true, b);
        let frac = (alloc + h2d) / (alloc + h2d + kernel + d2h);
        assert!(frac > 0.70, "alloc+copyin fraction {frac}");
    }

    #[test]
    fn unoptimized_gpu_beats_cpu_only_above_crossover() {
        // Fig 5: HashGPU-alone loses to the CPU below ~64 KB blocks.
        let m = DeviceModel::gtx480();
        let cpu = CpuModel::xeon_2008();
        let t_unopt = |b: usize| {
            m.alloc_secs_op(true, b)
                + m.h2d_secs(b, false)
                + m.kernel_secs(true, b)
                + m.d2h_secs(true, b)
        };
        assert!(t_unopt(4 << 10) > cpu.window_secs(4 << 10, 1), "4KB");
        assert!(t_unopt(1 << 20) < cpu.window_secs(1 << 20, 1), "1MB");
    }

    #[test]
    fn optimization_ladder_ordering() {
        // alone < +reuse < +overlap < dual-GPU, as in Fig 5.
        let m = DeviceModel::gtx480();
        let b = 64 << 20;
        let alone = m.alloc_secs_op(true, b)
            + m.h2d_secs(b, false)
            + m.kernel_secs(true, b)
            + m.d2h_secs(true, b);
        let reuse =
            m.h2d_secs(b, true) + m.kernel_secs(true, b) + m.d2h_secs(true, b);
        // Overlap pipelines the three stages across a stream: steady-
        // state per-job time is the max stage.
        let overlap = m
            .h2d_secs(b, true)
            .max(m.kernel_secs(true, b))
            .max(m.d2h_secs(true, b));
        let dual = overlap / (1.0 + 0.73);
        assert!(alone > reuse && reuse > overlap && overlap > dual);
    }

    #[test]
    fn gpu_sliding_speedup_band() {
        // Full-stack (pinned, overlapped => kernel-bound) large-block
        // speedup vs one CPU core should land in the paper's ~100-190x
        // region before dual-GPU scaling.
        let m = DeviceModel::gtx480();
        let cpu = CpuModel::xeon_2008();
        let b = 64 << 20;
        let gpu = m.kernel_secs(true, b); // overlap hides transfers
        let host = cpu.window_secs(b, 1);
        let speedup = host / gpu;
        assert!((80.0..260.0).contains(&speedup), "{speedup}x");
    }

    #[test]
    fn c2050_slower_than_gtx480() {
        assert!(
            DeviceModel::tesla_c2050().sliding_bps < DeviceModel::gtx480().sliding_bps
        );
    }
}
