//! Streaming sessions over the SAI: [`FileWriter`] (incremental write →
//! chunk → hash → dedup → replicate pipeline, commit on close) and
//! [`FileReader`] (prefetching, integrity-verified block streaming with
//! replica failover).
//!
//! The writer is the paper's pipeline made visible in the API: each
//! filled write buffer's block digests are *submitted* to the hash
//! engine (non-blocking on accelerator engines) and redeemed one buffer
//! later, so buffer N's hashing overlaps buffer N-1's placement
//! and transfers, and buffer N+1's accumulation/chunking — CrystalGPU's
//! transfer/compute overlap, end to end.  Synchronous engines
//! (CPU/oracle) degrade gracefully to the serial path through the same
//! code.
//!
//! Control-plane v2: once a batch's digests are known, the writer asks
//! the *manager* where the blocks go ([`Sai::alloc_placement`]); the
//! reply carries a replica set per block plus a freshness bit
//! (manager-side global dedup).  Fresh blocks are transferred to every
//! assigned replica; duplicates are recorded in the block-map without
//! transfer (CA modes).  Dropping a writer without closing releases its
//! provisional claims back to the manager.
//!
//! Control-plane v3 (leases): the writer's claims are held under a
//! manager lease that a dedicated heartbeat thread renews, so a writer
//! killed without running `Drop` (SIGKILL, power loss) has its claims
//! lapse and its blocks reclaimed instead of stranding forever; renewal
//! failures are survived gracefully (the session keeps streaming and
//! the commit itself revalidates the lease).  The reader's lease pins
//! the opened version's blocks at the manager, so a concurrent
//! overwrite's commit-time GC defers their deletion until this session
//! finishes — a mid-file reader can no longer lose its snapshot.
//!
//! Buffering is caller-split-invariant: the writer re-buffers incoming
//! bytes to exactly `write_buffer`-sized batches internally, so a file
//! streamed in arbitrary splits produces a block-map byte-identical to
//! a one-shot [`super::Sai::write_file`] (property-tested).
//!
//! Data-plane v2 (pipelined duplex): node operations stream over
//! [`DuplexClient`](super::duplex::DuplexClient) links that keep many
//! requests in flight per socket, so the session no longer meters
//! transfers by *count* (the old `2 × stripe` window).  Both directions
//! are governed by one **in-flight-bytes budget**
//! (`ClientConfig::inflight_budget`): the writer stops accepting new
//! batches once that many payload bytes are unacknowledged (each
//! replica copy counted once — what is actually buffered on the wire),
//! and the reader prefetches ahead of the consumer only up to the same
//! budget.  Deep pipelines get bandwidth-bound throughput without
//! ballooning memory; a budget smaller than one block degenerates to
//! one operation at a time, never a deadlock (the over-budget operation
//! is already on the wire when the session waits for it).

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::duplex::{closed, Block};
use super::proto::{BlockMeta, BlockSpec, Msg};
use super::sai::{Sai, WriteReport};
use crate::chunking::ContentChunker;
use crate::config::CaMode;
use crate::hash::{md5, Digest};
use crate::hashgpu::{DigestsTicket, HashTiming};
use crate::net::Conn;
use crate::{Error, Result};

/// Mode-specific chunking state of a write session.
enum ModeState {
    /// Non-CA: blocks addressed by (file, index); `index` is the global
    /// block counter across the whole stream.
    None { index: u64 },
    /// Fixed-size blocks.
    Fixed,
    /// Content-defined chunking (stream-continuous across buffers).
    Cdc { chunker: ContentChunker },
}

/// A submitted-but-unredeemed digest batch: the payloads it covers ride
/// along so blocks can be placed once the digests arrive.
struct Inflight {
    blocks: Arc<Vec<Vec<u8>>>,
    ticket: DigestsTicket,
}

/// Per-block put-failure budget shared by all of one block's replica
/// (or shard) transfers.  A redundant block's write survives losing
/// some of its copies — all-or-nothing acking would turn any single
/// node death into a failed write, defeating the redundancy the extra
/// copies exist to provide.  The committed meta keeps the FULL planned
/// replica set either way: the scrub loop re-creates whatever failed
/// here.  `max_failures` is `replicas - 1` for replication (at least
/// one copy must land) and `m` for `ec:k,m` (any `k` shards suffice).
struct PutTolerance {
    failed: AtomicU64,
    max_failures: u64,
}

impl PutTolerance {
    /// Record one failed copy; `true` while the block is still
    /// recoverable (the failure is absorbed, not surfaced).
    fn absorb(&self) -> bool {
        self.failed.fetch_add(1, Ordering::Relaxed) < self.max_failures
    }
}

/// Monotonic per-process counter feeding session claim tokens.
static SESSION_SEQ: AtomicU64 = AtomicU64::new(0);

/// Floor on the write-lease renewal cadence: even against a manager
/// configured with a very short lease timeout the heartbeat thread
/// never busy-spins.
const MIN_RENEW_INTERVAL: Duration = Duration::from_millis(25);

/// The write session's lease heartbeat: a thread with its own manager
/// connection renewing the claim lease every `ttl / 3`, so a slow or
/// idle-but-alive writer keeps its claims while a SIGKILL'd one lapses.
/// Renewal failures are survived, not surfaced: transport errors retry
/// over a fresh connection next tick, and a logical "lease lapsed"
/// reply latches [`LeaseHeartbeat::lost`] — the commit revalidates the
/// lease anyway, so the session fails at close with a clear error
/// instead of panicking mid-stream.
struct LeaseHeartbeat {
    /// Dropping the sender stops the thread at its next tick.
    stop: Option<Sender<()>>,
    /// Fault-injection hook: while set, ticks skip renewal (the
    /// in-process analog of a SIGKILL'd client's silence).
    pause: Arc<AtomicBool>,
    /// Latched when the manager reports the lease lapsed.
    lost: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl LeaseHeartbeat {
    fn spawn(manager_addr: String, lease: u64, ttl: Duration) -> LeaseHeartbeat {
        let (stop, rx) = mpsc::channel::<()>();
        let pause = Arc::new(AtomicBool::new(false));
        let lost = Arc::new(AtomicBool::new(false));
        let (p, l) = (pause.clone(), lost.clone());
        let every = (ttl / 3).max(MIN_RENEW_INTERVAL);
        let handle = std::thread::Builder::new()
            .name(format!("sai-lease-{lease}"))
            .spawn(move || {
                let mut manager_addr = manager_addr;
                let mut link: Option<Conn> = None;
                loop {
                    match rx.recv_timeout(every) {
                        Err(RecvTimeoutError::Timeout) => {}
                        _ => break, // stop requested or writer dropped
                    }
                    if p.load(Ordering::Relaxed) {
                        continue;
                    }
                    if link.is_none() {
                        // Bounded connect AND bounded reads: a manager
                        // that accepts but never replies must not wedge
                        // this thread — FileWriter::drop joins it.
                        link = Conn::connect_timeout(&manager_addr, Duration::from_secs(1))
                            .and_then(|c| {
                                c.set_read_timeout(Duration::from_secs(1))?;
                                Ok(c)
                            })
                            .ok();
                    }
                    let Some(c) = link.as_mut() else { continue };
                    let reply = (|| -> Result<Msg> {
                        Msg::RenewLease { lease }.write_to(c)?;
                        Msg::read_from(c)?.ok_or_else(closed)
                    })();
                    match reply {
                        Ok(Msg::Ok) => {}
                        // Leadership moved while this session is alive:
                        // renew against the hinted leader from the next
                        // tick on (renewals are idempotent, so chasing
                        // the hint late costs nothing).
                        Ok(Msg::NotLeader { hint }) => {
                            if !hint.is_empty() {
                                manager_addr = hint;
                            }
                            link = None;
                        }
                        // A leader that can't commit the renewal on a
                        // quorum (partition/election in progress) is
                        // transient — the lease is NOT known lost.
                        Ok(Msg::Err(e)) if e.starts_with("no quorum") => link = None,
                        // The manager says the lease is gone: renewing
                        // further is pointless — latch and stop.
                        Ok(Msg::Err(_)) => {
                            l.store(true, Ordering::Relaxed);
                            break;
                        }
                        // Transport trouble or protocol noise: retry
                        // over a fresh connection next tick.
                        _ => link = None,
                    }
                }
            })
            .ok();
        LeaseHeartbeat {
            stop: Some(stop),
            pause,
            lost,
            handle,
        }
    }

    fn stop(&mut self) {
        self.stop.take(); // disconnects the channel -> thread exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Streaming write session (from [`Sai::create`]).  Implements
/// [`std::io::Write`]; call [`close`](FileWriter::close) to commit the
/// block-map and obtain the [`WriteReport`].  Dropping the writer
/// without closing abandons the write: nothing is committed, and the
/// session's claim lease is dropped so already-transferred blocks can
/// be garbage-collected.  A writer that never runs `Drop` at all
/// (SIGKILL) is covered by lease expiry: its heartbeats stop and the
/// manager reclaims the claims after the lease timeout.
pub struct FileWriter<'a> {
    sai: &'a Sai,
    name: String,
    /// Unique claim token for this write session, sent as the "file" of
    /// [`Msg::AllocPlacement`].  The manager dedups uncommitted pending
    /// claims only against the SAME token — a file name would wrongly
    /// match a crashed earlier attempt (whose transfer may never have
    /// happened) or a concurrent writer of the same file.
    claim: String,
    /// Manager lease holding this session's claims (renewed by
    /// `heartbeat`, consumed by the commit, dropped on abort).
    lease: u64,
    /// Renewal thread (stopped on drop, surviving sessions only).
    heartbeat: Option<LeaseHeartbeat>,
    mode: ModeState,
    /// Bytes accumulated toward the next `write_buffer`-sized batch.
    buf: Vec<u8>,
    metas: Vec<BlockMeta>,
    /// Outstanding node-put acknowledgements, oldest first, each with
    /// the payload bytes it holds on the wire (one entry per replica
    /// copy or shard) and its block's shared failure budget.
    pending: VecDeque<(u64, Receiver<Result<()>>, Arc<PutTolerance>)>,
    /// Total unacknowledged put bytes — held at or under
    /// `ClientConfig::inflight_budget` by [`FileWriter::reclaim_to`].
    inflight_bytes: u64,
    /// The previous buffer's digest batch, still being hashed.
    inflight: Option<Inflight>,
    committed: bool,
    report: WriteReport,
    /// Sum of device-batch depths behind `report.hash_batches` — kept
    /// here (not in the report) so the mean is computed once at close.
    hash_depth_sum: u64,
    t0: Instant,
}

impl<'a> FileWriter<'a> {
    pub(super) fn new(sai: &'a Sai, name: &str) -> Result<FileWriter<'a>> {
        let t0 = Instant::now();
        let mode = match sai.cfg.ca_mode {
            CaMode::None => ModeState::None { index: 0 },
            CaMode::Fixed => ModeState::Fixed,
            CaMode::Cdc => ModeState::Cdc {
                chunker: ContentChunker::new(sai.cfg.chunk_params()),
            },
        };
        // pid + per-process counter + wall-clock nanos: unique across
        // hosts and pid reuse (claims must never collide — a collision
        // would let one session dedup against another's possibly-
        // incomplete transfer).
        let nonce = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let claim = format!(
            "{name}#{}.{}.{nonce:x}",
            std::process::id(),
            SESSION_SEQ.fetch_add(1, Ordering::Relaxed)
        );
        // Claim lease: every occurrence this session allocates is held
        // under it, so a vanished writer's claims lapse after the
        // manager's lease timeout instead of stranding forever.
        let (lease, ttl_ms, _, _) = sai.open_lease(&claim, true)?;
        let heartbeat = (lease != 0).then(|| {
            LeaseHeartbeat::spawn(
                sai.manager_addr(),
                lease,
                Duration::from_millis(ttl_ms.max(1)),
            )
        });
        Ok(FileWriter {
            sai,
            name: name.to_string(),
            claim,
            lease,
            heartbeat,
            mode,
            buf: Vec::with_capacity(sai.cfg.write_buffer),
            metas: Vec::new(),
            pending: VecDeque::new(),
            inflight_bytes: 0,
            inflight: None,
            committed: false,
            report: WriteReport::default(),
            hash_depth_sum: 0,
            t0,
        })
    }

    /// The file being written.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Bytes accepted so far.
    pub fn bytes_written(&self) -> u64 {
        self.report.bytes
    }

    /// The manager lease holding this session's claims.
    pub fn lease(&self) -> u64 {
        self.lease
    }

    /// Whether the claim lease is known to have lapsed (a renewal was
    /// rejected).  The session survives — the commit revalidates the
    /// lease and fails with a clear error if it is really gone.
    pub fn lease_lost(&self) -> bool {
        self.heartbeat
            .as_ref()
            .map(|h| h.lost.load(Ordering::Relaxed))
            .unwrap_or(false)
    }

    /// Fault-injection hook: stop renewing the claim lease without
    /// stopping the session — the in-process analog of SIGKILLing the
    /// writer's host (its heartbeats go silent but the manager still
    /// holds its claims until the lease times out).  Pair with
    /// `std::mem::forget` to model a crash that never runs `Drop`.
    pub fn pause_lease_heartbeat(&self) {
        if let Some(h) = &self.heartbeat {
            h.pause.store(true, Ordering::Relaxed);
        }
    }

    /// Feed payload bytes into the pipeline (the [`std::io::Write`]
    /// impl routes here).  Processes a batch whenever the internal
    /// buffer reaches `write_buffer` bytes.
    pub fn push_bytes(&mut self, mut data: &[u8]) -> Result<()> {
        self.report.bytes += data.len() as u64;
        let cap = self.sai.cfg.write_buffer;
        while self.buf.len() + data.len() >= cap {
            let take = cap - self.buf.len();
            self.buf.extend_from_slice(&data[..take]);
            data = &data[take..];
            self.process_buffer()?;
        }
        self.buf.extend_from_slice(data);
        Ok(())
    }

    /// Commit the new block-map (the POSIX `release` step) after
    /// flushing the tail of the stream, and return the write report.
    pub fn close(mut self) -> Result<WriteReport> {
        if !self.buf.is_empty() {
            self.process_buffer()?;
        }
        // Drain the pipeline: redeem the last buffer's digests...
        let prev = self.inflight.take();
        self.resolve(prev)?;
        // ...then the final partial CDC chunk, if any.
        let final_chunk = match &mut self.mode {
            ModeState::Cdc { chunker } => chunker.finish(),
            _ => None,
        };
        if let Some(chunk) = final_chunk {
            let blocks = Arc::new(vec![chunk.data]);
            let ticket = self.sai.engine.submit_direct_batch(blocks.clone())?;
            self.resolve(Some(Inflight { blocks, ticket }))?;
        }
        // Wait for all outstanding transfers.
        self.reclaim_to(0)?;

        match self.sai.manager_call(Msg::CommitBlockMap {
            file: self.name.clone(),
            lease: self.lease,
            blocks: self.metas.clone(),
        })? {
            Msg::Ok => {}
            m => return Err(Error::Proto(format!("unexpected commit reply {m:?}"))),
        }
        // The commit consumed this session's claim lease; the Drop impl
        // must not release it a second time.
        self.committed = true;

        self.report.blocks = self.metas.len();
        if self.report.replication == 0 {
            self.report.replication = 1;
        }
        if self.report.hash_batches > 0 {
            self.report.hash_batch_depth_mean =
                self.hash_depth_sum as f64 / self.report.hash_batches as f64;
        }
        self.report.elapsed = self.t0.elapsed();
        self.report.similarity = if self.report.bytes == 0 {
            0.0
        } else {
            1.0 - self.report.new_payload_bytes as f64 / self.report.bytes as f64
        };
        Ok(self.report.clone())
    }

    /// Process one accumulated batch (exactly `write_buffer` bytes,
    /// except the final partial batch at close).
    fn process_buffer(&mut self) -> Result<()> {
        let buf = std::mem::take(&mut self.buf);
        if buf.is_empty() {
            return Ok(());
        }
        let result = match self.sai.cfg.ca_mode {
            CaMode::None => self.process_non_ca(&buf),
            CaMode::Fixed => {
                let blocks: Vec<Vec<u8>> = buf
                    .chunks(self.sai.cfg.block_size)
                    .map(|b| b.to_vec())
                    .collect();
                self.submit_and_rotate(blocks)
            }
            CaMode::Cdc => self.process_cdc(&buf),
        };
        // Hand the (now drained) allocation back so the next batch does
        // not re-grow a fresh write buffer.
        self.buf = buf;
        self.buf.clear();
        result
    }

    /// Non-CA: no content hashing — blocks are keyed by (file, index)
    /// and always transferred, but placement still comes from the
    /// manager (same [`Sai::alloc_placement`] path as CA modes).
    fn process_non_ca(&mut self, buf: &[u8]) -> Result<()> {
        let mut blocks = Vec::new();
        let mut digests = Vec::new();
        for blk in buf.chunks(self.sai.cfg.block_size) {
            let ModeState::None { index } = &mut self.mode else {
                return Err(Error::Other("mode state mismatch".into()));
            };
            let i = *index;
            *index += 1;
            let mut key = Vec::with_capacity(self.name.len() + 8);
            key.extend_from_slice(self.name.as_bytes());
            key.extend_from_slice(&i.to_le_bytes());
            digests.push(md5(&key));
            blocks.push(blk.to_vec());
        }
        self.place_batch(blocks, &digests)
    }

    /// CDC: window-hash this buffer (async where the engine allows),
    /// overlap the wait with placement of the previous buffer's chunks,
    /// then cut boundaries and submit the finished chunks' digests.
    fn process_cdc(&mut self, buf: &[u8]) -> Result<()> {
        let ext = match &self.mode {
            ModeState::Cdc { chunker } => chunker.extended(buf),
            _ => return Err(Error::Other("mode state mismatch".into())),
        };
        let wticket = self.sai.engine.submit_window_hashes(ext)?;
        // While the engine hashes windows, place the previous buffer's
        // chunks (their digests were submitted a buffer ago).
        let prev = self.inflight.take();
        self.resolve(prev)?;
        let (hashes, t) = wticket.wait()?;
        self.add_hash_timing(t);
        let finished = match &mut self.mode {
            ModeState::Cdc { chunker } => chunker.push_with_hashes(buf, &hashes),
            _ => return Err(Error::Other("mode state mismatch".into())),
        };
        if finished.is_empty() {
            return Ok(());
        }
        let blocks: Vec<Vec<u8>> = finished.into_iter().map(|c| c.data).collect();
        let blocks = Arc::new(blocks);
        let ticket = self.sai.engine.submit_direct_batch(blocks.clone())?;
        debug_assert!(self.inflight.is_none());
        self.inflight = Some(Inflight { blocks, ticket });
        Ok(())
    }

    /// Submit a batch's digests (non-blocking on async engines), then
    /// redeem and place the *previous* batch — the pipeline's rotation.
    fn submit_and_rotate(&mut self, blocks: Vec<Vec<u8>>) -> Result<()> {
        if blocks.is_empty() {
            return Ok(());
        }
        let blocks = Arc::new(blocks);
        let ticket = self.sai.engine.submit_direct_batch(blocks.clone())?;
        let prev = self.inflight.replace(Inflight { blocks, ticket });
        self.resolve(prev)
    }

    /// Redeem an in-flight digest batch and place its blocks.
    fn resolve(&mut self, inflight: Option<Inflight>) -> Result<()> {
        let Some(Inflight { blocks, ticket }) = inflight else {
            return Ok(());
        };
        let (digests, t) = ticket.wait()?;
        self.add_hash_timing(t);
        if digests.len() != blocks.len() {
            return Err(Error::Other(format!(
                "engine returned {} digests for {} blocks",
                digests.len(),
                blocks.len()
            )));
        }
        // The ticket has been redeemed, so the engine normally dropped
        // its clone of the batch and the unwrap is copy-free; a still-
        // shared batch falls back to one clone (never worse than a
        // per-block copy).
        let owned = Arc::try_unwrap(blocks).unwrap_or_else(|a| a.as_ref().clone());
        self.place_batch(owned, &digests)
    }

    fn add_hash_timing(&mut self, t: HashTiming) {
        self.report.hash_secs += t.exposed.as_secs_f64();
        self.report.hash_hidden_secs += t.hidden.as_secs_f64();
        self.report.hash_linger_secs += t.svc_wait.as_secs_f64();
        // Window-hash tickets report no device-batch depth; only the
        // direct-hash batches count toward batching stats.
        if t.batch_blocks > 0 {
            self.report.hash_batches += 1;
            self.hash_depth_sum += t.batch_blocks as u64;
            self.report.hash_batch_depth_max =
                self.report.hash_batch_depth_max.max(t.batch_blocks);
        }
    }

    /// Manager-driven placement + transfer for one hashed batch: one
    /// [`Msg::AllocPlacement`] round-trip, then fresh blocks go out to
    /// every assigned replica while duplicates only land in the map.
    fn place_batch(&mut self, blocks: Vec<Vec<u8>>, digests: &[Digest]) -> Result<()> {
        if blocks.is_empty() {
            return Ok(());
        }
        let specs: Vec<BlockSpec> = digests
            .iter()
            .zip(&blocks)
            .map(|(h, b)| BlockSpec {
                hash: *h,
                len: b.len() as u32,
            })
            .collect();
        let assignments = self.sai.alloc_placement(&self.claim, self.lease, specs)?;
        // Every occurrence is now claimed on the manager, recorded
        // against this session's lease server-side — a mid-batch error
        // below (or a crash right here) cannot strand pending claims:
        // the lease's release or expiry returns them all.
        // Non-CA keys are positional, not content hashes: a rewrite
        // reuses the key with different bytes, so the data must always
        // be transferred even when the manager already knows the key.
        let always_transfer = self.sai.cfg.ca_mode == CaMode::None;
        for ((data, digest), asg) in blocks.into_iter().zip(digests).zip(assignments) {
            let len = data.len();
            if asg.fresh || always_transfer {
                match asg.ec {
                    // Erasure coded: split into k data + m parity
                    // shards; shard `i` goes to `replicas[i]` (the
                    // replica list IS the shard order), all keyed by
                    // the parent block's content hash.
                    Some((k, m)) => {
                        let (k, m) = (k as usize, m as usize);
                        if asg.replicas.len() != k + m {
                            return Err(Error::Proto(format!(
                                "ec:{k},{m} assignment carries {} homes, need {}",
                                asg.replicas.len(),
                                k + m
                            )));
                        }
                        let shards = crate::ec::encode(k, m, &data);
                        let tol = Arc::new(PutTolerance {
                            failed: AtomicU64::new(0),
                            max_failures: m as u64,
                        });
                        let mut sent = 0u64;
                        for (shard, &id) in shards.into_iter().zip(&asg.replicas) {
                            let slen = shard.len() as u64;
                            self.put_tolerant(id, *digest, Arc::new(shard), slen, &tol)?;
                            sent += slen;
                        }
                        self.report.new_bytes += sent;
                    }
                    // Replicated / single copy: the payload moves into
                    // one shared allocation serving every replica — no
                    // copies on the transfer path.
                    None => {
                        let payload: Block = Arc::new(data);
                        let tol = Arc::new(PutTolerance {
                            failed: AtomicU64::new(0),
                            max_failures: asg.replicas.len().saturating_sub(1) as u64,
                        });
                        for &id in &asg.replicas {
                            self.put_tolerant(id, *digest, payload.clone(), len as u64, &tol)?;
                        }
                        self.report.new_bytes += (len * asg.replicas.len()) as u64;
                    }
                }
                self.report.new_blocks += 1;
                self.report.new_payload_bytes += len as u64;
                self.report.replication = self.report.replication.max(asg.replicas.len());
            } else {
                self.report.dup_blocks += 1;
            }
            self.metas.push(BlockMeta {
                hash: *digest,
                len: len as u32,
                replicas: asg.replicas,
                ec: asg.ec,
            });
        }
        self.reclaim_to(self.sai.cfg.inflight_budget as u64)
    }

    /// Issue one copy/shard put, absorbing the failure against the
    /// block's budget when the node is unreachable (a dead link fails
    /// here, before anything is on the wire; in-flight failures are
    /// absorbed at ack time in [`FileWriter::reclaim_to`]).
    fn put_tolerant(
        &mut self,
        id: u32,
        digest: Digest,
        payload: Block,
        bytes: u64,
        tol: &Arc<PutTolerance>,
    ) -> Result<()> {
        match self.sai.node(id).and_then(|n| n.put(digest, payload)) {
            Ok(rx) => {
                self.pending.push_back((bytes, rx, tol.clone()));
                self.inflight_bytes += bytes;
                Ok(())
            }
            Err(_) if tol.absorb() => {
                self.report.put_failures += 1;
                Ok(())
            }
            Err(e) => Err(e),
        }
    }

    /// Await acks (oldest first) until at most `max_bytes` of put
    /// payload remain unacknowledged.  With the duplex links keeping
    /// many requests on the wire per node, this byte budget is the
    /// session's only transfer flow control: it bounds buffered memory
    /// without capping pipeline depth the way the old
    /// `2 × stripe`-operation window did.  A single block larger than
    /// the budget is admitted (it is already on the wire when we get
    /// here) and then immediately awaited — degenerating to lock-step,
    /// never deadlocking.
    fn reclaim_to(&mut self, max_bytes: u64) -> Result<()> {
        // `max_bytes == 0` is the full drain (commit barrier): every
        // ack must land, even a hypothetical zero-length one the byte
        // count alone would never pop.
        while self.inflight_bytes > max_bytes || (max_bytes == 0 && !self.pending.is_empty()) {
            let (len, rx, tol) = self.pending.pop_front().expect("inflight accounting");
            self.inflight_bytes -= len;
            let res = rx.recv().map_err(|_| closed()).and_then(|r| r);
            if let Err(e) = res {
                // A failed copy is absorbed while its block's
                // redundancy budget holds (a node died mid-write;
                // remaining copies/shards still satisfy the floor) and
                // fatal past it.
                if !tol.absorb() {
                    return Err(e);
                }
                self.report.put_failures += 1;
            }
        }
        Ok(())
    }
}

impl Drop for FileWriter<'_> {
    fn drop(&mut self) {
        if let Some(hb) = &mut self.heartbeat {
            hb.stop();
        }
        if !self.committed {
            // Abandoned session: wait out the in-flight puts (so a GC
            // delete cannot be overtaken by a straggling transfer),
            // then drop the claim lease so the manager reclaims the
            // blocks now.  All best effort with bounded waits — a
            // frozen node or dead manager must not hang the drop
            // (claims a dead manager can't release lapse via lease
            // expiry once it restarts... or cost nothing if it never
            // does).
            for (_, rx, _) in self.pending.drain(..) {
                let _ = rx.recv_timeout(Duration::from_secs(5));
            }
            self.sai.drop_lease(self.lease);
        }
    }
}

impl Write for FileWriter<'_> {
    fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
        self.push_bytes(data)?;
        Ok(data.len())
    }

    /// No-op: blocks are pipelined internally and the block-map only
    /// becomes visible at [`close`](FileWriter::close), so there is no
    /// meaningful intermediate flush point.
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Streaming read session (from [`Sai::open`]).  Implements
/// [`std::io::Read`]: blocks are prefetched from their replica nodes
/// ahead of the consumer and each block's content hash is re-verified
/// before its bytes are served (CA modes).  When a copy cannot be
/// fetched — node down, short read, integrity mismatch — the reader
/// transparently fails over to the block's remaining replicas and only
/// errors once every copy has been tried.
///
/// The session holds a manager *read lease* pinning the opened
/// version's blocks: a concurrent overwrite cannot garbage-collect
/// them out from under this reader (the delete is deferred to the last
/// lease's release).  The lease is acquired atomically with the
/// block-map, renewed lazily while the session reads, and dropped —
/// running any deferred deletes — when the reader is dropped.
pub struct FileReader<'a> {
    sai: &'a Sai,
    blocks: Vec<BlockMeta>,
    version: u64,
    /// Manager read lease pinning `blocks`.
    lease: u64,
    /// Lease timeout reported by the manager; renew at `ttl / 3`.
    ttl: Duration,
    /// Last renewal (or acquisition) on this client's clock.
    last_renew: Instant,
    /// Next block index to request from its primary replica.
    next_fetch: usize,
    /// Next block index to hand to the consumer.
    next_read: usize,
    /// Outstanding fetches, in block order: (replica id tried, whether
    /// that already was a non-primary re-route, block bytes, rx).
    /// `id == u32::MAX` marks a block with no reachable replica at
    /// prefetch time (resolved — or failed — via failover).
    rxs: VecDeque<(u32, bool, u64, Receiver<Result<Block>>)>,
    /// Total bytes of outstanding prefetches — held at or under
    /// `ClientConfig::inflight_budget`.
    inflight_bytes: u64,
    /// Blocks served from a non-primary replica (failover events).
    failovers: usize,
    /// Current block being drained by `read` (shared with the node
    /// link's reader — no per-block copy on the way here).
    cur: Block,
    cur_off: usize,
    /// Once a block fails on EVERY replica the session is poisoned:
    /// fetch/read bookkeeping is no longer aligned, so all further
    /// reads fail instead of serving misattributed blocks.
    failed: bool,
}

impl<'a> FileReader<'a> {
    pub(super) fn new(sai: &'a Sai, name: &str) -> Result<FileReader<'a>> {
        // Atomic snapshot + pin: the lease grant carries the block-map,
        // so there is no window between "map fetched" and "blocks
        // pinned" for a concurrent overwrite's GC to slip through.
        let (lease, ttl_ms, version, blocks) = sai.open_lease(name, false)?;
        if version == 0 {
            return Err(Error::Manager(format!("no such file: {name}")));
        }
        let mut r = FileReader {
            sai,
            blocks,
            version,
            lease,
            ttl: Duration::from_millis(ttl_ms.max(1)),
            last_renew: Instant::now(),
            next_fetch: 0,
            next_read: 0,
            rxs: VecDeque::new(),
            inflight_bytes: 0,
            failovers: 0,
            cur: Arc::new(Vec::new()),
            cur_off: 0,
            failed: false,
        };
        r.prefetch();
        Ok(r)
    }

    /// Total file size in bytes.
    pub fn len(&self) -> u64 {
        self.blocks.iter().map(|b| b.len as u64).sum()
    }

    /// Whether the file is empty.
    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// The file version this session reads.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Number of blocks in the file.
    pub fn block_count(&self) -> usize {
        self.blocks.len()
    }

    /// Blocks *served* from a fallback replica — because a fetch
    /// attempt failed mid-flight, or because the primary's link was
    /// already known dead when the prefetch was issued (eager death
    /// detection on the duplex links routes around a down node before
    /// wasting a request on it).  Each block counts at most once, and
    /// only when it was actually served.
    pub fn failover_count(&self) -> usize {
        self.failovers
    }

    /// The manager read lease pinning this session's version.
    pub fn lease(&self) -> u64 {
        self.lease
    }

    /// Lazy renewal: piggybacked on the read path instead of a thread —
    /// a reader that stops consuming eventually lapses (by design: an
    /// abandoned session must not pin blocks forever), while any
    /// actively-draining session renews far inside the window.
    /// Best-effort — if the lease is already gone the blocks may be
    /// deleted mid-read, which surfaces as an ordinary all-replicas
    /// read failure.
    fn maybe_renew(&mut self) {
        if self.lease != 0 && self.last_renew.elapsed() > self.ttl / 3 {
            let _ = self.sai.renew_lease(self.lease);
            self.last_renew = Instant::now();
        }
    }

    /// Keep fetches outstanding ahead of the reader, up to the
    /// session's in-flight-bytes budget (always at least one, so a
    /// block larger than the whole budget still streams — one at a
    /// time).  Each block is requested from its first *connected*
    /// replica; blocks with no connected replica enter the queue as
    /// immediate failures and are retried (and properly diagnosed) by
    /// the failover path.  The duplex node links pipeline these
    /// requests on the wire, so a deep budget keeps every replica NIC
    /// busy instead of paying one RTT per block.
    fn prefetch(&mut self) {
        let budget = self.sai.cfg.inflight_budget as u64;
        while self.next_fetch < self.blocks.len() {
            let b = &self.blocks[self.next_fetch];
            if !self.rxs.is_empty() && self.inflight_bytes + b.len as u64 > budget {
                break;
            }
            if b.ec.is_some() {
                // Erasure-coded blocks need k shards gathered and
                // decoded, not one whole copy — they take the coded
                // path in `next_block_inner`.  A placeholder keeps the
                // queue aligned with block order (and the budget
                // honest about the decode working set).
                self.rxs
                    .push_back((u32::MAX, false, b.len as u64, std::sync::mpsc::channel().1));
                self.inflight_bytes += b.len as u64;
                self.next_fetch += 1;
                continue;
            }
            let primary = b.primary();
            let entry = b
                .replicas
                .iter()
                .find_map(|&id| {
                    let rx = self.sai.node(id).ok()?.get(b.hash).ok()?;
                    // Routing around a known-dead primary IS a
                    // failover, just detected before the wasted
                    // request; it is counted when the block is served.
                    let rerouted = Some(id) != primary;
                    Some((id, rerouted, b.len as u64, rx))
                })
                .unwrap_or_else(|| {
                    // No replica reachable: a receiver whose sender is
                    // gone yields an immediate RecvError downstream.
                    (u32::MAX, false, b.len as u64, std::sync::mpsc::channel().1)
                });
            self.inflight_bytes += entry.2;
            self.rxs.push_back(entry);
            self.next_fetch += 1;
        }
    }

    /// Fetch, verify and return the next whole block (None at EOF),
    /// failing over across replicas.  An error means every replica of
    /// the block failed; it poisons the session and subsequent calls
    /// keep failing rather than serving blocks misaligned with their
    /// metadata.
    pub fn next_block(&mut self) -> Result<Option<Block>> {
        if self.failed {
            return Err(Error::Node("read session failed earlier".into()));
        }
        self.maybe_renew();
        match self.next_block_inner() {
            Ok(v) => Ok(v),
            Err(e) => {
                self.failed = true;
                Err(e)
            }
        }
    }

    /// Validate one fetched copy against the block's metadata.
    fn check(&self, meta: &BlockMeta, data: &[u8]) -> Result<()> {
        if data.len() != meta.len as usize {
            return Err(Error::Node(format!(
                "block length mismatch: got {}, expected {}",
                data.len(),
                meta.len
            )));
        }
        if self.sai.cfg.ca_mode != CaMode::None {
            // Integrity check: recompute the content hash.
            let th = self.sai.engine.direct_hash(data)?;
            if th != meta.hash {
                return Err(Error::Node("block integrity check failed".into()));
            }
        }
        Ok(())
    }

    /// Degraded-capable erasure-coded read: gather any `k` of the
    /// block's `k+m` shards (shard `i` lives on `replicas[i]`, keyed by
    /// the parent block's hash), reconstruct, and verify the rebuilt
    /// block's content hash.  Shards whose node is dead or whose copy
    /// is the wrong size are skipped — losing up to `m` of them is the
    /// redundancy working as designed, counted as one failover per
    /// block, with wrong-size (served-but-bad) copies reported to the
    /// manager for repair.  Fewer than `k` reachable shards is a hard
    /// error.
    fn read_coded(&mut self, meta: &BlockMeta, k: u8, m: u8) -> Result<Block> {
        let (k, m) = (k as usize, m as usize);
        let n = k + m;
        if meta.replicas.len() != n {
            return Err(Error::Node(format!(
                "coded block carries {} homes for {n} shards",
                meta.replicas.len()
            )));
        }
        let slen = crate::ec::shard_len(meta.len as usize, k);
        let mut shards: Vec<Option<Vec<u8>>> = vec![None; n];
        let mut have = 0usize;
        let mut skipped = 0usize;
        for (i, &id) in meta.replicas.iter().enumerate() {
            if have >= k {
                break;
            }
            let got = self
                .sai
                .node(id)
                .and_then(|nl| nl.get(meta.hash))
                .and_then(|rx| rx.recv().map_err(|_| closed()).and_then(|r| r));
            match got {
                Ok(s) if s.len() == slen => {
                    shards[i] = Some(s.as_ref().clone());
                    have += 1;
                }
                Ok(_) => {
                    // Served a wrong-size shard: a corrupt copy, not a
                    // dead node — flag it for repair.
                    self.sai.report_corrupt(meta.hash, id);
                    skipped += 1;
                }
                Err(_) => skipped += 1,
            }
        }
        if have < k {
            return Err(Error::Node(format!(
                "block {}: only {have} of the {k} shards needed are reachable \
                 ({n} homes, {skipped} failed)",
                self.next_read
            )));
        }
        let data = crate::ec::reconstruct(k, m, &shards, meta.len as usize).map_err(Error::Node)?;
        if self.sai.cfg.ca_mode != CaMode::None {
            let th = self.sai.engine.direct_hash(&data)?;
            if th != meta.hash {
                return Err(Error::Node(
                    "coded block failed its integrity check after reconstruction".into(),
                ));
            }
        }
        if skipped > 0 {
            // Served degraded: shards were missing but the coding
            // absorbed it.  One failover event per block, same meaning
            // as the replicated path's count.
            self.failovers += 1;
        }
        Ok(Arc::new(data))
    }

    fn next_block_inner(&mut self) -> Result<Option<Block>> {
        if self.next_read >= self.blocks.len() {
            return Ok(None);
        }
        let (tried, rerouted, len, rx) = self.rxs.pop_front().expect("prefetch invariant");
        self.inflight_bytes -= len;
        if let Some((k, m)) = self.blocks[self.next_read].ec {
            drop(rx); // placeholder — no fetch was issued
            let meta = self.blocks[self.next_read].clone();
            let data = self.read_coded(&meta, k, m)?;
            self.next_read += 1;
            self.prefetch();
            return Ok(Some(data));
        }
        let primary = rx
            .recv()
            .map_err(|_| closed())
            .and_then(|r| r)
            .and_then(|data| {
                match self.check(&self.blocks[self.next_read], &data) {
                    Ok(()) => Ok(data),
                    Err(e) => {
                        // The node SERVED bytes that do not verify — a
                        // corrupt copy, not a dead node.  Tell the
                        // manager so the scrub loop re-creates it; this
                        // reader meanwhile fails over.
                        if tried != u32::MAX {
                            self.sai.report_corrupt(self.blocks[self.next_read].hash, tried);
                        }
                        Err(e)
                    }
                }
            });
        let data = match primary {
            Ok(data) => {
                if rerouted {
                    // Served from a fallback replica the prefetch
                    // already routed to (primary link known dead).
                    self.failovers += 1;
                }
                data
            }
            Err(first_err) => {
                // Failover: try the remaining replicas synchronously.
                let meta = self.blocks[self.next_read].clone();
                let mut last_err = first_err;
                let mut found = None;
                for &id in meta.replicas.iter().filter(|&&id| id != tried) {
                    let res = match self.sai.node(id).and_then(|n| n.get(meta.hash)) {
                        Ok(rx) => rx.recv().map_err(|_| closed()).and_then(|r| r),
                        Err(e) => Err(e),
                    };
                    match res.and_then(|data| match self.check(&meta, &data) {
                        Ok(()) => Ok(data),
                        Err(e) => {
                            // Served-but-unverifiable: flag the copy
                            // for repair (transport failures are not
                            // reported — liveness is the heartbeat's
                            // job).
                            self.sai.report_corrupt(meta.hash, id);
                            Err(e)
                        }
                    }) {
                        Ok(data) => {
                            found = Some(data);
                            break;
                        }
                        Err(e) => last_err = e,
                    }
                }
                match found {
                    Some(data) => {
                        self.failovers += 1;
                        data
                    }
                    None => {
                        return Err(Error::Node(format!(
                            "block {} failed on all {} replica(s): {last_err}",
                            self.next_read,
                            meta.replicas.len().max(1)
                        )))
                    }
                }
            }
        };
        self.next_read += 1;
        self.prefetch();
        Ok(Some(data))
    }
}

impl Drop for FileReader<'_> {
    fn drop(&mut self) {
        // Unpin: any deletes deferred to this session (the version was
        // overwritten while we streamed it) run inside this call, so
        // reclamation stays observable at the client.  Best effort — a
        // dead manager lapses the lease by expiry instead.
        self.sai.drop_lease(self.lease);
    }
}

impl Read for FileReader<'_> {
    fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
        if out.is_empty() {
            return Ok(0);
        }
        while self.cur_off >= self.cur.len() {
            match self.next_block()? {
                Some(b) => {
                    self.cur = b;
                    self.cur_off = 0;
                }
                None => return Ok(0),
            }
        }
        let n = (self.cur.len() - self.cur_off).min(out.len());
        out[..n].copy_from_slice(&self.cur[self.cur_off..self.cur_off + n]);
        self.cur_off += n;
        Ok(n)
    }
}
