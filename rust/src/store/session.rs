//! Streaming sessions over the SAI: [`FileWriter`] (incremental write →
//! chunk → hash → dedup → stripe pipeline, commit on close) and
//! [`FileReader`] (prefetching, integrity-verified block streaming).
//!
//! The writer is the paper's pipeline made visible in the API: each
//! filled write buffer's block digests are *submitted* to the hash
//! engine (non-blocking on accelerator engines) and redeemed one buffer
//! later, so buffer N's hashing overlaps buffer N-1's block placement
//! and transfers, and buffer N+1's accumulation/chunking — CrystalGPU's
//! transfer/compute overlap, end to end.  Synchronous engines
//! (CPU/oracle) degrade gracefully to the serial path through the same
//! code.
//!
//! Buffering is caller-split-invariant: the writer re-buffers incoming
//! bytes to exactly `write_buffer`-sized batches internally, so a file
//! streamed in arbitrary splits produces a block-map byte-identical to
//! a one-shot [`super::Sai::write_file`] (property-tested).

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Instant;

use super::proto::{BlockMeta, Msg};
use super::sai::{closed, Sai, WriteReport};
use crate::chunking::ContentChunker;
use crate::config::CaMode;
use crate::hash::{md5, Digest};
use crate::hashgpu::{DigestsTicket, HashTiming};
use crate::{Error, Result};

/// Mode-specific chunking state of a write session.
enum ModeState {
    /// Non-CA: blocks addressed by (file, index); `index` is the global
    /// block counter across the whole stream.
    None { index: u64 },
    /// Fixed-size blocks.
    Fixed,
    /// Content-defined chunking (stream-continuous across buffers).
    Cdc { chunker: ContentChunker },
}

/// A submitted-but-unredeemed digest batch: the payloads it covers ride
/// along so blocks can be placed once the digests arrive.
struct Inflight {
    blocks: Arc<Vec<Vec<u8>>>,
    ticket: DigestsTicket,
}

/// Streaming write session (from [`Sai::create`]).  Implements
/// [`std::io::Write`]; call [`close`](FileWriter::close) to commit the
/// block-map and obtain the [`WriteReport`].  Dropping the writer
/// without closing abandons the write: nothing is committed (already
/// transferred blocks remain on the nodes as unreferenced garbage, as
/// with any aborted write).
pub struct FileWriter<'a> {
    sai: &'a Sai,
    name: String,
    mode: ModeState,
    /// Bytes accumulated toward the next `write_buffer`-sized batch.
    buf: Vec<u8>,
    /// hash -> node of every block known to dedup against (previous
    /// version + blocks placed by this write).
    known: HashMap<Digest, u32>,
    metas: Vec<BlockMeta>,
    /// Outstanding node-put acknowledgements.
    pending: Vec<Receiver<Result<()>>>,
    /// The previous buffer's digest batch, still being hashed.
    inflight: Option<Inflight>,
    report: WriteReport,
    t0: Instant,
}

impl<'a> FileWriter<'a> {
    pub(super) fn new(sai: &'a Sai, name: &str) -> Result<FileWriter<'a>> {
        let t0 = Instant::now();
        // Previous version's block-map: hash -> node.
        let (_, old_blocks) = sai.get_block_map(name)?;
        let known = old_blocks.iter().map(|b| (b.hash, b.node)).collect();
        let mode = match sai.cfg.ca_mode {
            CaMode::None => ModeState::None { index: 0 },
            CaMode::Fixed => ModeState::Fixed,
            CaMode::Cdc => ModeState::Cdc {
                chunker: ContentChunker::new(sai.cfg.chunk_params()),
            },
        };
        Ok(FileWriter {
            sai,
            name: name.to_string(),
            mode,
            buf: Vec::with_capacity(sai.cfg.write_buffer),
            known,
            metas: Vec::new(),
            pending: Vec::new(),
            inflight: None,
            report: WriteReport::default(),
            t0,
        })
    }

    /// The file being written.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Bytes accepted so far.
    pub fn bytes_written(&self) -> u64 {
        self.report.bytes
    }

    /// Feed payload bytes into the pipeline (the [`std::io::Write`]
    /// impl routes here).  Processes a batch whenever the internal
    /// buffer reaches `write_buffer` bytes.
    pub fn push_bytes(&mut self, mut data: &[u8]) -> Result<()> {
        self.report.bytes += data.len() as u64;
        let cap = self.sai.cfg.write_buffer;
        while self.buf.len() + data.len() >= cap {
            let take = cap - self.buf.len();
            self.buf.extend_from_slice(&data[..take]);
            data = &data[take..];
            self.process_buffer()?;
        }
        self.buf.extend_from_slice(data);
        Ok(())
    }

    /// Commit the new block-map (the POSIX `release` step) after
    /// flushing the tail of the stream, and return the write report.
    pub fn close(mut self) -> Result<WriteReport> {
        if !self.buf.is_empty() {
            self.process_buffer()?;
        }
        // Drain the pipeline: redeem the last buffer's digests...
        let prev = self.inflight.take();
        self.resolve(prev)?;
        // ...then the final partial CDC chunk, if any.
        let final_chunk = match &mut self.mode {
            ModeState::Cdc { chunker } => chunker.finish(),
            _ => None,
        };
        if let Some(chunk) = final_chunk {
            let blocks = Arc::new(vec![chunk.data]);
            let ticket = self.sai.engine.submit_direct_batch(blocks.clone())?;
            self.resolve(Some(Inflight { blocks, ticket }))?;
        }
        // Wait for all outstanding transfers.
        self.collect_window(0)?;

        match self.sai.manager_call(Msg::CommitBlockMap {
            file: self.name.clone(),
            blocks: self.metas.clone(),
        })? {
            Msg::Ok => {}
            m => return Err(Error::Proto(format!("unexpected commit reply {m:?}"))),
        }

        self.report.blocks = self.metas.len();
        self.report.elapsed = self.t0.elapsed();
        self.report.similarity = if self.report.bytes == 0 {
            0.0
        } else {
            1.0 - self.report.new_bytes as f64 / self.report.bytes as f64
        };
        Ok(self.report)
    }

    /// Process one accumulated batch (exactly `write_buffer` bytes,
    /// except the final partial batch at close).
    fn process_buffer(&mut self) -> Result<()> {
        let buf = std::mem::take(&mut self.buf);
        if buf.is_empty() {
            return Ok(());
        }
        let result = match self.sai.cfg.ca_mode {
            CaMode::None => self.process_non_ca(&buf),
            CaMode::Fixed => {
                let blocks: Vec<Vec<u8>> = buf
                    .chunks(self.sai.cfg.block_size)
                    .map(|b| b.to_vec())
                    .collect();
                self.submit_and_rotate(blocks)
            }
            CaMode::Cdc => self.process_cdc(&buf),
        };
        // Hand the (now drained) allocation back so the next batch does
        // not re-grow a fresh write buffer.
        self.buf = buf;
        self.buf.clear();
        result
    }

    /// Non-CA: no hashing, blocks addressed by (file, index) and shipped
    /// straight out.
    fn process_non_ca(&mut self, buf: &[u8]) -> Result<()> {
        for blk in buf.chunks(self.sai.cfg.block_size) {
            let ModeState::None { index } = &mut self.mode else {
                return Err(Error::Other("mode state mismatch".into()));
            };
            let i = *index;
            *index += 1;
            let mut key = Vec::with_capacity(self.name.len() + 8);
            key.extend_from_slice(self.name.as_bytes());
            key.extend_from_slice(&i.to_le_bytes());
            let hash = md5(&key);
            let node = (i as usize % self.sai.stripe()) as u32;
            self.pending
                .push(self.sai.nodes[node as usize].put(hash, blk.to_vec()));
            self.report.new_blocks += 1;
            self.report.new_bytes += blk.len() as u64;
            self.metas.push(BlockMeta {
                hash,
                len: blk.len() as u32,
                node,
            });
            self.collect_window(2 * self.sai.stripe())?;
        }
        Ok(())
    }

    /// CDC: window-hash this buffer (async where the engine allows),
    /// overlap the wait with placement of the previous buffer's chunks,
    /// then cut boundaries and submit the finished chunks' digests.
    fn process_cdc(&mut self, buf: &[u8]) -> Result<()> {
        let ext = match &self.mode {
            ModeState::Cdc { chunker } => chunker.extended(buf),
            _ => return Err(Error::Other("mode state mismatch".into())),
        };
        let wticket = self.sai.engine.submit_window_hashes(ext)?;
        // While the engine hashes windows, place the previous buffer's
        // chunks (their digests were submitted a buffer ago).
        let prev = self.inflight.take();
        self.resolve(prev)?;
        let (hashes, t) = wticket.wait()?;
        self.add_hash_timing(t);
        let finished = match &mut self.mode {
            ModeState::Cdc { chunker } => chunker.push_with_hashes(buf, &hashes),
            _ => return Err(Error::Other("mode state mismatch".into())),
        };
        if finished.is_empty() {
            return Ok(());
        }
        let blocks: Vec<Vec<u8>> = finished.into_iter().map(|c| c.data).collect();
        let blocks = Arc::new(blocks);
        let ticket = self.sai.engine.submit_direct_batch(blocks.clone())?;
        debug_assert!(self.inflight.is_none());
        self.inflight = Some(Inflight { blocks, ticket });
        Ok(())
    }

    /// Submit a batch's digests (non-blocking on async engines), then
    /// redeem and place the *previous* batch — the pipeline's rotation.
    fn submit_and_rotate(&mut self, blocks: Vec<Vec<u8>>) -> Result<()> {
        if blocks.is_empty() {
            return Ok(());
        }
        let blocks = Arc::new(blocks);
        let ticket = self.sai.engine.submit_direct_batch(blocks.clone())?;
        let prev = self.inflight.replace(Inflight { blocks, ticket });
        self.resolve(prev)
    }

    /// Redeem an in-flight digest batch and place its blocks.
    fn resolve(&mut self, inflight: Option<Inflight>) -> Result<()> {
        let Some(Inflight { blocks, ticket }) = inflight else {
            return Ok(());
        };
        let (digests, t) = ticket.wait()?;
        self.add_hash_timing(t);
        if digests.len() != blocks.len() {
            return Err(Error::Other(format!(
                "engine returned {} digests for {} blocks",
                digests.len(),
                blocks.len()
            )));
        }
        for (blk, digest) in blocks.iter().zip(digests) {
            self.place_block(blk, digest);
        }
        self.collect_window(2 * self.sai.stripe())
    }

    fn add_hash_timing(&mut self, t: HashTiming) {
        self.report.hash_secs += t.exposed.as_secs_f64();
        self.report.hash_hidden_secs += t.hidden.as_secs_f64();
    }

    /// Dedup decision + transfer for one block.
    fn place_block(&mut self, data: &[u8], digest: Digest) {
        if let Some(&node) = self.known.get(&digest) {
            self.report.dup_blocks += 1;
            self.metas.push(BlockMeta {
                hash: digest,
                len: data.len() as u32,
                node,
            });
            return;
        }
        let node = (self.metas.len() % self.sai.stripe()) as u32;
        self.pending
            .push(self.sai.nodes[node as usize].put(digest, data.to_vec()));
        self.known.insert(digest, node);
        self.report.new_blocks += 1;
        self.report.new_bytes += data.len() as u64;
        self.metas.push(BlockMeta {
            hash: digest,
            len: data.len() as u32,
            node,
        });
    }

    /// Await acks until at most `max_left` puts remain outstanding.
    fn collect_window(&mut self, max_left: usize) -> Result<()> {
        while self.pending.len() > max_left {
            let rx = self.pending.remove(0);
            rx.recv().map_err(|_| closed())??;
        }
        Ok(())
    }
}

impl Write for FileWriter<'_> {
    fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
        self.push_bytes(data)?;
        Ok(data.len())
    }

    /// No-op: blocks are pipelined internally and the block-map only
    /// becomes visible at [`close`](FileWriter::close), so there is no
    /// meaningful intermediate flush point.
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Streaming read session (from [`Sai::open`]).  Implements
/// [`std::io::Read`]: blocks are prefetched from the stripe nodes ahead
/// of the consumer and each block's content hash is re-verified before
/// its bytes are served (CA modes).
pub struct FileReader<'a> {
    sai: &'a Sai,
    blocks: Vec<BlockMeta>,
    version: u64,
    /// Next block index to request from its node.
    next_fetch: usize,
    /// Next block index to hand to the consumer.
    next_read: usize,
    /// Outstanding fetches, in block order.
    rxs: VecDeque<Receiver<Result<Vec<u8>>>>,
    /// Current block being drained by `read`.
    cur: Vec<u8>,
    cur_off: usize,
    /// Once any block fails (transport, length, integrity), the session
    /// is poisoned: fetch/read bookkeeping is no longer aligned, so all
    /// further reads fail instead of serving misattributed blocks.
    failed: bool,
}

impl<'a> FileReader<'a> {
    pub(super) fn new(sai: &'a Sai, name: &str) -> Result<FileReader<'a>> {
        let (version, blocks) = sai.get_block_map(name)?;
        if version == 0 {
            return Err(Error::Manager(format!("no such file: {name}")));
        }
        let mut r = FileReader {
            sai,
            blocks,
            version,
            next_fetch: 0,
            next_read: 0,
            rxs: VecDeque::new(),
            cur: Vec::new(),
            cur_off: 0,
            failed: false,
        };
        r.prefetch()?;
        Ok(r)
    }

    /// Total file size in bytes.
    pub fn len(&self) -> u64 {
        self.blocks.iter().map(|b| b.len as u64).sum()
    }

    /// Whether the file is empty.
    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// The file version this session reads.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Number of blocks in the file.
    pub fn block_count(&self) -> usize {
        self.blocks.len()
    }

    /// Keep up to `2 * stripe` fetches outstanding ahead of the reader.
    fn prefetch(&mut self) -> Result<()> {
        let window = 2 * self.sai.stripe().max(1);
        while self.next_fetch < self.blocks.len() && self.rxs.len() < window {
            let b = &self.blocks[self.next_fetch];
            let node = self
                .sai
                .nodes
                .get(b.node as usize)
                .ok_or_else(|| Error::Node(format!("block maps to unknown node {}", b.node)))?;
            self.rxs.push_back(node.get(b.hash));
            self.next_fetch += 1;
        }
        Ok(())
    }

    /// Fetch, verify and return the next whole block (None at EOF).
    /// Any error poisons the session: subsequent calls keep failing
    /// rather than serving blocks misaligned with their metadata.
    pub fn next_block(&mut self) -> Result<Option<Vec<u8>>> {
        if self.failed {
            return Err(Error::Node("read session failed earlier".into()));
        }
        match self.next_block_inner() {
            Ok(v) => Ok(v),
            Err(e) => {
                self.failed = true;
                Err(e)
            }
        }
    }

    fn next_block_inner(&mut self) -> Result<Option<Vec<u8>>> {
        if self.next_read >= self.blocks.len() {
            return Ok(None);
        }
        let rx = self.rxs.pop_front().expect("prefetch invariant");
        let data = rx.recv().map_err(|_| closed())??;
        let meta = &self.blocks[self.next_read];
        if data.len() != meta.len as usize {
            return Err(Error::Node(format!(
                "block length mismatch: got {}, expected {}",
                data.len(),
                meta.len
            )));
        }
        if self.sai.cfg.ca_mode != CaMode::None {
            // Integrity check: recompute the content hash.
            let th = self.sai.engine.direct_hash(&data)?;
            if th != meta.hash {
                return Err(Error::Node("block integrity check failed".into()));
            }
        }
        self.next_read += 1;
        self.prefetch()?;
        Ok(Some(data))
    }
}

impl Read for FileReader<'_> {
    fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
        if out.is_empty() {
            return Ok(0);
        }
        while self.cur_off >= self.cur.len() {
            match self.next_block()? {
                Some(b) => {
                    self.cur = b;
                    self.cur_off = 0;
                }
                None => return Ok(0),
            }
        }
        let n = (self.cur.len() - self.cur_off).min(out.len());
        out[..n].copy_from_slice(&self.cur[self.cur_off..self.cur_off + n]);
        self.cur_off += n;
        Ok(n)
    }
}
