//! Single-process cluster bring-up: manager + N storage nodes on
//! loopback TCP, with an optional shared client-NIC shaper — the paper's
//! 22-node/1 Gbps testbed in one process.

use std::sync::Arc;

use super::manager::Manager;
use super::node::StorageNode;
use super::sai::Sai;
use crate::config::{ClientConfig, ClusterConfig};
use crate::hashgpu::HashEngine;
use crate::net::Shaper;
use crate::Result;

/// A running cluster.
pub struct Cluster {
    manager: Manager,
    nodes: Vec<StorageNode>,
    cfg: ClusterConfig,
}

impl Cluster {
    /// Spawn a manager and `cfg.nodes` storage nodes on ephemeral ports.
    pub fn spawn(cfg: ClusterConfig) -> Result<Cluster> {
        let manager = Manager::spawn("127.0.0.1:0")?;
        let nodes = (0..cfg.nodes)
            .map(|_| StorageNode::spawn("127.0.0.1:0"))
            .collect::<Result<Vec<_>>>()?;
        Ok(Cluster {
            manager,
            nodes,
            cfg,
        })
    }

    /// Manager address.
    pub fn manager_addr(&self) -> &str {
        self.manager.addr()
    }

    /// Node addresses.
    pub fn node_addrs(&self) -> Vec<String> {
        self.nodes.iter().map(|n| n.addr().to_string()).collect()
    }

    /// The client-side NIC shaper implied by the cluster config
    /// (None if shaping is disabled).
    pub fn client_shaper(&self) -> Option<Arc<Shaper>> {
        self.cfg
            .shape
            .then(|| Arc::new(Shaper::from_bits_per_sec(self.cfg.link_bps)))
    }

    /// Connect a SAI client with the given config and engine.
    pub fn client(&self, cfg: ClientConfig, engine: Arc<dyn HashEngine>) -> Result<Sai> {
        Sai::connect(
            self.manager_addr(),
            &self.node_addrs(),
            cfg,
            engine,
            self.client_shaper(),
        )
    }

    /// Kill one storage node (failure injection for tests): stops its
    /// accept loop and severs existing connections.
    pub fn kill_node(&mut self, idx: usize) {
        if idx < self.nodes.len() {
            self.nodes[idx].shutdown();
        }
    }

    /// Total (blocks, bytes) across storage nodes.
    pub fn storage_stats(&self) -> (u64, u64) {
        use super::proto::Msg;
        let mut blocks = 0;
        let mut bytes = 0;
        for n in &self.nodes {
            if let Msg::Stats { blocks: b, bytes: by } = n.state().handle(Msg::NodeStats) {
                blocks += b;
                bytes += by;
            }
        }
        (blocks, bytes)
    }
}
