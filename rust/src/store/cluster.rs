//! Single-process cluster bring-up: manager + N storage nodes on
//! loopback TCP, with an optional shared client-NIC shaper — the paper's
//! 22-node/1 Gbps testbed in one process.
//!
//! Control-plane v2: nodes register with the manager at spawn (join +
//! heartbeat), the manager owns placement via a
//! [`PlacementPolicy`](super::manager::PlacementPolicy) derived from
//! [`ClusterConfig::replication`], and clients bootstrap from the
//! manager address alone.  Control-plane v3: the manager's lease
//! timeout comes from [`ClusterConfig::lease_timeout`].

use std::sync::Arc;

use super::manager::{policy_for, ConsensusOpts, ErasureCoded, Manager, ManagerState, PlacementPolicy};
use super::node::{NodeOpts, StorageNode};
use super::sai::Sai;
use crate::config::{ClientConfig, ClusterConfig, Placement};
use crate::hashgpu::HashEngine;
use crate::net::{Listener, Shaper};
use crate::wal::DurabilityOpts;
use crate::{Error, Result};

/// A running cluster.
pub struct Cluster {
    managers: Vec<Manager>,
    nodes: Vec<StorageNode>,
    cfg: ClusterConfig,
}

impl Cluster {
    /// Spawn `cfg.managers` manager(s) and `cfg.nodes` storage nodes on
    /// ephemeral ports.  The nodes join manager 0's registry; managers
    /// place blocks with `cfg.replication` copies each.  With
    /// `cfg.managers >= 2` the managers form a quorum group (member 0
    /// the initial leader) and clients bootstrap from the full member
    /// list.
    pub fn spawn(cfg: ClusterConfig) -> Result<Cluster> {
        if cfg.homes_per_block() == 0 {
            return Err(Error::Config("replication must be >= 1".into()));
        }
        if cfg.homes_per_block() > cfg.nodes {
            return Err(Error::Config(format!(
                "placement needs {} homes per block but the cluster has only {} nodes",
                cfg.homes_per_block(),
                cfg.nodes
            )));
        }
        if cfg.lease_timeout.is_zero() {
            return Err(Error::Config("lease_timeout must be non-zero".into()));
        }
        if cfg.managers == 0 {
            return Err(Error::Config("managers must be >= 1".into()));
        }
        // Bind every member's listener first: the full peer address
        // list must exist before any member's consensus is configured.
        let listeners = (0..cfg.managers)
            .map(|_| Listener::bind("127.0.0.1:0"))
            .collect::<Result<Vec<_>>>()?;
        let addrs = listeners
            .iter()
            .map(|l| l.local_addr())
            .collect::<Result<Vec<_>>>()?;
        let mut managers = Vec::with_capacity(cfg.managers);
        for (i, listener) in listeners.into_iter().enumerate() {
            let durability = durability_for(&cfg, i);
            let state = Arc::new(ManagerState::with_durability(
                policy_from(&cfg)?,
                cfg.lease_timeout,
                durability.clone(),
            )?);
            state.set_scrub(cfg.scrub_interval, cfg.repair_mbps);
            if cfg.managers > 1 {
                state.set_consensus(
                    ConsensusOpts {
                        self_addr: addrs[i].clone(),
                        peers: peer_addrs(&addrs, i),
                        initial_leader: i == 0,
                    },
                    durability.map(|d| d.data_dir),
                )?;
            }
            managers.push(Manager::serve_listener_opts(
                listener,
                state,
                cfg.serve_mode,
                cfg.serve_threads,
            )?);
        }
        let nodes = (0..cfg.nodes)
            .map(|_| {
                StorageNode::spawn_opts(
                    "127.0.0.1:0",
                    NodeOpts {
                        manager: Some(managers[0].addr().to_string()),
                        // Each node gets its own NIC on the modeled
                        // fabric: replies (the read path) are paced at
                        // link speed just like the client's puts.
                        reply_shaper: cfg
                            .shape
                            .then(|| Arc::new(Shaper::from_bits_per_sec(cfg.link_bps))),
                        reply_latency: cfg.node_rtt,
                        serve_mode: cfg.serve_mode,
                        serve_threads: cfg.serve_threads,
                        ..NodeOpts::default()
                    },
                )
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Cluster {
            managers,
            nodes,
            cfg,
        })
    }

    /// Manager 0's address (the classic single-manager bootstrap
    /// address; multi-manager clients should prefer
    /// [`Cluster::bootstrap_addrs`]).
    pub fn manager_addr(&self) -> &str {
        self.managers[0].addr()
    }

    /// Every manager's address, in member order.
    pub fn manager_addrs(&self) -> Vec<String> {
        self.managers.iter().map(|m| m.addr().to_string()).collect()
    }

    /// The comma-separated bootstrap list [`Sai::connect`] understands
    /// (all members — redirects find the leader from any of them).
    pub fn bootstrap_addrs(&self) -> String {
        self.manager_addrs().join(",")
    }

    /// The manager itself (registry/refcount introspection in tests).
    pub fn manager(&self) -> &Manager {
        &self.managers[0]
    }

    /// Manager by member index.
    pub fn manager_at(&self, i: usize) -> &Manager {
        &self.managers[i]
    }

    /// Member index of the current quorum leader, skipping crashed
    /// members (`None` while an election is unsettled).
    pub fn leader_idx(&self) -> Option<usize> {
        self.managers
            .iter()
            .position(|m| m.up() && m.state().is_leader())
    }

    /// Run one consensus timer tick on every live member (tests drive
    /// elections deterministically with this plus
    /// [`ManagerState::advance_clock`]).
    pub fn tick_managers(&self) {
        for m in &self.managers {
            if m.up() {
                m.state().tick_consensus();
            }
        }
    }

    /// Kill the manager in place (see [`Manager::crash`]): in-memory
    /// state discarded, WAL handle released, address kept — only what
    /// the log and snapshots captured survives.
    pub fn crash_manager(&self) {
        self.crash_manager_at(0);
    }

    /// Kill manager `i` in place.
    pub fn crash_manager_at(&self, i: usize) {
        self.managers[i].crash();
    }

    /// Respawn the crashed manager on the same address, recovering from
    /// the cluster's configured data dir (a no-op recovery when the
    /// cluster runs without durability).
    pub fn restart_manager(&self) -> Result<()> {
        self.restart_manager_at(0)
    }

    /// Respawn crashed manager `i` on its old address.  In a quorum
    /// group the member restarts as a *follower* regardless of its
    /// pre-crash role (its persisted term/vote reload from disk; it
    /// rejoins and catches up from the current leader's heartbeats).
    pub fn restart_manager_at(&self, i: usize) -> Result<()> {
        let durability = durability_for(&self.cfg, i);
        let state = Arc::new(ManagerState::with_durability(
            policy_from(&self.cfg)?,
            self.cfg.lease_timeout,
            durability.clone(),
        )?);
        state.set_scrub(self.cfg.scrub_interval, self.cfg.repair_mbps);
        if self.managers.len() > 1 {
            let addrs = self.manager_addrs();
            state.set_consensus(
                ConsensusOpts {
                    self_addr: addrs[i].clone(),
                    peers: peer_addrs(&addrs, i),
                    initial_leader: false,
                },
                durability.map(|d| d.data_dir),
            )?;
        }
        self.managers[i].restart_state(state);
        Ok(())
    }

    /// Node addresses, by node id.
    pub fn node_addrs(&self) -> Vec<String> {
        self.nodes.iter().map(|n| n.addr().to_string()).collect()
    }

    /// The client-side NIC shaper implied by the cluster config
    /// (None if shaping is disabled).
    pub fn client_shaper(&self) -> Option<Arc<Shaper>> {
        self.cfg
            .shape
            .then(|| Arc::new(Shaper::from_bits_per_sec(self.cfg.link_bps)))
    }

    /// Connect a SAI client with the given config and engine (nodes are
    /// discovered through the manager).  Multi-manager clusters hand
    /// the client the full member list so `NotLeader` redirects always
    /// have somewhere to rotate to.
    pub fn client(&self, cfg: ClientConfig, engine: Arc<dyn HashEngine>) -> Result<Sai> {
        Sai::connect(&self.bootstrap_addrs(), cfg, engine, self.client_shaper())
    }

    /// Connect a SAI client whose engine is a handle onto the shared
    /// process-wide hash service (see [`crate::hashsvc`]).  The cluster
    /// config's batching knobs (`hash_batch` / `hash_linger_us` /
    /// `hash_devices`) are stamped onto the client config first, so
    /// every client of this cluster coalesces into the same service.
    pub fn service_client(&self, cfg: ClientConfig) -> Result<Sai> {
        let mut cfg = cfg;
        cfg.hash_batch = self.cfg.hash_batch;
        cfg.hash_linger_us = self.cfg.hash_linger_us;
        cfg.hash_devices = self.cfg.hash_devices;
        let engine = crate::hashsvc::session_engine(&cfg, None)?;
        self.client(cfg, engine)
    }

    /// Kill one storage node (failure injection for tests): stops its
    /// accept loop, its heartbeats, and severs existing connections.
    pub fn kill_node(&mut self, idx: usize) {
        if idx < self.nodes.len() {
            self.nodes[idx].shutdown();
        }
    }

    /// Total (blocks, bytes) across storage nodes, counting each
    /// replica copy.
    pub fn storage_stats(&self) -> (u64, u64) {
        let mut blocks = 0;
        let mut bytes = 0;
        for (b, by) in self.per_node_stats() {
            blocks += b;
            bytes += by;
        }
        (blocks, bytes)
    }

    /// Every serve loop's gauges, labeled — `("manager0", ..)` per
    /// manager, `("node3", ..)` per node.  Empty in thread mode (no
    /// reactor, no gauges); `gpustore demo --verbose` prints these.
    pub fn serve_gauges(&self) -> Vec<(String, Arc<crate::metrics::ServeGauges>)> {
        let mut out = Vec::new();
        for (i, m) in self.managers.iter().enumerate() {
            if let Some(g) = m.serve_gauges() {
                out.push((format!("manager{i}"), g));
            }
        }
        for (i, n) in self.nodes.iter().enumerate() {
            if let Some(g) = n.serve_gauges() {
                out.push((format!("node{i}"), g));
            }
        }
        out
    }

    /// Per-node (blocks, bytes), by node id.
    pub fn per_node_stats(&self) -> Vec<(u64, u64)> {
        use super::proto::Msg;
        self.nodes
            .iter()
            .map(|n| match n.state().handle(Msg::NodeStats) {
                Msg::Stats { blocks, bytes } => (blocks, bytes),
                _ => (0, 0),
            })
            .collect()
    }
}

/// The placement policy the cluster config asks for: the explicit
/// [`ClusterConfig::placement`] when set (PR 10), otherwise derived
/// from the replication factor as before.
fn policy_from(cfg: &ClusterConfig) -> Result<Box<dyn PlacementPolicy>> {
    match cfg.placement {
        None => Ok(policy_for(cfg.replication)),
        Some(Placement::RoundRobin) => Ok(policy_for(1)),
        Some(Placement::Replicated(r)) => Ok(policy_for(r)),
        Some(Placement::Erasure { k, m }) => Ok(Box::new(ErasureCoded::new(k, m)?)),
    }
}

/// Member `i`'s durability options: the configured data dir itself for
/// a single manager (backward compatible), an `m<i>` subdirectory per
/// member for a quorum group (each member owns its own WAL, snapshots
/// and term sidecar).
fn durability_for(cfg: &ClusterConfig, i: usize) -> Option<DurabilityOpts> {
    cfg.durability.clone().map(|mut d| {
        if cfg.managers > 1 {
            d.data_dir = d.data_dir.join(format!("m{i}"));
        }
        d
    })
}

/// Every member address except `i`'s own.
fn peer_addrs(addrs: &[String], i: usize) -> Vec<String> {
    addrs
        .iter()
        .enumerate()
        .filter(|(j, _)| *j != i)
        .map(|(_, a)| a.clone())
        .collect()
}
