//! Single-process cluster bring-up: manager + N storage nodes on
//! loopback TCP, with an optional shared client-NIC shaper — the paper's
//! 22-node/1 Gbps testbed in one process.
//!
//! Control-plane v2: nodes register with the manager at spawn (join +
//! heartbeat), the manager owns placement via a
//! [`PlacementPolicy`](super::manager::PlacementPolicy) derived from
//! [`ClusterConfig::replication`], and clients bootstrap from the
//! manager address alone.  Control-plane v3: the manager's lease
//! timeout comes from [`ClusterConfig::lease_timeout`].

use std::sync::Arc;

use super::manager::{policy_for, Manager};
use super::node::{NodeOpts, StorageNode};
use super::sai::Sai;
use crate::config::{ClientConfig, ClusterConfig};
use crate::hashgpu::HashEngine;
use crate::net::Shaper;
use crate::{Error, Result};

/// A running cluster.
pub struct Cluster {
    manager: Manager,
    nodes: Vec<StorageNode>,
    cfg: ClusterConfig,
}

impl Cluster {
    /// Spawn a manager and `cfg.nodes` storage nodes on ephemeral
    /// ports.  The nodes join the manager's registry; the manager
    /// places blocks with `cfg.replication` copies each.
    pub fn spawn(cfg: ClusterConfig) -> Result<Cluster> {
        if cfg.replication == 0 {
            return Err(Error::Config("replication must be >= 1".into()));
        }
        if cfg.replication > cfg.nodes {
            return Err(Error::Config(format!(
                "replication {} exceeds node count {}",
                cfg.replication, cfg.nodes
            )));
        }
        if cfg.lease_timeout.is_zero() {
            return Err(Error::Config("lease_timeout must be non-zero".into()));
        }
        let manager = Manager::spawn_with_opts(
            "127.0.0.1:0",
            policy_for(cfg.replication),
            cfg.lease_timeout,
            cfg.durability.clone(),
        )?;
        let nodes = (0..cfg.nodes)
            .map(|_| {
                StorageNode::spawn_opts(
                    "127.0.0.1:0",
                    NodeOpts {
                        manager: Some(manager.addr().to_string()),
                        // Each node gets its own NIC on the modeled
                        // fabric: replies (the read path) are paced at
                        // link speed just like the client's puts.
                        reply_shaper: cfg
                            .shape
                            .then(|| Arc::new(Shaper::from_bits_per_sec(cfg.link_bps))),
                        reply_latency: cfg.node_rtt,
                        ..NodeOpts::default()
                    },
                )
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Cluster {
            manager,
            nodes,
            cfg,
        })
    }

    /// Manager address (the client bootstrap address).
    pub fn manager_addr(&self) -> &str {
        self.manager.addr()
    }

    /// The manager itself (registry/refcount introspection in tests).
    pub fn manager(&self) -> &Manager {
        &self.manager
    }

    /// Kill the manager in place (see [`Manager::crash`]): in-memory
    /// state discarded, WAL handle released, address kept — only what
    /// the log and snapshots captured survives.
    pub fn crash_manager(&self) {
        self.manager.crash();
    }

    /// Respawn the crashed manager on the same address, recovering from
    /// the cluster's configured data dir (a no-op recovery when the
    /// cluster runs without durability).
    pub fn restart_manager(&self) -> Result<()> {
        self.manager.restart(
            policy_for(self.cfg.replication),
            self.cfg.lease_timeout,
            self.cfg.durability.clone(),
        )
    }

    /// Node addresses, by node id.
    pub fn node_addrs(&self) -> Vec<String> {
        self.nodes.iter().map(|n| n.addr().to_string()).collect()
    }

    /// The client-side NIC shaper implied by the cluster config
    /// (None if shaping is disabled).
    pub fn client_shaper(&self) -> Option<Arc<Shaper>> {
        self.cfg
            .shape
            .then(|| Arc::new(Shaper::from_bits_per_sec(self.cfg.link_bps)))
    }

    /// Connect a SAI client with the given config and engine (nodes are
    /// discovered through the manager).
    pub fn client(&self, cfg: ClientConfig, engine: Arc<dyn HashEngine>) -> Result<Sai> {
        Sai::connect(self.manager_addr(), cfg, engine, self.client_shaper())
    }

    /// Connect a SAI client whose engine is a handle onto the shared
    /// process-wide hash service (see [`crate::hashsvc`]).  The cluster
    /// config's batching knobs (`hash_batch` / `hash_linger_us` /
    /// `hash_devices`) are stamped onto the client config first, so
    /// every client of this cluster coalesces into the same service.
    pub fn service_client(&self, cfg: ClientConfig) -> Result<Sai> {
        let mut cfg = cfg;
        cfg.hash_batch = self.cfg.hash_batch;
        cfg.hash_linger_us = self.cfg.hash_linger_us;
        cfg.hash_devices = self.cfg.hash_devices;
        let engine = crate::hashsvc::session_engine(&cfg, None)?;
        self.client(cfg, engine)
    }

    /// Kill one storage node (failure injection for tests): stops its
    /// accept loop, its heartbeats, and severs existing connections.
    pub fn kill_node(&mut self, idx: usize) {
        if idx < self.nodes.len() {
            self.nodes[idx].shutdown();
        }
    }

    /// Total (blocks, bytes) across storage nodes, counting each
    /// replica copy.
    pub fn storage_stats(&self) -> (u64, u64) {
        let mut blocks = 0;
        let mut bytes = 0;
        for (b, by) in self.per_node_stats() {
            blocks += b;
            bytes += by;
        }
        (blocks, bytes)
    }

    /// Per-node (blocks, bytes), by node id.
    pub fn per_node_stats(&self) -> Vec<(u64, u64)> {
        use super::proto::Msg;
        self.nodes
            .iter()
            .map(|n| match n.state().handle(Msg::NodeStats) {
                Msg::Stats { blocks, bytes } => (blocks, bytes),
                _ => (0, 0),
            })
            .collect()
    }
}
