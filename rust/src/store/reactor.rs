//! Event-driven serve loop (PR 9): a hand-rolled readiness reactor that
//! multiplexes thousands of connections over a handful of threads.
//!
//! The pre-PR-9 node and manager spent 2+ OS threads per connection
//! (reader + delayed-reply writer), which dies at tens of sessions —
//! fatal for the north star's "millions of users".  GNStor (PAPERS.md)
//! is the exemplar: a remote array serving many initiators at line rate
//! from a small number of event-driven cores.  This module is the
//! zero-dependency equivalent: nonblocking std TCP plus a `poll(2)`
//! readiness loop (declared directly against libc, which std already
//! links) driving a fixed worker pool.
//!
//! Architecture:
//!
//! - One **poll thread** owns every socket.  It accepts, reads, parses
//!   length-prefixed frames into each connection's `pending` queue, and
//!   flushes each connection's `outbox` back to the wire — honoring
//!   per-reply due times (the modeled fabric RTT delay line) and the
//!   optional bandwidth [`Shaper`] without ever parking.
//! - A fixed pool of **workers**, partitioned into *lanes*, pops ready
//!   connections and runs the protocol handler.  A connection is
//!   *claimed* by at most one worker at a time and its frames are
//!   served FIFO, so replies stay in request order — the pipelined
//!   duplex client's ordering contract is preserved.
//! - A **wake pipe** lets workers and `shutdown` interrupt `poll`
//!   directly: no more self-connect "poke" connections to unblock a
//!   blocking accept loop.
//!
//! Lanes exist for the manager: a consensus leader's mutation handler
//! blocks on remote quorum acks while a follower's `Replicate` handler
//! may block fetching the leader's snapshot — if those shared one pool
//! with the snapshot-serving reads, two mutually-replicating managers
//! could deadlock.  Handlers that never block remotely get their own
//! lane, breaking the cycle ([`FrameHandler::lane`]).

use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::proto::MAX_FRAME;
use crate::metrics::ServeGauges;
use crate::net::{Listener, Shaper};
use crate::Result;

/// Shaping granularity, matching [`crate::net::Conn`]: tokens are
/// claimed per segment so large replies smear over time.
const SEG: usize = 64 * 1024;

/// Raw libc declarations (std links libc; declaring the three syscall
/// wrappers we need keeps the zero-dependency constraint).
#[allow(non_camel_case_types)]
mod sys {
    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct pollfd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    extern "C" {
        pub fn poll(fds: *mut pollfd, nfds: u64, timeout: i32) -> i32;
        pub fn pipe(fds: *mut i32) -> i32;
        pub fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        pub fn write(fd: i32, buf: *const u8, count: usize) -> isize;
        pub fn close(fd: i32) -> i32;
    }
}

/// Self-pipe used to interrupt `poll(2)` from workers and `shutdown`.
struct WakePipe {
    r: i32,
    w: i32,
}

impl WakePipe {
    fn new() -> Result<WakePipe> {
        let mut fds = [0i32; 2];
        if unsafe { sys::pipe(fds.as_mut_ptr()) } != 0 {
            return Err(crate::Error::Other("pipe() failed".into()));
        }
        Ok(WakePipe {
            r: fds[0],
            w: fds[1],
        })
    }

    /// Make the next (or current) `poll` call return immediately.
    fn wake(&self) {
        let b = [1u8];
        unsafe { sys::write(self.w, b.as_ptr(), 1) };
    }

    /// Swallow queued wake bytes.  Called only when the read end polled
    /// readable; reads once, so it never blocks (leftovers just make
    /// the next poll return immediately, which is harmless).
    fn drain(&self) {
        let mut b = [0u8; 256];
        unsafe { sys::read(self.r, b.as_mut_ptr(), b.len()) };
    }
}

impl Drop for WakePipe {
    fn drop(&mut self) {
        unsafe {
            sys::close(self.r);
            sys::close(self.w);
        }
    }
}

/// Protocol glue between the reactor and a node/manager: one call per
/// complete request frame.
pub trait FrameHandler: Send + Sync + 'static {
    /// Handle one request frame (already stripped of its length prefix)
    /// and append any replies.
    fn on_frame(&self, tag: u8, body: Vec<u8>, replies: &mut Replies);

    /// Number of worker lanes this handler wants (default 1).
    fn lanes(&self) -> usize {
        1
    }

    /// Which lane serves a connection whose next pending frame has
    /// `tag`.  Handlers that can block on *remote* calls must keep
    /// never-blocking tags in a separate lane (see module docs).
    fn lane(&self, _tag: u8) -> usize {
        0
    }
}

/// Reply sink handed to [`FrameHandler::on_frame`].  Replies inherit
/// the frame's arrival time plus the configured reply latency as their
/// *due* time — the same delay line the threaded node used, letting
/// pipelined requests overlap their modeled RTTs.
pub struct Replies {
    due: Instant,
    out: Vec<OutMsg>,
    close: bool,
}

impl Replies {
    /// Queue one encoded reply frame.
    pub fn frame(&mut self, frame: Vec<u8>) {
        self.out.push(OutMsg {
            due: self.due,
            header: frame,
            body: None,
        });
    }

    /// Queue a header + shared payload (the copy-free `Data` reply
    /// path: the block's `Arc` is sliced straight onto the wire).
    pub fn frame_with_body(&mut self, header: Vec<u8>, body: Arc<Vec<u8>>) {
        self.out.push(OutMsg {
            due: self.due,
            header,
            body: Some(body),
        });
    }

    /// Sever this connection immediately (protocol error, or a crashed
    /// manager slot suppressing its reply).  Queued replies are
    /// discarded, mirroring a killed thread-per-connection handler.
    pub fn sever(&mut self) {
        self.close = true;
    }
}

/// One queued reply: an owned header (usually the whole frame) plus an
/// optional shared payload.
struct OutMsg {
    due: Instant,
    header: Vec<u8>,
    body: Option<Arc<Vec<u8>>>,
}

impl OutMsg {
    fn total(&self) -> usize {
        self.header.len() + self.body.as_ref().map_or(0, |b| b.len())
    }

    /// Up to `max` contiguous unwritten bytes starting at `off`.
    fn chunk(&self, off: usize, max: usize) -> &[u8] {
        let h = self.header.len();
        if off < h {
            &self.header[off..h.min(off + max)]
        } else {
            let b = self.body.as_ref().map_or(&[][..], |b| &b[..]);
            let boff = off - h;
            &b[boff..b.len().min(boff + max)]
        }
    }
}

/// Connection state shared between the poll thread (producer of
/// `pending`, consumer of `outbox`) and workers (the reverse).
struct ConnShared {
    /// Complete request frames awaiting a worker: (arrival, tag, body).
    pending: Mutex<VecDeque<(Instant, u8, Vec<u8>)>>,
    /// True while some worker owns this connection's frames.  At most
    /// one claimant at a time keeps replies in request order.
    claimed: AtomicBool,
    /// Replies awaiting the wire.
    outbox: Mutex<VecDeque<OutMsg>>,
    /// Worker asked for an immediate sever.
    sever: AtomicBool,
}

impl ConnShared {
    fn new() -> ConnShared {
        ConnShared {
            pending: Mutex::new(VecDeque::new()),
            claimed: AtomicBool::new(false),
            outbox: Mutex::new(VecDeque::new()),
            sever: AtomicBool::new(false),
        }
    }
}

/// One worker lane: a FIFO of claimed-and-ready connections.
#[derive(Default)]
struct Lane {
    q: Mutex<VecDeque<Arc<ConnShared>>>,
    cv: Condvar,
}

/// Everything the poll thread and workers share.
struct Core {
    handler: Arc<dyn FrameHandler>,
    lanes: Vec<Lane>,
    stop: AtomicBool,
    wake: WakePipe,
    gauges: Arc<ServeGauges>,
    reply_latency: Duration,
    shaper: Option<Arc<Shaper>>,
}

impl Core {
    /// Hand `conn` to a worker lane if it has pending frames and nobody
    /// owns it yet.  Called by the poll thread after parsing frames and
    /// by workers after releasing a claim (the release/recheck pair
    /// guarantees no frame is stranded unclaimed).
    fn dispatch(&self, conn: &Arc<ConnShared>) {
        loop {
            if conn.claimed.swap(true, Ordering::AcqRel) {
                // The current owner re-checks `pending` after releasing.
                return;
            }
            let tag = conn.pending.lock().unwrap().front().map(|(_, t, _)| *t);
            match tag {
                Some(tag) => {
                    let lane = self.handler.lane(tag).min(self.lanes.len() - 1);
                    self.gauges.ready_depth.fetch_add(1, Ordering::Relaxed);
                    let l = &self.lanes[lane];
                    l.q.lock().unwrap().push_back(conn.clone());
                    l.cv.notify_one();
                    return;
                }
                None => {
                    conn.claimed.store(false, Ordering::Release);
                    if conn.pending.lock().unwrap().is_empty() {
                        return;
                    }
                    // A frame landed between the check and the release;
                    // retry so it cannot be stranded.
                }
            }
        }
    }
}

/// Worker body: serve claimed connections' frames FIFO until shutdown.
fn worker_loop(core: Arc<Core>, lane_idx: usize) {
    let lane = &core.lanes[lane_idx];
    loop {
        let conn = {
            let mut q = lane.q.lock().unwrap();
            loop {
                if let Some(c) = q.pop_front() {
                    break c;
                }
                if core.stop.load(Ordering::Acquire) {
                    return;
                }
                q = lane.cv.wait(q).unwrap();
            }
        };
        core.gauges.ready_depth.fetch_sub(1, Ordering::Relaxed);
        core.gauges.workers_busy.fetch_add(1, Ordering::Relaxed);
        let mut served = 0u64;
        loop {
            let item = conn.pending.lock().unwrap().pop_front();
            let Some((arrived, tag, body)) = item else {
                break;
            };
            let mut replies = Replies {
                due: arrived + core.reply_latency,
                out: Vec::new(),
                close: false,
            };
            core.handler.on_frame(tag, body, &mut replies);
            if !replies.out.is_empty() {
                conn.outbox.lock().unwrap().extend(replies.out);
            }
            served += 1;
            if replies.close {
                conn.sever.store(true, Ordering::Release);
                break;
            }
        }
        conn.claimed.store(false, Ordering::Release);
        if served > 0 || conn.sever.load(Ordering::Acquire) {
            core.gauges.frames_served.fetch_add(served, Ordering::Relaxed);
            core.wake.wake();
        }
        if !conn.pending.lock().unwrap().is_empty() {
            core.dispatch(&conn);
        }
        core.gauges.workers_busy.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Poll-thread-private per-connection state.
struct PollConn {
    stream: TcpStream,
    shared: Arc<ConnShared>,
    /// Partial inbound frame bytes.
    inbuf: Vec<u8>,
    /// Client half-closed its write side; serve what's queued, flush,
    /// then close (the duplex client's graceful-teardown contract).
    eof: bool,
    /// Bytes of the front outbox message already written.
    woff: usize,
    /// Shaper-reserved bytes not yet written (carried across
    /// `WouldBlock` so tokens are never double-claimed).
    reserved: usize,
    /// Earliest instant the reserved segment may hit the wire.
    gate: Instant,
    /// Socket returned `WouldBlock`; wait for `POLLOUT`.
    want_pollout: bool,
    /// Read/write error; reap on the next sweep.
    dead: bool,
}

enum Flush {
    /// Outbox empty.
    Idle,
    /// More to write, but not before this instant (due time or shaper
    /// gate) — becomes the poll timeout.
    WaitUntil(Instant),
    /// Socket buffer full; `POLLOUT` registered.
    Blocked,
    /// Connection broke.
    Dead,
}

/// Write as much of the outbox as due times, the shaper and the socket
/// allow.  Runs on the poll thread only.
fn flush_conn(pc: &mut PollConn, shaper: &Option<Arc<Shaper>>) -> Flush {
    if pc.want_pollout {
        return Flush::Blocked;
    }
    let mut ob = pc.shared.outbox.lock().unwrap();
    loop {
        let Some(front) = ob.front() else {
            return Flush::Idle;
        };
        let now = Instant::now();
        if front.due > now {
            return Flush::WaitUntil(front.due);
        }
        let total = front.total();
        if pc.reserved == 0 {
            let seg = (total - pc.woff).min(SEG);
            if seg == 0 {
                ob.pop_front();
                pc.woff = 0;
                continue;
            }
            pc.gate = match shaper {
                Some(sh) => now + sh.reserve(seg as u64),
                None => now,
            };
            pc.reserved = seg;
        }
        if pc.gate > now {
            return Flush::WaitUntil(pc.gate);
        }
        let chunk = front.chunk(pc.woff, pc.reserved);
        match pc.stream.write(chunk) {
            Ok(0) => return Flush::Dead,
            Ok(n) => {
                pc.woff += n;
                pc.reserved -= n;
                if pc.woff == total {
                    ob.pop_front();
                    pc.woff = 0;
                    pc.reserved = 0;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                pc.want_pollout = true;
                return Flush::Blocked;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return Flush::Dead,
        }
    }
}

/// Drain readable bytes, parse complete frames into `pending`, and
/// dispatch.  Runs on the poll thread only.
fn read_conn(pc: &mut PollConn, buf: &mut [u8], core: &Core) {
    loop {
        match pc.stream.read(buf) {
            Ok(0) => {
                pc.eof = true;
                break;
            }
            Ok(n) => {
                pc.inbuf.extend_from_slice(&buf[..n]);
                if n < buf.len() {
                    break;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                pc.dead = true;
                return;
            }
        }
    }
    let now = Instant::now();
    let mut consumed = 0;
    let mut pushed = false;
    loop {
        let rem = &pc.inbuf[consumed..];
        if rem.len() < 4 {
            break;
        }
        let len = u32::from_le_bytes([rem[0], rem[1], rem[2], rem[3]]) as usize;
        if len == 0 || len > MAX_FRAME + 1 {
            pc.dead = true; // framing violation: sever, like read_from
            break;
        }
        if rem.len() < 4 + len {
            break;
        }
        let tag = rem[4];
        let body = rem[5..4 + len].to_vec();
        pc.shared
            .pending
            .lock()
            .unwrap()
            .push_back((now, tag, body));
        pushed = true;
        consumed += 4 + len;
    }
    if consumed > 0 {
        pc.inbuf.drain(..consumed);
    }
    if pushed {
        core.dispatch(&pc.shared);
    }
}

/// The poll thread: accept, read, flush, sleep until the next due time.
fn poll_loop(listener: TcpListener, core: Arc<Core>) {
    let _ = listener.set_nonblocking(true);
    let mut conns: HashMap<u64, PollConn> = HashMap::new();
    let mut next_id: u64 = 1;
    let mut read_buf = vec![0u8; 256 * 1024];
    let mut pfds: Vec<sys::pollfd> = Vec::new();
    let mut slot_ids: Vec<u64> = Vec::new();
    loop {
        if core.stop.load(Ordering::Acquire) {
            break;
        }
        // Flush, then reap connections that are finished: severed,
        // broken, or gracefully done (client EOF + everything served).
        let mut next_wake: Option<Instant> = None;
        for pc in conns.values_mut() {
            if pc.dead || pc.shared.sever.load(Ordering::Acquire) {
                continue;
            }
            match flush_conn(pc, &core.shaper) {
                Flush::Idle | Flush::Blocked => {}
                Flush::WaitUntil(t) => {
                    next_wake = Some(next_wake.map_or(t, |w: Instant| w.min(t)));
                }
                Flush::Dead => pc.dead = true,
            }
        }
        conns.retain(|_, pc| {
            let done = pc.dead
                || pc.shared.sever.load(Ordering::Acquire)
                || (pc.eof
                    && !pc.shared.claimed.load(Ordering::Acquire)
                    && pc.shared.pending.lock().unwrap().is_empty()
                    && pc.shared.outbox.lock().unwrap().is_empty());
            if done {
                core.gauges.open_conns.fetch_sub(1, Ordering::Relaxed);
            }
            !done
        });
        // Build the poll set: listener, wake pipe, then live sockets.
        pfds.clear();
        slot_ids.clear();
        pfds.push(sys::pollfd {
            fd: listener.as_raw_fd(),
            events: sys::POLLIN,
            revents: 0,
        });
        pfds.push(sys::pollfd {
            fd: core.wake.r,
            events: sys::POLLIN,
            revents: 0,
        });
        for (&id, pc) in conns.iter() {
            let mut ev = 0i16;
            if !pc.eof {
                ev |= sys::POLLIN;
            }
            if pc.want_pollout {
                ev |= sys::POLLOUT;
            }
            pfds.push(sys::pollfd {
                fd: pc.stream.as_raw_fd(),
                events: ev,
                revents: 0,
            });
            slot_ids.push(id);
        }
        let timeout = match next_wake {
            // +1 ms so sub-millisecond remainders don't busy-spin.
            Some(t) => (t.saturating_duration_since(Instant::now()).as_millis() as i64 + 1)
                .min(60_000) as i32,
            None => -1,
        };
        let n = unsafe { sys::poll(pfds.as_mut_ptr(), pfds.len() as u64, timeout) };
        if n < 0 {
            continue; // EINTR
        }
        if core.stop.load(Ordering::Acquire) {
            break;
        }
        if pfds[1].revents & sys::POLLIN != 0 {
            core.wake.drain();
        }
        if pfds[0].revents & sys::POLLIN != 0 {
            loop {
                match listener.accept() {
                    Ok((s, _)) => {
                        let _ = s.set_nonblocking(true);
                        let _ = s.set_nodelay(true);
                        let id = next_id;
                        next_id += 1;
                        conns.insert(
                            id,
                            PollConn {
                                stream: s,
                                shared: Arc::new(ConnShared::new()),
                                inbuf: Vec::new(),
                                eof: false,
                                woff: 0,
                                reserved: 0,
                                gate: Instant::now(),
                                want_pollout: false,
                                dead: false,
                            },
                        );
                        core.gauges.open_conns.fetch_add(1, Ordering::Relaxed);
                        core.gauges.accepted.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => break,
                }
            }
        }
        for (slot, &id) in slot_ids.iter().enumerate() {
            let re = pfds[slot + 2].revents;
            if re == 0 {
                continue;
            }
            let Some(pc) = conns.get_mut(&id) else {
                continue;
            };
            if re & sys::POLLOUT != 0 {
                pc.want_pollout = false;
            }
            if re & (sys::POLLIN | sys::POLLHUP | sys::POLLERR) != 0 && !pc.eof {
                read_conn(pc, &mut read_buf, &core);
            }
            if re & sys::POLLNVAL != 0 {
                pc.dead = true;
            }
        }
    }
    // Shutdown: dropping the listener and the sockets severs everything
    // (kill_node / crash semantics; racing clients see a clean error).
    drop(conns);
    drop(listener);
}

/// Worker-pool sizing for a reactor.
#[derive(Debug, Clone)]
pub struct ReactorOpts {
    /// Thread-name prefix (truncated to 15 bytes by the kernel; tests
    /// count live threads by this prefix).
    pub name: String,
    /// Workers per lane; missing entries default to 2, zero entries are
    /// clamped to 1.
    pub workers: Vec<usize>,
    /// Due-time delay applied to every reply (the modeled fabric RTT).
    pub reply_latency: Duration,
    /// Optional bandwidth shaper pacing reply bytes.
    pub reply_shaper: Option<Arc<Shaper>>,
}

impl Default for ReactorOpts {
    fn default() -> Self {
        ReactorOpts {
            name: "serve".into(),
            workers: Vec::new(),
            reply_latency: Duration::ZERO,
            reply_shaper: None,
        }
    }
}

/// A running event loop: poll thread + worker pool bound to one
/// listener.  Dropping (or [`Reactor::shutdown`]) wakes the poll thread
/// through the pipe — no self-connect poke — and joins every thread.
pub struct Reactor {
    addr: String,
    core: Arc<Core>,
    poll: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Reactor {
    /// Serve `listener` with `handler` until shutdown.
    pub fn serve(
        listener: Listener,
        handler: Arc<dyn FrameHandler>,
        opts: ReactorOpts,
    ) -> Result<Reactor> {
        let addr = listener.local_addr()?;
        let listener = listener.into_std();
        let nlanes = handler.lanes().max(1);
        let lanes: Vec<Lane> = (0..nlanes).map(|_| Lane::default()).collect();
        let gauges = Arc::new(ServeGauges::default());
        let core = Arc::new(Core {
            handler,
            lanes,
            stop: AtomicBool::new(false),
            wake: WakePipe::new()?,
            gauges,
            reply_latency: opts.reply_latency,
            shaper: opts.reply_shaper,
        });
        let mut workers = Vec::new();
        for lane in 0..nlanes {
            let n = opts.workers.get(lane).copied().unwrap_or(2).max(1);
            for i in 0..n {
                let c = core.clone();
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("{}-w{}{}", opts.name, lane, i))
                        .spawn(move || worker_loop(c, lane))?,
                );
            }
        }
        core.gauges
            .workers_total
            .store(workers.len() as u64, Ordering::Relaxed);
        let c = core.clone();
        let poll = std::thread::Builder::new()
            .name(format!("{}-poll", opts.name))
            .spawn(move || poll_loop(listener, c))?;
        Ok(Reactor {
            addr,
            core,
            poll: Some(poll),
            workers,
        })
    }

    /// The bound address.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Live serve-loop gauges.
    pub fn gauges(&self) -> Arc<ServeGauges> {
        self.core.gauges.clone()
    }

    /// Stop serving: wakes the poll loop through the pipe (no poke
    /// connection), severs every connection, and joins all threads.
    /// Idempotent.
    pub fn shutdown(&mut self) {
        if self.core.stop.swap(true, Ordering::AcqRel) {
            return;
        }
        self.core.wake.wake();
        for l in &self.core.lanes {
            l.cv.notify_all();
        }
        if let Some(t) = self.poll.take() {
            let _ = t.join();
        }
        for t in self.workers.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Reactor {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::TcpStream;

    /// Echo handler: replies with the request frame verbatim; tag 99
    /// requests a sever.
    struct Echo;

    impl FrameHandler for Echo {
        fn on_frame(&self, tag: u8, body: Vec<u8>, replies: &mut Replies) {
            if tag == 99 {
                replies.sever();
                return;
            }
            replies.frame(frame(tag, &body));
        }
    }

    fn frame(tag: u8, body: &[u8]) -> Vec<u8> {
        let mut f = Vec::with_capacity(5 + body.len());
        f.extend_from_slice(&(body.len() as u32 + 1).to_le_bytes());
        f.push(tag);
        f.extend_from_slice(body);
        f
    }

    fn read_frame(s: &mut TcpStream) -> (u8, Vec<u8>) {
        let mut len = [0u8; 4];
        s.read_exact(&mut len).unwrap();
        let len = u32::from_le_bytes(len) as usize;
        let mut p = vec![0u8; len];
        s.read_exact(&mut p).unwrap();
        (p[0], p[1..].to_vec())
    }

    fn spawn_echo(name: &str) -> Reactor {
        Reactor::serve(
            Listener::bind("127.0.0.1:0").unwrap(),
            Arc::new(Echo),
            ReactorOpts {
                name: name.into(),
                ..Default::default()
            },
        )
        .unwrap()
    }

    fn threads_with_prefix(prefix: &str) -> usize {
        std::fs::read_dir("/proc/self/task")
            .unwrap()
            .flatten()
            .filter(|e| {
                std::fs::read_to_string(e.path().join("comm"))
                    .map(|n| n.trim_end().starts_with(prefix))
                    .unwrap_or(false)
            })
            .count()
    }

    #[test]
    fn echo_roundtrip() {
        let mut r = spawn_echo("rx-echo");
        let mut s = TcpStream::connect(r.addr()).unwrap();
        s.write_all(&frame(7, b"hello")).unwrap();
        let (tag, body) = read_frame(&mut s);
        assert_eq!(tag, 7);
        assert_eq!(body, b"hello");
        r.shutdown();
    }

    #[test]
    fn slow_reader_partial_frames_reassemble() {
        let mut r = spawn_echo("rx-slow");
        let mut s = TcpStream::connect(r.addr()).unwrap();
        // One frame dribbled in three writes across poll wakeups...
        let f = frame(3, &[9u8; 300]);
        s.write_all(&f[..2]).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        s.write_all(&f[2..7]).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        s.write_all(&f[7..]).unwrap();
        // ...then two more pipelined in a single write.
        let mut two = frame(4, b"a");
        two.extend_from_slice(&frame(5, b"b"));
        s.write_all(&two).unwrap();
        let (t1, b1) = read_frame(&mut s);
        assert_eq!((t1, b1.len()), (3, 300));
        let (t2, _) = read_frame(&mut s);
        let (t3, _) = read_frame(&mut s);
        assert_eq!((t2, t3), (4, 5), "replies must keep request order");
        r.shutdown();
    }

    #[test]
    fn half_close_still_gets_replies() {
        let mut r = spawn_echo("rx-eof");
        let mut s = TcpStream::connect(r.addr()).unwrap();
        for i in 0..3u8 {
            s.write_all(&frame(10 + i, &[i])).unwrap();
        }
        s.shutdown(std::net::Shutdown::Write).unwrap();
        for i in 0..3u8 {
            let (tag, _) = read_frame(&mut s);
            assert_eq!(tag, 10 + i);
        }
        // Server closes after flushing: clean EOF, not a reset.
        let mut rest = Vec::new();
        assert_eq!(s.read_to_end(&mut rest).unwrap(), 0);
        r.shutdown();
    }

    #[test]
    fn sever_drops_connection() {
        let mut r = spawn_echo("rx-sever");
        let mut s = TcpStream::connect(r.addr()).unwrap();
        s.write_all(&frame(99, b"")).unwrap();
        let mut rest = Vec::new();
        let _ = s.read_to_end(&mut rest); // EOF or reset, never a reply
        assert!(rest.is_empty());
        r.shutdown();
    }

    #[test]
    fn bad_frame_length_severs() {
        let mut r = spawn_echo("rx-bad");
        let mut s = TcpStream::connect(r.addr()).unwrap();
        s.write_all(&0u32.to_le_bytes()).unwrap(); // len 0: invalid
        let mut rest = Vec::new();
        let _ = s.read_to_end(&mut rest);
        assert!(rest.is_empty());
        r.shutdown();
    }

    #[test]
    fn connection_storm_all_served() {
        let mut r = spawn_echo("rx-storm");
        let n = 1000usize;
        let mut socks = Vec::with_capacity(n);
        for i in 0..n {
            let mut s = TcpStream::connect(r.addr()).unwrap();
            s.write_all(&frame(1, &(i as u32).to_le_bytes())).unwrap();
            socks.push(s);
        }
        for (i, s) in socks.iter_mut().enumerate() {
            let (tag, body) = read_frame(s);
            assert_eq!(tag, 1);
            assert_eq!(u32::from_le_bytes(body.try_into().unwrap()), i as u32);
        }
        let g = r.gauges().snapshot();
        assert_eq!(g.accepted, n as u64);
        assert_eq!(g.frames_served, n as u64);
        assert_eq!(g.open_conns, n as u64);
        assert!(g.workers_total >= 1);
        r.shutdown();
    }

    #[test]
    fn shutdown_joins_every_thread_without_poke() {
        assert_eq!(threads_with_prefix("rx-leak"), 0);
        let mut r = spawn_echo("rx-leak");
        assert!(threads_with_prefix("rx-leak") >= 2, "poll + workers live");
        // Parked, idle poll loop: shutdown must wake it via the pipe
        // (no connection is ever made here) and join everything.
        r.shutdown();
        assert_eq!(threads_with_prefix("rx-leak"), 0, "leaked serve threads");
        r.shutdown(); // idempotent
    }

    #[test]
    fn reply_latency_is_a_delay_line() {
        let mut r = Reactor::serve(
            Listener::bind("127.0.0.1:0").unwrap(),
            Arc::new(Echo),
            ReactorOpts {
                name: "rx-delay".into(),
                reply_latency: Duration::from_millis(40),
                ..Default::default()
            },
        )
        .unwrap();
        let mut s = TcpStream::connect(r.addr()).unwrap();
        let t0 = Instant::now();
        for i in 0..8u8 {
            s.write_all(&frame(2, &[i])).unwrap();
        }
        for _ in 0..8 {
            read_frame(&mut s);
        }
        let dt = t0.elapsed();
        // Pipelined requests overlap their latencies: ~1 RTT total, not 8.
        assert!(dt >= Duration::from_millis(35), "delay not applied: {dt:?}");
        assert!(dt < Duration::from_millis(320), "delays serialized: {dt:?}");
        r.shutdown();
    }

    #[test]
    fn shaped_replies_are_paced() {
        // 1 MB/s shaper, 200 KB of replies => ~0.2 s wall time floor
        // (minus the burst allowance).
        let mut r = Reactor::serve(
            Listener::bind("127.0.0.1:0").unwrap(),
            Arc::new(Echo),
            ReactorOpts {
                name: "rx-shape".into(),
                reply_shaper: Some(Arc::new(Shaper::new(1e6, 64.0 * 1024.0))),
                ..Default::default()
            },
        )
        .unwrap();
        let mut s = TcpStream::connect(r.addr()).unwrap();
        let body = vec![0u8; 100 * 1024];
        let t0 = Instant::now();
        s.write_all(&frame(6, &body)).unwrap();
        s.write_all(&frame(6, &body)).unwrap();
        read_frame(&mut s);
        read_frame(&mut s);
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt > 0.1, "shaper ignored: {dt}");
        r.shutdown();
    }

    #[test]
    fn lanes_route_by_tag() {
        struct Laned;
        impl FrameHandler for Laned {
            fn lanes(&self) -> usize {
                2
            }
            fn lane(&self, tag: u8) -> usize {
                usize::from(tag >= 128)
            }
            fn on_frame(&self, tag: u8, _body: Vec<u8>, replies: &mut Replies) {
                if tag < 128 {
                    // Lane 0 stalls; lane 1 must still make progress.
                    std::thread::sleep(Duration::from_millis(80));
                }
                replies.frame(frame(tag, b""));
            }
        }
        let mut r = Reactor::serve(
            Listener::bind("127.0.0.1:0").unwrap(),
            Arc::new(Laned),
            ReactorOpts {
                name: "rx-lane".into(),
                workers: vec![1, 1],
                ..Default::default()
            },
        )
        .unwrap();
        let mut slow = TcpStream::connect(r.addr()).unwrap();
        slow.write_all(&frame(1, b"")).unwrap();
        let mut fast = TcpStream::connect(r.addr()).unwrap();
        let t0 = Instant::now();
        fast.write_all(&frame(200, b"")).unwrap();
        let (tag, _) = read_frame(&mut fast);
        assert_eq!(tag, 200);
        assert!(
            t0.elapsed() < Duration::from_millis(60),
            "fast lane stuck behind slow lane"
        );
        read_frame(&mut slow);
        r.shutdown();
    }
}
