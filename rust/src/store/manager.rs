//! The centralized metadata manager (paper §3.2.1), control-plane v3:
//! besides per-file block-maps and versions it owns *placement* —
//! clients ask where blocks go ([`Msg::AllocPlacement`]) and a pluggable
//! [`PlacementPolicy`] answers with an n-way replica set — plus a node
//! registry fed by [`Msg::NodeJoin`]/[`Msg::Heartbeat`], per-block
//! reference counting across file versions, commit-time garbage
//! collection (blocks orphaned by a version overwrite are deleted from
//! their owning nodes), and *leases*: read leases pin an opened
//! version's blocks so GC defers their deletion until the last lease
//! drops, and writer claim leases expire when the owning client stops
//! heartbeating, returning an abandoned session's pending claims to the
//! GC pool.  Lease expiry shares the manager's liveness clock, which a
//! test-only hook ([`ManagerState::advance_clock`]) can advance so
//! every expiry path is testable without wall-clock sleeps.
//!
//! **Serve architecture (PR 9).**  By default the manager serves
//! through the event-driven reactor ([`super::reactor`]): one poll
//! thread owns every socket and three worker lanes run the handlers —
//! client mutations (may block on the quorum barrier), peer consensus
//! RPCs (may block re-bootstrapping from a leader's snapshot) and
//! never-remotely-blocking reads (snapshot/WAL fetch, heartbeats, node
//! listings).  Separating those lanes is what makes two
//! mutually-replicating managers deadlock-free: the read lane that
//! serves a peer's re-bootstrap never itself waits on a remote call.
//! The legacy thread-per-connection path is retained behind
//! [`crate::config::ServeMode::Thread`] as the benchmark baseline.
//! The block and lease tables are hash-prefix-sharded
//! ([`super::shard::ShardedMap`]) so reads, stats and the apply side
//! only contend per shard; mutations are still planned and logged under
//! the (much smaller) `Inner` lock, keeping the WAL a single total
//! order.
//!
//! **Durable control plane.**  With a [`DurabilityOpts`] attached,
//! every state mutation is planned (validated + decided) under the
//! lock, serialized as a typed [`Record`], appended to the write-ahead
//! log, and only then applied — through `ManagerState::apply`, the
//! single mutation path that live execution, crash-recovery replay
//! ([`ManagerState::with_durability`]) and log-shipping followers
//! ([`Follower`]) all share.  Append-before-mutate means an append
//! failure surfaces as a logical error with the state untouched; a
//! crash after the append but before the reply leaves a durable but
//! unacknowledged mutation — exactly what a real crash gives a client.
//! Recovery resumes lease clocks conservatively (full TTL: surviving
//! writers revalidate on their next renewal, abandoned ones lapse one
//! window after restart) and re-learns node liveness through the
//! existing heartbeat re-join path.  Volatile facts (heartbeats,
//! re-joins of known addresses, the placement cursor) are never
//! logged; `Alloc` records carry their decided replica sets instead.
//!
//! **Quorum replication (control-plane v5).**  With
//! [`ManagerState::set_consensus`] a manager joins a quorum group:
//! exactly one leader per term accepts mutations, pushes every appended
//! record to its peers ([`Msg::Replicate`]) and replies to the client
//! only once a quorum holds the records durably; peers answer client
//! calls with [`Msg::NotLeader`] redirects.  Elections
//! ([`Msg::RequestVote`]) follow Raft's rules — persisted term + vote,
//! `(last_term, last_lsn)` log up-to-dateness, majority to win — and a
//! peer that accepts appends from a *new* leader first re-bootstraps
//! wholesale from that leader's snapshot, discarding any uncommitted
//! divergent tail (the shipped-snapshot equivalent of Raft's log
//! truncation).  All timers run on the manager's skewable test clock
//! and fire only inside [`ManagerState::tick_consensus`], so every
//! election schedule is deterministic under test; the CLI runs a small
//! ticker thread ([`Manager::start_ticker`]) instead.

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::io::{BufReader, BufWriter};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::proto::{Assignment, BlockMeta, BlockSpec, Msg, NodeEntry, WalEntry, MAX_REPLICAS};
use super::reactor::{FrameHandler, Reactor, ReactorOpts, Replies};
use super::shard::ShardedMap;
use crate::config::ServeMode;
use crate::hash::Digest;
use crate::metrics::ServeGauges;
use crate::net::{Conn, Listener};
use crate::wal::{self, DurabilityOpts, Record, SnapBlock, SnapLease, SnapshotState, Wal};
use crate::{Error, Result};

/// How a placement policy chooses nodes for a new block.
///
/// Policies are deliberately tiny state machines: the manager hands them
/// the current *alive* node ids (sorted) and they answer with a replica
/// set, one call per fresh block, in request order.  This is the plug
/// point CrystalGPU used for GPU task scheduling and GNStor for
/// recovery placement — new policies (locality-, load- or
/// capacity-aware) implement this trait and slot into
/// [`Manager::spawn_with_policy`].
pub trait PlacementPolicy: Send + std::fmt::Debug {
    /// Human-readable policy name (surfaced in logs/CLI).
    fn name(&self) -> &'static str;
    /// Target replication factor (what the policy aims for when enough
    /// nodes are alive).
    fn replication(&self) -> usize;
    /// Choose the replica set for one new block.  `alive` is non-empty
    /// and sorted by node id.
    fn place(&mut self, alive: &[u32]) -> Vec<u32>;
    /// Erasure-coding descriptor `(k, m)` when this policy stores
    /// blocks as k data + m parity shards — position `i` of a placed
    /// replica set is then the home of shard `i`.  `None` (the
    /// default) means whole-block copies.
    fn ec(&self) -> Option<(u8, u8)> {
        None
    }
}

/// Today's behaviour as a policy: blocks round-robin across the alive
/// nodes, one copy each (replication = 1).
#[derive(Debug, Default)]
pub struct RoundRobinStripe {
    next: usize,
}

impl PlacementPolicy for RoundRobinStripe {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn replication(&self) -> usize {
        1
    }

    fn place(&mut self, alive: &[u32]) -> Vec<u32> {
        let id = alive[self.next % alive.len()];
        self.next = self.next.wrapping_add(1);
        vec![id]
    }
}

/// n-way replication over a rotating stripe: block `i` goes to `r`
/// consecutive alive nodes starting at the rotating cursor, so both the
/// primaries and the replica sets spread evenly.
#[derive(Debug)]
pub struct ReplicatedStripe {
    /// Target copies per block (clamped to the alive node count).
    pub replicas: usize,
    next: usize,
}

impl ReplicatedStripe {
    /// Policy with a target replication factor (clamped to
    /// `1..=MAX_REPLICAS`, the wire format's bound).
    pub fn new(replicas: usize) -> Self {
        ReplicatedStripe {
            replicas: replicas.clamp(1, MAX_REPLICAS),
            next: 0,
        }
    }
}

impl PlacementPolicy for ReplicatedStripe {
    fn name(&self) -> &'static str {
        "replicated-stripe"
    }

    fn replication(&self) -> usize {
        self.replicas
    }

    fn place(&mut self, alive: &[u32]) -> Vec<u32> {
        let r = self.replicas.min(alive.len()).max(1);
        let start = self.next;
        self.next = self.next.wrapping_add(1);
        (0..r).map(|k| alive[(start + k) % alive.len()]).collect()
    }
}

/// Erasure-coded striping: every block is split into `k` data + `m`
/// parity shards (GF(256) Reed–Solomon, [`crate::ec`]) and shard `i`
/// lands on position `i` of the replica set — the replica list IS the
/// shard order, which is why nothing downstream may reorder or shrink
/// it.  Tolerates any `m` shard losses at `(k+m)/k`× storage overhead
/// (vs. `(m+1)`× for replication at equal fault tolerance).
#[derive(Debug)]
pub struct ErasureCoded {
    /// Data shards per block.
    pub k: u8,
    /// Parity shards per block.
    pub m: u8,
    next: usize,
}

impl ErasureCoded {
    /// Policy splitting blocks into `k` data + `m` parity shards.
    /// Shard counts are validated loudly, not clamped — silently
    /// weakening a redundancy guarantee is worse than refusing to
    /// start: `k >= 1`, `m >= 1`, `k + m <= MAX_REPLICAS`.
    pub fn new(k: u8, m: u8) -> Result<ErasureCoded> {
        if k < 1 || m < 1 {
            return Err(Error::Manager(format!(
                "erasure coding needs k >= 1 data and m >= 1 parity shards (got {k},{m})"
            )));
        }
        if k as usize + m as usize > MAX_REPLICAS {
            return Err(Error::Manager(format!(
                "erasure coding k+m = {} exceeds the {MAX_REPLICAS}-home wire bound",
                k as usize + m as usize
            )));
        }
        Ok(ErasureCoded { k, m, next: 0 })
    }
}

impl PlacementPolicy for ErasureCoded {
    fn name(&self) -> &'static str {
        "erasure-coded"
    }

    fn replication(&self) -> usize {
        self.k as usize + self.m as usize
    }

    fn place(&mut self, alive: &[u32]) -> Vec<u32> {
        // k+m DISTINCT homes from the rotating cursor.  The planner
        // guarantees `alive.len() >= k + m` before calling (two shards
        // on one node would silently void the coding guarantee, so a
        // thin cluster fails the allocation loudly instead).
        let n = self.replication();
        let start = self.next;
        self.next = self.next.wrapping_add(1);
        (0..n).map(|i| alive[(start + i) % alive.len()]).collect()
    }

    fn ec(&self) -> Option<(u8, u8)> {
        Some((self.k, self.m))
    }
}

/// The policy implied by a replication factor: classic single-copy
/// round-robin striping for `r == 1`, n-way [`ReplicatedStripe`]
/// otherwise.  Single source of truth for every entry point (in-process
/// clusters, the manager CLI).
pub fn policy_for(replication: usize) -> Box<dyn PlacementPolicy> {
    if replication > 1 {
        Box::new(ReplicatedStripe::new(replication))
    } else {
        Box::new(RoundRobinStripe::default())
    }
}

#[derive(Debug, Default)]
struct FileEntry {
    version: u64,
    blocks: Vec<BlockMeta>,
}

/// Global (cross-file, cross-version) bookkeeping for one stored block.
#[derive(Debug)]
struct BlockInfo {
    /// Where the block lives (decided once, at first allocation).
    /// Under erasure coding, position `i` holds shard `i`.
    replicas: Vec<u32>,
    /// Erasure-coding descriptor `(k, m)` the block was STORED under
    /// (`None` = whole-block copies).  Recorded per block, not read
    /// from the current policy: mixed-policy histories and dedup must
    /// decode what is actually on the nodes.
    ec: Option<(u8, u8)>,
    /// Payload length (for stats / future rebalancing).
    len: u32,
    /// Occurrences in committed block-maps.
    refs: u64,
    /// Provisional claims: allocated by a writer that has not committed
    /// or released yet.  Blocks with `refs == 0 && pending == 0 &&
    /// pins == 0` are garbage and get deleted from their nodes.
    pending: u64,
    /// Read-lease pins: occurrences in version snapshots still being
    /// streamed by readers.  A pinned block survives losing its last
    /// committed reference; the delete is deferred until the last
    /// lease drops or lapses.
    pins: u64,
    /// While `refs == 0`, the claim tag of the session that first
    /// allocated the block (clients send a unique per-session token as
    /// `AllocPlacement.file`).  Dedup against a merely-pending block is
    /// only safe for that same session (a commit proves the bytes
    /// landed, a pending claim does not); everyone else transfers too.
    placed_by: String,
}

/// One granted lease: a read-session version pin or a write-session
/// claim holder.  Leases lapse when `expires_at` (on the manager's
/// clock) passes without a renewal; the expiry sweep runs lazily at the
/// top of every handled message.
#[derive(Debug)]
struct Lease {
    /// Read lease: the opened file.  Write lease: the session's claim
    /// token.  Carried through the log and snapshots so a recovered
    /// manager reproduces the lease table exactly.
    tag: String,
    /// Writer claim lease (releases `pending`) vs. read lease
    /// (releases `pins`).
    write: bool,
    /// Hash occurrences held: one entry per pinned block-map slot
    /// (read) or per allocated claim (write).  Occurrences, not unique
    /// hashes — a file of n identical blocks holds n entries.
    hashes: Vec<Digest>,
    /// Lapse deadline on the manager's clock.
    expires_at: Instant,
}

#[derive(Debug)]
struct NodeSlot {
    addr: String,
    last_beat: Instant,
}

/// The serialized core of the manager: everything that orders mutations
/// (the WAL, the ship buffer, placement, the file table).  The hot
/// block and lease tables moved out to [`ManagerState`]'s sharded maps
/// in PR 9 — mutators touch them while holding this lock (preserving
/// the single total order), but readers and stats no longer queue here.
#[derive(Debug)]
struct Inner {
    files: HashMap<String, FileEntry>,
    nodes: Vec<NodeSlot>,
    policy: Box<dyn PlacementPolicy>,
    /// Next lease id (ids start at 1; 0 means "no lease" on the wire).
    next_lease: u64,
    /// The write-ahead log, when this manager is durable (`None` = the
    /// pre-PR-7 in-memory mode; records still flow through
    /// [`ManagerState::apply`] and the ship buffer either way).
    wal: Option<Wal>,
    /// LSN of the last record logged/applied (0 = none yet).
    last_lsn: u64,
    /// Recent records retained in memory for log-shipping followers
    /// (`(lsn, encoded record)`, dense).  Bounded by [`SHIP_CAP`]; a
    /// follower further behind re-bootstraps from a snapshot.
    ship: VecDeque<(u64, Vec<u8>)>,
    /// CRC32 of each record's encoded bytes by lsn (recent window,
    /// bounded by [`CRC_LOG_CAP`], cleared on snapshot install).  The
    /// committed-prefix divergence property compares these across
    /// replicas: two nodes' entries must agree on every lsn both hold
    /// at or below their commit index.
    crc_log: BTreeMap<u64, u32>,
}

/// Consensus role of a manager in a quorum group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Accepts mutations and replicates them to peers.
    Leader,
    /// Applies shipped records; redirects clients to the leader.
    Follower,
    /// Mid-election: has voted for itself and is soliciting votes.
    Candidate,
}

/// Options wiring a manager into a quorum group
/// ([`ManagerState::set_consensus`]).
#[derive(Debug, Clone)]
pub struct ConsensusOpts {
    /// This manager's advertised address (what peers dial, what clients
    /// are redirected to, and the fault id the partition table keys on).
    pub self_addr: String,
    /// Peer manager addresses (excluding `self_addr`).  Quorum =
    /// majority of `peers.len() + 1`.
    pub peers: Vec<String>,
    /// Bootstrap convention: exactly one manager of a fresh group
    /// starts as the term-1 leader (with its vote durably cast for
    /// itself, so a same-term rival cannot also win).
    pub initial_leader: bool,
}

/// Per-manager consensus state, guarded separately from [`Inner`] so
/// peer RPCs never serialize behind block-table work.  Lock order:
/// `repl` before `inner` when nested; NEVER held across network calls.
#[derive(Debug)]
struct Repl {
    /// This manager's advertised address ("" = solo/unconfigured).
    self_addr: String,
    /// Peer manager addresses (empty = solo mode: every append is
    /// trivially committed, preserving single-manager behavior).
    peers: Vec<String>,
    role: Role,
    /// Current term (persisted via the WAL's term sidecar).
    term: u64,
    /// Who we voted for in `term` (persisted before any grant).
    voted_for: Option<String>,
    /// Term of the leader whose history this log currently follows —
    /// Raft's "term of the last log entry".  A [`Msg::Replicate`] at a
    /// *different* term forces a wholesale re-bootstrap from that
    /// leader before any append is accepted, which is what guarantees
    /// divergent uncommitted tails die on leader change.  Persisted
    /// (after the re-bootstrap, before the first ack at the new term).
    accepted_term: u64,
    /// Last known leader address (the [`Msg::NotLeader`] redirect).
    leader_hint: String,
    /// Highest lsn known replicated on a quorum.  Only records at or
    /// below this index count as *committed*.
    commit_lsn: u64,
    /// Last time we heard from a valid leader (or granted a vote), on
    /// the manager's skewable clock: the election timer's base.
    last_contact: Instant,
    /// Where the term sidecar lives (`None` = in-memory manager; terms
    /// and votes then do not survive a restart, which is safe only
    /// because such a state also loses its log and rejoins empty).
    term_dir: Option<PathBuf>,
}

impl Repl {
    fn solo() -> Repl {
        Repl {
            self_addr: String::new(),
            peers: Vec::new(),
            role: Role::Leader,
            term: 0,
            voted_for: None,
            accepted_term: 0,
            leader_hint: String::new(),
            commit_lsn: 0,
            last_contact: Instant::now(),
            term_dir: None,
        }
    }
}

/// Manager state shared across serve threads.
#[derive(Debug)]
pub struct ManagerState {
    inner: Mutex<Inner>,
    /// Global (cross-file, cross-version) block bookkeeping, sharded by
    /// digest prefix.  Mutated only while `inner` is held (WAL order);
    /// read lock-free by stats and validation.
    blocks: ShardedMap<Digest, BlockInfo>,
    /// Live leases by id, sharded by id (a monotone counter, so
    /// consecutive grants round-robin across shards).
    leases: ShardedMap<u64, Lease>,
    /// Quorum-replication state (solo defaults when not configured).
    repl: Mutex<Repl>,
    /// A node is considered alive if it joined or heartbeated within
    /// this window.
    heartbeat_timeout: Duration,
    /// A lease lapses if not renewed within this window.
    lease_timeout: Duration,
    /// Test-only time hook: an offset added to `Instant::now()` to form
    /// the manager's clock.  [`ManagerState::advance_clock`] bumps it so
    /// lease expiry (and node liveness) can be driven deterministically
    /// instead of with sleeps.
    clock_skew: Mutex<Duration>,
    /// Hashes whose on-node copies are being deleted by an in-flight GC
    /// batch.  Allocations of these hashes wait until the deletes have
    /// landed, so a stale `DeleteBlock` can never destroy a copy a
    /// client re-uploaded after re-allocating the hash.
    gc_inflight: Mutex<HashSet<Digest>>,
    gc_done: Condvar,
    /// Background scrub/repair configuration and last-run clock
    /// ([`ManagerState::set_scrub`]); the cadence reads the skewable
    /// clock, so tests drive scrub like lease expiry.
    scrub: Mutex<ScrubState>,
    /// Replica copies readers reported corrupt ([`Msg::ReportCorrupt`])
    /// or anti-entropy found missing: volatile repair hints, never
    /// logged — a restart merely loses the hint until a reader trips
    /// over the copy again.
    suspects: Mutex<HashSet<(Digest, u32)>>,
}

/// Scrub/repair loop knobs + clock (all behind one lock: they are read
/// together at the top of every tick).
#[derive(Debug, Clone, Copy)]
struct ScrubState {
    /// Pass cadence (ZERO = scrubbing disabled, the default).
    interval: Duration,
    /// Repair bandwidth budget in Mbit/s (`0.0` = unlimited): one pass
    /// moves at most `interval × repair_mbps` of payload, so repair
    /// traffic cannot starve foreground writes.
    repair_mbps: f64,
    /// When the last pass started, on the manager's skewable clock.
    last_run: Option<Instant>,
}

impl Default for ManagerState {
    fn default() -> Self {
        ManagerState::new(Box::new(RoundRobinStripe::default()))
    }
}

/// Default liveness window: generous relative to the nodes' ~250 ms
/// heartbeat interval, so a few dropped beats don't flap placement.
const HEARTBEAT_TIMEOUT: Duration = Duration::from_secs(3);

/// Default lease timeout: generous relative to the clients' `ttl / 3`
/// renewal cadence, so a few dropped renewals don't lapse a live
/// session, while an abandoned writer's claims return to the GC pool in
/// human time.  Overridable per deployment (`--lease-timeout`,
/// [`crate::config::ClusterConfig::lease_timeout`]).
pub const DEFAULT_LEASE_TIMEOUT: Duration = Duration::from_secs(30);

/// Floor for configured lease timeouts: zero (or near-zero) would
/// lapse every lease at its first expiry sweep, so
/// [`ManagerState::with_lease_timeout`] clamps up to this.
pub const MIN_LEASE_TIMEOUT: Duration = Duration::from_millis(1);

/// Upper bound on how long an allocation waits for an in-flight GC
/// batch covering one of its hashes (best effort beyond that).
const GC_WAIT: Duration = Duration::from_secs(2);

/// How many recent records the manager keeps in memory for followers
/// to tail, and the follower-fetch batch bound.
const SHIP_CAP: usize = 4096;

/// Max records returned per [`Msg::FetchWal`] (keeps reply frames
/// well under `MAX_FRAME` even with large commit records).
const SHIP_BATCH: usize = 512;

/// Recent-record CRC window for the committed-prefix divergence checks.
const CRC_LOG_CAP: usize = 8192;

/// Default shard count for the block and lease tables.  16 spreads a
/// uniformly-distributed digest prefix well past the worker-pool sizes
/// in play while keeping the memory overhead of mostly-empty shards
/// negligible.  [`ManagerState::with_shards`] overrides it (the
/// sharded-vs-unsharded equivalence property runs at 1 vs. 16).
const DEFAULT_SHARDS: usize = 16;

/// Base election timeout: a peer that has not heard from a leader for
/// this long (plus its stagger) campaigns on its next
/// [`ManagerState::tick_consensus`].
const ELECTION_TIMEOUT_BASE: Duration = Duration::from_secs(1);

/// Deterministic stagger between peers' election timeouts (by rank of
/// `self_addr` in the sorted member list) — replaces Raft's randomized
/// timeouts so tests can schedule elections exactly, while still making
/// split votes unlikely in live deployments.
const ELECTION_STAGGER: Duration = Duration::from_millis(300);

/// Bounded connect to a consensus peer (loopback fails fast; a WAN
/// deploy tolerates a slow SYN without stalling an election forever).
const PEER_CONNECT_TIMEOUT: Duration = Duration::from_secs(1);

/// Bounded wait for a peer's reply — covers a follower that must pull
/// a snapshot from the leader before it can ack a Replicate.
const PEER_READ_TIMEOUT: Duration = Duration::from_secs(3);

/// Freed blocks + the node address book, handed out of the state lock
/// for execution (network deletes happen outside the lock).
type GcBatch = (Vec<(Digest, Vec<u32>)>, Vec<String>);

impl ManagerState {
    /// State with an explicit placement policy and the default lease
    /// timeout.
    pub fn new(policy: Box<dyn PlacementPolicy>) -> ManagerState {
        ManagerState::with_lease_timeout(policy, DEFAULT_LEASE_TIMEOUT)
    }

    /// State with an explicit placement policy and lease timeout.  A
    /// zero timeout would lapse every lease at its very first sweep
    /// (silently reopening the reader-vs-GC race), so it is clamped to
    /// [`MIN_LEASE_TIMEOUT`] here, at the layer that owns the invariant
    /// — front ends (`Cluster::spawn`, `--lease-timeout`) additionally
    /// reject zero loudly.
    pub fn with_lease_timeout(
        policy: Box<dyn PlacementPolicy>,
        lease_timeout: Duration,
    ) -> ManagerState {
        ManagerState::with_shards(policy, lease_timeout, DEFAULT_SHARDS)
    }

    /// State with an explicit shard count for the block/lease tables.
    /// Observable behavior must not depend on `shards` (snapshots sort
    /// their entries) — the equivalence property in
    /// `rust/tests/properties.rs` runs the same op sequence at 1 and 16
    /// shards and compares [`ManagerState::snapshot_state`] images.
    pub fn with_shards(
        policy: Box<dyn PlacementPolicy>,
        lease_timeout: Duration,
        shards: usize,
    ) -> ManagerState {
        let lease_timeout = lease_timeout.max(MIN_LEASE_TIMEOUT);
        ManagerState {
            inner: Mutex::new(Inner {
                files: HashMap::new(),
                nodes: Vec::new(),
                policy,
                next_lease: 1,
                wal: None,
                last_lsn: 0,
                ship: VecDeque::new(),
                crc_log: BTreeMap::new(),
            }),
            blocks: ShardedMap::new(shards),
            leases: ShardedMap::new(shards),
            repl: Mutex::new(Repl::solo()),
            heartbeat_timeout: HEARTBEAT_TIMEOUT,
            lease_timeout,
            clock_skew: Mutex::new(Duration::ZERO),
            gc_inflight: Mutex::new(HashSet::new()),
            gc_done: Condvar::new(),
            scrub: Mutex::new(ScrubState {
                interval: Duration::ZERO,
                repair_mbps: 0.0,
                last_run: None,
            }),
            suspects: Mutex::new(HashSet::new()),
        }
    }

    /// Durable state: open (or initialize) `opts.data_dir`, install the
    /// latest snapshot, replay the log tail through the same
    /// [`ManagerState::apply`] path live execution uses, and continue
    /// logging to the recovered WAL.  `durability: None` degrades to
    /// the in-memory [`ManagerState::with_lease_timeout`].
    ///
    /// Replay's GC side effects are discarded: the pre-crash manager
    /// already issued those (idempotent) deletes before replying, and
    /// whatever it did not finish is space the next real sweep of the
    /// same hashes reclaims.
    pub fn with_durability(
        policy: Box<dyn PlacementPolicy>,
        lease_timeout: Duration,
        durability: Option<DurabilityOpts>,
    ) -> Result<ManagerState> {
        let state = ManagerState::with_lease_timeout(policy, lease_timeout);
        let Some(opts) = durability else {
            return Ok(state);
        };
        let recovery = wal::recover(&opts)?;
        {
            let mut guard = state.inner.lock().unwrap();
            let g = &mut *guard;
            let now = state.now();
            if let Some(snap) = &recovery.snapshot {
                state.install_snapshot_into(g, snap, now);
            }
            let mut freed = Vec::new();
            for (lsn, rec) in recovery.records {
                state.apply(g, rec, now, &mut freed);
                g.last_lsn = lsn;
            }
            g.last_lsn = g.last_lsn.max(recovery.wal.next_lsn().saturating_sub(1));
            g.wal = Some(recovery.wal);
        }
        // Replay ran sweeps that marked hashes GC-in-flight; no deletes
        // will be issued for them, so unmark.
        state.gc_inflight.lock().unwrap().clear();
        Ok(state)
    }

    /// A serializable image of the durable state (sorted, so two
    /// replicas of the same history compare equal regardless of
    /// hash-map iteration order).  Powers on-disk snapshots, follower
    /// bootstrap and the recovery property tests.
    pub fn snapshot_state(&self) -> SnapshotState {
        let g = self.inner.lock().unwrap();
        let lsn = g.last_lsn;
        self.snapshot_of(&g, lsn)
    }

    /// Replace this state with a snapshot image (follower bootstrap).
    /// Liveness and lease clocks restart conservatively: nodes are
    /// "alive" until the heartbeat window re-judges them, leases get a
    /// full TTL.
    /// A durable replica also resets its WAL to the snapshot image,
    /// discarding any locally-retained tail — on a quorum replica that
    /// tail was never committed (bootstrap only happens when adopting a
    /// new leader's history), so dropping it is exactly the protocol's
    /// intent.
    pub fn install_snapshot(&self, snap: &SnapshotState) -> Result<()> {
        let mut guard = self.inner.lock().unwrap();
        let now = self.now();
        self.install_snapshot_into(&mut guard, snap, now);
        if let Some(w) = guard.wal.as_mut() {
            w.reset_to(snap)?;
        }
        drop(guard);
        self.gc_inflight.lock().unwrap().clear();
        Ok(())
    }

    /// Apply one record shipped from a primary (strictly in lsn order;
    /// a gap means frames were lost and the follower must re-sync).
    /// The follower never issues GC deletes — the primary already did.
    pub fn apply_shipped(&self, lsn: u64, data: &[u8]) -> Result<()> {
        let rec = Record::decode(data)?;
        let mut guard = self.inner.lock().unwrap();
        let g = &mut *guard;
        if lsn != g.last_lsn + 1 {
            return Err(Error::Manager(format!(
                "shipped record lsn {lsn} does not follow {}",
                g.last_lsn
            )));
        }
        // Durable replicas log the shipped record before applying it
        // (same append-before-mutate rule as the live path) so an acked
        // record survives this replica's own restart — an ack is a
        // commit vote, and a vote that evaporates on crash breaks the
        // quorum-intersection argument.
        if let Some(w) = g.wal.as_mut() {
            if let Err(e) = w.append(lsn, data) {
                return Err(Error::Manager(format!(
                    "manager: follower wal append failed: {e}"
                )));
            }
        }
        let now = self.now();
        let mut freed = Vec::new();
        self.apply(g, rec, now, &mut freed);
        g.last_lsn = lsn;
        g.crc_log.insert(lsn, wal::crc32(data));
        trim_crc_log(g);
        g.ship.push_back((lsn, data.to_vec()));
        if g.ship.len() > SHIP_CAP {
            g.ship.pop_front();
        }
        self.maybe_snapshot(g);
        drop(guard);
        if !freed.is_empty() {
            let mut inflight = self.gc_inflight.lock().unwrap();
            for (h, _) in &freed {
                inflight.remove(h);
            }
        }
        Ok(())
    }

    /// LSN of the last record logged/applied.
    pub fn last_lsn(&self) -> u64 {
        self.inner.lock().unwrap().last_lsn
    }

    /// Drop the WAL handle (crash simulation: the dropped handle syncs
    /// its tail, mimicking an OS that flushed what the process wrote —
    /// from here on this state object can no longer log anything).
    /// Serializes on the state lock, so a mutation that raced this call
    /// either made it to the log or lands only in the discarded memory.
    pub fn detach_wal(&self) {
        let _ = self.inner.lock().unwrap().wal.take();
    }

    /// The manager's notion of "now": real time plus the test skew.
    fn now(&self) -> Instant {
        Instant::now() + *self.clock_skew.lock().unwrap()
    }

    /// Test-only time hook: advance the manager's clock by `by`.  Lease
    /// expiry and node liveness both read this clock, so fault-injection
    /// tests drive timeouts deterministically (pair with
    /// [`ManagerState::tick`] to run the expiry sweep).
    pub fn advance_clock(&self, by: Duration) {
        *self.clock_skew.lock().unwrap() += by;
    }

    /// Run the lazy lease-expiry sweep now (every *mutating* message
    /// does this first; read-only traffic no longer sweeps — see
    /// [`ManagerState::handle_inner`]) and execute any resulting GC
    /// deletes before returning.  Ops/test hook — pairs with
    /// [`ManagerState::advance_clock`].
    pub fn tick(&self) {
        let gc = {
            let mut guard = self.inner.lock().unwrap();
            let g = &mut *guard;
            let now = self.now();
            let mut freed = Vec::new();
            self.expire_leases(g, now, &mut freed);
            self.maybe_snapshot(g);
            self.gc_batch(g, freed)
        };
        self.execute_gc(gc);
        self.maybe_scrub();
    }

    /// Handle one request message.
    pub fn handle(&self, msg: Msg) -> Msg {
        // GC work (network deletes) is collected under the lock and
        // executed after it is released — synchronously, on purpose:
        // the reply to a commit/release is only written once the
        // orphaned blocks are really gone, which keeps reclamation
        // observable (and testable) at the client.  Unreachable nodes
        // are skipped fast on loopback; a slow real-network connect
        // only delays this one caller.  This ordering also makes the
        // in-call expiry/alloc interleaving safe: a hash freed by the
        // expiry sweep and immediately re-allocated by the same message
        // has its stale on-node copies deleted BEFORE the reply (and
        // thus the client's re-upload) goes out.
        let (reply, gc) = self.handle_inner(msg);
        self.execute_gc(gc);
        reply
    }

    /// Issue a GC batch's node-side deletes, then unmark the hashes and
    /// wake allocations waiting on them.  On the quorum path this runs
    /// only AFTER the records that freed the blocks are quorum-acked
    /// (see [`ManagerState::handle_replicated`]): a delete must never
    /// land for a release the group might not have committed.
    fn execute_gc(&self, gc: Option<GcBatch>) {
        let Some((freed, addrs)) = gc else {
            return;
        };
        gc_delete(&freed, &addrs);
        let mut inflight = self.gc_inflight.lock().unwrap();
        for (h, _) in &freed {
            inflight.remove(h);
        }
        drop(inflight);
        self.gc_done.notify_all();
    }

    /// Suppress a GC batch whose quorum barrier failed: unmark the
    /// hashes (so allocations stop waiting) but issue NO deletes — the
    /// records are durable locally and may yet commit retroactively,
    /// but this leader cannot prove it, so the node-side copies stay.
    /// The cost is a bounded conservative leak (the copies are
    /// unreferenced space until the hash is reallocated or the node
    /// churns), which is the safe side of the ledger: the alternative —
    /// deleting against an uncommitted release — destroys data a
    /// surviving quorum still references.
    fn abandon_gc(&self, gc: Option<GcBatch>) {
        let Some((freed, _)) = gc else {
            return;
        };
        let mut inflight = self.gc_inflight.lock().unwrap();
        for (h, _) in &freed {
            inflight.remove(h);
        }
        drop(inflight);
        self.gc_done.notify_all();
    }

    /// Block until no in-flight GC batch covers any of `specs` (bounded
    /// by [`GC_WAIT`]).  Touches only `gc_inflight` + the condvar —
    /// never the state lock — so other manager operations proceed while
    /// an allocation waits.
    fn await_gc(&self, specs: &[BlockSpec]) {
        let mut inflight = self.gc_inflight.lock().unwrap();
        let deadline = Instant::now() + GC_WAIT;
        while specs.iter().any(|s| inflight.contains(&s.hash)) {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            let (g, _) = self.gc_done.wait_timeout(inflight, left).unwrap();
            inflight = g;
        }
    }

    /// True if any of `specs` is covered by an in-flight GC batch.
    fn gc_covers(&self, specs: &[BlockSpec]) -> bool {
        let inflight = self.gc_inflight.lock().unwrap();
        specs.iter().any(|s| inflight.contains(&s.hash))
    }

    fn handle_inner(&self, msg: Msg) -> (Msg, Option<GcBatch>) {
        // Allocations wait out GC batches covering their hashes BEFORE
        // taking the state lock (so the wait stalls only this caller),
        // then re-check under the lock: a sweep that started in between
        // sends us back to waiting.  Bounded attempts — after that,
        // proceed best-effort (same exposure as not waiting at all).
        let msg = match msg {
            Msg::AllocPlacement { file, lease, blocks } => {
                for attempt in 0..3 {
                    if attempt > 0 || self.gc_covers(&blocks) {
                        self.await_gc(&blocks);
                    }
                    let mut guard = self.inner.lock().unwrap();
                    if self.gc_covers(&blocks) && attempt < 2 {
                        continue; // sweep raced us; wait again unlocked
                    }
                    let g = &mut *guard;
                    let now = self.now();
                    // Lapsed leases release their claims/pins first, so
                    // an abandoned writer's stale claims never satisfy
                    // this allocation's dedup.
                    let mut freed = Vec::new();
                    self.expire_leases(g, now, &mut freed);
                    // Plan (read-only decisions, policy cursor aside),
                    // then log + apply: the Alloc record carries the
                    // decided replica sets, so replay never re-runs
                    // placement.
                    let reply = match self.plan_alloc(g, &file, lease, &blocks, now) {
                        Ok((assignments, metas)) => {
                            let rec = Record::Alloc {
                                tag: file,
                                lease,
                                blocks: metas,
                            };
                            match self.log_apply(g, rec, now, &mut freed) {
                                Ok(()) => Msg::Placement { assignments },
                                Err(e) => Msg::Err(e),
                            }
                        }
                        Err(e) => Msg::Err(e),
                    };
                    self.maybe_snapshot(g);
                    return (reply, self.gc_batch(g, freed));
                }
                unreachable!("alloc loop always returns by attempt 2");
            }
            other => other,
        };
        let mut guard = self.inner.lock().unwrap();
        // Reborrow as a plain `&mut Inner` so field borrows split.
        let g = &mut *guard;
        let now = self.now();
        // Lazy expiry sweep: every *mutating* message first lapses
        // overdue leases (claims/pins release, newly-unreferenced
        // blocks join this message's GC batch).  No background timer —
        // expiry is deterministic given the clock, which tests control.
        // Read-only traffic (snapshot/WAL fetch, heartbeats, node
        // listings) skips the sweep: any replica serves those, at high
        // rates, and a sweep there would append expiry records — and
        // free blocks — outside the leader's quorum-gated GC path.
        // [`ManagerState::tick`] runs the sweep on demand.
        let mut freed = Vec::new();
        if !matches!(
            msg,
            Msg::FetchSnapshot
                | Msg::FetchWal { .. }
                | Msg::Heartbeat { .. }
                | Msg::NodeList
                | Msg::ReportCorrupt { .. }
        ) {
            self.expire_leases(g, now, &mut freed);
        }
        let reply = match msg {
            Msg::GetBlockMap { file } => match g.files.get(&file) {
                Some(e) => Msg::BlockMap {
                    version: e.version,
                    blocks: e.blocks.clone(),
                },
                None => Msg::BlockMap {
                    version: 0,
                    blocks: Vec::new(),
                },
            },
            Msg::CommitBlockMap { file, lease, blocks } => {
                match self.plan_commit(g, lease, &blocks) {
                    Ok(()) => {
                        let rec = Record::Commit { file, lease, blocks };
                        match self.log_apply(g, rec, now, &mut freed) {
                            Ok(()) => Msg::Ok,
                            Err(e) => Msg::Err(e),
                        }
                    }
                    Err(e) => Msg::Err(e),
                }
            }
            // AllocPlacement is handled above (it interleaves with the
            // GC-in-flight barrier before taking the state lock).
            Msg::AllocPlacement { .. } => unreachable!("handled before the lock"),
            Msg::ReleaseBlocks { hashes } => {
                match self.log_apply(g, Record::Release { hashes }, now, &mut freed) {
                    Ok(()) => Msg::Ok,
                    Err(e) => Msg::Err(e),
                }
            }
            Msg::OpenLease { file, write } => self.open_lease(g, file, write, now, &mut freed),
            Msg::RenewLease { lease } => {
                // Renewals of unknown/lapsed leases are not logged —
                // there is nothing durable to change.
                if self.leases.contains(&lease) {
                    match self.log_apply(g, Record::RenewLease { id: lease }, now, &mut freed) {
                        Ok(()) => Msg::Ok,
                        Err(e) => Msg::Err(e),
                    }
                } else {
                    Msg::Err(format!("lease {lease} unknown or lapsed"))
                }
            }
            Msg::DropLease { lease } => {
                // Idempotent: dropping a lapsed/consumed lease is OK (a
                // committed writer's lease is consumed by the commit)
                // and not logged — there is no lease to release.
                if self.leases.contains(&lease) {
                    match self.log_apply(g, Record::DropLease { id: lease }, now, &mut freed) {
                        Ok(()) => Msg::Ok,
                        Err(e) => Msg::Err(e),
                    }
                } else {
                    Msg::Ok
                }
            }
            Msg::NodeJoin { addr } => match g.nodes.iter().position(|n| n.addr == addr) {
                Some(id) => {
                    // Re-join of a known address only refreshes the
                    // volatile liveness clock — not logged.
                    g.nodes[id].last_beat = now;
                    Msg::NodeId { id: id as u32 }
                }
                None => {
                    let id = g.nodes.len() as u32;
                    match self.log_apply(g, Record::NodeJoin { id, addr }, now, &mut freed) {
                        Ok(()) => Msg::NodeId { id },
                        Err(e) => Msg::Err(e),
                    }
                }
            },
            Msg::Heartbeat { node } => match g.nodes.get_mut(node as usize) {
                Some(n) => {
                    n.last_beat = now;
                    Msg::Ok
                }
                None => Msg::Err(format!("heartbeat from unregistered node {node}")),
            },
            Msg::NodeList => {
                let timeout = self.heartbeat_timeout;
                Msg::Nodes {
                    nodes: g
                        .nodes
                        .iter()
                        .enumerate()
                        .map(|(id, n)| NodeEntry {
                            id: id as u32,
                            addr: n.addr.clone(),
                            alive: now.saturating_duration_since(n.last_beat) < timeout,
                        })
                        .collect(),
                }
            }
            Msg::ListFiles => {
                let mut list: Vec<(String, u64)> =
                    g.files.iter().map(|(k, v)| (k.clone(), v.version)).collect();
                list.sort();
                Msg::Files { files: list }
            }
            Msg::FetchSnapshot => {
                let lsn = g.last_lsn;
                Msg::SnapshotData {
                    data: self.snapshot_of(g, lsn).encode(),
                }
            }
            Msg::FetchWal { after } => {
                let retained = match g.ship.front() {
                    Some((front, _)) => after.saturating_add(1) >= *front,
                    None => after >= g.last_lsn,
                };
                if retained {
                    let records: Vec<WalEntry> = g
                        .ship
                        .iter()
                        .filter(|(l, _)| *l > after)
                        .take(SHIP_BATCH)
                        .map(|(l, d)| WalEntry {
                            lsn: *l,
                            data: d.clone(),
                        })
                        .collect();
                    Msg::WalRecords { records }
                } else {
                    Msg::Err(format!(
                        "wal: records after {after} no longer retained; re-snapshot"
                    ))
                }
            }
            Msg::ReportCorrupt { hash, node } => {
                // Volatile repair hint (never logged): the next scrub
                // pass re-verifies it against the block table before
                // moving any bytes, so a bogus report costs nothing.
                self.suspects.lock().unwrap().insert((hash, node));
                Msg::Ok
            }
            other => Msg::Err(format!("manager: unexpected message {other:?}")),
        };
        self.maybe_snapshot(g);
        (reply, self.gc_batch(g, freed))
    }

    /// The single durability gate: encode the record, append it to the
    /// log (append-before-mutate — a failed append leaves the state
    /// untouched and surfaces as a logical error), buffer it for
    /// shipping followers, then apply it.
    fn log_apply(
        &self,
        g: &mut Inner,
        rec: Record,
        now: Instant,
        freed: &mut Vec<(Digest, Vec<u32>)>,
    ) -> std::result::Result<(), String> {
        let bytes = rec.encode();
        let lsn = g.last_lsn + 1;
        if let Some(w) = g.wal.as_mut() {
            if let Err(e) = w.append(lsn, &bytes) {
                return Err(format!("manager: wal append failed: {e}"));
            }
        }
        g.last_lsn = lsn;
        g.crc_log.insert(lsn, wal::crc32(&bytes));
        trim_crc_log(g);
        g.ship.push_back((lsn, bytes));
        if g.ship.len() > SHIP_CAP {
            g.ship.pop_front();
        }
        self.apply(g, rec, now, freed);
        Ok(())
    }

    /// Cut a snapshot when the log has grown past the configured
    /// cadence.  Best-effort at runtime: a failed snapshot leaves the
    /// log authoritative (recovery just replays more), so it logs to
    /// stderr instead of failing the triggering request.
    fn maybe_snapshot(&self, g: &mut Inner) {
        if !g.wal.as_ref().is_some_and(|w| w.wants_snapshot()) {
            return;
        }
        let lsn = g.last_lsn;
        let snap = self.snapshot_of(g, lsn);
        if let Some(w) = g.wal.as_mut() {
            if let Err(e) = w.snapshot(&snap) {
                eprintln!("gpustore manager: snapshot failed (log stays authoritative): {e}");
            }
        }
    }

    /// Apply one record.  The ONLY place records mutate durable state:
    /// the live path calls it right after appending, crash recovery
    /// replays the log tail through it, and followers feed shipped
    /// records into it — one code path, three consumers.
    ///
    /// Apply is deliberately more tolerant than the live planners
    /// (missing leases are skipped, not panicked on): the planner
    /// validated before logging, so on replay the lookups succeed; the
    /// tolerance only guards against logs hand-edited or written by a
    /// newer version.
    fn apply(&self, g: &mut Inner, rec: Record, now: Instant, freed: &mut Vec<(Digest, Vec<u32>)>) {
        match rec {
            Record::Commit { file, lease, blocks } => {
                // The planner verified the lease is a live write lease
                // (or 0 = untracked), so remove() here yields the claim
                // holder to redeem.
                let held = match lease {
                    0 => None,
                    id => self.leases.remove(&id),
                };
                for m in &blocks {
                    self.blocks.or_insert_mutate(
                        &m.hash,
                        || BlockInfo {
                            replicas: m.replicas.clone(),
                            ec: m.ec,
                            len: m.len,
                            refs: 0,
                            pending: 0,
                            pins: 0,
                            placed_by: String::new(),
                        },
                        |e| {
                            e.refs += 1;
                            e.pending = e.pending.saturating_sub(1);
                        },
                    );
                }
                // Claim occurrences the commit did not consume
                // (allocated but left out of the final map) are
                // released with the lease.
                if let Some(l) = held {
                    let mut consumed: HashMap<Digest, u64> = HashMap::new();
                    for m in &blocks {
                        *consumed.entry(m.hash).or_default() += 1;
                    }
                    let mut leftovers = Vec::new();
                    for h in l.hashes {
                        match consumed.get_mut(&h) {
                            Some(n) if *n > 0 => *n -= 1,
                            _ => {
                                self.blocks.mutate(&h, |e| {
                                    e.pending = e.pending.saturating_sub(1);
                                });
                                leftovers.push(h);
                            }
                        }
                    }
                    self.sweep(&leftovers, freed);
                }
                let f = g.files.entry(file).or_default();
                f.version += 1;
                let old = std::mem::replace(&mut f.blocks, blocks);
                for m in &old {
                    self.blocks.mutate(&m.hash, |e| {
                        e.refs = e.refs.saturating_sub(1);
                    });
                }
                // Only the old map's hashes can have newly reached zero
                // references (the new map's all got refs += 1).
                // Read-leased blocks have pins > 0 and survive; their
                // deferred deletes run when the last lease drops.
                let candidates: Vec<Digest> = old.iter().map(|m| m.hash).collect();
                self.sweep(&candidates, freed);
            }
            Record::Release { hashes } => {
                for h in &hashes {
                    self.blocks.mutate(h, |e| {
                        e.pending = e.pending.saturating_sub(1);
                    });
                }
                self.sweep(&hashes, freed);
            }
            Record::OpenLease { id, tag, write, hashes } => {
                if !write {
                    for h in &hashes {
                        self.blocks.mutate(h, |e| e.pins += 1);
                    }
                }
                self.leases.insert(
                    id,
                    Lease {
                        tag,
                        write,
                        hashes,
                        expires_at: now + self.lease_timeout,
                    },
                );
                g.next_lease = g.next_lease.max(id + 1);
            }
            Record::RenewLease { id } => {
                self.leases.mutate(&id, |l| {
                    l.expires_at = now + self.lease_timeout;
                });
            }
            Record::DropLease { id } | Record::ExpireLease { id } => {
                if let Some(l) = self.leases.remove(&id) {
                    self.release_lease(l, freed);
                }
            }
            Record::Alloc { tag, lease, blocks } => {
                for m in &blocks {
                    self.blocks.or_insert_mutate(
                        &m.hash,
                        || BlockInfo {
                            replicas: m.replicas.clone(),
                            ec: m.ec,
                            len: m.len,
                            refs: 0,
                            pending: 0,
                            pins: 0,
                            placed_by: tag.clone(),
                        },
                        |e| {
                            e.pending += 1;
                            // The planner re-homed dead replica sets at
                            // log time; for live sets it recorded the
                            // existing one, so this is a no-op there.
                            e.replicas = m.replicas.clone();
                            e.ec = m.ec;
                        },
                    );
                }
                // Record the claim occurrences against the lease and
                // renew it (an actively-allocating writer is live).
                if lease != 0 {
                    self.leases.mutate(&lease, |l| {
                        l.hashes.extend(blocks.iter().map(|m| m.hash));
                        l.expires_at = now + self.lease_timeout;
                    });
                }
            }
            Record::NodeJoin { id, addr } => {
                let idx = id as usize;
                if idx == g.nodes.len() {
                    g.nodes.push(NodeSlot {
                        addr,
                        last_beat: now,
                    });
                } else if let Some(n) = g.nodes.get_mut(idx) {
                    n.addr = addr;
                    n.last_beat = now;
                }
            }
            Record::Rehome { hash, replicas } => {
                // Scrub/repair re-homing: swap the block's replica set
                // (shard ORDER preserved — under EC, position i is
                // still shard i; only its home moved).  A block
                // released since the record was logged is a no-op.
                let mut present = false;
                self.blocks.mutate(&hash, |e| {
                    e.replicas = replicas.clone();
                    present = true;
                });
                if present {
                    // Committed file maps carry their own replica
                    // lists; re-point them so readers opening the
                    // current version chase live homes.
                    for f in g.files.values_mut() {
                        for m in f.blocks.iter_mut() {
                            if m.hash == hash {
                                m.replicas = replicas.clone();
                            }
                        }
                    }
                }
            }
        }
    }

    /// Validate a commit without mutating anything (the mutation is the
    /// logged [`Record::Commit`]'s `apply`): node ids must be
    /// registered, and a lease-tracked commit must present a live write
    /// lease — if it lapsed, its claims were already released and the
    /// blocks may be gone from the nodes, so committing would publish
    /// an unreadable file.
    fn plan_commit(
        &self,
        g: &Inner,
        lease: u64,
        blocks: &[BlockMeta],
    ) -> std::result::Result<(), String> {
        // Satellite (PR 2): validate node ids against the registry
        // before accepting, so readers never chase a block to a node
        // that does not exist.
        if let Some(err) = validate_blocks(blocks, g.nodes.len()) {
            return Err(err);
        }
        match lease {
            0 => Ok(()),
            id => match self.leases.get_with(&id, |l| l.write) {
                Some(true) => Ok(()),
                Some(false) => Err(format!("commit: lease {id} is not a write lease")),
                None => Err(format!(
                    "commit: write lease {id} lapsed and its claims were released"
                )),
            },
        }
    }

    /// Grant a lease: read leases atomically snapshot + pin the file's
    /// current block-map, write leases register an (initially empty)
    /// claim holder.  The grant is logged (pins and the claim holder
    /// are durable facts GC depends on); the no-such-file read case
    /// grants nothing and is not.
    fn open_lease(
        &self,
        g: &mut Inner,
        file: String,
        write: bool,
        now: Instant,
        freed: &mut Vec<(Digest, Vec<u32>)>,
    ) -> Msg {
        let ttl_ms = self.lease_timeout.as_millis() as u64;
        let (version, blocks) = if write {
            (0, Vec::new())
        } else {
            match g.files.get(&file) {
                Some(e) if e.version > 0 => (e.version, e.blocks.clone()),
                _ => {
                    // No such file: nothing to pin, no lease granted.
                    return Msg::LeaseGrant {
                        lease: 0,
                        ttl_ms,
                        version: 0,
                        blocks: Vec::new(),
                    };
                }
            }
        };
        let id = g.next_lease;
        let rec = Record::OpenLease {
            id,
            tag: file,
            write,
            hashes: blocks.iter().map(|m| m.hash).collect(),
        };
        match self.log_apply(g, rec, now, freed) {
            Ok(()) => Msg::LeaseGrant {
                lease: id,
                ttl_ms,
                version,
                blocks,
            },
            Err(e) => Msg::Err(e),
        }
    }

    /// Lapse every overdue lease (release its claims/pins and sweep).
    /// Each lapse is logged as a [`Record::ExpireLease`] — expiry is a
    /// durable state change like any other, and replaying it beats
    /// making recovery re-derive it from clocks that did not survive
    /// the crash.  Sorted ids keep the log deterministic for a given
    /// set of overdue leases.
    fn expire_leases(&self, g: &mut Inner, now: Instant, freed: &mut Vec<(Digest, Vec<u32>)>) {
        let mut lapsed: Vec<u64> = Vec::new();
        self.leases.for_each(|id, l| {
            if l.expires_at <= now {
                lapsed.push(*id);
            }
        });
        lapsed.sort_unstable();
        for id in lapsed {
            // Append-before-mutate: if the log rejects the record the
            // lease stays (still overdue), and the next sweep retries.
            if self
                .log_apply(g, Record::ExpireLease { id }, now, freed)
                .is_err()
            {
                break;
            }
        }
    }

    /// Return a lease's held occurrences to the pool: a write lease's
    /// claims stop pending, a read lease's pins drop — then sweep.
    fn release_lease(&self, l: Lease, freed: &mut Vec<(Digest, Vec<u32>)>) {
        for h in &l.hashes {
            self.blocks.mutate(h, |e| {
                if l.write {
                    e.pending = e.pending.saturating_sub(1);
                } else {
                    e.pins = e.pins.saturating_sub(1);
                }
            });
        }
        self.sweep(&l.hashes, freed);
    }

    /// Collect garbage among `candidates` (the hashes whose counters
    /// this operation decremented — anything else cannot have newly
    /// reached zero): drop every candidate with no committed
    /// references, no pending claims and no read-lease pins, and mark
    /// the freed hashes GC-in-flight (while still holding the state
    /// lock, so allocations of these hashes wait — see
    /// [`ManagerState::await_gc`]).  Deletion itself runs outside the
    /// lock, via [`ManagerState::gc_batch`].
    fn sweep(&self, candidates: &[Digest], freed: &mut Vec<(Digest, Vec<u32>)>) {
        let mut marked = Vec::new();
        for h in candidates {
            // Duplicate candidates are harmless: once removed, the
            // second lookup misses.
            if let Some(b) = self
                .blocks
                .remove_if(h, |b| b.refs == 0 && b.pending == 0 && b.pins == 0)
            {
                freed.push((*h, b.replicas));
                marked.push(*h);
            }
        }
        if !marked.is_empty() {
            self.gc_inflight.lock().unwrap().extend(marked);
        }
    }

    /// Package this message's freed blocks with the node address book
    /// for execution outside the state lock.
    fn gc_batch(&self, g: &Inner, freed: Vec<(Digest, Vec<u32>)>) -> Option<GcBatch> {
        if freed.is_empty() {
            return None;
        }
        Some((freed, g.nodes.iter().map(|n| n.addr.clone()).collect()))
    }

    /// Plan one placement batch: validate the lease, decide every
    /// block's replica set and freshness, and return the assignments
    /// plus the [`BlockMeta`]s an [`Record::Alloc`] will carry — but
    /// mutate nothing except the policy cursor (volatile by design; it
    /// is not persisted, because the decided replica sets are).  The
    /// counter bumps happen in `apply` once the record is logged.
    ///
    /// `planned` overlays in-batch decisions over the block table so a hash
    /// that repeats inside one batch deduplicates against its own first
    /// occurrence, exactly as the pre-WAL mutate-as-you-go version did.
    fn plan_alloc(
        &self,
        g: &mut Inner,
        file: &str,
        lease: u64,
        specs: &[BlockSpec],
        now: Instant,
    ) -> std::result::Result<(Vec<Assignment>, Vec<BlockMeta>), String> {
        // Claims must be held under a live write lease (`0` = untracked
        // legacy claims, kept for raw protocol users): a lapsed lease
        // means this writer's earlier claims were already reclaimed —
        // it must re-open rather than keep streaming into a void.
        if lease != 0 {
            match self.leases.get_with(&lease, |l| l.write) {
                Some(true) => {}
                Some(false) => return Err(format!("alloc: lease {lease} is not a write lease")),
                None => return Err(format!("alloc: write lease {lease} lapsed")),
            }
        }
        let alive: Vec<u32> = g
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| {
                now.saturating_duration_since(n.last_beat) < self.heartbeat_timeout
            })
            .map(|(id, _)| id as u32)
            .collect();
        if alive.is_empty() {
            return Err(if g.nodes.is_empty() {
                "no storage nodes registered".into()
            } else {
                "no storage nodes alive".into()
            });
        }
        // Erasure coding needs k+m DISTINCT homes — one node holding
        // two shards silently voids the m-failure guarantee, so a thin
        // cluster fails the allocation loudly instead of degrading.
        if let Some((k, m)) = g.policy.ec() {
            let need = k as usize + m as usize;
            if alive.len() < need {
                return Err(format!(
                    "erasure coding ec:{k},{m} needs {need} distinct alive nodes, \
                     only {} alive",
                    alive.len()
                ));
            }
        }
        // A known replica set still serves this block if enough of its
        // homes are alive: any one copy for replication, any k shards
        // for erasure coding.
        let usable = |replicas: &[u32], ec: Option<(u8, u8)>| {
            let up = replicas.iter().filter(|r| alive.contains(r)).count();
            match ec {
                Some((k, _)) => up >= k as usize,
                None => up >= 1,
            }
        };
        // hash -> (decided replicas, stored coding, dedup_ok: later
        // occurrences in this batch may skip the transfer).
        let mut planned: HashMap<Digest, (Vec<u32>, Option<(u8, u8)>, bool)> = HashMap::new();
        let mut out = Vec::with_capacity(specs.len());
        let mut metas = Vec::with_capacity(specs.len());
        for s in specs {
            let (replicas, ec, fresh) =
                if let Some((replicas, ec, dedup_ok)) = planned.get(&s.hash) {
                    (replicas.clone(), *ec, !*dedup_ok)
                } else {
                    // One bounded shard-lock hold to read the entry; the
                    // placement decision runs outside it.
                    let looked = self.blocks.get_with(&s.hash, |e| {
                        (e.replicas.clone(), e.ec, e.refs > 0 || e.placed_by == file)
                    });
                    match looked {
                        // Committed somewhere (a commit proves the
                        // transfer completed), or claimed by this same
                        // session (which is the one doing the transfer):
                        // safe to dedup — PROVIDED the stored copy is
                        // still readable (`usable`).  The assignment
                        // echoes the STORED coding, not the current
                        // policy's: dedup against an ec:4,2 block from a
                        // rep:3 cluster must read 4+2 shards, not 3
                        // copies.  A known block that is no longer
                        // readable is re-placed (under the CURRENT
                        // policy/coding) and re-transferred — the writer
                        // has the bytes in hand; dedup against dead
                        // nodes would commit an unreadable file.
                        Some((known, kec, true)) => {
                            if usable(&known, kec) {
                                planned.insert(s.hash, (known.clone(), kec, true));
                                (known, kec, false)
                            } else {
                                let replicas = g.policy.place(&alive);
                                let ec = g.policy.ec();
                                planned.insert(s.hash, (replicas.clone(), ec, true));
                                (replicas, ec, true)
                            }
                        }
                        // Known only as ANOTHER session's uncommitted
                        // claim: that transfer may still fail or be
                        // abandoned, so this writer must transfer too
                        // (puts are idempotent by key) — same homes and
                        // coding (re-placed if unreadable), but fresh
                        // from the caller's point of view, and every
                        // in-batch repeat stays fresh too.
                        //
                        // Re-placing (here and above) deliberately does
                        // NOT delete the old replicas' copies: those
                        // nodes look dead, so the deletes could not land
                        // anyway, and if a node was merely partitioned,
                        // its surviving copy may be the only one a
                        // pinned reader's snapshot map can still name —
                        // eager deletion would break that reader when
                        // the node heals.  The leak is reclaimed by the
                        // anti-entropy sweep once the node rejoins.
                        Some((known, kec, false)) => {
                            let (replicas, ec) = if usable(&known, kec) {
                                (known, kec)
                            } else {
                                (g.policy.place(&alive), g.policy.ec())
                            };
                            planned.insert(s.hash, (replicas.clone(), ec, false));
                            (replicas, ec, true)
                        }
                        None => {
                            let replicas = g.policy.place(&alive);
                            let ec = g.policy.ec();
                            debug_assert!(!replicas.is_empty());
                            planned.insert(s.hash, (replicas.clone(), ec, true));
                            (replicas, ec, true)
                        }
                    }
                };
            metas.push(BlockMeta {
                hash: s.hash,
                len: s.len,
                replicas: replicas.clone(),
                ec,
            });
            out.push(Assignment { replicas, fresh, ec });
        }
        Ok((out, metas))
    }

    /// Aggregate manager bookkeeping, counting each replica copy —
    /// includes the lease subsystem's counters, which the
    /// fault-injection tests assert on ("zero stranded pending
    /// claims").  Counters reflect the state as of the last handled
    /// message; call [`ManagerState::tick`] first to fold in overdue
    /// lease expiries.
    pub fn block_stats(&self) -> BlockStats {
        // Lock-free with respect to `Inner` since PR 9: the sharded
        // tables are read shard-by-shard, so a stats poll never stalls
        // the plan/log path (and vice versa).
        let mut s = BlockStats::default();
        self.blocks.for_each(|_, b| {
            let copies = b.replicas.len() as u64;
            s.blocks += copies;
            s.bytes += copies * b.len as u64;
            s.pending_claims += b.pending;
            if b.pins > 0 {
                s.pinned_blocks += 1;
            }
        });
        self.leases.for_each(|_, l| {
            if l.write {
                s.write_leases += 1;
            } else {
                s.read_leases += 1;
            }
        });
        s
    }
}

// ---- quorum replication (consensus over the shipped WAL) ----
impl ManagerState {
    /// Wire this manager into a quorum group.  Reloads any persisted
    /// term/vote from `term_dir` first (forgetting either across a
    /// crash could elect two leaders in one term); the designated
    /// initial leader durably casts a self-vote at term 1 so a
    /// same-term rival cannot also be granted.
    pub fn set_consensus(&self, opts: ConsensusOpts, term_dir: Option<PathBuf>) -> Result<()> {
        let mut r = self.repl.lock().unwrap();
        r.self_addr = opts.self_addr;
        r.peers = opts.peers;
        r.term_dir = term_dir;
        if let Some(dir) = r.term_dir.clone() {
            if let Some((term, voted, accepted)) = wal::load_term(&dir)? {
                r.term = term;
                r.voted_for = voted;
                r.accepted_term = accepted;
            }
        }
        if opts.initial_leader {
            r.term = r.term.max(1);
            r.role = Role::Leader;
            r.voted_for = Some(r.self_addr.clone());
            r.accepted_term = r.term;
            r.leader_hint = r.self_addr.clone();
            if let Some(dir) = r.term_dir.clone() {
                wal::save_term(&dir, r.term, r.voted_for.as_deref(), r.accepted_term)?;
            }
        } else {
            r.role = Role::Follower;
            r.leader_hint = String::new();
        }
        r.last_contact = self.now();
        Ok(())
    }

    /// Handle one request under the quorum protocol: peer RPCs go to
    /// the election/replication handlers, reads any replica can serve
    /// go straight through, and everything else is leader-only — a
    /// mutation's reply is held until a quorum of managers holds its
    /// appended records durably, and a non-leader answers
    /// [`Msg::NotLeader`] instead.  With no peers configured this
    /// degenerates to [`ManagerState::handle`] (single-manager mode).
    pub fn handle_replicated(&self, msg: Msg) -> Msg {
        match msg {
            // Consensus traffic between managers.
            Msg::RequestVote { .. } | Msg::Replicate { .. } => return self.handle_peer(msg),
            // Reads any replica serves: follower bootstrap/tailing,
            // node liveness beats, registry listings.
            Msg::FetchSnapshot | Msg::FetchWal { .. } | Msg::Heartbeat { .. } | Msg::NodeList => {
                return self.handle(msg)
            }
            _ => {}
        }
        let (solo, is_leader, hint) = {
            let r = self.repl.lock().unwrap();
            (
                r.peers.is_empty(),
                r.role == Role::Leader,
                r.leader_hint.clone(),
            )
        };
        if solo {
            // Single-manager group: every append is trivially
            // committed the moment it is applied.
            let reply = self.handle(msg);
            let last = self.last_lsn();
            let mut r = self.repl.lock().unwrap();
            r.commit_lsn = r.commit_lsn.max(last);
            return reply;
        }
        if !is_leader {
            return Msg::NotLeader { hint };
        }
        let before = self.last_lsn();
        // GC fan-out is DEFERRED past the quorum barrier (PR 9, closing
        // PR 8's known limitation): node-side `DeleteBlock`s for blocks
        // this mutation freed must not be issued unless a majority holds
        // the records that justify them — a leader partitioned below
        // quorum would otherwise delete blocks its successors still
        // consider live.
        let (reply, gc) = self.handle_inner(msg);
        let appended = self.ship_tail_since(before);
        if appended.is_empty() {
            self.execute_gc(gc);
            return reply;
        }
        // The quorum-commit barrier: an error here means the mutation
        // is durable locally but NOT acknowledged — the client must
        // retry (possibly against a new leader).  Retries are
        // at-least-once: every logged record's apply is state-idempotent
        // across replicas, so a duplicate application cannot diverge
        // the group (see README, "Consensus & failover").
        match self.replicate_to_quorum(before, appended) {
            Ok(()) => {
                self.execute_gc(gc);
                reply
            }
            Err(e) => {
                self.abandon_gc(gc);
                Msg::Err(e)
            }
        }
    }

    /// Manager↔manager RPCs (votes and log replication).
    fn handle_peer(&self, msg: Msg) -> Msg {
        match msg {
            Msg::RequestVote {
                term,
                candidate,
                last_term,
                last_lsn,
            } => self.handle_vote(term, candidate, last_term, last_lsn),
            Msg::Replicate {
                term,
                leader,
                prev_lsn,
                commit_lsn,
                records,
            } => self.handle_replicate(term, leader, prev_lsn, commit_lsn, records),
            other => Msg::Err(format!("manager: unexpected peer message {other:?}")),
        }
    }

    /// Grant or refuse a vote (Raft §5.2/§5.4.1): the candidate's term
    /// must be current, we must not have voted for anyone else this
    /// term, and its log `(last_term, last_lsn)` must be at least as up
    /// to date as ours.  Both the term bump and the vote are persisted
    /// BEFORE the reply leaves — an unpersistable vote is refused.
    fn handle_vote(&self, term: u64, candidate: String, last_term: u64, last_lsn: u64) -> Msg {
        let my_last = self.last_lsn();
        let mut r = self.repl.lock().unwrap();
        if term > r.term {
            r.term = term;
            r.voted_for = None;
            r.role = Role::Follower;
            r.leader_hint = String::new();
            if let Some(dir) = r.term_dir.clone() {
                if wal::save_term(&dir, r.term, None, r.accepted_term).is_err() {
                    return Msg::VoteReply {
                        term: r.term,
                        granted: false,
                    };
                }
            }
        }
        let up_to_date = (last_term, last_lsn) >= (r.accepted_term, my_last);
        let not_voted_other = match &r.voted_for {
            None => true,
            Some(v) => *v == candidate,
        };
        let granted = term == r.term && up_to_date && not_voted_other;
        if granted {
            if r.voted_for.is_none() {
                r.voted_for = Some(candidate.clone());
                if let Some(dir) = r.term_dir.clone() {
                    if wal::save_term(&dir, r.term, Some(&candidate), r.accepted_term).is_err() {
                        r.voted_for = None;
                        return Msg::VoteReply {
                            term: r.term,
                            granted: false,
                        };
                    }
                }
            }
            // Granting resets the election timer (don't immediately
            // campaign against the candidate we just endorsed).
            r.last_contact = self.now();
        }
        Msg::VoteReply {
            term: r.term,
            granted,
        }
    }

    /// Accept (or refuse) a leader's append/heartbeat.  First contact
    /// from a new leader re-bootstraps this replica wholesale from that
    /// leader's snapshot — discarding any divergent uncommitted tail —
    /// and records the adoption durably before any ack at the new term
    /// can count toward its quorum.  A gap against the leader's window
    /// self-heals by pulling [`Msg::FetchWal`] catch-up batches.  An
    /// `ok` ack promises every covered record is fsynced locally.
    fn handle_replicate(
        &self,
        term: u64,
        leader: String,
        prev_lsn: u64,
        commit_lsn: u64,
        records: Vec<WalEntry>,
    ) -> Msg {
        let pre_last = self.last_lsn();
        let need_bootstrap;
        {
            let mut r = self.repl.lock().unwrap();
            if term < r.term {
                return Msg::ReplicateAck {
                    term: r.term,
                    last_lsn: pre_last,
                    ok: false,
                };
            }
            if term > r.term {
                r.term = term;
                r.voted_for = None;
                if let Some(dir) = r.term_dir.clone() {
                    if wal::save_term(&dir, r.term, None, r.accepted_term).is_err() {
                        return Msg::ReplicateAck {
                            term: r.term,
                            last_lsn: pre_last,
                            ok: false,
                        };
                    }
                }
            }
            // A Replicate at the current term is from THE leader of
            // that term (elections are unique per term): follow it — a
            // candidate abandons its election, a deposed leader demotes.
            r.role = Role::Follower;
            r.leader_hint = leader.clone();
            r.last_contact = self.now();
            need_bootstrap = r.accepted_term != term;
        }
        if need_bootstrap {
            if self.bootstrap_from(&leader).is_err() {
                return Msg::ReplicateAck {
                    term,
                    last_lsn: self.last_lsn(),
                    ok: false,
                };
            }
            let mut r = self.repl.lock().unwrap();
            if let Some(dir) = r.term_dir.clone() {
                if wal::save_term(&dir, r.term, r.voted_for.as_deref(), term).is_err() {
                    return Msg::ReplicateAck {
                        term,
                        last_lsn: self.last_lsn(),
                        ok: false,
                    };
                }
            }
            r.accepted_term = term;
        }
        let mut appended = need_bootstrap;
        let mut last = self.last_lsn();
        if prev_lsn > last {
            if self.catch_up(&leader).is_err() {
                return Msg::ReplicateAck {
                    term,
                    last_lsn: last,
                    ok: false,
                };
            }
            let caught = self.last_lsn();
            appended = appended || caught != last;
            last = caught;
        }
        for e in &records {
            if e.lsn <= last {
                continue; // overlap with an already-applied window
            }
            if e.lsn != last + 1 || self.apply_shipped(e.lsn, &e.data).is_err() {
                return Msg::ReplicateAck {
                    term,
                    last_lsn: last,
                    ok: false,
                };
            }
            last = e.lsn;
            appended = true;
        }
        // Durability barrier: an ok ack is a commit vote, so everything
        // it covers must be on disk first.
        if appended && self.sync_wal().is_err() {
            return Msg::ReplicateAck {
                term,
                last_lsn: last,
                ok: false,
            };
        }
        {
            let mut r = self.repl.lock().unwrap();
            r.commit_lsn = r.commit_lsn.max(commit_lsn.min(last));
        }
        Msg::ReplicateAck {
            term,
            last_lsn: last,
            ok: true,
        }
    }

    /// Pull shipped records from the leader until caught up (or until
    /// it tells us to re-snapshot).  Called when a Replicate's
    /// `prev_lsn` shows we missed earlier records.
    fn catch_up(&self, leader: &str) -> Result<()> {
        let self_addr = self.repl.lock().unwrap().self_addr.clone();
        loop {
            let after = self.last_lsn();
            match peer_call(&self_addr, leader, Msg::FetchWal { after })?.into_result() {
                Ok(Msg::WalRecords { records }) => {
                    if records.is_empty() {
                        return Ok(());
                    }
                    for e in records {
                        self.apply_shipped(e.lsn, &e.data)?;
                    }
                }
                Ok(other) => {
                    return Err(Error::Manager(format!(
                        "catch-up: unexpected reply {other:?}"
                    )))
                }
                Err(Error::Proto(e)) if e.contains("re-snapshot") => {
                    self.bootstrap_from(leader)?;
                    return Ok(());
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Re-install the leader's current snapshot wholesale (and reset
    /// the local WAL to it, discarding any divergent tail).
    fn bootstrap_from(&self, leader: &str) -> Result<()> {
        let self_addr = self.repl.lock().unwrap().self_addr.clone();
        match peer_call(&self_addr, leader, Msg::FetchSnapshot)? {
            Msg::SnapshotData { data } => {
                let snap = SnapshotState::decode(&data)?;
                self.install_snapshot(&snap)
            }
            other => Err(Error::Manager(format!(
                "bootstrap: unexpected snapshot reply {other:?}"
            ))),
        }
    }

    /// Leader-side commit barrier: fsync our own copy (it counts toward
    /// the quorum), push `records` to every peer, and succeed only once
    /// a majority of the group holds them durably.  Seeing a higher
    /// term in any ack deposes us on the spot.
    fn replicate_to_quorum(
        &self,
        prev_lsn: u64,
        records: Vec<WalEntry>,
    ) -> std::result::Result<(), String> {
        if let Err(e) = self.sync_wal() {
            return Err(format!("no quorum: leader wal sync failed: {e}"));
        }
        let (term, self_addr, peers, commit) = {
            let r = self.repl.lock().unwrap();
            if r.role != Role::Leader {
                return Err("no quorum: leadership lost".into());
            }
            (r.term, r.self_addr.clone(), r.peers.clone(), r.commit_lsn)
        };
        let last = records.last().map(|e| e.lsn).unwrap_or(prev_lsn);
        let quorum = (peers.len() + 1) / 2 + 1;
        let mut acked = 1usize; // self, synced above
        let mut max_term = term;
        for p in &peers {
            let req = Msg::Replicate {
                term,
                leader: self_addr.clone(),
                prev_lsn,
                commit_lsn: commit,
                records: records.clone(),
            };
            if let Ok(Msg::ReplicateAck {
                term: t,
                last_lsn,
                ok,
            }) = peer_call(&self_addr, p, req)
            {
                max_term = max_term.max(t);
                if ok && t == term && last_lsn >= last {
                    acked += 1;
                }
            }
            if acked >= quorum {
                break; // laggards catch up via the next heartbeat
            }
        }
        let mut r = self.repl.lock().unwrap();
        if max_term > r.term {
            r.term = max_term;
            r.voted_for = None;
            r.role = Role::Follower;
            r.leader_hint = String::new();
            if let Some(dir) = r.term_dir.clone() {
                let _ = wal::save_term(&dir, r.term, None, r.accepted_term);
            }
            return Err(format!("no quorum: deposed by term {max_term}"));
        }
        if acked >= quorum {
            if r.role == Role::Leader && r.term == term {
                r.commit_lsn = r.commit_lsn.max(last);
            }
            Ok(())
        } else {
            Err(format!("no quorum: {acked}/{quorum} acks for lsn {last}"))
        }
    }

    /// Leader heartbeat round: empty Replicates to every peer (resetting
    /// their election timers, triggering catch-up on laggards) and a
    /// quorum-median pass over the acked lsns to advance the commit
    /// index — which lets records that missed their own quorum barrier
    /// (e.g. during a healed partition) commit retroactively.
    fn send_heartbeats(&self) {
        let (term, self_addr, peers, commit) = {
            let r = self.repl.lock().unwrap();
            if r.role != Role::Leader || r.peers.is_empty() {
                return;
            }
            (r.term, r.self_addr.clone(), r.peers.clone(), r.commit_lsn)
        };
        let my_last = self.last_lsn();
        let mut lsns = vec![my_last];
        let mut max_term = term;
        for p in &peers {
            let req = Msg::Replicate {
                term,
                leader: self_addr.clone(),
                prev_lsn: my_last,
                commit_lsn: commit,
                records: Vec::new(),
            };
            match peer_call(&self_addr, p, req) {
                Ok(Msg::ReplicateAck {
                    term: t,
                    last_lsn,
                    ok,
                }) => {
                    max_term = max_term.max(t);
                    lsns.push(if ok && t == term { last_lsn } else { 0 });
                }
                _ => lsns.push(0),
            }
        }
        let quorum = (peers.len() + 1) / 2 + 1;
        lsns.sort_unstable_by(|a, b| b.cmp(a));
        let quorum_lsn = lsns[quorum - 1];
        let mut r = self.repl.lock().unwrap();
        if max_term > r.term {
            r.term = max_term;
            r.voted_for = None;
            r.role = Role::Follower;
            r.leader_hint = String::new();
            if let Some(dir) = r.term_dir.clone() {
                let _ = wal::save_term(&dir, r.term, None, r.accepted_term);
            }
            return;
        }
        if r.role == Role::Leader && r.term == term {
            r.commit_lsn = r.commit_lsn.max(quorum_lsn.min(my_last));
        }
    }

    /// One consensus timer tick: a leader heartbeats its peers, a
    /// follower/candidate whose election timer expired campaigns.  All
    /// timers read the manager's skewable clock, so tests drive
    /// elections with [`ManagerState::advance_clock`] + explicit ticks;
    /// nothing fires between ticks.
    pub fn tick_consensus(&self) {
        // Scrub rides the same ticker (leader-gated inside): solo
        // managers return early below and would otherwise never scrub.
        self.maybe_scrub();
        let (role, solo, due) = {
            let r = self.repl.lock().unwrap();
            let due = self.now().saturating_duration_since(r.last_contact) >= election_timeout(&r);
            (r.role, r.peers.is_empty(), due)
        };
        if solo {
            return;
        }
        match role {
            Role::Leader => self.send_heartbeats(),
            Role::Follower | Role::Candidate => {
                if due {
                    if let Err(e) = self.campaign() {
                        eprintln!("gpustore manager: election aborted: {e}");
                    }
                }
            }
        }
    }

    /// Stand for election (Raft §5.2): durably bump the term with a
    /// self-vote, solicit votes from every peer, and take leadership on
    /// a majority.  Returns `Ok(true)` iff this manager is the leader
    /// afterwards.  Winning refreshes node liveness (storage nodes
    /// heartbeat their configured manager, not us) and immediately
    /// heartbeats the group to establish authority.
    pub fn campaign(&self) -> Result<bool> {
        let last_lsn = self.last_lsn();
        let (term, self_addr, peers, accepted) = {
            let mut r = self.repl.lock().unwrap();
            if r.peers.is_empty() || r.role == Role::Leader {
                return Ok(r.role == Role::Leader);
            }
            r.term += 1;
            r.role = Role::Candidate;
            r.voted_for = Some(r.self_addr.clone());
            r.leader_hint = String::new();
            r.last_contact = self.now();
            if let Some(dir) = r.term_dir.clone() {
                // An unpersistable self-vote must not be cast.
                wal::save_term(&dir, r.term, r.voted_for.as_deref(), r.accepted_term)?;
            }
            (r.term, r.self_addr.clone(), r.peers.clone(), r.accepted_term)
        };
        let quorum = (peers.len() + 1) / 2 + 1;
        let mut granted = 1usize; // self
        let mut max_term = term;
        for p in &peers {
            let req = Msg::RequestVote {
                term,
                candidate: self_addr.clone(),
                last_term: accepted,
                last_lsn,
            };
            if let Ok(Msg::VoteReply { term: t, granted: g }) = peer_call(&self_addr, p, req) {
                max_term = max_term.max(t);
                if g && t == term {
                    granted += 1;
                }
            }
        }
        let mut r = self.repl.lock().unwrap();
        if max_term > r.term {
            r.term = max_term;
            r.voted_for = None;
            r.role = Role::Follower;
            if let Some(dir) = r.term_dir.clone() {
                let _ = wal::save_term(&dir, r.term, None, r.accepted_term);
            }
            return Ok(false);
        }
        if r.term != term || r.role != Role::Candidate {
            // Superseded while we were soliciting (a valid leader
            // contacted us, or a newer campaign started).
            return Ok(r.role == Role::Leader);
        }
        if granted >= quorum {
            // From here on our log is the canonical term-`term` history
            // (every peer re-bootstraps to match it) — adopt the term
            // as our log's accepted term, durably, before leading.
            if let Some(dir) = r.term_dir.clone() {
                wal::save_term(&dir, r.term, r.voted_for.as_deref(), term)?;
            }
            r.accepted_term = term;
            r.role = Role::Leader;
            r.leader_hint = r.self_addr.clone();
            r.last_contact = self.now();
            drop(r);
            self.refresh_node_liveness();
            self.send_heartbeats();
            return Ok(true);
        }
        r.role = Role::Follower;
        Ok(false)
    }

    /// Refresh every registered node's liveness clock (used when taking
    /// leadership: nodes heartbeat their configured manager, so a fresh
    /// leader would otherwise judge them all dead for placement until
    /// the heartbeat window re-elapses).
    fn refresh_node_liveness(&self) {
        let mut guard = self.inner.lock().unwrap();
        let now = self.now();
        for n in guard.nodes.iter_mut() {
            n.last_beat = now;
        }
    }

    /// Records appended after `after`, from the ship buffer.
    fn ship_tail_since(&self, after: u64) -> Vec<WalEntry> {
        let g = self.inner.lock().unwrap();
        g.ship
            .iter()
            .filter(|(l, _)| *l > after)
            .map(|(l, d)| WalEntry {
                lsn: *l,
                data: d.clone(),
            })
            .collect()
    }

    /// Force the WAL tail to disk (the quorum-commit durability
    /// barrier; no-op for in-memory managers).
    fn sync_wal(&self) -> Result<()> {
        let mut guard = self.inner.lock().unwrap();
        if let Some(w) = guard.wal.as_mut() {
            w.sync()?;
        }
        Ok(())
    }

    /// This manager's current consensus role.
    pub fn role(&self) -> Role {
        self.repl.lock().unwrap().role
    }

    /// True when this manager currently leads its quorum group (always
    /// true in solo mode).
    pub fn is_leader(&self) -> bool {
        self.role() == Role::Leader
    }

    /// Current term.
    pub fn current_term(&self) -> u64 {
        self.repl.lock().unwrap().term
    }

    /// Last known leader address ("" = unknown).
    pub fn leader_hint(&self) -> String {
        self.repl.lock().unwrap().leader_hint.clone()
    }

    /// Highest lsn known replicated on a quorum (== `last_lsn` in solo
    /// mode, once a message has been handled).
    pub fn commit_lsn(&self) -> u64 {
        self.repl.lock().unwrap().commit_lsn
    }

    /// `(lsn, crc32)` of every retained record at or below the commit
    /// index — the committed prefix the divergence property compares
    /// across replicas (on the intersection of retained windows).
    pub fn committed_crcs(&self) -> Vec<(u64, u32)> {
        let commit = self.repl.lock().unwrap().commit_lsn;
        let g = self.inner.lock().unwrap();
        g.crc_log
            .range(..=commit)
            .map(|(l, c)| (*l, *c))
            .collect()
    }
}

/// Election timeout for this member: base plus a deterministic stagger
/// by rank in the sorted member list.
fn election_timeout(r: &Repl) -> Duration {
    let mut members: Vec<&str> = r.peers.iter().map(|s| s.as_str()).collect();
    members.push(r.self_addr.as_str());
    members.sort_unstable();
    let idx = members
        .iter()
        .position(|m| *m == r.self_addr.as_str())
        .unwrap_or(0);
    ELECTION_TIMEOUT_BASE + ELECTION_STAGGER * (idx as u32)
}

/// One request/reply to a consensus peer on a fresh bounded connection.
/// Consults the fault-injection partition table first, so tests can cut
/// manager↔manager links deterministically (and instantaneously — a cut
/// link fails at dial time, no timeouts involved).
pub fn peer_call(from: &str, to: &str, msg: Msg) -> Result<Msg> {
    if super::partition::is_partitioned(from, to) {
        return Err(Error::Manager(format!("partitioned: {from} <-> {to}")));
    }
    let conn = Conn::connect_timeout(to, PEER_CONNECT_TIMEOUT)?;
    conn.set_read_timeout(PEER_READ_TIMEOUT)?;
    let rc = conn.try_clone()?;
    let mut r = BufReader::new(rc);
    let mut w = BufWriter::new(conn);
    msg.write_to(&mut w)?;
    Msg::read_from(&mut r)?
        .ok_or_else(|| Error::Manager(format!("peer {to} closed the connection")))
}

/// Aggregate manager bookkeeping returned by
/// [`ManagerState::block_stats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct BlockStats {
    /// Replica copies the manager believes live (committed or pending).
    pub blocks: u64,
    /// Payload bytes behind those copies.
    pub bytes: u64,
    /// Outstanding provisional claim occurrences (uncommitted writers).
    pub pending_claims: u64,
    /// Blocks currently pinned by at least one read lease.
    pub pinned_blocks: u64,
    /// Live read leases.
    pub read_leases: u64,
    /// Live write leases.
    pub write_leases: u64,
}

fn validate_blocks(blocks: &[BlockMeta], registered: usize) -> Option<String> {
    for (i, m) in blocks.iter().enumerate() {
        if m.replicas.is_empty() {
            return Some(format!("block {i}: empty replica set"));
        }
        for &r in &m.replicas {
            if r as usize >= registered {
                return Some(format!(
                    "block {i}: replica node {r} is not registered ({registered} nodes known)"
                ));
            }
        }
    }
    None
}

impl ManagerState {
    /// Serialize the durable slice of the state (everything except
    /// clocks, the policy cursor and the ship buffer) into a canonical,
    /// sorted [`SnapshotState`] — sorted so images of the same history
    /// compare equal regardless of hash-map iteration order AND shard
    /// count (the properties suite compares sharded to unsharded
    /// through this).
    fn snapshot_of(&self, g: &Inner, lsn: u64) -> SnapshotState {
        let mut files: Vec<(String, u64, Vec<BlockMeta>)> = g
            .files
            .iter()
            .map(|(name, e)| (name.clone(), e.version, e.blocks.clone()))
            .collect();
        files.sort_by(|a, b| a.0.cmp(&b.0));
        let mut blocks: Vec<SnapBlock> = Vec::new();
        self.blocks.for_each(|hash, b| {
            blocks.push(SnapBlock {
                hash: *hash,
                len: b.len,
                replicas: b.replicas.clone(),
                refs: b.refs,
                pending: b.pending,
                pins: b.pins,
                placed_by: b.placed_by.clone(),
                ec: b.ec,
            });
        });
        blocks.sort_by_key(|b| b.hash);
        let mut leases: Vec<SnapLease> = Vec::new();
        self.leases.for_each(|id, l| {
            leases.push(SnapLease {
                id: *id,
                tag: l.tag.clone(),
                write: l.write,
                hashes: l.hashes.clone(),
            });
        });
        leases.sort_by_key(|l| l.id);
        SnapshotState {
            lsn,
            files,
            blocks,
            nodes: g.nodes.iter().map(|n| n.addr.clone()).collect(),
            leases,
            next_lease: g.next_lease,
        }
    }

    /// Rebuild the in-memory state from a snapshot image.  Clocks
    /// restart conservatively: every node is "alive" as of now (the
    /// heartbeat window re-judges it within one timeout) and every
    /// lease gets a full TTL (surviving holders renew as usual,
    /// abandoned ones lapse one window after restart — PR 3's
    /// reclamation, just delayed).
    fn install_snapshot_into(&self, g: &mut Inner, snap: &SnapshotState, now: Instant) {
        g.files = snap
            .files
            .iter()
            .map(|(name, version, blocks)| {
                (
                    name.clone(),
                    FileEntry {
                        version: *version,
                        blocks: blocks.clone(),
                    },
                )
            })
            .collect();
        self.blocks.clear();
        for b in &snap.blocks {
            self.blocks.insert(
                b.hash,
                BlockInfo {
                    replicas: b.replicas.clone(),
                    ec: b.ec,
                    len: b.len,
                    refs: b.refs,
                    pending: b.pending,
                    pins: b.pins,
                    placed_by: b.placed_by.clone(),
                },
            );
        }
        g.nodes = snap
            .nodes
            .iter()
            .map(|addr| NodeSlot {
                addr: addr.clone(),
                last_beat: now,
            })
            .collect();
        self.leases.clear();
        for l in &snap.leases {
            self.leases.insert(
                l.id,
                Lease {
                    tag: l.tag.clone(),
                    write: l.write,
                    hashes: l.hashes.clone(),
                    expires_at: now + self.lease_timeout,
                },
            );
        }
        g.next_lease = snap.next_lease;
        g.last_lsn = snap.lsn;
        g.ship.clear();
        g.crc_log.clear();
    }
}

// ---- background scrub/repair + anti-entropy (PR 10) ----

/// Outcome of one scrub/repair pass ([`ManagerState::scrub_once`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScrubReport {
    /// Committed/pinned blocks examined.
    pub scanned: u64,
    /// Blocks found under-redundant (dead home or suspect copy).
    pub degraded: u64,
    /// Blocks whose full redundancy was restored this pass.
    pub repaired: u64,
    /// Payload bytes moved by repairs this pass.
    pub bytes_moved: u64,
    /// Degraded blocks left for a later pass (budget exhausted, no
    /// healthy source, or nowhere live to put the new copy).
    pub deferred: u64,
}

/// Outcome of one anti-entropy sweep ([`ManagerState::anti_entropy`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AntiEntropyReport {
    /// Nodes whose inventories were fetched and reconciled.
    pub nodes_swept: u64,
    /// Copies held by a node that the manager no longer accounts for —
    /// deleted (includes replicas stranded by GC batches abandoned at
    /// a failed quorum barrier).
    pub stale_copies: u64,
    /// Copies the manager expects on a node that the node lacks —
    /// marked suspect for the next scrub pass to re-create.
    pub missing_copies: u64,
}

/// Live-redundancy summary ([`ManagerState::redundancy_report`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RedundancyReport {
    /// Committed/pinned blocks examined.
    pub blocks: u64,
    /// Blocks with every home alive and no suspect copies.
    pub fully_redundant: u64,
    /// Blocks still readable but below their redundancy target.
    pub degraded: u64,
    /// Blocks with too few healthy homes left to read or rebuild.
    pub unreadable: u64,
}

impl ManagerState {
    /// Configure the background scrub/repair loop: run a pass every
    /// `interval` (ZERO disables, the default) moving at most
    /// `interval × repair_mbps` (Mbit/s) of repair payload per pass
    /// (`0.0` = unlimited).  Takes effect at the next tick.
    pub fn set_scrub(&self, interval: Duration, repair_mbps: f64) {
        let mut s = self.scrub.lock().unwrap();
        s.interval = interval;
        s.repair_mbps = repair_mbps;
    }

    /// Run a scrub + anti-entropy pass when the configured interval
    /// has elapsed on the manager's (skewable) clock.  Rides
    /// [`ManagerState::tick`] and [`ManagerState::tick_consensus`];
    /// leader-gated inside, so exactly one manager of a quorum group
    /// repairs.
    pub fn maybe_scrub(&self) {
        let due = {
            let mut s = self.scrub.lock().unwrap();
            if s.interval.is_zero() {
                return;
            }
            let now = self.now();
            let elapsed = match s.last_run {
                Some(t) => now.saturating_duration_since(t) >= s.interval,
                None => true,
            };
            if elapsed {
                s.last_run = Some(now);
            }
            elapsed
        };
        if due && self.is_leader() {
            self.scrub_once();
            self.anti_entropy();
        }
    }

    /// One scrub/repair pass (leader/solo only — followers receive the
    /// resulting [`Record::Rehome`]s through replication).  Detects
    /// committed blocks that lost a home (dead node) or hold a suspect
    /// copy, re-creates the missing copies/shards on live nodes from
    /// the surviving ones, and publishes each new replica set through
    /// the logged, quorum-gated [`Record::Rehome`] path.  Bytes move
    /// outside every lock; the re-home re-validates under the lock
    /// before logging.
    pub fn scrub_once(&self) -> ScrubReport {
        let mut report = ScrubReport::default();
        if !self.is_leader() {
            return report;
        }
        let (alive, addrs) = {
            let g = self.inner.lock().unwrap();
            let now = self.now();
            let alive: Vec<u32> = g
                .nodes
                .iter()
                .enumerate()
                .filter(|(_, n)| {
                    now.saturating_duration_since(n.last_beat) < self.heartbeat_timeout
                })
                .map(|(id, _)| id as u32)
                .collect();
            let addrs: Vec<String> = g.nodes.iter().map(|n| n.addr.clone()).collect();
            (alive, addrs)
        };
        let cfg = *self.scrub.lock().unwrap();
        // Per-pass byte budget from the bandwidth token bucket: one
        // interval's worth of Mbit/s (a direct call with scrubbing
        // disabled budgets one second's worth).
        let window = if cfg.interval.is_zero() {
            1.0
        } else {
            cfg.interval.as_secs_f64()
        };
        let mut budget: u64 = if cfg.repair_mbps <= 0.0 {
            u64::MAX
        } else {
            (cfg.repair_mbps * 125_000.0 * window).max(1.0) as u64
        };
        let suspects: HashSet<(Digest, u32)> = self.suspects.lock().unwrap().clone();
        let healthy =
            |hash: &Digest, r: u32| alive.contains(&r) && !suspects.contains(&(*hash, r));
        // Candidates: committed or pinned blocks with an unhealthy
        // home.  Mid-write (pending-only) blocks are the writer's to
        // finish — repairing them would race the transfer.
        let mut candidates: Vec<(Digest, u32, Vec<u32>, Option<(u8, u8)>)> = Vec::new();
        self.blocks.for_each(|hash, b| {
            if b.refs == 0 && b.pins == 0 {
                return;
            }
            report.scanned += 1;
            if b.replicas.iter().any(|r| !healthy(hash, *r)) {
                candidates.push((*hash, b.len, b.replicas.clone(), b.ec));
            }
        });
        candidates.sort_by_key(|c| c.0); // deterministic repair order
        report.degraded = candidates.len() as u64;
        for (hash, len, replicas, ec) in candidates {
            if budget == 0 {
                report.deferred += 1;
                continue;
            }
            let bad: Vec<usize> = (0..replicas.len())
                .filter(|&i| !healthy(&hash, replicas[i]))
                .collect();
            // New homes: a suspect copy on a live node heals in place
            // (the re-put overwrites it); a dead home moves to a live
            // node not already holding part of this block.
            let mut new_replicas = replicas.clone();
            let mut taken: HashSet<u32> = replicas
                .iter()
                .copied()
                .filter(|r| alive.contains(r))
                .collect();
            let mut placed = true;
            for &i in &bad {
                if alive.contains(&replicas[i]) {
                    continue;
                }
                match alive.iter().copied().find(|n| !taken.contains(n)) {
                    Some(fresh) => {
                        taken.insert(fresh);
                        new_replicas[i] = fresh;
                    }
                    None => {
                        placed = false;
                        break;
                    }
                }
            }
            if !placed {
                report.deferred += 1;
                continue;
            }
            let moved = match ec {
                // Replication: copy from any healthy source, verified
                // against the content address so a silently corrupt
                // source never propagates.
                None => {
                    let data = (0..replicas.len())
                        .filter(|i| !bad.contains(i))
                        .find_map(|i| {
                            let d = fetch_block(&addrs, replicas[i], hash)?;
                            (crate::hash::md5(&d) == hash).then_some(d)
                        });
                    let Some(data) = data else {
                        report.deferred += 1;
                        continue;
                    };
                    let mut bytes = 0u64;
                    let ok = bad.iter().all(|&i| {
                        let put = put_block(&addrs, new_replicas[i], hash, data.clone());
                        if put {
                            bytes += data.len() as u64;
                        }
                        put
                    });
                    if !ok {
                        report.deferred += 1;
                        continue;
                    }
                    bytes
                }
                // Erasure coding: gather any k healthy shards and
                // rebuild exactly the lost positions.
                Some((k, m)) => {
                    let (k, m) = (k as usize, m as usize);
                    let slen = crate::ec::shard_len(len as usize, k);
                    let mut shards: Vec<Option<Vec<u8>>> = vec![None; replicas.len()];
                    let mut have = 0usize;
                    for i in 0..replicas.len() {
                        if have >= k {
                            break;
                        }
                        if bad.contains(&i) {
                            continue;
                        }
                        if let Some(s) = fetch_block(&addrs, replicas[i], hash) {
                            if s.len() == slen {
                                shards[i] = Some(s);
                                have += 1;
                            }
                        }
                    }
                    if have < k || shards.len() != k + m {
                        report.deferred += 1;
                        continue;
                    }
                    let mut bytes = 0u64;
                    let ok = bad.iter().all(|&i| {
                        match crate::ec::rebuild_shard(k, m, &shards, len as usize, i) {
                            Ok(shard) => {
                                let put = put_block(&addrs, new_replicas[i], hash, shard);
                                if put {
                                    bytes += slen as u64;
                                }
                                put
                            }
                            Err(_) => false,
                        }
                    });
                    if !ok {
                        report.deferred += 1;
                        continue;
                    }
                    bytes
                }
            };
            // Publish the new homes through the same logged,
            // quorum-gated path every other mutation takes (no-op when
            // only in-place suspect copies were healed).
            if new_replicas != replicas && !self.log_rehome(hash, &replicas, new_replicas) {
                report.deferred += 1;
                continue;
            }
            budget = budget.saturating_sub(moved.max(1));
            report.bytes_moved += moved;
            report.repaired += 1;
            let mut sus = self.suspects.lock().unwrap();
            for &i in &bad {
                sus.remove(&(hash, replicas[i]));
            }
        }
        report
    }

    /// Log + apply a [`Record::Rehome`] for `hash`, gated on the block
    /// still holding `expect` (the repair moved bytes outside the
    /// lock; a release or competing re-home in between voids the
    /// plan), then push it through the quorum barrier.  False = not
    /// acknowledged (the pass defers; stray copies are anti-entropy's
    /// to reclaim).
    fn log_rehome(&self, hash: Digest, expect: &[u32], new_replicas: Vec<u32>) -> bool {
        let before = self.last_lsn();
        let logged = {
            let mut guard = self.inner.lock().unwrap();
            let g = &mut *guard;
            let now = self.now();
            let unchanged = self
                .blocks
                .get_with(&hash, |e| e.replicas.as_slice() == expect)
                .unwrap_or(false);
            if !unchanged {
                false
            } else {
                let mut freed = Vec::new();
                let ok = self
                    .log_apply(
                        g,
                        Record::Rehome {
                            hash,
                            replicas: new_replicas,
                        },
                        now,
                        &mut freed,
                    )
                    .is_ok();
                debug_assert!(freed.is_empty(), "rehome frees nothing");
                ok
            }
        };
        if !logged {
            return false;
        }
        let appended = self.ship_tail_since(before);
        appended.is_empty() || self.replicate_to_quorum(before, appended).is_ok()
    }

    /// One anti-entropy sweep (leader/solo only): fetch every node's
    /// block inventory and reconcile it against the manager's block
    /// table.  A copy the manager no longer accounts for — the hash
    /// gone entirely (e.g. stranded by a GC batch abandoned at a
    /// failed quorum barrier) or this node no longer among its homes
    /// (re-homed by repair) — is deleted; a copy the manager expects
    /// that the node lacks is marked suspect for the next scrub pass.
    /// Metadata is never mutated: the sweep only moves the nodes
    /// toward what the table already says.
    pub fn anti_entropy(&self) -> AntiEntropyReport {
        let mut report = AntiEntropyReport::default();
        if !self.is_leader() {
            return report;
        }
        let addrs: Vec<String> = {
            let g = self.inner.lock().unwrap();
            g.nodes.iter().map(|n| n.addr.clone()).collect()
        };
        for (id, addr) in addrs.iter().enumerate() {
            let id = id as u32;
            let Some(inventory) = list_blocks_on(addr) else {
                continue; // unreachable: reconciled on a later pass
            };
            report.nodes_swept += 1;
            let held: HashSet<Digest> = inventory.iter().copied().collect();
            // Stale copies are decided UNDER the state lock and marked
            // GC-in-flight before any delete goes out — the same
            // discipline as commit-time GC, so an allocation racing
            // this sweep waits instead of re-uploading into a pending
            // delete.
            let stale: Vec<Digest> = {
                let _g = self.inner.lock().unwrap();
                let mut inflight = self.gc_inflight.lock().unwrap();
                inventory
                    .into_iter()
                    .filter(|h| {
                        let keep = self
                            .blocks
                            .get_with(h, |e| e.replicas.contains(&id))
                            .unwrap_or(false)
                            // An in-flight GC batch already owns this
                            // hash's deletes; don't double-claim it.
                            || inflight.contains(h);
                        if !keep {
                            inflight.insert(*h);
                        }
                        !keep
                    })
                    .collect()
            };
            if !stale.is_empty() {
                let freed: Vec<(Digest, Vec<u32>)> =
                    stale.iter().map(|h| (*h, vec![id])).collect();
                gc_delete(&freed, &addrs);
                let mut inflight = self.gc_inflight.lock().unwrap();
                for h in &stale {
                    inflight.remove(h);
                }
                drop(inflight);
                self.gc_done.notify_all();
                report.stale_copies += stale.len() as u64;
            }
            // The reverse direction: copies the table expects here
            // that the node lost go on the suspect list — the next
            // scrub pass re-creates them from the surviving homes.
            let mut missing = Vec::new();
            self.blocks.for_each(|hash, b| {
                if (b.refs > 0 || b.pins > 0)
                    && b.replicas.contains(&id)
                    && !held.contains(hash)
                {
                    missing.push(*hash);
                }
            });
            if !missing.is_empty() {
                report.missing_copies += missing.len() as u64;
                let mut sus = self.suspects.lock().unwrap();
                for h in missing {
                    sus.insert((h, id));
                }
            }
        }
        report
    }

    /// Live-redundancy summary over committed/pinned blocks (what the
    /// fault-injection tests and the repair bench assert on): a block
    /// is *degraded* when any home is dead or suspect, *unreadable*
    /// when fewer healthy homes remain than a read needs (one copy, or
    /// k shards).
    pub fn redundancy_report(&self) -> RedundancyReport {
        let alive: Vec<u32> = {
            let g = self.inner.lock().unwrap();
            let now = self.now();
            g.nodes
                .iter()
                .enumerate()
                .filter(|(_, n)| {
                    now.saturating_duration_since(n.last_beat) < self.heartbeat_timeout
                })
                .map(|(id, _)| id as u32)
                .collect()
        };
        let suspects = self.suspects.lock().unwrap().clone();
        let mut report = RedundancyReport::default();
        self.blocks.for_each(|hash, b| {
            if b.refs == 0 && b.pins == 0 {
                return;
            }
            report.blocks += 1;
            let up = b
                .replicas
                .iter()
                .filter(|&&r| alive.contains(&r) && !suspects.contains(&(*hash, r)))
                .count();
            let need = match b.ec {
                Some((k, _)) => k as usize,
                None => 1,
            };
            if up < need {
                report.unreadable += 1;
            } else if up < b.replicas.len() {
                report.degraded += 1;
            } else {
                report.fully_redundant += 1;
            }
        });
        report
    }
}

/// Fetch one block/shard copy from a node (bounded connect,
/// content-addressed; `None` on any error — repair defers to a later
/// pass rather than blocking).
fn fetch_block(addrs: &[String], node: u32, hash: Digest) -> Option<Vec<u8>> {
    let addr = addrs.get(node as usize)?;
    let conn = Conn::connect_timeout(addr, Duration::from_secs(1)).ok()?;
    let rc = conn.try_clone().ok()?;
    let mut r = BufReader::new(rc);
    let mut w = BufWriter::new(conn);
    Msg::GetBlock { req: 0, hash }.write_to(&mut w).ok()?;
    match Msg::read_from(&mut r).ok()?? {
        Msg::Data { data, .. } => Some(data),
        _ => None,
    }
}

/// Put one repaired block/shard copy onto a node.
fn put_block(addrs: &[String], node: u32, hash: Digest, data: Vec<u8>) -> bool {
    let Some(addr) = addrs.get(node as usize) else {
        return false;
    };
    let Ok(conn) = Conn::connect_timeout(addr, Duration::from_secs(1)) else {
        return false;
    };
    let Ok(rc) = conn.try_clone() else {
        return false;
    };
    let mut r = BufReader::new(rc);
    let mut w = BufWriter::new(conn);
    if (Msg::PutBlock { req: 0, hash, data }).write_to(&mut w).is_err() {
        return false;
    }
    matches!(Msg::read_from(&mut r), Ok(Some(Msg::OkFor { .. })))
}

/// Fetch a node's full block inventory (`None` = unreachable).
fn list_blocks_on(addr: &str) -> Option<Vec<Digest>> {
    let conn = Conn::connect_timeout(addr, Duration::from_secs(1)).ok()?;
    let rc = conn.try_clone().ok()?;
    let mut r = BufReader::new(rc);
    let mut w = BufWriter::new(conn);
    Msg::ListBlocks.write_to(&mut w).ok()?;
    match Msg::read_from(&mut r).ok()?? {
        Msg::BlockList { hashes } => Some(hashes),
        _ => None,
    }
}

/// Keep the per-lsn crc history bounded (oldest entries fall off; the
/// divergence property compares prefixes on the retained intersection).
fn trim_crc_log(g: &mut Inner) {
    while g.crc_log.len() > CRC_LOG_CAP {
        let Some(k) = g.crc_log.keys().next().copied() else {
            break;
        };
        g.crc_log.remove(&k);
    }
}

/// Best-effort deletion of freed blocks on their owning nodes.  Dead or
/// unreachable nodes are skipped — the block is already unreferenced,
/// so a leaked copy only costs space until the node rejoins or dies.
fn gc_delete(freed: &[(Digest, Vec<u32>)], addrs: &[String]) {
    let mut per_node: HashMap<u32, Vec<Digest>> = HashMap::new();
    for (hash, replicas) in freed {
        for r in replicas {
            per_node.entry(*r).or_default().push(*hash);
        }
    }
    for (node, hashes) in per_node {
        let Some(addr) = addrs.get(node as usize) else {
            continue;
        };
        // Bounded connect: a black-holed node must not stall the
        // committing client for the OS SYN timeout.
        let Ok(conn) = Conn::connect_timeout(addr, Duration::from_secs(1)) else {
            continue;
        };
        let Ok(rc) = conn.try_clone() else { continue };
        let mut r = BufReader::new(rc);
        let mut w = BufWriter::new(conn);
        for hash in hashes {
            if Msg::DeleteBlock { hash }.write_to(&mut w).is_err() {
                break;
            }
            if Msg::read_from(&mut r).is_err() {
                break;
            }
        }
    }
}

/// The servable state behind a running [`Manager`]: swapping it (and
/// bumping `epoch`) is how [`Manager::crash`]/[`Manager::restart`]
/// simulate a process kill without giving up the bound port — the
/// listener survives, so clients see connection-level errors while
/// "down" and recover against the same address, with no TIME_WAIT
/// rebind races in tests.
struct Slot {
    state: Arc<ManagerState>,
    up: bool,
    /// Bumped on every crash/restart.  A connection thread that
    /// resolved state before a crash re-checks the epoch before writing
    /// its reply: a stale reply (computed against the now-discarded
    /// state) is dropped on the floor, exactly like a reply a killed
    /// process never sent.
    epoch: u64,
}

// ---- the manager's serve loop (PR 9) ----

/// Message tags the reactor routes by (must match [`Msg::tag`]; the
/// `lane_tags_match_protocol` test pins them together).
const TAG_HEARTBEAT: u8 = 19;
const TAG_NODE_LIST: u8 = 20;
const TAG_FETCH_SNAPSHOT: u8 = 30;
const TAG_FETCH_WAL: u8 = 32;
const TAG_REQUEST_VOTE: u8 = 34;
const TAG_REPLICATE: u8 = 36;

/// Worker lanes.  Three lanes keep the pool deadlock-free under
/// consensus: client mutations (lane 0) may block inside the quorum
/// barrier, peer consensus traffic (lane 1) may block calling back to
/// the leader during catch-up, and reads (lane 2) never make an
/// outbound call — so the messages a blocked lane is WAITING ON are
/// always served by a different lane.
const LANE_CLIENT: usize = 0;
const LANE_PEER: usize = 1;
const LANE_READ: usize = 2;

/// Default client-lane worker count in event mode (mirrors the node's).
pub const DEFAULT_MANAGER_SERVE_THREADS: usize = 4;
const PEER_LANE_WORKERS: usize = 2;
const READ_LANE_WORKERS: usize = 2;

/// [`FrameHandler`] adapter: decodes each frame into a [`Msg`], routes
/// it to a lane by tag, resolves the serve [`Slot`] per message (so
/// crash/restart stays visible mid-connection) and suppresses replies
/// computed against a crashed epoch — the exact semantics of the old
/// thread-per-connection `serve_conn`, minus the thread.
struct ManagerService {
    slot: Arc<Mutex<Slot>>,
}

impl FrameHandler for ManagerService {
    fn lanes(&self) -> usize {
        3
    }

    fn lane(&self, tag: u8) -> usize {
        match tag {
            TAG_REQUEST_VOTE | TAG_REPLICATE => LANE_PEER,
            TAG_HEARTBEAT | TAG_NODE_LIST | TAG_FETCH_SNAPSHOT | TAG_FETCH_WAL => LANE_READ,
            _ => LANE_CLIENT,
        }
    }

    fn on_frame(&self, tag: u8, body: Vec<u8>, replies: &mut Replies) {
        let Ok(msg) = Msg::decode(tag, &body) else {
            replies.sever();
            return;
        };
        let (state, epoch) = {
            let slot = self.slot.lock().unwrap();
            if !slot.up {
                replies.sever();
                return;
            }
            (slot.state.clone(), slot.epoch)
        };
        let reply = state.handle_replicated(msg);
        // A crash while we were handling: the state this reply was
        // computed against is gone.  Sever instead of answering from
        // the dead.
        if self.slot.lock().unwrap().epoch != epoch {
            replies.sever();
            return;
        }
        replies.frame(reply.encode());
    }
}

/// How a [`Manager`] serves its listener.
enum ManagerServe {
    /// PR 9 default: the readiness reactor + worker lanes.
    Event(Option<Reactor>),
    /// Legacy thread-per-connection accept loop (`--serve-threads 0`).
    Thread {
        accept_thread: Option<JoinHandle<()>>,
    },
}

/// A running manager server.
pub struct Manager {
    addr: String,
    slot: Arc<Mutex<Slot>>,
    stop: Arc<AtomicBool>,
    serve: ManagerServe,
    ticker_thread: Option<JoinHandle<()>>,
}

impl Manager {
    /// Bind and serve on `addr` ("127.0.0.1:0" for ephemeral) with the
    /// default single-copy round-robin policy.
    pub fn spawn(addr: &str) -> Result<Manager> {
        Manager::spawn_with_policy(addr, Box::new(RoundRobinStripe::default()))
    }

    /// Bind and serve with an explicit placement policy and the default
    /// lease timeout.
    pub fn spawn_with_policy(addr: &str, policy: Box<dyn PlacementPolicy>) -> Result<Manager> {
        Manager::spawn_with_opts(addr, policy, DEFAULT_LEASE_TIMEOUT, None)
    }

    /// Bind and serve with an explicit placement policy, lease timeout
    /// (surfaced as `--lease-timeout` in the CLI and
    /// [`crate::config::ClusterConfig::lease_timeout`]) and optional
    /// durability (`--data-dir`): with a data dir the manager recovers
    /// its state from the latest snapshot + log tail before serving.
    pub fn spawn_with_opts(
        addr: &str,
        policy: Box<dyn PlacementPolicy>,
        lease_timeout: Duration,
        durability: Option<DurabilityOpts>,
    ) -> Result<Manager> {
        let state = Arc::new(ManagerState::with_durability(
            policy,
            lease_timeout,
            durability,
        )?);
        Manager::serve(addr, state)
    }

    /// Bind and serve an already-built state (follower promotion, or a
    /// state recovered/inspected out-of-band).
    pub fn serve(addr: &str, state: Arc<ManagerState>) -> Result<Manager> {
        Manager::serve_listener(Listener::bind(addr)?, state)
    }

    /// Serve an already-built state on an already-bound listener.  The
    /// multi-manager cluster spawner binds every member's listener
    /// first so the full peer address list exists before any member's
    /// consensus state is configured.  Serves in the PR 9 default mode
    /// (event-driven reactor); see [`Manager::serve_listener_opts`].
    pub fn serve_listener(listener: Listener, state: Arc<ManagerState>) -> Result<Manager> {
        Manager::serve_listener_opts(listener, state, ServeMode::default(), 0)
    }

    /// Serve with an explicit serve mode.  `serve_threads` sizes the
    /// client-mutation worker lane in event mode (0 = the default,
    /// [`DEFAULT_MANAGER_SERVE_THREADS`]); the peer and read lanes have
    /// fixed small pools.  Thread mode reproduces the pre-PR-9
    /// thread-per-connection accept loop bit-for-bit.
    pub fn serve_listener_opts(
        listener: Listener,
        state: Arc<ManagerState>,
        mode: ServeMode,
        serve_threads: usize,
    ) -> Result<Manager> {
        let addr = listener.local_addr()?;
        let slot = Arc::new(Mutex::new(Slot {
            state,
            up: true,
            epoch: 0,
        }));
        let stop = Arc::new(AtomicBool::new(false));
        let serve = match mode {
            ServeMode::Event => {
                let client_workers = if serve_threads == 0 {
                    DEFAULT_MANAGER_SERVE_THREADS
                } else {
                    serve_threads
                };
                let port = addr.rsplit(':').next().unwrap_or("0");
                let reactor = Reactor::serve(
                    listener,
                    Arc::new(ManagerService { slot: slot.clone() }),
                    ReactorOpts {
                        name: format!("mg{port}"),
                        workers: vec![client_workers, PEER_LANE_WORKERS, READ_LANE_WORKERS],
                        ..ReactorOpts::default()
                    },
                )?;
                ManagerServe::Event(Some(reactor))
            }
            ServeMode::Thread => {
                let (sl, sp) = (slot.clone(), stop.clone());
                let accept_thread = std::thread::Builder::new()
                    .name("mosa-manager".into())
                    .spawn(move || accept_loop(listener, sl, sp))
                    .map_err(crate::Error::Io)?;
                ManagerServe::Thread {
                    accept_thread: Some(accept_thread),
                }
            }
        };
        Ok(Manager {
            addr,
            slot,
            stop,
            serve,
            ticker_thread: None,
        })
    }

    /// The serve loop's gauges (event mode only; `None` in thread mode).
    pub fn serve_gauges(&self) -> Option<Arc<ServeGauges>> {
        match &self.serve {
            ManagerServe::Event(Some(r)) => Some(r.gauges()),
            _ => None,
        }
    }

    /// Run [`ManagerState::tick_consensus`] every `every` until
    /// shutdown.  The CLI path: tests never start a ticker (they drive
    /// ticks explicitly for determinism).
    pub fn start_ticker(&mut self, every: Duration) {
        let (slot, stop) = (self.slot.clone(), self.stop.clone());
        let t = std::thread::Builder::new()
            .name("mosa-manager-tick".into())
            .spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    let state = {
                        let s = slot.lock().unwrap();
                        if s.up {
                            Some(s.state.clone())
                        } else {
                            None
                        }
                    };
                    if let Some(state) = state {
                        state.tick_consensus();
                    }
                    std::thread::sleep(every);
                }
            });
        if let Ok(t) = t {
            self.ticker_thread = Some(t);
        }
    }

    /// The bound address.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Direct (in-process) access for tests.
    pub fn state(&self) -> Arc<ManagerState> {
        self.slot.lock().unwrap().state.clone()
    }

    /// True unless crashed (tests skip downed members when hunting the
    /// current leader).
    pub fn up(&self) -> bool {
        self.slot.lock().unwrap().up
    }

    /// Simulate a process kill: mark the slot down (in-flight requests'
    /// replies are suppressed via the epoch, new requests are severed),
    /// discard the in-memory state, and release the WAL handle so the
    /// data dir can be re-opened.  Only what the log/snapshot captured
    /// survives — exactly a SIGKILL's durability contract.
    pub fn crash(&self) {
        let old = {
            let mut slot = self.slot.lock().unwrap();
            slot.up = false;
            slot.epoch += 1;
            std::mem::replace(&mut slot.state, Arc::new(ManagerState::default()))
        };
        // Outside the slot lock (detach serializes on the state lock,
        // which an in-flight handler may hold).
        old.detach_wal();
    }

    /// Respawn after [`Manager::crash`] on the same address: recover a
    /// fresh state from the data dir and start serving it.
    pub fn restart(
        &self,
        policy: Box<dyn PlacementPolicy>,
        lease_timeout: Duration,
        durability: Option<DurabilityOpts>,
    ) -> Result<()> {
        let state = Arc::new(ManagerState::with_durability(
            policy,
            lease_timeout,
            durability,
        )?);
        let mut slot = self.slot.lock().unwrap();
        slot.state = state;
        slot.epoch += 1;
        slot.up = true;
        Ok(())
    }

    /// Respawn after [`Manager::crash`] with an already-built state
    /// (the multi-manager restart path: the caller recovers the state,
    /// re-wires its consensus config, then installs it here).
    pub fn restart_state(&self, state: Arc<ManagerState>) {
        let mut slot = self.slot.lock().unwrap();
        slot.state = state;
        slot.epoch += 1;
        slot.up = true;
    }

    /// Stop accepting (existing connections finish their current call).
    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return; // already shut down
        }
        match &mut self.serve {
            // Event mode wakes its poll loop through the reactor's
            // internal wake pipe — no self-connect poke needed (PR 9
            // retired the poke: it could race the listener teardown and
            // it burned an ephemeral port per shutdown).
            ManagerServe::Event(reactor) => {
                if let Some(mut r) = reactor.take() {
                    r.shutdown();
                }
            }
            // Thread mode still pokes: connect-and-close guarantees the
            // blocked `accept()` returns at least once after the stop
            // flag is set.  The accept loop serves that last connection
            // regardless (a real client racing shutdown gets its call
            // answered; the poke itself sends nothing and its serve
            // thread exits on EOF).
            ManagerServe::Thread { accept_thread } => {
                let _ = Conn::connect(&self.addr);
                if let Some(t) = accept_thread.take() {
                    let _ = t.join();
                }
            }
        }
        if let Some(t) = self.ticker_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Manager {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: Listener, slot: Arc<Mutex<Slot>>, stop: Arc<AtomicBool>) {
    loop {
        let conn = match listener.accept() {
            Ok(c) => c,
            Err(_) => break,
        };
        // Race fix: the stop flag is checked before DROPPING the
        // connection, not before serving it — a real client that
        // connected concurrently with shutdown is still served (its
        // serve thread runs to completion), and the shutdown poke's
        // connection reads clean EOF and exits immediately.
        let stopping = stop.load(Ordering::SeqCst);
        let sl = slot.clone();
        let _ = std::thread::Builder::new()
            .name("mosa-manager-conn".into())
            .spawn(move || serve_conn(conn, sl));
        if stopping {
            break;
        }
    }
}

fn serve_conn(conn: Conn, slot: Arc<Mutex<Slot>>) {
    let reader = match conn.try_clone() {
        Ok(c) => c,
        Err(_) => return,
    };
    let mut r = BufReader::new(reader);
    let mut w = BufWriter::new(conn);
    while let Ok(Some(msg)) = Msg::read_from(&mut r) {
        // Resolve the state per message, not per connection, so a
        // restart is visible to connections that outlive it.  A crashed
        // slot severs the connection (client sees EOF, like a dead
        // process).
        let (state, epoch) = {
            let slot = slot.lock().unwrap();
            if !slot.up {
                return;
            }
            (slot.state.clone(), slot.epoch)
        };
        let reply = state.handle_replicated(msg);
        // A crash while we were handling: the state this reply was
        // computed against is gone.  Suppress the reply (the client
        // sees the connection die mid-call) — never answer from the
        // dead.
        if slot.lock().unwrap().epoch != epoch {
            return;
        }
        if reply.write_to(&mut w).is_err() {
            break;
        }
    }
}

/// A log-shipping follower: bootstraps from the primary's snapshot,
/// then tails its WAL over the wire ([`Msg::FetchWal`]), applying each
/// shipped record through the same `apply` path the primary used.  On
/// primary loss the follower can be [`Follower::promote`]d into a
/// serving [`Manager`] — proving the log format is replication-ready.
///
/// Deliberately minimal: pull-based, one primary, no election — the
/// smallest thing that demonstrates a second machine can hold a
/// promotable copy of the control plane.
pub struct Follower {
    state: Arc<ManagerState>,
    primary: String,
    /// Identity in the fault-injection partition table (see
    /// [`Follower::set_fault_id`]); defaults to `"follower"`.
    fault_id: String,
}

impl Follower {
    /// Connect to a primary and bootstrap from its current snapshot.
    pub fn connect(primary: &str, lease_timeout: Duration) -> Result<Follower> {
        let state = Arc::new(ManagerState::with_lease_timeout(
            Box::new(RoundRobinStripe::default()),
            lease_timeout,
        ));
        let f = Follower {
            state,
            primary: primary.to_string(),
            fault_id: "follower".to_string(),
        };
        f.bootstrap()?;
        Ok(f)
    }

    /// Give this follower an identity in the fault-injection partition
    /// table (tests cut the follower↔primary link with
    /// `Hiccup::partition(fault_id, primary_addr)`).
    pub fn set_fault_id(&mut self, id: &str) {
        self.fault_id = id.to_string();
    }

    /// One request/reply against the primary on a fresh connection
    /// (simplest thing that survives primary restarts between polls).
    fn call(&self, msg: Msg) -> Result<Msg> {
        if super::partition::is_partitioned(&self.fault_id, &self.primary) {
            return Err(Error::Manager(format!(
                "partitioned: {} <-> {}",
                self.fault_id, self.primary
            )));
        }
        let conn = Conn::connect_timeout(&self.primary, Duration::from_secs(1))?;
        let rc = conn.try_clone()?;
        let mut r = BufReader::new(rc);
        let mut w = BufWriter::new(conn);
        msg.write_to(&mut w)?;
        Msg::read_from(&mut r)?
            .ok_or_else(|| Error::Manager("primary closed the connection".into()))?
            .into_result()
    }

    /// (Re-)install the primary's current snapshot.
    fn bootstrap(&self) -> Result<()> {
        match self.call(Msg::FetchSnapshot)? {
            Msg::SnapshotData { data } => {
                let snap = SnapshotState::decode(&data)?;
                self.state.install_snapshot(&snap)
            }
            other => Err(Error::Manager(format!(
                "follower: unexpected snapshot reply {other:?}"
            ))),
        }
    }

    /// Fetch and apply the next batch of shipped records.  Returns how
    /// many were applied (0 = caught up).  If the primary no longer
    /// retains our position, re-bootstraps from a fresh snapshot.
    pub fn poll(&self) -> Result<usize> {
        let after = self.state.last_lsn();
        let records = match self.call(Msg::FetchWal { after }) {
            Ok(Msg::WalRecords { records }) => records,
            Ok(other) => {
                return Err(Error::Manager(format!(
                    "follower: unexpected wal reply {other:?}"
                )))
            }
            Err(Error::Proto(e)) if e.contains("re-snapshot") => {
                self.bootstrap()?;
                return Ok(0);
            }
            Err(e) => return Err(e),
        };
        let n = records.len();
        for entry in records {
            self.state.apply_shipped(entry.lsn, &entry.data)?;
        }
        Ok(n)
    }

    /// The replicated state (tests assert it matches the primary's).
    pub fn state(&self) -> Arc<ManagerState> {
        self.state.clone()
    }

    /// Highest LSN applied so far.
    pub fn last_lsn(&self) -> u64 {
        self.state.last_lsn()
    }

    /// Promote: stop following and serve the replicated state on
    /// `addr`.  (The caller decides *when* — e.g. after N failed
    /// polls; see `gpustore manager --follow`.)
    ///
    /// **Unsafe against split-brain** — this blindly starts serving
    /// whether or not the old primary is still alive on the other side
    /// of a partition.  Kept for single-follower setups and for the
    /// regression test that demonstrates the divergence; quorum
    /// deployments use [`Follower::promote_gated`].
    pub fn promote(self, addr: &str) -> Result<Manager> {
        Manager::serve(addr, self.state)
    }

    /// Quorum-gated promotion (the PR-8 replacement for the blind
    /// 20-failed-polls auto-promote): join the quorum group as a
    /// candidate and serve only after *winning* an election — which
    /// requires a majority of `peers` reachable and an up-to-date log.
    /// Anything short of that (no peers configured, peers unreachable,
    /// vote lost) refuses loudly and serves nothing.
    pub fn promote_gated(
        self,
        addr: &str,
        peers: Vec<String>,
        term_dir: Option<PathBuf>,
    ) -> Result<Manager> {
        let listener = Listener::bind(addr)?;
        let self_addr = listener.local_addr()?;
        let state = self.state.clone();
        state.set_consensus(
            ConsensusOpts {
                self_addr: self_addr.clone(),
                peers,
                initial_leader: false,
            },
            term_dir,
        )?;
        let m = Manager::serve_listener(listener, state.clone())?;
        match state.campaign() {
            Ok(true) => Ok(m),
            Ok(false) => {
                drop(m); // shuts the listener down — we serve nothing
                Err(Error::Manager(format!(
                    "promotion refused: no quorum granted {self_addr} leadership (term {}); \
                     refusing to serve rather than risk split-brain",
                    state.current_term()
                )))
            }
            Err(e) => {
                drop(m);
                Err(e)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(i: u8) -> BlockMeta {
        BlockMeta {
            hash: [i; 16],
            len: 100,
            replicas: vec![0],
            ec: None,
        }
    }

    /// Register `n` fake nodes directly against the state.  The
    /// addresses point at closed loopback ports so GC deletes fail
    /// *immediately* (connection refused) instead of hanging.
    fn join_nodes(s: &ManagerState, n: usize) {
        for i in 0..n {
            let r = s.handle(Msg::NodeJoin {
                addr: format!("127.0.0.1:{}", i + 1),
            });
            assert_eq!(r, Msg::NodeId { id: i as u32 });
        }
    }

    #[test]
    fn state_commit_and_get() {
        let s = ManagerState::default();
        join_nodes(&s, 1);
        let r = s.handle(Msg::GetBlockMap { file: "f".into() });
        assert_eq!(
            r,
            Msg::BlockMap {
                version: 0,
                blocks: vec![]
            }
        );
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![meta(1)],
        });
        let r = s.handle(Msg::GetBlockMap { file: "f".into() });
        assert_eq!(
            r,
            Msg::BlockMap {
                version: 1,
                blocks: vec![meta(1)]
            }
        );
    }

    #[test]
    fn state_versions_increment() {
        let s = ManagerState::default();
        join_nodes(&s, 1);
        for i in 1..=3 {
            s.handle(Msg::CommitBlockMap {
                file: "f".into(),
                lease: 0,
                blocks: vec![meta(i)],
            });
            let Msg::BlockMap { version, .. } = s.handle(Msg::GetBlockMap { file: "f".into() })
            else {
                panic!()
            };
            assert_eq!(version, i as u64);
        }
    }

    #[test]
    fn state_list_files_sorted() {
        let s = ManagerState::default();
        join_nodes(&s, 1);
        for f in ["b", "a"] {
            s.handle(Msg::CommitBlockMap {
                file: f.into(),
                lease: 0,
                blocks: vec![],
            });
        }
        let Msg::Files { files } = s.handle(Msg::ListFiles) else {
            panic!()
        };
        assert_eq!(files, vec![("a".into(), 1), ("b".into(), 1)]);
    }

    #[test]
    fn state_rejects_wrong_message() {
        let s = ManagerState::default();
        assert!(matches!(s.handle(Msg::Ok), Msg::Err(_)));
    }

    #[test]
    fn commit_rejects_unregistered_node() {
        let s = ManagerState::default();
        join_nodes(&s, 2);
        let bad = BlockMeta {
            hash: [1; 16],
            len: 10,
            replicas: vec![0, 7], // node 7 does not exist
            ec: None,
        };
        assert!(matches!(
            s.handle(Msg::CommitBlockMap {
                file: "f".into(),
                lease: 0,
                blocks: vec![bad],
            }),
            Msg::Err(_)
        ));
        // And an empty replica set is rejected too.
        let empty = BlockMeta {
            hash: [2; 16],
            len: 10,
            replicas: vec![],
            ec: None,
        };
        assert!(matches!(
            s.handle(Msg::CommitBlockMap {
                file: "f".into(),
                lease: 0,
                blocks: vec![empty],
            }),
            Msg::Err(_)
        ));
    }

    #[test]
    fn alloc_requires_registered_nodes() {
        let s = ManagerState::default();
        let r = s.handle(Msg::AllocPlacement {
            file: "f".into(),
            lease: 0,
            blocks: vec![BlockSpec { hash: [1; 16], len: 5 }],
        });
        assert!(matches!(r, Msg::Err(_)));
    }

    #[test]
    fn alloc_round_robins_and_dedups() {
        let s = ManagerState::default();
        join_nodes(&s, 3);
        let specs: Vec<BlockSpec> = (0..4u8)
            .map(|i| BlockSpec {
                hash: [i; 16],
                len: 10,
            })
            .collect();
        let Msg::Placement { assignments } = s.handle(Msg::AllocPlacement {
            file: "f".into(),
            lease: 0,
            blocks: specs.clone(),
        }) else {
            panic!()
        };
        assert_eq!(assignments.len(), 4);
        assert!(assignments.iter().all(|a| a.fresh));
        let picked: Vec<u32> = assignments.iter().map(|a| a.replicas[0]).collect();
        assert_eq!(picked, vec![0, 1, 2, 0], "round-robin over 3 nodes");

        // The same session (file) re-allocating its own pending blocks
        // dedups: it is the one doing the transfer.
        let Msg::Placement { assignments: same } = s.handle(Msg::AllocPlacement {
            file: "f".into(),
            lease: 0,
            blocks: specs.clone(),
        }) else {
            panic!()
        };
        assert!(same.iter().all(|a| !a.fresh));

        // ANOTHER session must not dedup against a merely-pending claim
        // (the first transfer may never complete): same homes, but it
        // is told to transfer too.
        let Msg::Placement { assignments: other } = s.handle(Msg::AllocPlacement {
            file: "g".into(),
            lease: 0,
            blocks: specs.clone(),
        }) else {
            panic!()
        };
        assert!(other.iter().all(|a| a.fresh));
        assert_eq!(
            other.iter().map(|a| a.replicas[0]).collect::<Vec<_>>(),
            picked,
            "pending blocks keep their assigned homes"
        );

        // Once committed, any session dedups against it.
        let metas: Vec<BlockMeta> = (0..4u8)
            .map(|i| BlockMeta {
                hash: [i; 16],
                len: 10,
                replicas: vec![picked[i as usize]],
                ec: None,
            })
            .collect();
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: metas,
        });
        let Msg::Placement { assignments: after } = s.handle(Msg::AllocPlacement {
            file: "h".into(),
            lease: 0,
            blocks: specs,
        }) else {
            panic!()
        };
        assert!(after.iter().all(|a| !a.fresh), "committed blocks dedup globally");
    }

    #[test]
    fn replicated_stripe_places_distinct_copies() {
        let mut p = ReplicatedStripe::new(2);
        let alive = vec![0u32, 1, 2, 3];
        for _ in 0..8 {
            let set = p.place(&alive);
            assert_eq!(set.len(), 2);
            assert_ne!(set[0], set[1]);
        }
        // Replication clamps to the alive count.
        let mut p = ReplicatedStripe::new(5);
        let set = p.place(&[7, 9]);
        assert_eq!(set.len(), 2);
    }

    #[test]
    fn erasure_coded_validates_and_places_distinct_homes() {
        assert!(ErasureCoded::new(0, 1).is_err(), "k >= 1");
        assert!(ErasureCoded::new(1, 0).is_err(), "m >= 1");
        assert!(
            ErasureCoded::new(60, 10).is_err(),
            "k + m must fit the wire bound"
        );
        let mut p = ErasureCoded::new(4, 2).unwrap();
        assert_eq!(p.replication(), 6);
        assert_eq!(p.ec(), Some((4, 2)));
        let alive: Vec<u32> = (0..7).collect();
        for _ in 0..10 {
            let set = p.place(&alive);
            assert_eq!(set.len(), 6);
            let distinct: HashSet<u32> = set.iter().copied().collect();
            assert_eq!(distinct.len(), 6, "one shard per node");
        }
    }

    #[test]
    fn alloc_under_ec_requires_k_plus_m_nodes_and_stamps_coding() {
        let s = ManagerState::with_lease_timeout(
            Box::new(ErasureCoded::new(2, 1).unwrap()),
            Duration::from_secs(30),
        );
        join_nodes(&s, 2);
        // 2 alive nodes cannot host 3 distinct shards: loud failure,
        // not a silently-weakened placement.
        let r = s.handle(Msg::AllocPlacement {
            file: "f".into(),
            lease: 0,
            blocks: vec![BlockSpec { hash: [1; 16], len: 10 }],
        });
        assert!(matches!(r, Msg::Err(_)));
        s.handle(Msg::NodeJoin {
            addr: "127.0.0.1:3".into(),
        });
        let Msg::Placement { assignments } = s.handle(Msg::AllocPlacement {
            file: "f".into(),
            lease: 0,
            blocks: vec![BlockSpec { hash: [1; 16], len: 10 }],
        }) else {
            panic!()
        };
        assert_eq!(assignments.len(), 1);
        assert_eq!(assignments[0].ec, Some((2, 1)));
        assert_eq!(assignments[0].replicas.len(), 3);
        let distinct: HashSet<u32> = assignments[0].replicas.iter().copied().collect();
        assert_eq!(distinct.len(), 3);
    }

    #[test]
    fn dedup_returns_stored_coding_not_current_policy() {
        // A block committed under ec:2,1 must dedup with ITS coding
        // even when the manager's current policy is plain round-robin —
        // the reader has to decode what is actually on the nodes.
        let s = ManagerState::default();
        join_nodes(&s, 3);
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![BlockMeta {
                hash: [7; 16],
                len: 10,
                replicas: vec![0, 1, 2],
                ec: Some((2, 1)),
            }],
        });
        let Msg::Placement { assignments } = s.handle(Msg::AllocPlacement {
            file: "g".into(),
            lease: 0,
            blocks: vec![BlockSpec { hash: [7; 16], len: 10 }],
        }) else {
            panic!()
        };
        assert!(!assignments[0].fresh, "committed block dedups");
        assert_eq!(assignments[0].ec, Some((2, 1)), "stored coding wins");
        assert_eq!(assignments[0].replicas, vec![0, 1, 2]);
    }

    #[test]
    fn shipped_rehome_updates_block_and_file_maps() {
        let s = ManagerState::default();
        join_nodes(&s, 2);
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![meta(1)],
        });
        let lsn = s.last_lsn();
        let rec = Record::Rehome {
            hash: [1; 16],
            replicas: vec![1],
        };
        s.apply_shipped(lsn + 1, &rec.encode()).unwrap();
        let Msg::BlockMap { blocks, .. } = s.handle(Msg::GetBlockMap { file: "f".into() })
        else {
            panic!()
        };
        assert_eq!(blocks[0].replicas, vec![1], "file map re-homed");
        let snap = s.snapshot_state();
        assert_eq!(snap.blocks[0].replicas, vec![1], "block table re-homed");
        // Re-homing a hash the table does not hold is a no-op (the
        // repair raced a release) — not a panic, not a resurrection.
        let gone = Record::Rehome {
            hash: [9; 16],
            replicas: vec![0],
        };
        s.apply_shipped(lsn + 2, &gone.encode()).unwrap();
        assert_eq!(s.snapshot_state().blocks.len(), 1);
    }

    #[test]
    fn redundancy_report_tracks_suspects_and_dead_nodes() {
        let s = ManagerState::default();
        join_nodes(&s, 2);
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![
                BlockMeta { hash: [1; 16], len: 10, replicas: vec![0, 1], ec: None },
                BlockMeta { hash: [2; 16], len: 10, replicas: vec![0, 1], ec: Some((1, 1)) },
            ],
        });
        let r = s.redundancy_report();
        assert_eq!((r.blocks, r.fully_redundant), (2, 2));
        // A corruption report degrades one copy of block 1.
        assert_eq!(
            s.handle(Msg::ReportCorrupt { hash: [1; 16], node: 1 }),
            Msg::Ok
        );
        let r = s.redundancy_report();
        assert_eq!((r.fully_redundant, r.degraded, r.unreadable), (1, 1, 0));
        // Node 1 misses the heartbeat window: both blocks degraded
        // (each still readable from node 0 — one copy / k=1 shards).
        s.advance_clock(Duration::from_secs(4));
        s.handle(Msg::Heartbeat { node: 0 });
        let r = s.redundancy_report();
        assert_eq!((r.fully_redundant, r.degraded, r.unreadable), (0, 2, 0));
        // Block 2's last healthy shard goes suspect: unreadable.
        s.handle(Msg::ReportCorrupt { hash: [2; 16], node: 0 });
        let r = s.redundancy_report();
        assert_eq!((r.degraded, r.unreadable), (1, 1));
    }

    #[test]
    fn scrub_detects_degraded_but_defers_without_sources() {
        let s = ManagerState::default();
        join_nodes(&s, 2);
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![BlockMeta { hash: [1; 16], len: 10, replicas: vec![0, 1], ec: None }],
        });
        let r = s.scrub_once();
        assert_eq!((r.scanned, r.degraded, r.repaired), (1, 0, 0), "healthy: nothing to do");
        // Node 1 dies.  The fixture nodes are closed loopback ports, so
        // the repair's source fetch fails fast — the pass must defer
        // (and leave metadata untouched), never half-repair.
        s.advance_clock(Duration::from_secs(4));
        s.handle(Msg::Heartbeat { node: 0 });
        let r = s.scrub_once();
        assert_eq!((r.degraded, r.repaired, r.deferred), (1, 0, 1));
        let Msg::BlockMap { blocks, .. } = s.handle(Msg::GetBlockMap { file: "f".into() })
        else {
            panic!()
        };
        assert_eq!(blocks[0].replicas, vec![0, 1], "deferred repair mutates nothing");
    }

    #[test]
    fn refcount_overwrite_frees_old_blocks() {
        let s = ManagerState::default();
        join_nodes(&s, 1);
        // v1 references block 1; v2 references block 2 only.
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![meta(1)],
        });
        assert_eq!(s.block_stats().blocks, 1);
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![meta(2)],
        });
        // Block 1 had refs 0 after the overwrite -> swept.
        assert_eq!(s.block_stats().blocks, 1);
        // A block shared by two files survives one file's overwrite.
        s.handle(Msg::CommitBlockMap {
            file: "g".into(),
            lease: 0,
            blocks: vec![meta(2)],
        });
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![],
        });
        assert_eq!(s.block_stats().blocks, 1, "g still references block 2");
    }

    #[test]
    fn release_drops_pending_claims() {
        let s = ManagerState::default();
        join_nodes(&s, 1);
        let spec = BlockSpec { hash: [9; 16], len: 7 };
        s.handle(Msg::AllocPlacement {
            file: "f".into(),
            lease: 0,
            blocks: vec![spec],
        });
        assert_eq!(s.block_stats().blocks, 1, "pending claim keeps the block");
        s.handle(Msg::ReleaseBlocks {
            hashes: vec![[9; 16]],
        });
        assert_eq!(s.block_stats().blocks, 0, "released + unreferenced -> swept");
    }

    #[test]
    fn node_join_is_idempotent_and_heartbeat_tracked() {
        let s = ManagerState::default();
        let r1 = s.handle(Msg::NodeJoin { addr: "a:1".into() });
        let r2 = s.handle(Msg::NodeJoin { addr: "b:2".into() });
        let r3 = s.handle(Msg::NodeJoin { addr: "a:1".into() });
        assert_eq!(r1, Msg::NodeId { id: 0 });
        assert_eq!(r2, Msg::NodeId { id: 1 });
        assert_eq!(r3, Msg::NodeId { id: 0 }, "rejoin keeps the id");
        assert_eq!(s.handle(Msg::Heartbeat { node: 1 }), Msg::Ok);
        assert!(matches!(s.handle(Msg::Heartbeat { node: 9 }), Msg::Err(_)));
        let Msg::Nodes { nodes } = s.handle(Msg::NodeList) else {
            panic!()
        };
        assert_eq!(nodes.len(), 2);
        assert!(nodes.iter().all(|n| n.alive));
    }

    #[test]
    fn tcp_serving_works() {
        let mgr = Manager::spawn("127.0.0.1:0").unwrap();
        let mut c = Conn::connect(mgr.addr()).unwrap();
        Msg::NodeJoin { addr: "x:1".into() }.write_to(&mut c).unwrap();
        assert_eq!(
            Msg::read_from(&mut c).unwrap().unwrap(),
            Msg::NodeId { id: 0 }
        );
        Msg::CommitBlockMap {
            file: "x".into(),
            lease: 0,
            blocks: vec![meta(5)],
        }
        .write_to(&mut c)
        .unwrap();
        let r = Msg::read_from(&mut c).unwrap().unwrap();
        assert_eq!(r, Msg::Ok);
        Msg::GetBlockMap { file: "x".into() }.write_to(&mut c).unwrap();
        let r = Msg::read_from(&mut c).unwrap().unwrap();
        assert_eq!(
            r,
            Msg::BlockMap {
                version: 1,
                blocks: vec![meta(5)]
            }
        );
    }

    #[test]
    fn multiple_clients() {
        let mgr = Manager::spawn("127.0.0.1:0").unwrap();
        mgr.state().handle(Msg::NodeJoin { addr: "x:1".into() });
        let addr = mgr.addr().to_string();
        let threads: Vec<_> = (0..4)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = Conn::connect(&addr).unwrap();
                    Msg::CommitBlockMap {
                        file: format!("f{i}"),
                        lease: 0,
                        blocks: vec![meta(i as u8)],
                    }
                    .write_to(&mut c)
                    .unwrap();
                    assert_eq!(Msg::read_from(&mut c).unwrap().unwrap(), Msg::Ok);
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let Msg::Files { files } = mgr.state().handle(Msg::ListFiles) else {
            panic!()
        };
        assert_eq!(files.len(), 4);
    }

    #[test]
    fn shutdown_still_serves_racing_client() {
        // A client connecting concurrently with shutdown must get its
        // in-flight call answered, not be silently dropped.
        for _ in 0..8 {
            let mut mgr = Manager::spawn("127.0.0.1:0").unwrap();
            let addr = mgr.addr().to_string();
            let client = std::thread::spawn(move || {
                let mut c = Conn::connect(&addr)?;
                Msg::ListFiles.write_to(&mut c)?;
                Msg::read_from(&mut c)
            });
            mgr.shutdown();
            match client.join().unwrap() {
                // Served (possibly during shutdown) or cleanly refused;
                // a hang would fail the test via the harness timeout.
                Ok(Some(Msg::Files { .. })) | Ok(None) | Err(_) => {}
                other => panic!("unexpected reply: {other:?}"),
            }
        }
    }

    // ---- leases (control-plane v3) ----

    /// 5-second lease window + 1 node, the lease unit-test fixture.
    fn leased_state() -> ManagerState {
        let s = ManagerState::with_lease_timeout(
            Box::new(RoundRobinStripe::default()),
            Duration::from_secs(5),
        );
        join_nodes(&s, 1);
        s
    }

    fn open_write_lease(s: &ManagerState, tag: &str) -> u64 {
        let Msg::LeaseGrant { lease, ttl_ms, version, blocks } = s.handle(Msg::OpenLease {
            file: tag.into(),
            write: true,
        }) else {
            panic!("no grant")
        };
        assert!(lease != 0);
        assert_eq!(ttl_ms, 5_000);
        assert_eq!((version, blocks.len()), (0, 0));
        lease
    }

    #[test]
    fn zero_lease_timeout_clamped_to_floor() {
        // The invariant lives in with_lease_timeout itself, not only in
        // the front ends: a zero window must not lapse leases at grant.
        let s = ManagerState::with_lease_timeout(
            Box::new(RoundRobinStripe::default()),
            Duration::ZERO,
        );
        let Msg::LeaseGrant { lease, ttl_ms, .. } = s.handle(Msg::OpenLease {
            file: "t".into(),
            write: true,
        }) else {
            panic!()
        };
        assert!(lease != 0);
        assert!(ttl_ms >= 1, "ttl clamped to the floor, not zero");
    }

    #[test]
    fn write_lease_claims_lapse_on_expiry() {
        let s = leased_state();
        let lease = open_write_lease(&s, "sess");
        s.handle(Msg::AllocPlacement {
            file: "sess".into(),
            lease,
            blocks: vec![BlockSpec { hash: [9; 16], len: 7 }],
        });
        assert_eq!(s.block_stats().pending_claims, 1);
        // Within the window nothing lapses.
        s.advance_clock(Duration::from_secs(4));
        s.tick();
        assert_eq!(s.block_stats().pending_claims, 1);
        assert_eq!(s.block_stats().write_leases, 1);
        // The allocation renewed the lease, so expiry counts from it.
        s.advance_clock(Duration::from_secs(2));
        s.tick();
        assert_eq!(s.block_stats().pending_claims, 0, "claims lapsed");
        assert_eq!(s.block_stats().write_leases, 0);
        assert_eq!(s.block_stats().blocks, 0, "orphaned block swept");
        // A lapsed lease can neither allocate nor commit.
        assert!(matches!(
            s.handle(Msg::AllocPlacement {
                file: "sess".into(),
                lease,
                blocks: vec![BlockSpec { hash: [9; 16], len: 7 }],
            }),
            Msg::Err(_)
        ));
        assert!(matches!(
            s.handle(Msg::CommitBlockMap {
                file: "f".into(),
                lease,
                blocks: vec![meta(9)],
            }),
            Msg::Err(_)
        ));
    }

    #[test]
    fn renew_extends_write_lease() {
        let s = leased_state();
        let lease = open_write_lease(&s, "sess");
        s.handle(Msg::AllocPlacement {
            file: "sess".into(),
            lease,
            blocks: vec![BlockSpec { hash: [8; 16], len: 3 }],
        });
        for _ in 0..3 {
            s.advance_clock(Duration::from_secs(4));
            assert_eq!(s.handle(Msg::RenewLease { lease }), Msg::Ok);
        }
        s.tick();
        assert_eq!(s.block_stats().pending_claims, 1, "renewals kept the claim");
        // Stop renewing: one full window later the claim lapses.
        s.advance_clock(Duration::from_secs(6));
        assert!(matches!(s.handle(Msg::RenewLease { lease }), Msg::Err(_)));
        assert_eq!(s.block_stats().pending_claims, 0);
    }

    #[test]
    fn commit_consumes_write_lease() {
        let s = leased_state();
        let lease = open_write_lease(&s, "sess");
        s.handle(Msg::AllocPlacement {
            file: "sess".into(),
            lease,
            blocks: vec![BlockSpec { hash: [1; 16], len: 100 }],
        });
        assert_eq!(
            s.handle(Msg::CommitBlockMap {
                file: "f".into(),
                lease,
                blocks: vec![meta(1)],
            }),
            Msg::Ok
        );
        let stats = s.block_stats();
        assert_eq!(stats.pending_claims, 0, "claims redeemed into refs");
        assert_eq!(stats.write_leases, 0, "lease consumed");
        assert_eq!(stats.blocks, 1);
        // Expiry long after the commit must not touch the committed
        // version.
        s.advance_clock(Duration::from_secs(60));
        s.tick();
        assert_eq!(s.block_stats().blocks, 1);
        // Dropping the consumed lease is a harmless no-op.
        assert_eq!(s.handle(Msg::DropLease { lease }), Msg::Ok);
        assert_eq!(s.block_stats().blocks, 1);
    }

    #[test]
    fn commit_releases_unused_claims() {
        // A writer allocates two blocks but commits only one (e.g. the
        // app truncated): the unused claim is released with the lease.
        let s = leased_state();
        let lease = open_write_lease(&s, "sess");
        s.handle(Msg::AllocPlacement {
            file: "sess".into(),
            lease,
            blocks: vec![
                BlockSpec { hash: [1; 16], len: 100 },
                BlockSpec { hash: [2; 16], len: 100 },
            ],
        });
        assert_eq!(s.block_stats().pending_claims, 2);
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease,
            blocks: vec![meta(1)],
        });
        let stats = s.block_stats();
        assert_eq!(stats.pending_claims, 0);
        assert_eq!(stats.blocks, 1, "unused claim's block swept");
    }

    #[test]
    fn read_lease_pins_overwritten_version() {
        let s = leased_state();
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![meta(1)],
        });
        let Msg::LeaseGrant { lease, version, blocks, .. } = s.handle(Msg::OpenLease {
            file: "f".into(),
            write: false,
        }) else {
            panic!()
        };
        assert!(lease != 0);
        assert_eq!(version, 1);
        assert_eq!(blocks, vec![meta(1)]);
        // Overwrite: block 1 loses its last reference but is pinned.
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![meta(2)],
        });
        let stats = s.block_stats();
        assert_eq!(stats.blocks, 2, "old block pinned, not swept");
        assert_eq!(stats.pinned_blocks, 1);
        assert_eq!(stats.read_leases, 1);
        // Dropping the lease runs the deferred delete.
        assert_eq!(s.handle(Msg::DropLease { lease }), Msg::Ok);
        let stats = s.block_stats();
        assert_eq!(stats.blocks, 1, "deferred GC ran on lease drop");
        assert_eq!((stats.pinned_blocks, stats.read_leases), (0, 0));
    }

    #[test]
    fn read_lease_expiry_unpins() {
        let s = leased_state();
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![meta(1)],
        });
        let Msg::LeaseGrant { lease, .. } = s.handle(Msg::OpenLease {
            file: "f".into(),
            write: false,
        }) else {
            panic!()
        };
        assert!(lease != 0);
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![meta(2)],
        });
        assert_eq!(s.block_stats().blocks, 2);
        // The reader vanishes without dropping: the pin lapses with the
        // lease and the deferred delete runs.
        s.advance_clock(Duration::from_secs(6));
        s.tick();
        let stats = s.block_stats();
        assert_eq!(stats.blocks, 1, "pin lapsed, block reclaimed");
        assert_eq!(stats.read_leases, 0);
    }

    #[test]
    fn open_lease_on_missing_file_grants_nothing() {
        let s = leased_state();
        let Msg::LeaseGrant { lease, version, blocks, .. } = s.handle(Msg::OpenLease {
            file: "nope".into(),
            write: false,
        }) else {
            panic!()
        };
        assert_eq!((lease, version, blocks.len()), (0, 0, 0));
        assert_eq!(s.block_stats().read_leases, 0);
    }

    #[test]
    fn shared_block_pinned_by_two_readers() {
        let s = leased_state();
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![meta(1)],
        });
        let open = |s: &ManagerState| -> u64 {
            let Msg::LeaseGrant { lease, .. } = s.handle(Msg::OpenLease {
                file: "f".into(),
                write: false,
            }) else {
                panic!()
            };
            lease
        };
        let (l1, l2) = (open(&s), open(&s));
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![],
        });
        assert_eq!(s.block_stats().blocks, 1, "pinned twice");
        s.handle(Msg::DropLease { lease: l1 });
        assert_eq!(s.block_stats().blocks, 1, "still pinned once");
        s.handle(Msg::DropLease { lease: l2 });
        assert_eq!(s.block_stats().blocks, 0, "last pin dropped -> swept");
    }

    // ---- durability (PR 7) ----

    use crate::wal::testutil::TempDir;

    fn durable_opts(dir: &std::path::Path) -> DurabilityOpts {
        DurabilityOpts {
            data_dir: dir.to_path_buf(),
            sync_interval: Duration::ZERO,
            snapshot_every: 1_000_000,
        }
    }

    /// Durable 5-second-lease state on `dir` (the lease fixture's
    /// window, so `open_write_lease` works against it too).
    fn durable_state(dir: &std::path::Path) -> ManagerState {
        ManagerState::with_durability(
            Box::new(RoundRobinStripe::default()),
            Duration::from_secs(5),
            Some(durable_opts(dir)),
        )
        .unwrap()
    }

    #[test]
    fn durable_state_survives_crash_and_restart() {
        let t = TempDir::new("mgr-durable");
        let before = {
            let s = durable_state(&t.0);
            join_nodes(&s, 2);
            let lease = open_write_lease(&s, "sess");
            s.handle(Msg::AllocPlacement {
                file: "sess".into(),
                lease,
                blocks: vec![
                    BlockSpec { hash: [1; 16], len: 10 },
                    BlockSpec { hash: [2; 16], len: 20 },
                ],
            });
            assert_eq!(
                s.handle(Msg::CommitBlockMap {
                    file: "f".into(),
                    lease,
                    blocks: vec![
                        BlockMeta { hash: [1; 16], len: 10, replicas: vec![0], ec: None },
                        BlockMeta { hash: [2; 16], len: 20, replicas: vec![1], ec: None },
                    ],
                }),
                Msg::Ok
            );
            // An open read lease and a second in-flight writer are part
            // of the durable image too.
            let Msg::LeaseGrant { lease: rl, .. } = s.handle(Msg::OpenLease {
                file: "f".into(),
                write: false,
            }) else {
                panic!()
            };
            assert!(rl != 0);
            let _w2 = open_write_lease(&s, "sess2");
            let snap = s.snapshot_state();
            s.detach_wal(); // crash: from here nothing else persists
            snap
        };
        let s = durable_state(&t.0);
        assert_eq!(s.snapshot_state(), before, "recovered state == pre-crash");
        // The recovered manager keeps serving: the committed map reads
        // back byte-identical metadata.
        let Msg::BlockMap { version, blocks } = s.handle(Msg::GetBlockMap { file: "f".into() })
        else {
            panic!()
        };
        assert_eq!(version, 1);
        assert_eq!(blocks.len(), 2);
    }

    #[test]
    fn recovered_leases_get_full_ttl_then_lapse() {
        let t = TempDir::new("mgr-lease-ttl");
        {
            let s = durable_state(&t.0);
            join_nodes(&s, 1);
            let lease = open_write_lease(&s, "sess");
            s.handle(Msg::AllocPlacement {
                file: "sess".into(),
                lease,
                blocks: vec![BlockSpec { hash: [9; 16], len: 7 }],
            });
            s.detach_wal();
        }
        let s = durable_state(&t.0);
        assert_eq!(s.block_stats().write_leases, 1, "claim holder recovered");
        assert_eq!(s.block_stats().pending_claims, 1);
        // Conservative clocks: the recovered lease is good for one full
        // window after restart (its surviving writer renews as usual)...
        s.advance_clock(Duration::from_secs(4));
        s.tick();
        assert_eq!(s.block_stats().write_leases, 1);
        // ...then lapses if its writer never came back — PR 3's
        // reclamation, one window late, zero stranded claims.
        s.advance_clock(Duration::from_secs(2));
        s.tick();
        assert_eq!(s.block_stats().write_leases, 0);
        assert_eq!(s.block_stats().pending_claims, 0, "no stranded claims");
        assert_eq!(s.block_stats().blocks, 0, "orphaned claim swept");
    }

    #[test]
    fn snapshot_cadence_prunes_and_recovers() {
        let t = TempDir::new("mgr-snap");
        let opts = DurabilityOpts {
            data_dir: t.0.clone(),
            sync_interval: Duration::ZERO,
            snapshot_every: 4,
        };
        let before = {
            let s = ManagerState::with_durability(
                Box::new(RoundRobinStripe::default()),
                Duration::from_secs(5),
                Some(opts.clone()),
            )
            .unwrap();
            join_nodes(&s, 1);
            for i in 1..=6u8 {
                s.handle(Msg::CommitBlockMap {
                    file: format!("f{i}"),
                    lease: 0,
                    blocks: vec![meta(i)],
                });
            }
            let snap = s.snapshot_state();
            s.detach_wal();
            snap
        };
        let snaps = std::fs::read_dir(t.0.join("snap")).unwrap().count();
        assert_eq!(snaps, 1, "cadence cut a snapshot and pruned older ones");
        let s = ManagerState::with_durability(
            Box::new(RoundRobinStripe::default()),
            Duration::from_secs(5),
            Some(opts),
        )
        .unwrap();
        assert_eq!(s.snapshot_state(), before, "snapshot + tail replay");
    }

    #[test]
    fn follower_tails_primary_and_promotes() {
        let mgr = Manager::spawn("127.0.0.1:0").unwrap();
        let s = mgr.state();
        join_nodes(&s, 1);
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![meta(1)],
        });
        let f = Follower::connect(mgr.addr(), DEFAULT_LEASE_TIMEOUT).unwrap();
        assert_eq!(f.state().snapshot_state(), s.snapshot_state());
        // New mutations ship incrementally (no re-bootstrap).
        s.handle(Msg::CommitBlockMap {
            file: "g".into(),
            lease: 0,
            blocks: vec![meta(2)],
        });
        while f.poll().unwrap() > 0 {}
        assert_eq!(f.state().snapshot_state(), s.snapshot_state());
        // Promotion: the replicated state serves on its own address.
        let promoted = f.promote("127.0.0.1:0").unwrap();
        let Msg::BlockMap { version, blocks } = promoted
            .state()
            .handle(Msg::GetBlockMap { file: "g".into() })
        else {
            panic!()
        };
        assert_eq!((version, blocks), (1, vec![meta(2)]));
    }

    #[test]
    fn tcp_crash_then_restart_recovers_on_same_addr() {
        let t = TempDir::new("mgr-tcp-crash");
        let opts = durable_opts(&t.0);
        let mgr = Manager::spawn_with_opts(
            "127.0.0.1:0",
            Box::new(RoundRobinStripe::default()),
            Duration::from_secs(5),
            Some(opts.clone()),
        )
        .unwrap();
        let mut c = Conn::connect(mgr.addr()).unwrap();
        Msg::NodeJoin { addr: "x:1".into() }.write_to(&mut c).unwrap();
        assert_eq!(
            Msg::read_from(&mut c).unwrap().unwrap(),
            Msg::NodeId { id: 0 }
        );
        Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![meta(3)],
        }
        .write_to(&mut c)
        .unwrap();
        assert_eq!(Msg::read_from(&mut c).unwrap().unwrap(), Msg::Ok);
        mgr.crash();
        // While down the old connection is severed mid-call — the
        // client sees EOF or an error, never a reply from the dead.
        let dead = Msg::GetBlockMap { file: "f".into() }
            .write_to(&mut c)
            .and_then(|_| Msg::read_from(&mut c));
        assert!(!matches!(dead, Ok(Some(_))), "{dead:?}");
        mgr.restart(
            Box::new(RoundRobinStripe::default()),
            Duration::from_secs(5),
            Some(opts),
        )
        .unwrap();
        let mut c = Conn::connect(mgr.addr()).unwrap();
        Msg::GetBlockMap { file: "f".into() }.write_to(&mut c).unwrap();
        assert_eq!(
            Msg::read_from(&mut c).unwrap().unwrap(),
            Msg::BlockMap {
                version: 1,
                blocks: vec![meta(3)]
            }
        );
    }

    // ---- event-driven serving + sharded tables (PR 9) ----

    /// The lane constants must track [`Msg::tag`] — if the wire tags
    /// move, routing consensus traffic into the read lane would
    /// reintroduce the cross-manager deadlock the lanes exist to
    /// prevent.
    #[test]
    fn lane_tags_match_protocol() {
        assert_eq!(Msg::Heartbeat { node: 0 }.tag(), TAG_HEARTBEAT);
        assert_eq!(Msg::NodeList.tag(), TAG_NODE_LIST);
        assert_eq!(Msg::FetchSnapshot.tag(), TAG_FETCH_SNAPSHOT);
        assert_eq!(Msg::FetchWal { after: 0 }.tag(), TAG_FETCH_WAL);
        assert_eq!(
            Msg::RequestVote {
                term: 0,
                candidate: String::new(),
                last_term: 0,
                last_lsn: 0
            }
            .tag(),
            TAG_REQUEST_VOTE
        );
        assert_eq!(
            Msg::Replicate {
                term: 0,
                leader: String::new(),
                prev_lsn: 0,
                commit_lsn: 0,
                records: Vec::new()
            }
            .tag(),
            TAG_REPLICATE
        );
        let svc = ManagerService {
            slot: Arc::new(Mutex::new(Slot {
                state: Arc::new(ManagerState::default()),
                up: true,
                epoch: 0,
            })),
        };
        assert_eq!(svc.lanes(), 3);
        assert_eq!(svc.lane(TAG_REQUEST_VOTE), LANE_PEER);
        assert_eq!(svc.lane(TAG_REPLICATE), LANE_PEER);
        for t in [TAG_HEARTBEAT, TAG_NODE_LIST, TAG_FETCH_SNAPSHOT, TAG_FETCH_WAL] {
            assert_eq!(svc.lane(t), LANE_READ);
        }
        assert_eq!(svc.lane(Msg::NodeList.tag()), LANE_READ);
        assert_eq!(svc.lane(Msg::ListFiles.tag()), LANE_CLIENT);
        assert_eq!(svc.lane(Msg::CommitBlockMap { file: String::new(), lease: 0, blocks: vec![] }.tag()), LANE_CLIENT);
    }

    /// The shard count must be unobservable: the same op sequence at 1,
    /// 16 and 64 shards yields identical snapshot images (snapshots
    /// sort, so iteration order cannot leak through).
    #[test]
    fn sharded_tables_default_and_with_shards_agree() {
        let run = |shards: usize| {
            let s = ManagerState::with_shards(
                Box::new(RoundRobinStripe::default()),
                Duration::from_secs(5),
                shards,
            );
            join_nodes(&s, 2);
            let lease = open_write_lease(&s, "sess");
            s.handle(Msg::AllocPlacement {
                file: "sess".into(),
                lease,
                blocks: (0..32u8).map(|i| BlockSpec { hash: [i; 16], len: 10 }).collect(),
            });
            s.handle(Msg::CommitBlockMap {
                file: "f".into(),
                lease,
                blocks: (0..16u8)
                    .map(|i| BlockMeta {
                        hash: [i; 16],
                        len: 10,
                        replicas: vec![(i % 2) as u32],
                        ec: None,
                    })
                    .collect(),
            });
            let Msg::LeaseGrant { lease: rl, .. } = s.handle(Msg::OpenLease {
                file: "f".into(),
                write: false,
            }) else {
                panic!()
            };
            assert!(rl != 0);
            s.handle(Msg::CommitBlockMap {
                file: "f".into(),
                lease: 0,
                blocks: vec![meta(200)],
            });
            s.snapshot_state()
        };
        let one = run(1);
        assert_eq!(one, run(16));
        assert_eq!(one, run(64));
    }

    /// Event-mode manager: serves the protocol, exposes gauges, and a
    /// shutdown leaks no `mg{port}-` threads and needs no self-connect
    /// poke (the listener is already closed when shutdown returns).
    #[test]
    fn event_manager_gauges_and_clean_shutdown() {
        let threads_with_prefix = |prefix: &str| -> usize {
            let mut n = 0;
            if let Ok(tasks) = std::fs::read_dir("/proc/self/task") {
                for t in tasks.flatten() {
                    if let Ok(comm) = std::fs::read_to_string(t.path().join("comm")) {
                        if comm.trim_end().starts_with(prefix) {
                            n += 1;
                        }
                    }
                }
            }
            n
        };
        let mut mgr = Manager::serve_listener_opts(
            Listener::bind("127.0.0.1:0").unwrap(),
            Arc::new(ManagerState::default()),
            ServeMode::Event,
            2,
        )
        .unwrap();
        let port = mgr.addr().rsplit(':').next().unwrap().to_string();
        let prefix = format!("mg{port}");
        assert!(
            threads_with_prefix(&prefix) >= 2 + 2 + 2 + 1,
            "three worker lanes + poll thread running"
        );
        let mut c = Conn::connect(mgr.addr()).unwrap();
        Msg::NodeJoin { addr: "x:1".into() }.write_to(&mut c).unwrap();
        assert_eq!(
            Msg::read_from(&mut c).unwrap().unwrap(),
            Msg::NodeId { id: 0 }
        );
        Msg::ListFiles.write_to(&mut c).unwrap();
        assert!(matches!(
            Msg::read_from(&mut c).unwrap().unwrap(),
            Msg::Files { .. }
        ));
        let gauges = mgr.serve_gauges().expect("event mode exposes gauges");
        let snap = gauges.snapshot();
        assert!(snap.open_conns >= 1, "our connection is counted");
        assert_eq!(snap.workers_total, 2 + 2 + 2);
        assert!(snap.frames_served >= 2);
        drop(c);
        mgr.shutdown();
        mgr.shutdown(); // idempotent
        assert_eq!(
            threads_with_prefix(&prefix),
            0,
            "no serve threads leaked past shutdown"
        );
        assert!(
            Conn::connect(mgr.addr()).is_err(),
            "listener closed by the time shutdown returns"
        );
    }

    /// `--serve-threads 0`-style fallback: the legacy accept loop still
    /// serves, and reports no gauges.
    #[test]
    fn thread_mode_manager_still_serves() {
        let mut mgr = Manager::serve_listener_opts(
            Listener::bind("127.0.0.1:0").unwrap(),
            Arc::new(ManagerState::default()),
            ServeMode::Thread,
            0,
        )
        .unwrap();
        assert!(mgr.serve_gauges().is_none(), "thread mode has no reactor");
        let mut c = Conn::connect(mgr.addr()).unwrap();
        Msg::ListFiles.write_to(&mut c).unwrap();
        assert_eq!(
            Msg::read_from(&mut c).unwrap().unwrap(),
            Msg::Files { files: vec![] }
        );
        drop(c);
        mgr.shutdown();
    }
}
