//! The centralized metadata manager (paper §3.2.1), control-plane v3:
//! besides per-file block-maps and versions it owns *placement* —
//! clients ask where blocks go ([`Msg::AllocPlacement`]) and a pluggable
//! [`PlacementPolicy`] answers with an n-way replica set — plus a node
//! registry fed by [`Msg::NodeJoin`]/[`Msg::Heartbeat`], per-block
//! reference counting across file versions, commit-time garbage
//! collection (blocks orphaned by a version overwrite are deleted from
//! their owning nodes), and *leases*: read leases pin an opened
//! version's blocks so GC defers their deletion until the last lease
//! drops, and writer claim leases expire when the owning client stops
//! heartbeating, returning an abandoned session's pending claims to the
//! GC pool.  Lease expiry shares the manager's liveness clock, which a
//! test-only hook ([`ManagerState::advance_clock`]) can advance so
//! every expiry path is testable without wall-clock sleeps.
//! Thread-per-connection over the shared protocol.

use std::collections::{HashMap, HashSet};
use std::io::{BufReader, BufWriter};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::proto::{Assignment, BlockMeta, BlockSpec, Msg, NodeEntry, MAX_REPLICAS};
use crate::hash::Digest;
use crate::net::{Conn, Listener};
use crate::Result;

/// How a placement policy chooses nodes for a new block.
///
/// Policies are deliberately tiny state machines: the manager hands them
/// the current *alive* node ids (sorted) and they answer with a replica
/// set, one call per fresh block, in request order.  This is the plug
/// point CrystalGPU used for GPU task scheduling and GNStor for
/// recovery placement — new policies (locality-, load- or
/// capacity-aware) implement this trait and slot into
/// [`Manager::spawn_with_policy`].
pub trait PlacementPolicy: Send + std::fmt::Debug {
    /// Human-readable policy name (surfaced in logs/CLI).
    fn name(&self) -> &'static str;
    /// Target replication factor (what the policy aims for when enough
    /// nodes are alive).
    fn replication(&self) -> usize;
    /// Choose the replica set for one new block.  `alive` is non-empty
    /// and sorted by node id.
    fn place(&mut self, alive: &[u32]) -> Vec<u32>;
}

/// Today's behaviour as a policy: blocks round-robin across the alive
/// nodes, one copy each (replication = 1).
#[derive(Debug, Default)]
pub struct RoundRobinStripe {
    next: usize,
}

impl PlacementPolicy for RoundRobinStripe {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn replication(&self) -> usize {
        1
    }

    fn place(&mut self, alive: &[u32]) -> Vec<u32> {
        let id = alive[self.next % alive.len()];
        self.next = self.next.wrapping_add(1);
        vec![id]
    }
}

/// n-way replication over a rotating stripe: block `i` goes to `r`
/// consecutive alive nodes starting at the rotating cursor, so both the
/// primaries and the replica sets spread evenly.
#[derive(Debug)]
pub struct ReplicatedStripe {
    /// Target copies per block (clamped to the alive node count).
    pub replicas: usize,
    next: usize,
}

impl ReplicatedStripe {
    /// Policy with a target replication factor (clamped to
    /// `1..=MAX_REPLICAS`, the wire format's bound).
    pub fn new(replicas: usize) -> Self {
        ReplicatedStripe {
            replicas: replicas.clamp(1, MAX_REPLICAS),
            next: 0,
        }
    }
}

impl PlacementPolicy for ReplicatedStripe {
    fn name(&self) -> &'static str {
        "replicated-stripe"
    }

    fn replication(&self) -> usize {
        self.replicas
    }

    fn place(&mut self, alive: &[u32]) -> Vec<u32> {
        let r = self.replicas.min(alive.len()).max(1);
        let start = self.next;
        self.next = self.next.wrapping_add(1);
        (0..r).map(|k| alive[(start + k) % alive.len()]).collect()
    }
}

/// The policy implied by a replication factor: classic single-copy
/// round-robin striping for `r == 1`, n-way [`ReplicatedStripe`]
/// otherwise.  Single source of truth for every entry point (in-process
/// clusters, the manager CLI).
pub fn policy_for(replication: usize) -> Box<dyn PlacementPolicy> {
    if replication > 1 {
        Box::new(ReplicatedStripe::new(replication))
    } else {
        Box::new(RoundRobinStripe::default())
    }
}

#[derive(Debug, Default)]
struct FileEntry {
    version: u64,
    blocks: Vec<BlockMeta>,
}

/// Global (cross-file, cross-version) bookkeeping for one stored block.
#[derive(Debug)]
struct BlockInfo {
    /// Where the block lives (decided once, at first allocation).
    replicas: Vec<u32>,
    /// Payload length (for stats / future rebalancing).
    len: u32,
    /// Occurrences in committed block-maps.
    refs: u64,
    /// Provisional claims: allocated by a writer that has not committed
    /// or released yet.  Blocks with `refs == 0 && pending == 0 &&
    /// pins == 0` are garbage and get deleted from their nodes.
    pending: u64,
    /// Read-lease pins: occurrences in version snapshots still being
    /// streamed by readers.  A pinned block survives losing its last
    /// committed reference; the delete is deferred until the last
    /// lease drops or lapses.
    pins: u64,
    /// While `refs == 0`, the claim tag of the session that first
    /// allocated the block (clients send a unique per-session token as
    /// `AllocPlacement.file`).  Dedup against a merely-pending block is
    /// only safe for that same session (a commit proves the bytes
    /// landed, a pending claim does not); everyone else transfers too.
    placed_by: String,
}

/// One granted lease: a read-session version pin or a write-session
/// claim holder.  Leases lapse when `expires_at` (on the manager's
/// clock) passes without a renewal; the expiry sweep runs lazily at the
/// top of every handled message.
#[derive(Debug)]
struct Lease {
    /// Read lease: the opened file.  Write lease: the session's claim
    /// token.  Diagnostics only (Debug output) — the hash occurrences
    /// below are the authoritative state.
    #[allow(dead_code)]
    tag: String,
    /// Writer claim lease (releases `pending`) vs. read lease
    /// (releases `pins`).
    write: bool,
    /// Hash occurrences held: one entry per pinned block-map slot
    /// (read) or per allocated claim (write).  Occurrences, not unique
    /// hashes — a file of n identical blocks holds n entries.
    hashes: Vec<Digest>,
    /// Lapse deadline on the manager's clock.
    expires_at: Instant,
}

#[derive(Debug)]
struct NodeSlot {
    addr: String,
    last_beat: Instant,
}

#[derive(Debug)]
struct Inner {
    files: HashMap<String, FileEntry>,
    blocks: HashMap<Digest, BlockInfo>,
    nodes: Vec<NodeSlot>,
    policy: Box<dyn PlacementPolicy>,
    /// Live leases by id.
    leases: HashMap<u64, Lease>,
    /// Next lease id (ids start at 1; 0 means "no lease" on the wire).
    next_lease: u64,
}

/// Manager state shared across connection threads.
#[derive(Debug)]
pub struct ManagerState {
    inner: Mutex<Inner>,
    /// A node is considered alive if it joined or heartbeated within
    /// this window.
    heartbeat_timeout: Duration,
    /// A lease lapses if not renewed within this window.
    lease_timeout: Duration,
    /// Test-only time hook: an offset added to `Instant::now()` to form
    /// the manager's clock.  [`ManagerState::advance_clock`] bumps it so
    /// lease expiry (and node liveness) can be driven deterministically
    /// instead of with sleeps.
    clock_skew: Mutex<Duration>,
    /// Hashes whose on-node copies are being deleted by an in-flight GC
    /// batch.  Allocations of these hashes wait until the deletes have
    /// landed, so a stale `DeleteBlock` can never destroy a copy a
    /// client re-uploaded after re-allocating the hash.
    gc_inflight: Mutex<HashSet<Digest>>,
    gc_done: Condvar,
}

impl Default for ManagerState {
    fn default() -> Self {
        ManagerState::new(Box::new(RoundRobinStripe::default()))
    }
}

/// Default liveness window: generous relative to the nodes' ~250 ms
/// heartbeat interval, so a few dropped beats don't flap placement.
const HEARTBEAT_TIMEOUT: Duration = Duration::from_secs(3);

/// Default lease timeout: generous relative to the clients' `ttl / 3`
/// renewal cadence, so a few dropped renewals don't lapse a live
/// session, while an abandoned writer's claims return to the GC pool in
/// human time.  Overridable per deployment (`--lease-timeout`,
/// [`crate::config::ClusterConfig::lease_timeout`]).
pub const DEFAULT_LEASE_TIMEOUT: Duration = Duration::from_secs(30);

/// Floor for configured lease timeouts: zero (or near-zero) would
/// lapse every lease at its first expiry sweep, so
/// [`ManagerState::with_lease_timeout`] clamps up to this.
pub const MIN_LEASE_TIMEOUT: Duration = Duration::from_millis(1);

/// Upper bound on how long an allocation waits for an in-flight GC
/// batch covering one of its hashes (best effort beyond that).
const GC_WAIT: Duration = Duration::from_secs(2);

/// Freed blocks + the node address book, handed out of the state lock
/// for execution (network deletes happen outside the lock).
type GcBatch = (Vec<(Digest, Vec<u32>)>, Vec<String>);

impl ManagerState {
    /// State with an explicit placement policy and the default lease
    /// timeout.
    pub fn new(policy: Box<dyn PlacementPolicy>) -> ManagerState {
        ManagerState::with_lease_timeout(policy, DEFAULT_LEASE_TIMEOUT)
    }

    /// State with an explicit placement policy and lease timeout.  A
    /// zero timeout would lapse every lease at its very first sweep
    /// (silently reopening the reader-vs-GC race), so it is clamped to
    /// [`MIN_LEASE_TIMEOUT`] here, at the layer that owns the invariant
    /// — front ends (`Cluster::spawn`, `--lease-timeout`) additionally
    /// reject zero loudly.
    pub fn with_lease_timeout(
        policy: Box<dyn PlacementPolicy>,
        lease_timeout: Duration,
    ) -> ManagerState {
        let lease_timeout = lease_timeout.max(MIN_LEASE_TIMEOUT);
        ManagerState {
            inner: Mutex::new(Inner {
                files: HashMap::new(),
                blocks: HashMap::new(),
                nodes: Vec::new(),
                policy,
                leases: HashMap::new(),
                next_lease: 1,
            }),
            heartbeat_timeout: HEARTBEAT_TIMEOUT,
            lease_timeout,
            clock_skew: Mutex::new(Duration::ZERO),
            gc_inflight: Mutex::new(HashSet::new()),
            gc_done: Condvar::new(),
        }
    }

    /// The manager's notion of "now": real time plus the test skew.
    fn now(&self) -> Instant {
        Instant::now() + *self.clock_skew.lock().unwrap()
    }

    /// Test-only time hook: advance the manager's clock by `by`.  Lease
    /// expiry and node liveness both read this clock, so fault-injection
    /// tests drive timeouts deterministically (pair with
    /// [`ManagerState::tick`] to run the expiry sweep).
    pub fn advance_clock(&self, by: Duration) {
        *self.clock_skew.lock().unwrap() += by;
    }

    /// Run the lazy lease-expiry sweep now (every handled message does
    /// this first) and execute any resulting GC deletes before
    /// returning.  Ops/test hook — pairs with
    /// [`ManagerState::advance_clock`].
    pub fn tick(&self) {
        let _ = self.handle(Msg::NodeList);
    }

    /// Handle one request message.
    pub fn handle(&self, msg: Msg) -> Msg {
        // GC work (network deletes) is collected under the lock and
        // executed after it is released — synchronously, on purpose:
        // the reply to a commit/release is only written once the
        // orphaned blocks are really gone, which keeps reclamation
        // observable (and testable) at the client.  Unreachable nodes
        // are skipped fast on loopback; a slow real-network connect
        // only delays this one caller.  This ordering also makes the
        // in-call expiry/alloc interleaving safe: a hash freed by the
        // expiry sweep and immediately re-allocated by the same message
        // has its stale on-node copies deleted BEFORE the reply (and
        // thus the client's re-upload) goes out.
        let (reply, gc) = self.handle_inner(msg);
        if let Some((freed, addrs)) = gc {
            gc_delete(&freed, &addrs);
            let mut inflight = self.gc_inflight.lock().unwrap();
            for (h, _) in &freed {
                inflight.remove(h);
            }
            drop(inflight);
            self.gc_done.notify_all();
        }
        reply
    }

    /// Block until no in-flight GC batch covers any of `specs` (bounded
    /// by [`GC_WAIT`]).  Touches only `gc_inflight` + the condvar —
    /// never the state lock — so other manager operations proceed while
    /// an allocation waits.
    fn await_gc(&self, specs: &[BlockSpec]) {
        let mut inflight = self.gc_inflight.lock().unwrap();
        let deadline = Instant::now() + GC_WAIT;
        while specs.iter().any(|s| inflight.contains(&s.hash)) {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            let (g, _) = self.gc_done.wait_timeout(inflight, left).unwrap();
            inflight = g;
        }
    }

    /// True if any of `specs` is covered by an in-flight GC batch.
    fn gc_covers(&self, specs: &[BlockSpec]) -> bool {
        let inflight = self.gc_inflight.lock().unwrap();
        specs.iter().any(|s| inflight.contains(&s.hash))
    }

    fn handle_inner(&self, msg: Msg) -> (Msg, Option<GcBatch>) {
        // Allocations wait out GC batches covering their hashes BEFORE
        // taking the state lock (so the wait stalls only this caller),
        // then re-check under the lock: a sweep that started in between
        // sends us back to waiting.  Bounded attempts — after that,
        // proceed best-effort (same exposure as not waiting at all).
        let msg = match msg {
            Msg::AllocPlacement { file, lease, blocks } => {
                for attempt in 0..3 {
                    if attempt > 0 || self.gc_covers(&blocks) {
                        self.await_gc(&blocks);
                    }
                    let mut guard = self.inner.lock().unwrap();
                    if self.gc_covers(&blocks) && attempt < 2 {
                        continue; // sweep raced us; wait again unlocked
                    }
                    let g = &mut *guard;
                    let now = self.now();
                    // Lapsed leases release their claims/pins first, so
                    // an abandoned writer's stale claims never satisfy
                    // this allocation's dedup.
                    let mut freed = Vec::new();
                    self.expire_leases(g, now, &mut freed);
                    let reply = match self.alloc(g, &file, lease, &blocks, now) {
                        Ok(assignments) => Msg::Placement { assignments },
                        Err(e) => Msg::Err(e),
                    };
                    return (reply, self.gc_batch(g, freed));
                }
                unreachable!("alloc loop always returns by attempt 2");
            }
            other => other,
        };
        let mut guard = self.inner.lock().unwrap();
        // Reborrow as a plain `&mut Inner` so field borrows split.
        let g = &mut *guard;
        let now = self.now();
        // Lazy expiry sweep: every handled message first lapses overdue
        // leases (claims/pins release, newly-unreferenced blocks join
        // this message's GC batch).  No background timer — expiry is
        // deterministic given the clock, which tests control.
        let mut freed = Vec::new();
        self.expire_leases(g, now, &mut freed);
        let reply = match msg {
            Msg::GetBlockMap { file } => match g.files.get(&file) {
                Some(e) => Msg::BlockMap {
                    version: e.version,
                    blocks: e.blocks.clone(),
                },
                None => Msg::BlockMap {
                    version: 0,
                    blocks: Vec::new(),
                },
            },
            Msg::CommitBlockMap { file, lease, blocks } => {
                match self.commit(g, file, lease, blocks, &mut freed) {
                    Ok(()) => Msg::Ok,
                    Err(e) => Msg::Err(e),
                }
            }
            // AllocPlacement is handled above (it interleaves with the
            // GC-in-flight barrier before taking the state lock).
            Msg::AllocPlacement { .. } => unreachable!("handled before the lock"),
            Msg::ReleaseBlocks { hashes } => {
                for h in &hashes {
                    if let Some(e) = g.blocks.get_mut(h) {
                        e.pending = e.pending.saturating_sub(1);
                    }
                }
                self.sweep(g, &hashes, &mut freed);
                Msg::Ok
            }
            Msg::OpenLease { file, write } => self.open_lease(g, file, write, now),
            Msg::RenewLease { lease } => match g.leases.get_mut(&lease) {
                Some(l) => {
                    l.expires_at = now + self.lease_timeout;
                    Msg::Ok
                }
                None => Msg::Err(format!("lease {lease} unknown or lapsed")),
            },
            Msg::DropLease { lease } => {
                // Idempotent: dropping a lapsed/consumed lease is OK (a
                // committed writer's lease is consumed by the commit).
                if let Some(l) = g.leases.remove(&lease) {
                    self.release_lease(g, l, &mut freed);
                }
                Msg::Ok
            }
            Msg::NodeJoin { addr } => match g.nodes.iter().position(|n| n.addr == addr) {
                Some(id) => {
                    g.nodes[id].last_beat = now;
                    Msg::NodeId { id: id as u32 }
                }
                None => {
                    g.nodes.push(NodeSlot {
                        addr,
                        last_beat: now,
                    });
                    Msg::NodeId {
                        id: (g.nodes.len() - 1) as u32,
                    }
                }
            },
            Msg::Heartbeat { node } => match g.nodes.get_mut(node as usize) {
                Some(n) => {
                    n.last_beat = now;
                    Msg::Ok
                }
                None => Msg::Err(format!("heartbeat from unregistered node {node}")),
            },
            Msg::NodeList => {
                let timeout = self.heartbeat_timeout;
                Msg::Nodes {
                    nodes: g
                        .nodes
                        .iter()
                        .enumerate()
                        .map(|(id, n)| NodeEntry {
                            id: id as u32,
                            addr: n.addr.clone(),
                            alive: now.saturating_duration_since(n.last_beat) < timeout,
                        })
                        .collect(),
                }
            }
            Msg::ListFiles => {
                let mut list: Vec<(String, u64)> =
                    g.files.iter().map(|(k, v)| (k.clone(), v.version)).collect();
                list.sort();
                Msg::Files { files: list }
            }
            other => Msg::Err(format!("manager: unexpected message {other:?}")),
        };
        (reply, self.gc_batch(g, freed))
    }

    /// Commit one new version: validate, redeem the write lease's
    /// claims into committed references, release the overwritten map's
    /// references and sweep what dropped to zero (pinned blocks are
    /// deferred to their last lease's release).
    fn commit(
        &self,
        g: &mut Inner,
        file: String,
        lease: u64,
        blocks: Vec<BlockMeta>,
        freed: &mut Vec<(Digest, Vec<u32>)>,
    ) -> std::result::Result<(), String> {
        // Satellite (PR 2): validate node ids against the registry
        // before accepting, so readers never chase a block to a node
        // that does not exist.
        if let Some(err) = validate_blocks(&blocks, g.nodes.len()) {
            return Err(err);
        }
        // A lease-tracked commit must present a live write lease: if it
        // lapsed, its claims were already released and the blocks may
        // be gone from the nodes — committing would publish an
        // unreadable file.  The commit consumes the lease (it redeems
        // every claim; the writer's Drop must not release them again).
        let held = match lease {
            0 => None,
            id => match g.leases.remove(&id) {
                Some(l) if l.write => Some(l),
                Some(l) => {
                    g.leases.insert(id, l);
                    return Err(format!("commit: lease {id} is not a write lease"));
                }
                None => {
                    return Err(format!(
                        "commit: write lease {id} lapsed and its claims were released"
                    ))
                }
            },
        };
        for m in &blocks {
            let e = g.blocks.entry(m.hash).or_insert_with(|| BlockInfo {
                replicas: m.replicas.clone(),
                len: m.len,
                refs: 0,
                pending: 0,
                pins: 0,
                placed_by: String::new(),
            });
            e.refs += 1;
            e.pending = e.pending.saturating_sub(1);
        }
        // Claim occurrences the commit did not consume (allocated but
        // left out of the final map) are released with the lease.
        if let Some(l) = held {
            let mut consumed: HashMap<Digest, u64> = HashMap::new();
            for m in &blocks {
                *consumed.entry(m.hash).or_default() += 1;
            }
            let mut leftovers = Vec::new();
            for h in l.hashes {
                match consumed.get_mut(&h) {
                    Some(n) if *n > 0 => *n -= 1,
                    _ => {
                        if let Some(e) = g.blocks.get_mut(&h) {
                            e.pending = e.pending.saturating_sub(1);
                        }
                        leftovers.push(h);
                    }
                }
            }
            self.sweep(g, &leftovers, freed);
        }
        let f = g.files.entry(file).or_default();
        f.version += 1;
        let old = std::mem::replace(&mut f.blocks, blocks);
        for m in &old {
            if let Some(e) = g.blocks.get_mut(&m.hash) {
                e.refs = e.refs.saturating_sub(1);
            }
        }
        // Only the old map's hashes can have newly reached zero
        // references (the new map's all got refs += 1).  Read-leased
        // blocks have pins > 0 and survive; their deferred deletes run
        // when the last lease drops — the ROADMAP reader-snapshot race,
        // closed.
        let candidates: Vec<Digest> = old.iter().map(|m| m.hash).collect();
        self.sweep(g, &candidates, freed);
        Ok(())
    }

    /// Grant a lease: read leases atomically snapshot + pin the file's
    /// current block-map, write leases register an (initially empty)
    /// claim holder.
    fn open_lease(&self, g: &mut Inner, file: String, write: bool, now: Instant) -> Msg {
        let ttl_ms = self.lease_timeout.as_millis() as u64;
        let (version, blocks) = if write {
            (0, Vec::new())
        } else {
            match g.files.get(&file) {
                Some(e) if e.version > 0 => (e.version, e.blocks.clone()),
                _ => {
                    // No such file: nothing to pin, no lease granted.
                    return Msg::LeaseGrant {
                        lease: 0,
                        ttl_ms,
                        version: 0,
                        blocks: Vec::new(),
                    };
                }
            }
        };
        for m in &blocks {
            if let Some(e) = g.blocks.get_mut(&m.hash) {
                e.pins += 1;
            }
        }
        let id = g.next_lease;
        g.next_lease += 1;
        g.leases.insert(
            id,
            Lease {
                tag: file,
                write,
                hashes: blocks.iter().map(|m| m.hash).collect(),
                expires_at: now + self.lease_timeout,
            },
        );
        Msg::LeaseGrant {
            lease: id,
            ttl_ms,
            version,
            blocks,
        }
    }

    /// Lapse every overdue lease (release its claims/pins and sweep).
    fn expire_leases(&self, g: &mut Inner, now: Instant, freed: &mut Vec<(Digest, Vec<u32>)>) {
        let lapsed: Vec<u64> = g
            .leases
            .iter()
            .filter(|(_, l)| l.expires_at <= now)
            .map(|(id, _)| *id)
            .collect();
        for id in lapsed {
            let l = g.leases.remove(&id).expect("collected under the same lock");
            self.release_lease(g, l, freed);
        }
    }

    /// Return a lease's held occurrences to the pool: a write lease's
    /// claims stop pending, a read lease's pins drop — then sweep.
    fn release_lease(&self, g: &mut Inner, l: Lease, freed: &mut Vec<(Digest, Vec<u32>)>) {
        for h in &l.hashes {
            if let Some(e) = g.blocks.get_mut(h) {
                if l.write {
                    e.pending = e.pending.saturating_sub(1);
                } else {
                    e.pins = e.pins.saturating_sub(1);
                }
            }
        }
        self.sweep(g, &l.hashes, freed);
    }

    /// Collect garbage among `candidates` (the hashes whose counters
    /// this operation decremented — anything else cannot have newly
    /// reached zero): drop every candidate with no committed
    /// references, no pending claims and no read-lease pins, and mark
    /// the freed hashes GC-in-flight (while still holding the state
    /// lock, so allocations of these hashes wait — see
    /// [`ManagerState::await_gc`]).  Deletion itself runs outside the
    /// lock, via [`ManagerState::gc_batch`].
    fn sweep(&self, g: &mut Inner, candidates: &[Digest], freed: &mut Vec<(Digest, Vec<u32>)>) {
        let mut marked = Vec::new();
        for h in candidates {
            // Duplicate candidates are harmless: once removed, the
            // second lookup misses.
            if let Some(b) = g.blocks.get(h) {
                if b.refs == 0 && b.pending == 0 && b.pins == 0 {
                    freed.push((*h, b.replicas.clone()));
                    marked.push(*h);
                    g.blocks.remove(h);
                }
            }
        }
        if !marked.is_empty() {
            self.gc_inflight.lock().unwrap().extend(marked);
        }
    }

    /// Package this message's freed blocks with the node address book
    /// for execution outside the state lock.
    fn gc_batch(&self, g: &Inner, freed: Vec<(Digest, Vec<u32>)>) -> Option<GcBatch> {
        if freed.is_empty() {
            return None;
        }
        Some((freed, g.nodes.iter().map(|n| n.addr.clone()).collect()))
    }

    /// Manager-driven placement for one batch (claims held under the
    /// caller's write lease, which the allocation also renews).
    fn alloc(
        &self,
        g: &mut Inner,
        file: &str,
        lease: u64,
        specs: &[BlockSpec],
        now: Instant,
    ) -> std::result::Result<Vec<Assignment>, String> {
        // Claims must be held under a live write lease (`0` = untracked
        // legacy claims, kept for raw protocol users): a lapsed lease
        // means this writer's earlier claims were already reclaimed —
        // it must re-open rather than keep streaming into a void.
        if lease != 0 {
            match g.leases.get(&lease) {
                Some(l) if l.write => {}
                Some(_) => return Err(format!("alloc: lease {lease} is not a write lease")),
                None => return Err(format!("alloc: write lease {lease} lapsed")),
            }
        }
        let alive: Vec<u32> = g
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| {
                now.saturating_duration_since(n.last_beat) < self.heartbeat_timeout
            })
            .map(|(id, _)| id as u32)
            .collect();
        if alive.is_empty() {
            return Err(if g.nodes.is_empty() {
                "no storage nodes registered".into()
            } else {
                "no storage nodes alive".into()
            });
        }
        let mut out = Vec::with_capacity(specs.len());
        for s in specs {
            match g.blocks.get_mut(&s.hash) {
                // Committed somewhere (a commit proves the transfer
                // completed), or claimed by this same session (which is
                // the one doing the transfer): safe to dedup — PROVIDED
                // at least one replica is still alive.  A known block
                // whose replicas all died is re-homed and
                // re-transferred (the writer has the bytes in hand;
                // dedup against dead nodes would commit an unreadable
                // file).
                Some(e) if e.refs > 0 || e.placed_by == file => {
                    e.pending += 1;
                    if e.replicas.iter().any(|r| alive.contains(r)) {
                        out.push(Assignment {
                            replicas: e.replicas.clone(),
                            fresh: false,
                        });
                    } else {
                        e.replicas = g.policy.place(&alive);
                        out.push(Assignment {
                            replicas: e.replicas.clone(),
                            fresh: true,
                        });
                    }
                }
                // Known only as ANOTHER session's uncommitted claim:
                // that transfer may still fail or be abandoned, so this
                // writer must transfer too (puts are idempotent by key)
                // — same homes (re-homed if all dead), but fresh from
                // the caller's point of view.
                //
                // Re-homing (here and above) deliberately does NOT
                // delete the old replicas' copies: those nodes look
                // dead, so the deletes could not land anyway, and if a
                // node was merely partitioned, its surviving copy may
                // be the only one a pinned reader's snapshot map can
                // still name — eager deletion would break that reader
                // when the node heals.  The cost is a bounded space
                // leak on a flapping node (ROADMAP, lease limitations).
                Some(e) => {
                    e.pending += 1;
                    if !e.replicas.iter().any(|r| alive.contains(r)) {
                        e.replicas = g.policy.place(&alive);
                    }
                    out.push(Assignment {
                        replicas: e.replicas.clone(),
                        fresh: true,
                    });
                }
                None => {
                    let replicas = g.policy.place(&alive);
                    debug_assert!(!replicas.is_empty());
                    g.blocks.insert(
                        s.hash,
                        BlockInfo {
                            replicas: replicas.clone(),
                            len: s.len,
                            refs: 0,
                            pending: 1,
                            pins: 0,
                            placed_by: file.to_string(),
                        },
                    );
                    out.push(Assignment {
                        replicas,
                        fresh: true,
                    });
                }
            }
        }
        // Record the claim occurrences against the lease and renew it
        // (an actively-allocating writer is a live writer).
        if lease != 0 {
            let l = g.leases.get_mut(&lease).expect("validated above");
            l.hashes.extend(specs.iter().map(|s| s.hash));
            l.expires_at = now + self.lease_timeout;
        }
        Ok(out)
    }

    /// Aggregate manager bookkeeping, counting each replica copy —
    /// includes the lease subsystem's counters, which the
    /// fault-injection tests assert on ("zero stranded pending
    /// claims").  Counters reflect the state as of the last handled
    /// message; call [`ManagerState::tick`] first to fold in overdue
    /// lease expiries.
    pub fn block_stats(&self) -> BlockStats {
        let g = self.inner.lock().unwrap();
        let mut s = BlockStats::default();
        for b in g.blocks.values() {
            let copies = b.replicas.len() as u64;
            s.blocks += copies;
            s.bytes += copies * b.len as u64;
            s.pending_claims += b.pending;
            if b.pins > 0 {
                s.pinned_blocks += 1;
            }
        }
        for l in g.leases.values() {
            if l.write {
                s.write_leases += 1;
            } else {
                s.read_leases += 1;
            }
        }
        s
    }
}

/// Aggregate manager bookkeeping returned by
/// [`ManagerState::block_stats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct BlockStats {
    /// Replica copies the manager believes live (committed or pending).
    pub blocks: u64,
    /// Payload bytes behind those copies.
    pub bytes: u64,
    /// Outstanding provisional claim occurrences (uncommitted writers).
    pub pending_claims: u64,
    /// Blocks currently pinned by at least one read lease.
    pub pinned_blocks: u64,
    /// Live read leases.
    pub read_leases: u64,
    /// Live write leases.
    pub write_leases: u64,
}

fn validate_blocks(blocks: &[BlockMeta], registered: usize) -> Option<String> {
    for (i, m) in blocks.iter().enumerate() {
        if m.replicas.is_empty() {
            return Some(format!("block {i}: empty replica set"));
        }
        for &r in &m.replicas {
            if r as usize >= registered {
                return Some(format!(
                    "block {i}: replica node {r} is not registered ({registered} nodes known)"
                ));
            }
        }
    }
    None
}

/// Best-effort deletion of freed blocks on their owning nodes.  Dead or
/// unreachable nodes are skipped — the block is already unreferenced,
/// so a leaked copy only costs space until the node rejoins or dies.
fn gc_delete(freed: &[(Digest, Vec<u32>)], addrs: &[String]) {
    let mut per_node: HashMap<u32, Vec<Digest>> = HashMap::new();
    for (hash, replicas) in freed {
        for r in replicas {
            per_node.entry(*r).or_default().push(*hash);
        }
    }
    for (node, hashes) in per_node {
        let Some(addr) = addrs.get(node as usize) else {
            continue;
        };
        // Bounded connect: a black-holed node must not stall the
        // committing client for the OS SYN timeout.
        let Ok(conn) = Conn::connect_timeout(addr, Duration::from_secs(1)) else {
            continue;
        };
        let Ok(rc) = conn.try_clone() else { continue };
        let mut r = BufReader::new(rc);
        let mut w = BufWriter::new(conn);
        for hash in hashes {
            if Msg::DeleteBlock { hash }.write_to(&mut w).is_err() {
                break;
            }
            if Msg::read_from(&mut r).is_err() {
                break;
            }
        }
    }
}

/// A running manager server.
pub struct Manager {
    addr: String,
    state: Arc<ManagerState>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl Manager {
    /// Bind and serve on `addr` ("127.0.0.1:0" for ephemeral) with the
    /// default single-copy round-robin policy.
    pub fn spawn(addr: &str) -> Result<Manager> {
        Manager::spawn_with_policy(addr, Box::new(RoundRobinStripe::default()))
    }

    /// Bind and serve with an explicit placement policy and the default
    /// lease timeout.
    pub fn spawn_with_policy(addr: &str, policy: Box<dyn PlacementPolicy>) -> Result<Manager> {
        Manager::spawn_with_opts(addr, policy, DEFAULT_LEASE_TIMEOUT)
    }

    /// Bind and serve with an explicit placement policy and lease
    /// timeout (surfaced as `--lease-timeout` in the CLI and
    /// [`crate::config::ClusterConfig::lease_timeout`]).
    pub fn spawn_with_opts(
        addr: &str,
        policy: Box<dyn PlacementPolicy>,
        lease_timeout: Duration,
    ) -> Result<Manager> {
        let listener = Listener::bind(addr)?;
        let addr = listener.local_addr()?;
        let state = Arc::new(ManagerState::with_lease_timeout(policy, lease_timeout));
        let stop = Arc::new(AtomicBool::new(false));
        let (st, sp) = (state.clone(), stop.clone());
        let accept_thread = std::thread::Builder::new()
            .name("mosa-manager".into())
            .spawn(move || accept_loop(listener, st, sp))
            .map_err(crate::Error::Io)?;
        Ok(Manager {
            addr,
            state,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    /// The bound address.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Direct (in-process) access for tests.
    pub fn state(&self) -> &Arc<ManagerState> {
        &self.state
    }

    /// Stop accepting (existing connections finish their current call).
    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return; // already shut down
        }
        // Dedicated poke path: connect-and-close guarantees the blocked
        // `accept()` returns at least once after the stop flag is set.
        // The accept loop serves that last connection regardless (a
        // real client racing shutdown gets its call answered; the poke
        // itself sends nothing and its serve thread exits on EOF).
        let _ = Conn::connect(&self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Manager {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: Listener, state: Arc<ManagerState>, stop: Arc<AtomicBool>) {
    loop {
        let conn = match listener.accept() {
            Ok(c) => c,
            Err(_) => break,
        };
        // Race fix: the stop flag is checked before DROPPING the
        // connection, not before serving it — a real client that
        // connected concurrently with shutdown is still served (its
        // serve thread runs to completion), and the shutdown poke's
        // connection reads clean EOF and exits immediately.
        let stopping = stop.load(Ordering::SeqCst);
        let st = state.clone();
        let _ = std::thread::Builder::new()
            .name("mosa-manager-conn".into())
            .spawn(move || serve_conn(conn, st));
        if stopping {
            break;
        }
    }
}

fn serve_conn(conn: Conn, state: Arc<ManagerState>) {
    let reader = match conn.try_clone() {
        Ok(c) => c,
        Err(_) => return,
    };
    let mut r = BufReader::new(reader);
    let mut w = BufWriter::new(conn);
    while let Ok(Some(msg)) = Msg::read_from(&mut r) {
        let reply = state.handle(msg);
        if reply.write_to(&mut w).is_err() {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(i: u8) -> BlockMeta {
        BlockMeta {
            hash: [i; 16],
            len: 100,
            replicas: vec![0],
        }
    }

    /// Register `n` fake nodes directly against the state.  The
    /// addresses point at closed loopback ports so GC deletes fail
    /// *immediately* (connection refused) instead of hanging.
    fn join_nodes(s: &ManagerState, n: usize) {
        for i in 0..n {
            let r = s.handle(Msg::NodeJoin {
                addr: format!("127.0.0.1:{}", i + 1),
            });
            assert_eq!(r, Msg::NodeId { id: i as u32 });
        }
    }

    #[test]
    fn state_commit_and_get() {
        let s = ManagerState::default();
        join_nodes(&s, 1);
        let r = s.handle(Msg::GetBlockMap { file: "f".into() });
        assert_eq!(
            r,
            Msg::BlockMap {
                version: 0,
                blocks: vec![]
            }
        );
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![meta(1)],
        });
        let r = s.handle(Msg::GetBlockMap { file: "f".into() });
        assert_eq!(
            r,
            Msg::BlockMap {
                version: 1,
                blocks: vec![meta(1)]
            }
        );
    }

    #[test]
    fn state_versions_increment() {
        let s = ManagerState::default();
        join_nodes(&s, 1);
        for i in 1..=3 {
            s.handle(Msg::CommitBlockMap {
                file: "f".into(),
                lease: 0,
                blocks: vec![meta(i)],
            });
            let Msg::BlockMap { version, .. } = s.handle(Msg::GetBlockMap { file: "f".into() })
            else {
                panic!()
            };
            assert_eq!(version, i as u64);
        }
    }

    #[test]
    fn state_list_files_sorted() {
        let s = ManagerState::default();
        join_nodes(&s, 1);
        for f in ["b", "a"] {
            s.handle(Msg::CommitBlockMap {
                file: f.into(),
                lease: 0,
                blocks: vec![],
            });
        }
        let Msg::Files { files } = s.handle(Msg::ListFiles) else {
            panic!()
        };
        assert_eq!(files, vec![("a".into(), 1), ("b".into(), 1)]);
    }

    #[test]
    fn state_rejects_wrong_message() {
        let s = ManagerState::default();
        assert!(matches!(s.handle(Msg::Ok), Msg::Err(_)));
    }

    #[test]
    fn commit_rejects_unregistered_node() {
        let s = ManagerState::default();
        join_nodes(&s, 2);
        let bad = BlockMeta {
            hash: [1; 16],
            len: 10,
            replicas: vec![0, 7], // node 7 does not exist
        };
        assert!(matches!(
            s.handle(Msg::CommitBlockMap {
                file: "f".into(),
                lease: 0,
                blocks: vec![bad],
            }),
            Msg::Err(_)
        ));
        // And an empty replica set is rejected too.
        let empty = BlockMeta {
            hash: [2; 16],
            len: 10,
            replicas: vec![],
        };
        assert!(matches!(
            s.handle(Msg::CommitBlockMap {
                file: "f".into(),
                lease: 0,
                blocks: vec![empty],
            }),
            Msg::Err(_)
        ));
    }

    #[test]
    fn alloc_requires_registered_nodes() {
        let s = ManagerState::default();
        let r = s.handle(Msg::AllocPlacement {
            file: "f".into(),
            lease: 0,
            blocks: vec![BlockSpec { hash: [1; 16], len: 5 }],
        });
        assert!(matches!(r, Msg::Err(_)));
    }

    #[test]
    fn alloc_round_robins_and_dedups() {
        let s = ManagerState::default();
        join_nodes(&s, 3);
        let specs: Vec<BlockSpec> = (0..4u8)
            .map(|i| BlockSpec {
                hash: [i; 16],
                len: 10,
            })
            .collect();
        let Msg::Placement { assignments } = s.handle(Msg::AllocPlacement {
            file: "f".into(),
            lease: 0,
            blocks: specs.clone(),
        }) else {
            panic!()
        };
        assert_eq!(assignments.len(), 4);
        assert!(assignments.iter().all(|a| a.fresh));
        let picked: Vec<u32> = assignments.iter().map(|a| a.replicas[0]).collect();
        assert_eq!(picked, vec![0, 1, 2, 0], "round-robin over 3 nodes");

        // The same session (file) re-allocating its own pending blocks
        // dedups: it is the one doing the transfer.
        let Msg::Placement { assignments: same } = s.handle(Msg::AllocPlacement {
            file: "f".into(),
            lease: 0,
            blocks: specs.clone(),
        }) else {
            panic!()
        };
        assert!(same.iter().all(|a| !a.fresh));

        // ANOTHER session must not dedup against a merely-pending claim
        // (the first transfer may never complete): same homes, but it
        // is told to transfer too.
        let Msg::Placement { assignments: other } = s.handle(Msg::AllocPlacement {
            file: "g".into(),
            lease: 0,
            blocks: specs.clone(),
        }) else {
            panic!()
        };
        assert!(other.iter().all(|a| a.fresh));
        assert_eq!(
            other.iter().map(|a| a.replicas[0]).collect::<Vec<_>>(),
            picked,
            "pending blocks keep their assigned homes"
        );

        // Once committed, any session dedups against it.
        let metas: Vec<BlockMeta> = (0..4u8)
            .map(|i| BlockMeta {
                hash: [i; 16],
                len: 10,
                replicas: vec![picked[i as usize]],
            })
            .collect();
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: metas,
        });
        let Msg::Placement { assignments: after } = s.handle(Msg::AllocPlacement {
            file: "h".into(),
            lease: 0,
            blocks: specs,
        }) else {
            panic!()
        };
        assert!(after.iter().all(|a| !a.fresh), "committed blocks dedup globally");
    }

    #[test]
    fn replicated_stripe_places_distinct_copies() {
        let mut p = ReplicatedStripe::new(2);
        let alive = vec![0u32, 1, 2, 3];
        for _ in 0..8 {
            let set = p.place(&alive);
            assert_eq!(set.len(), 2);
            assert_ne!(set[0], set[1]);
        }
        // Replication clamps to the alive count.
        let mut p = ReplicatedStripe::new(5);
        let set = p.place(&[7, 9]);
        assert_eq!(set.len(), 2);
    }

    #[test]
    fn refcount_overwrite_frees_old_blocks() {
        let s = ManagerState::default();
        join_nodes(&s, 1);
        // v1 references block 1; v2 references block 2 only.
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![meta(1)],
        });
        assert_eq!(s.block_stats().blocks, 1);
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![meta(2)],
        });
        // Block 1 had refs 0 after the overwrite -> swept.
        assert_eq!(s.block_stats().blocks, 1);
        // A block shared by two files survives one file's overwrite.
        s.handle(Msg::CommitBlockMap {
            file: "g".into(),
            lease: 0,
            blocks: vec![meta(2)],
        });
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![],
        });
        assert_eq!(s.block_stats().blocks, 1, "g still references block 2");
    }

    #[test]
    fn release_drops_pending_claims() {
        let s = ManagerState::default();
        join_nodes(&s, 1);
        let spec = BlockSpec { hash: [9; 16], len: 7 };
        s.handle(Msg::AllocPlacement {
            file: "f".into(),
            lease: 0,
            blocks: vec![spec],
        });
        assert_eq!(s.block_stats().blocks, 1, "pending claim keeps the block");
        s.handle(Msg::ReleaseBlocks {
            hashes: vec![[9; 16]],
        });
        assert_eq!(s.block_stats().blocks, 0, "released + unreferenced -> swept");
    }

    #[test]
    fn node_join_is_idempotent_and_heartbeat_tracked() {
        let s = ManagerState::default();
        let r1 = s.handle(Msg::NodeJoin { addr: "a:1".into() });
        let r2 = s.handle(Msg::NodeJoin { addr: "b:2".into() });
        let r3 = s.handle(Msg::NodeJoin { addr: "a:1".into() });
        assert_eq!(r1, Msg::NodeId { id: 0 });
        assert_eq!(r2, Msg::NodeId { id: 1 });
        assert_eq!(r3, Msg::NodeId { id: 0 }, "rejoin keeps the id");
        assert_eq!(s.handle(Msg::Heartbeat { node: 1 }), Msg::Ok);
        assert!(matches!(s.handle(Msg::Heartbeat { node: 9 }), Msg::Err(_)));
        let Msg::Nodes { nodes } = s.handle(Msg::NodeList) else {
            panic!()
        };
        assert_eq!(nodes.len(), 2);
        assert!(nodes.iter().all(|n| n.alive));
    }

    #[test]
    fn tcp_serving_works() {
        let mgr = Manager::spawn("127.0.0.1:0").unwrap();
        let mut c = Conn::connect(mgr.addr()).unwrap();
        Msg::NodeJoin { addr: "x:1".into() }.write_to(&mut c).unwrap();
        assert_eq!(
            Msg::read_from(&mut c).unwrap().unwrap(),
            Msg::NodeId { id: 0 }
        );
        Msg::CommitBlockMap {
            file: "x".into(),
            lease: 0,
            blocks: vec![meta(5)],
        }
        .write_to(&mut c)
        .unwrap();
        let r = Msg::read_from(&mut c).unwrap().unwrap();
        assert_eq!(r, Msg::Ok);
        Msg::GetBlockMap { file: "x".into() }.write_to(&mut c).unwrap();
        let r = Msg::read_from(&mut c).unwrap().unwrap();
        assert_eq!(
            r,
            Msg::BlockMap {
                version: 1,
                blocks: vec![meta(5)]
            }
        );
    }

    #[test]
    fn multiple_clients() {
        let mgr = Manager::spawn("127.0.0.1:0").unwrap();
        mgr.state().handle(Msg::NodeJoin { addr: "x:1".into() });
        let addr = mgr.addr().to_string();
        let threads: Vec<_> = (0..4)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = Conn::connect(&addr).unwrap();
                    Msg::CommitBlockMap {
                        file: format!("f{i}"),
                        lease: 0,
                        blocks: vec![meta(i as u8)],
                    }
                    .write_to(&mut c)
                    .unwrap();
                    assert_eq!(Msg::read_from(&mut c).unwrap().unwrap(), Msg::Ok);
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let Msg::Files { files } = mgr.state().handle(Msg::ListFiles) else {
            panic!()
        };
        assert_eq!(files.len(), 4);
    }

    #[test]
    fn shutdown_still_serves_racing_client() {
        // A client connecting concurrently with shutdown must get its
        // in-flight call answered, not be silently dropped.
        for _ in 0..8 {
            let mut mgr = Manager::spawn("127.0.0.1:0").unwrap();
            let addr = mgr.addr().to_string();
            let client = std::thread::spawn(move || {
                let mut c = Conn::connect(&addr)?;
                Msg::ListFiles.write_to(&mut c)?;
                Msg::read_from(&mut c)
            });
            mgr.shutdown();
            match client.join().unwrap() {
                // Served (possibly during shutdown) or cleanly refused;
                // a hang would fail the test via the harness timeout.
                Ok(Some(Msg::Files { .. })) | Ok(None) | Err(_) => {}
                other => panic!("unexpected reply: {other:?}"),
            }
        }
    }

    // ---- leases (control-plane v3) ----

    /// 5-second lease window + 1 node, the lease unit-test fixture.
    fn leased_state() -> ManagerState {
        let s = ManagerState::with_lease_timeout(
            Box::new(RoundRobinStripe::default()),
            Duration::from_secs(5),
        );
        join_nodes(&s, 1);
        s
    }

    fn open_write_lease(s: &ManagerState, tag: &str) -> u64 {
        let Msg::LeaseGrant { lease, ttl_ms, version, blocks } = s.handle(Msg::OpenLease {
            file: tag.into(),
            write: true,
        }) else {
            panic!("no grant")
        };
        assert!(lease != 0);
        assert_eq!(ttl_ms, 5_000);
        assert_eq!((version, blocks.len()), (0, 0));
        lease
    }

    #[test]
    fn zero_lease_timeout_clamped_to_floor() {
        // The invariant lives in with_lease_timeout itself, not only in
        // the front ends: a zero window must not lapse leases at grant.
        let s = ManagerState::with_lease_timeout(
            Box::new(RoundRobinStripe::default()),
            Duration::ZERO,
        );
        let Msg::LeaseGrant { lease, ttl_ms, .. } = s.handle(Msg::OpenLease {
            file: "t".into(),
            write: true,
        }) else {
            panic!()
        };
        assert!(lease != 0);
        assert!(ttl_ms >= 1, "ttl clamped to the floor, not zero");
    }

    #[test]
    fn write_lease_claims_lapse_on_expiry() {
        let s = leased_state();
        let lease = open_write_lease(&s, "sess");
        s.handle(Msg::AllocPlacement {
            file: "sess".into(),
            lease,
            blocks: vec![BlockSpec { hash: [9; 16], len: 7 }],
        });
        assert_eq!(s.block_stats().pending_claims, 1);
        // Within the window nothing lapses.
        s.advance_clock(Duration::from_secs(4));
        s.tick();
        assert_eq!(s.block_stats().pending_claims, 1);
        assert_eq!(s.block_stats().write_leases, 1);
        // The allocation renewed the lease, so expiry counts from it.
        s.advance_clock(Duration::from_secs(2));
        s.tick();
        assert_eq!(s.block_stats().pending_claims, 0, "claims lapsed");
        assert_eq!(s.block_stats().write_leases, 0);
        assert_eq!(s.block_stats().blocks, 0, "orphaned block swept");
        // A lapsed lease can neither allocate nor commit.
        assert!(matches!(
            s.handle(Msg::AllocPlacement {
                file: "sess".into(),
                lease,
                blocks: vec![BlockSpec { hash: [9; 16], len: 7 }],
            }),
            Msg::Err(_)
        ));
        assert!(matches!(
            s.handle(Msg::CommitBlockMap {
                file: "f".into(),
                lease,
                blocks: vec![meta(9)],
            }),
            Msg::Err(_)
        ));
    }

    #[test]
    fn renew_extends_write_lease() {
        let s = leased_state();
        let lease = open_write_lease(&s, "sess");
        s.handle(Msg::AllocPlacement {
            file: "sess".into(),
            lease,
            blocks: vec![BlockSpec { hash: [8; 16], len: 3 }],
        });
        for _ in 0..3 {
            s.advance_clock(Duration::from_secs(4));
            assert_eq!(s.handle(Msg::RenewLease { lease }), Msg::Ok);
        }
        s.tick();
        assert_eq!(s.block_stats().pending_claims, 1, "renewals kept the claim");
        // Stop renewing: one full window later the claim lapses.
        s.advance_clock(Duration::from_secs(6));
        assert!(matches!(s.handle(Msg::RenewLease { lease }), Msg::Err(_)));
        assert_eq!(s.block_stats().pending_claims, 0);
    }

    #[test]
    fn commit_consumes_write_lease() {
        let s = leased_state();
        let lease = open_write_lease(&s, "sess");
        s.handle(Msg::AllocPlacement {
            file: "sess".into(),
            lease,
            blocks: vec![BlockSpec { hash: [1; 16], len: 100 }],
        });
        assert_eq!(
            s.handle(Msg::CommitBlockMap {
                file: "f".into(),
                lease,
                blocks: vec![meta(1)],
            }),
            Msg::Ok
        );
        let stats = s.block_stats();
        assert_eq!(stats.pending_claims, 0, "claims redeemed into refs");
        assert_eq!(stats.write_leases, 0, "lease consumed");
        assert_eq!(stats.blocks, 1);
        // Expiry long after the commit must not touch the committed
        // version.
        s.advance_clock(Duration::from_secs(60));
        s.tick();
        assert_eq!(s.block_stats().blocks, 1);
        // Dropping the consumed lease is a harmless no-op.
        assert_eq!(s.handle(Msg::DropLease { lease }), Msg::Ok);
        assert_eq!(s.block_stats().blocks, 1);
    }

    #[test]
    fn commit_releases_unused_claims() {
        // A writer allocates two blocks but commits only one (e.g. the
        // app truncated): the unused claim is released with the lease.
        let s = leased_state();
        let lease = open_write_lease(&s, "sess");
        s.handle(Msg::AllocPlacement {
            file: "sess".into(),
            lease,
            blocks: vec![
                BlockSpec { hash: [1; 16], len: 100 },
                BlockSpec { hash: [2; 16], len: 100 },
            ],
        });
        assert_eq!(s.block_stats().pending_claims, 2);
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease,
            blocks: vec![meta(1)],
        });
        let stats = s.block_stats();
        assert_eq!(stats.pending_claims, 0);
        assert_eq!(stats.blocks, 1, "unused claim's block swept");
    }

    #[test]
    fn read_lease_pins_overwritten_version() {
        let s = leased_state();
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![meta(1)],
        });
        let Msg::LeaseGrant { lease, version, blocks, .. } = s.handle(Msg::OpenLease {
            file: "f".into(),
            write: false,
        }) else {
            panic!()
        };
        assert!(lease != 0);
        assert_eq!(version, 1);
        assert_eq!(blocks, vec![meta(1)]);
        // Overwrite: block 1 loses its last reference but is pinned.
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![meta(2)],
        });
        let stats = s.block_stats();
        assert_eq!(stats.blocks, 2, "old block pinned, not swept");
        assert_eq!(stats.pinned_blocks, 1);
        assert_eq!(stats.read_leases, 1);
        // Dropping the lease runs the deferred delete.
        assert_eq!(s.handle(Msg::DropLease { lease }), Msg::Ok);
        let stats = s.block_stats();
        assert_eq!(stats.blocks, 1, "deferred GC ran on lease drop");
        assert_eq!((stats.pinned_blocks, stats.read_leases), (0, 0));
    }

    #[test]
    fn read_lease_expiry_unpins() {
        let s = leased_state();
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![meta(1)],
        });
        let Msg::LeaseGrant { lease, .. } = s.handle(Msg::OpenLease {
            file: "f".into(),
            write: false,
        }) else {
            panic!()
        };
        assert!(lease != 0);
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![meta(2)],
        });
        assert_eq!(s.block_stats().blocks, 2);
        // The reader vanishes without dropping: the pin lapses with the
        // lease and the deferred delete runs.
        s.advance_clock(Duration::from_secs(6));
        s.tick();
        let stats = s.block_stats();
        assert_eq!(stats.blocks, 1, "pin lapsed, block reclaimed");
        assert_eq!(stats.read_leases, 0);
    }

    #[test]
    fn open_lease_on_missing_file_grants_nothing() {
        let s = leased_state();
        let Msg::LeaseGrant { lease, version, blocks, .. } = s.handle(Msg::OpenLease {
            file: "nope".into(),
            write: false,
        }) else {
            panic!()
        };
        assert_eq!((lease, version, blocks.len()), (0, 0, 0));
        assert_eq!(s.block_stats().read_leases, 0);
    }

    #[test]
    fn shared_block_pinned_by_two_readers() {
        let s = leased_state();
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![meta(1)],
        });
        let open = |s: &ManagerState| -> u64 {
            let Msg::LeaseGrant { lease, .. } = s.handle(Msg::OpenLease {
                file: "f".into(),
                write: false,
            }) else {
                panic!()
            };
            lease
        };
        let (l1, l2) = (open(&s), open(&s));
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            lease: 0,
            blocks: vec![],
        });
        assert_eq!(s.block_stats().blocks, 1, "pinned twice");
        s.handle(Msg::DropLease { lease: l1 });
        assert_eq!(s.block_stats().blocks, 1, "still pinned once");
        s.handle(Msg::DropLease { lease: l2 });
        assert_eq!(s.block_stats().blocks, 0, "last pin dropped -> swept");
    }
}
