//! The centralized metadata manager (paper §3.2.1): keeps a block-map
//! per file — the ordered list of (hash, len, node) entries — and the
//! file's version.  Thread-per-connection over the shared protocol.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use super::proto::{BlockMeta, Msg};
use crate::net::{Conn, Listener};
use crate::Result;

#[derive(Debug, Default)]
struct FileEntry {
    version: u64,
    blocks: Vec<BlockMeta>,
}

/// Manager state shared across connection threads.
#[derive(Debug, Default)]
pub struct ManagerState {
    files: Mutex<HashMap<String, FileEntry>>,
}

impl ManagerState {
    /// Handle one request message.
    pub fn handle(&self, msg: Msg) -> Msg {
        match msg {
            Msg::GetBlockMap { file } => {
                let files = self.files.lock().unwrap();
                match files.get(&file) {
                    Some(e) => Msg::BlockMap {
                        version: e.version,
                        blocks: e.blocks.clone(),
                    },
                    None => Msg::BlockMap {
                        version: 0,
                        blocks: Vec::new(),
                    },
                }
            }
            Msg::CommitBlockMap { file, blocks } => {
                let mut files = self.files.lock().unwrap();
                let e = files.entry(file).or_default();
                e.version += 1;
                e.blocks = blocks;
                Msg::Ok
            }
            Msg::ListFiles => {
                let files = self.files.lock().unwrap();
                let mut list: Vec<(String, u64)> = files
                    .iter()
                    .map(|(k, v)| (k.clone(), v.version))
                    .collect();
                list.sort();
                Msg::Files { files: list }
            }
            other => Msg::Err(format!("manager: unexpected message {other:?}")),
        }
    }
}

/// A running manager server.
pub struct Manager {
    addr: String,
    state: Arc<ManagerState>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl Manager {
    /// Bind and serve on `addr` ("127.0.0.1:0" for ephemeral).
    pub fn spawn(addr: &str) -> Result<Manager> {
        let listener = Listener::bind(addr)?;
        let addr = listener.local_addr()?;
        let state = Arc::new(ManagerState::default());
        let stop = Arc::new(AtomicBool::new(false));
        let (st, sp) = (state.clone(), stop.clone());
        let accept_thread = std::thread::Builder::new()
            .name("mosa-manager".into())
            .spawn(move || accept_loop(listener, st, sp))
            .map_err(crate::Error::Io)?;
        Ok(Manager {
            addr,
            state,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    /// The bound address.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Direct (in-process) access for tests.
    pub fn state(&self) -> &Arc<ManagerState> {
        &self.state
    }

    /// Stop accepting (existing connections finish their current call).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Poke the accept loop.
        let _ = Conn::connect(&self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Manager {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: Listener, state: Arc<ManagerState>, stop: Arc<AtomicBool>) {
    loop {
        let conn = match listener.accept() {
            Ok(c) => c,
            Err(_) => break,
        };
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let st = state.clone();
        let _ = std::thread::Builder::new()
            .name("mosa-manager-conn".into())
            .spawn(move || serve_conn(conn, st));
    }
}

fn serve_conn(conn: Conn, state: Arc<ManagerState>) {
    let reader = match conn.try_clone() {
        Ok(c) => c,
        Err(_) => return,
    };
    let mut r = BufReader::new(reader);
    let mut w = BufWriter::new(conn);
    while let Ok(Some(msg)) = Msg::read_from(&mut r) {
        let reply = state.handle(msg);
        if reply.write_to(&mut w).is_err() {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(i: u8) -> BlockMeta {
        BlockMeta {
            hash: [i; 16],
            len: 100,
            node: 0,
        }
    }

    #[test]
    fn state_commit_and_get() {
        let s = ManagerState::default();
        let r = s.handle(Msg::GetBlockMap { file: "f".into() });
        assert_eq!(
            r,
            Msg::BlockMap {
                version: 0,
                blocks: vec![]
            }
        );
        s.handle(Msg::CommitBlockMap {
            file: "f".into(),
            blocks: vec![meta(1)],
        });
        let r = s.handle(Msg::GetBlockMap { file: "f".into() });
        assert_eq!(
            r,
            Msg::BlockMap {
                version: 1,
                blocks: vec![meta(1)]
            }
        );
    }

    #[test]
    fn state_versions_increment() {
        let s = ManagerState::default();
        for i in 1..=3 {
            s.handle(Msg::CommitBlockMap {
                file: "f".into(),
                blocks: vec![meta(i)],
            });
            let Msg::BlockMap { version, .. } = s.handle(Msg::GetBlockMap { file: "f".into() })
            else {
                panic!()
            };
            assert_eq!(version, i as u64);
        }
    }

    #[test]
    fn state_list_files_sorted() {
        let s = ManagerState::default();
        for f in ["b", "a"] {
            s.handle(Msg::CommitBlockMap {
                file: f.into(),
                blocks: vec![],
            });
        }
        let Msg::Files { files } = s.handle(Msg::ListFiles) else {
            panic!()
        };
        assert_eq!(files, vec![("a".into(), 1), ("b".into(), 1)]);
    }

    #[test]
    fn state_rejects_wrong_message() {
        let s = ManagerState::default();
        assert!(matches!(s.handle(Msg::Ok), Msg::Err(_)));
    }

    #[test]
    fn tcp_serving_works() {
        let mgr = Manager::spawn("127.0.0.1:0").unwrap();
        let mut c = Conn::connect(mgr.addr()).unwrap();
        Msg::CommitBlockMap {
            file: "x".into(),
            blocks: vec![meta(5)],
        }
        .write_to(&mut c)
        .unwrap();
        let r = Msg::read_from(&mut c).unwrap().unwrap();
        assert_eq!(r, Msg::Ok);
        Msg::GetBlockMap { file: "x".into() }.write_to(&mut c).unwrap();
        let r = Msg::read_from(&mut c).unwrap().unwrap();
        assert_eq!(
            r,
            Msg::BlockMap {
                version: 1,
                blocks: vec![meta(5)]
            }
        );
    }

    #[test]
    fn multiple_clients() {
        let mgr = Manager::spawn("127.0.0.1:0").unwrap();
        let addr = mgr.addr().to_string();
        let threads: Vec<_> = (0..4)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = Conn::connect(&addr).unwrap();
                    Msg::CommitBlockMap {
                        file: format!("f{i}"),
                        blocks: vec![meta(i as u8)],
                    }
                    .write_to(&mut c)
                    .unwrap();
                    assert_eq!(Msg::read_from(&mut c).unwrap().unwrap(), Msg::Ok);
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let Msg::Files { files } = mgr.state().handle(Msg::ListFiles) else {
            panic!()
        };
        assert_eq!(files.len(), 4);
    }
}
