//! The client System Access Interface (SAI) — the paper's Figure 3.
//!
//! The primary API is session-based: [`Sai::create`] returns a
//! [`FileWriter`](super::FileWriter) that implements [`std::io::Write`]
//! and feeds the chunk→hash→dedup→stripe pipeline incrementally as data
//! arrives; [`Sai::open`] returns a [`FileReader`](super::FileReader)
//! that implements [`std::io::Read`] and streams blocks back with
//! integrity verification.  Whole-buffer [`Sai::write_file`] /
//! [`Sai::read_file`] are thin wrappers over the sessions.
//!
//! Write path: application data accumulates in a write buffer; when the
//! buffer fills, the content-addressability module (a) detects block
//! boundaries (fixed-size or content-based via sliding-window hashes),
//! (b) submits the blocks' hashes to the configured
//! [`HashEngine`] — *asynchronously* on accelerator engines, so buffer
//! N's hashing overlaps buffer N-1's transfers — then (c) compares
//! digests against the file's previous-version block-map and
//! (d) transfers only new blocks, striped across `stripe_width` storage
//! nodes in parallel.  On close, the new block-map is committed to the
//! metadata manager.
//!
//! All node links share one bandwidth [`Shaper`] — the client's NIC.

use std::io::{BufReader, BufWriter, Write as _};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::proto::{BlockMeta, Msg};
use super::session::{FileReader, FileWriter};
use crate::config::{CaMode, ClientConfig};
use crate::hash::Digest;
use crate::hashgpu::HashEngine;
use crate::net::{Conn, Shaper};
use crate::{Error, Result};

/// Outcome of one file write.
#[derive(Debug, Clone, Default)]
pub struct WriteReport {
    /// Total payload bytes written by the application.
    pub bytes: u64,
    /// Total blocks in the new version.
    pub blocks: usize,
    /// Blocks actually transferred to storage nodes.
    pub new_blocks: usize,
    /// Blocks deduplicated (hash already known).
    pub dup_blocks: usize,
    /// Bytes actually transferred.
    pub new_bytes: u64,
    /// Wall-clock duration of the write.
    pub elapsed: Duration,
    /// Hash-engine time that stalled the write pipeline (window + direct
    /// hashing the client actually waited on).
    pub hash_secs: f64,
    /// Hash-engine time hidden behind transfers/chunking by asynchronous
    /// submission (zero for synchronous CPU/oracle engines).
    pub hash_hidden_secs: f64,
    /// Fraction of bytes deduplicated (similarity detected).
    pub similarity: f64,
}

impl WriteReport {
    /// Application-observed write throughput, MB/s (0.0 if no time has
    /// elapsed).
    pub fn mbps(&self) -> f64 {
        crate::util::mbps(self.bytes, self.elapsed.as_secs_f64())
    }

    /// Total hash-engine time: exposed + hidden.
    pub fn hash_total_secs(&self) -> f64 {
        self.hash_secs + self.hash_hidden_secs
    }

    /// Fraction of hash-engine time hidden behind the rest of the
    /// pipeline (0..1; 0.0 when no hashing happened).
    pub fn overlap_fraction(&self) -> f64 {
        let total = self.hash_total_secs();
        if total <= 0.0 {
            0.0
        } else {
            self.hash_hidden_secs / total
        }
    }
}

enum NodeCmd {
    Put {
        hash: Digest,
        data: Vec<u8>,
        done: Sender<Result<()>>,
    },
    Get {
        hash: Digest,
        done: Sender<Result<Vec<u8>>>,
    },
}

/// One storage node's client: a worker thread owning the (shaped)
/// connection, fed through a channel so puts to different nodes proceed
/// in parallel while the SAI keeps hashing.
pub(super) struct NodeClient {
    tx: Sender<NodeCmd>,
}

impl NodeClient {
    fn connect(addr: &str, shaper: Option<Arc<Shaper>>) -> Result<NodeClient> {
        let mut conn = Conn::connect(addr)?;
        if let Some(s) = shaper {
            conn = conn.with_shaper(s);
        }
        let (tx, rx): (Sender<NodeCmd>, Receiver<NodeCmd>) = mpsc::channel();
        std::thread::Builder::new()
            .name(format!("sai-node-{addr}"))
            .spawn(move || node_worker(conn, rx))
            .map_err(Error::Io)?;
        Ok(NodeClient { tx })
    }

    pub(super) fn put(&self, hash: Digest, data: Vec<u8>) -> Receiver<Result<()>> {
        let (done, rx) = mpsc::channel();
        let _ = self.tx.send(NodeCmd::Put { hash, data, done });
        rx
    }

    pub(super) fn get(&self, hash: Digest) -> Receiver<Result<Vec<u8>>> {
        let (done, rx) = mpsc::channel();
        let _ = self.tx.send(NodeCmd::Get { hash, done });
        rx
    }
}

fn node_worker(conn: Conn, rx: Receiver<NodeCmd>) {
    let reader = match conn.try_clone() {
        Ok(c) => c,
        Err(_) => return,
    };
    let mut r = BufReader::new(reader);
    let mut w = BufWriter::with_capacity(256 * 1024, conn);
    while let Ok(cmd) = rx.recv() {
        match cmd {
            NodeCmd::Put { hash, data, done } => {
                let res = (|| -> Result<()> {
                    Msg::PutBlock { hash, data }.write_to(&mut w)?;
                    w.flush()?;
                    match Msg::read_from(&mut r)?.ok_or_else(closed)?.into_result()? {
                        Msg::Ok => Ok(()),
                        m => Err(Error::Proto(format!("unexpected put reply {m:?}"))),
                    }
                })();
                let _ = done.send(res);
            }
            NodeCmd::Get { hash, done } => {
                let res = (|| -> Result<Vec<u8>> {
                    Msg::GetBlock { hash }.write_to(&mut w)?;
                    w.flush()?;
                    match Msg::read_from(&mut r)?.ok_or_else(closed)?.into_result()? {
                        Msg::Data { data } => Ok(data),
                        m => Err(Error::Proto(format!("unexpected get reply {m:?}"))),
                    }
                })();
                let _ = done.send(res);
            }
        }
    }
}

pub(super) fn closed() -> Error {
    Error::Node("connection closed".into())
}

/// The SAI client.
pub struct Sai {
    pub(super) cfg: ClientConfig,
    pub(super) engine: Arc<dyn HashEngine>,
    manager: Mutex<(BufReader<Conn>, BufWriter<Conn>)>,
    pub(super) nodes: Vec<NodeClient>,
}

impl Sai {
    /// Connect to a manager and a set of storage nodes.  `shaper`, if
    /// given, paces ALL node links together (the client's NIC).
    pub fn connect(
        manager_addr: &str,
        node_addrs: &[String],
        cfg: ClientConfig,
        engine: Arc<dyn HashEngine>,
        shaper: Option<Arc<Shaper>>,
    ) -> Result<Sai> {
        cfg.validate()?;
        if node_addrs.is_empty() {
            return Err(Error::Config("need at least one storage node".into()));
        }
        if cfg.ca_mode != CaMode::Cdc && cfg.write_buffer % cfg.block_size != 0 {
            return Err(Error::Config(
                "write_buffer must be a multiple of block_size".into(),
            ));
        }
        let conn = Conn::connect(manager_addr)?;
        let manager = Mutex::new((BufReader::new(conn.try_clone()?), BufWriter::new(conn)));
        let nodes = node_addrs
            .iter()
            .map(|a| NodeClient::connect(a, shaper.clone()))
            .collect::<Result<Vec<_>>>()?;
        Ok(Sai {
            cfg,
            engine,
            manager,
            nodes,
        })
    }

    /// The active configuration.
    pub fn config(&self) -> &ClientConfig {
        &self.cfg
    }

    /// The hash engine in use.
    pub fn engine(&self) -> &Arc<dyn HashEngine> {
        &self.engine
    }

    pub(super) fn manager_call(&self, msg: Msg) -> Result<Msg> {
        let mut g = self.manager.lock().unwrap();
        let (r, w) = &mut *g;
        msg.write_to(w)?;
        w.flush()?;
        Msg::read_from(r)?.ok_or_else(closed)?.into_result()
    }

    /// Fetch a file's current block-map (version 0 = absent).
    pub fn get_block_map(&self, file: &str) -> Result<(u64, Vec<BlockMeta>)> {
        match self.manager_call(Msg::GetBlockMap { file: file.into() })? {
            Msg::BlockMap { version, blocks } => Ok((version, blocks)),
            m => Err(Error::Proto(format!("unexpected reply {m:?}"))),
        }
    }

    /// List files known to the manager, sorted by name.  The sort is
    /// applied client-side so callers never depend on a manager
    /// implementation's map iteration order.
    pub fn list_files(&self) -> Result<Vec<(String, u64)>> {
        match self.manager_call(Msg::ListFiles)? {
            Msg::Files { mut files } => {
                files.sort();
                Ok(files)
            }
            m => Err(Error::Proto(format!("unexpected reply {m:?}"))),
        }
    }

    /// Open a streaming write session: returns a [`FileWriter`] that
    /// implements [`std::io::Write`].  Data is chunked, hashed,
    /// deduplicated and striped as it arrives; call
    /// [`FileWriter::close`] to commit the new version (the POSIX
    /// `release` step) and obtain the [`WriteReport`].
    pub fn create(&self, name: &str) -> Result<FileWriter<'_>> {
        FileWriter::new(self, name)
    }

    /// Open a streaming read session: returns a [`FileReader`] that
    /// implements [`std::io::Read`], prefetching blocks from the stripe
    /// nodes ahead of the consumer and verifying each block's integrity
    /// (CA modes).
    pub fn open(&self, name: &str) -> Result<FileReader<'_>> {
        FileReader::new(self, name)
    }

    /// Write a complete file (the paper's workloads write whole files
    /// back-to-back; `release` semantics = commit on return).  Thin
    /// wrapper over [`Sai::create`].
    pub fn write_file(&self, name: &str, data: &[u8]) -> Result<WriteReport> {
        let mut w = self.create(name)?;
        w.push_bytes(data)?;
        w.close()
    }

    /// Read a complete file and verify block integrity (CA modes).
    /// Thin wrapper over [`Sai::open`].
    pub fn read_file(&self, name: &str) -> Result<Vec<u8>> {
        let mut r = self.open(name)?;
        let mut out = Vec::with_capacity(r.len() as usize);
        while let Some(block) = r.next_block()? {
            out.extend_from_slice(&block);
        }
        Ok(out)
    }

    /// Integrity scrub: fetch every block of `name` and recompute its
    /// content hash (the paper's "traditional system that uses hashing
    /// to preserve data integrity").  Returns (ok, corrupt) counts.
    pub fn verify_file(&self, name: &str) -> Result<(usize, usize)> {
        let (version, blocks) = self.get_block_map(name)?;
        if version == 0 {
            return Err(Error::Manager(format!("no such file: {name}")));
        }
        if self.cfg.ca_mode == CaMode::None {
            return Err(Error::Config(
                "non-CA mode stores no content hashes to verify".into(),
            ));
        }
        let rxs: Vec<_> = blocks
            .iter()
            .map(|b| self.nodes[b.node as usize].get(b.hash))
            .collect();
        let mut ok = 0;
        let mut bad = 0;
        for (meta, rx) in blocks.iter().zip(rxs) {
            match rx.recv().map_err(|_| closed())? {
                Ok(data) => {
                    if data.len() == meta.len as usize
                        && self.engine.direct_hash(&data)? == meta.hash
                    {
                        ok += 1;
                    } else {
                        bad += 1;
                    }
                }
                Err(_) => bad += 1,
            }
        }
        Ok((ok, bad))
    }

    /// Number of stripe nodes in use.
    pub(super) fn stripe(&self) -> usize {
        self.cfg.stripe_width.min(self.nodes.len())
    }
}
