//! The client System Access Interface (SAI) — the paper's Figure 3.
//!
//! Write path: application data is accumulated in a write buffer; when
//! the buffer fills, the content-addressability module (a) detects block
//! boundaries (fixed-size or content-based via sliding-window hashes),
//! (b) computes each block's hash through the configured
//! [`HashEngine`] (CPU, accelerator, or oracle), (c) compares against
//! the file's previous-version block-map, and (d) transfers only new
//! blocks, striped across `stripe_width` storage nodes in parallel.
//! On close, the new block-map is committed to the metadata manager.
//!
//! All node links share one bandwidth [`Shaper`] — the client's NIC.

use std::io::{BufReader, BufWriter, Write as _};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::proto::{BlockMeta, Msg};
use crate::config::{CaMode, ClientConfig};
use crate::chunking::{ChunkParams, ContentChunker};
use crate::hash::{md5, Digest};
use crate::hashgpu::HashEngine;
use crate::net::{Conn, Shaper};
use crate::{Error, Result};

/// Outcome of one file write.
#[derive(Debug, Clone, Default)]
pub struct WriteReport {
    /// Total payload bytes written by the application.
    pub bytes: u64,
    /// Total blocks in the new version.
    pub blocks: usize,
    /// Blocks actually transferred to storage nodes.
    pub new_blocks: usize,
    /// Blocks deduplicated (hash already known).
    pub dup_blocks: usize,
    /// Bytes actually transferred.
    pub new_bytes: u64,
    /// Wall-clock duration of the write.
    pub elapsed: Duration,
    /// Time inside the hash engine (window + direct hashing).
    pub hash_secs: f64,
    /// Fraction of bytes deduplicated (similarity detected).
    pub similarity: f64,
}

impl WriteReport {
    /// Application-observed write throughput, MB/s.
    pub fn mbps(&self) -> f64 {
        crate::util::mbps(self.bytes, self.elapsed.as_secs_f64())
    }
}

enum NodeCmd {
    Put {
        hash: Digest,
        data: Vec<u8>,
        done: Sender<Result<()>>,
    },
    Get {
        hash: Digest,
        done: Sender<Result<Vec<u8>>>,
    },
}

/// One storage node's client: a worker thread owning the (shaped)
/// connection, fed through a channel so puts to different nodes proceed
/// in parallel while the SAI keeps hashing.
struct NodeClient {
    tx: Sender<NodeCmd>,
}

impl NodeClient {
    fn connect(addr: &str, shaper: Option<Arc<Shaper>>) -> Result<NodeClient> {
        let mut conn = Conn::connect(addr)?;
        if let Some(s) = shaper {
            conn = conn.with_shaper(s);
        }
        let (tx, rx): (Sender<NodeCmd>, Receiver<NodeCmd>) = mpsc::channel();
        std::thread::Builder::new()
            .name(format!("sai-node-{addr}"))
            .spawn(move || node_worker(conn, rx))
            .map_err(Error::Io)?;
        Ok(NodeClient { tx })
    }

    fn put(&self, hash: Digest, data: Vec<u8>) -> Receiver<Result<()>> {
        let (done, rx) = mpsc::channel();
        let _ = self.tx.send(NodeCmd::Put { hash, data, done });
        rx
    }

    fn get(&self, hash: Digest) -> Receiver<Result<Vec<u8>>> {
        let (done, rx) = mpsc::channel();
        let _ = self.tx.send(NodeCmd::Get { hash, done });
        rx
    }
}

fn node_worker(conn: Conn, rx: Receiver<NodeCmd>) {
    let reader = match conn.try_clone() {
        Ok(c) => c,
        Err(_) => return,
    };
    let mut r = BufReader::new(reader);
    let mut w = BufWriter::with_capacity(256 * 1024, conn);
    while let Ok(cmd) = rx.recv() {
        match cmd {
            NodeCmd::Put { hash, data, done } => {
                let res = (|| -> Result<()> {
                    Msg::PutBlock { hash, data }.write_to(&mut w)?;
                    w.flush()?;
                    match Msg::read_from(&mut r)?.ok_or_else(closed)?.into_result()? {
                        Msg::Ok => Ok(()),
                        m => Err(Error::Proto(format!("unexpected put reply {m:?}"))),
                    }
                })();
                let _ = done.send(res);
            }
            NodeCmd::Get { hash, done } => {
                let res = (|| -> Result<Vec<u8>> {
                    Msg::GetBlock { hash }.write_to(&mut w)?;
                    w.flush()?;
                    match Msg::read_from(&mut r)?.ok_or_else(closed)?.into_result()? {
                        Msg::Data { data } => Ok(data),
                        m => Err(Error::Proto(format!("unexpected get reply {m:?}"))),
                    }
                })();
                let _ = done.send(res);
            }
        }
    }
}

fn closed() -> Error {
    Error::Node("connection closed".into())
}

/// The SAI client.
pub struct Sai {
    cfg: ClientConfig,
    engine: Arc<dyn HashEngine>,
    manager: Mutex<(BufReader<Conn>, BufWriter<Conn>)>,
    nodes: Vec<NodeClient>,
}

impl Sai {
    /// Connect to a manager and a set of storage nodes.  `shaper`, if
    /// given, paces ALL node links together (the client's NIC).
    pub fn connect(
        manager_addr: &str,
        node_addrs: &[String],
        cfg: ClientConfig,
        engine: Arc<dyn HashEngine>,
        shaper: Option<Arc<Shaper>>,
    ) -> Result<Sai> {
        cfg.validate()?;
        if node_addrs.is_empty() {
            return Err(Error::Config("need at least one storage node".into()));
        }
        if cfg.ca_mode != CaMode::Cdc && cfg.write_buffer % cfg.block_size != 0 {
            return Err(Error::Config(
                "write_buffer must be a multiple of block_size".into(),
            ));
        }
        let conn = Conn::connect(manager_addr)?;
        let manager = Mutex::new((BufReader::new(conn.try_clone()?), BufWriter::new(conn)));
        let nodes = node_addrs
            .iter()
            .map(|a| NodeClient::connect(a, shaper.clone()))
            .collect::<Result<Vec<_>>>()?;
        Ok(Sai {
            cfg,
            engine,
            manager,
            nodes,
        })
    }

    /// The active configuration.
    pub fn config(&self) -> &ClientConfig {
        &self.cfg
    }

    /// The hash engine in use.
    pub fn engine(&self) -> &Arc<dyn HashEngine> {
        &self.engine
    }

    fn manager_call(&self, msg: Msg) -> Result<Msg> {
        let mut g = self.manager.lock().unwrap();
        let (r, w) = &mut *g;
        msg.write_to(w)?;
        w.flush()?;
        Msg::read_from(r)?.ok_or_else(closed)?.into_result()
    }

    /// Fetch a file's current block-map (version 0 = absent).
    pub fn get_block_map(&self, file: &str) -> Result<(u64, Vec<BlockMeta>)> {
        match self.manager_call(Msg::GetBlockMap { file: file.into() })? {
            Msg::BlockMap { version, blocks } => Ok((version, blocks)),
            m => Err(Error::Proto(format!("unexpected reply {m:?}"))),
        }
    }

    /// List files known to the manager.
    pub fn list_files(&self) -> Result<Vec<(String, u64)>> {
        match self.manager_call(Msg::ListFiles)? {
            Msg::Files { files } => Ok(files),
            m => Err(Error::Proto(format!("unexpected reply {m:?}"))),
        }
    }

    /// Write a complete file (the paper's workloads write whole files
    /// back-to-back; `release` semantics = commit on return).
    pub fn write_file(&self, name: &str, data: &[u8]) -> Result<WriteReport> {
        let t0 = Instant::now();
        let mut report = WriteReport {
            bytes: data.len() as u64,
            ..Default::default()
        };

        // 1. Previous version's block-map: hash -> node.
        let (_, old_blocks) = self.get_block_map(name)?;
        let mut known: std::collections::HashMap<Digest, u32> = old_blocks
            .iter()
            .map(|b| (b.hash, b.node))
            .collect();

        // 2. Chunk + hash + dedup + transfer, buffer by buffer.
        let mut metas: Vec<BlockMeta> = Vec::new();
        let mut pending: Vec<Receiver<Result<()>>> = Vec::new();
        let mut hash_secs = 0.0f64;

        match self.cfg.ca_mode {
            CaMode::None => {
                // No hashing: blocks are addressed by (file, index).
                for (i, blk) in data.chunks(self.cfg.block_size).enumerate() {
                    let mut key = Vec::with_capacity(name.len() + 8);
                    key.extend_from_slice(name.as_bytes());
                    key.extend_from_slice(&(i as u64).to_le_bytes());
                    let hash = md5(&key);
                    let node = (i % self.stripe()) as u32;
                    pending.push(self.nodes[node as usize].put(hash, blk.to_vec()));
                    report.new_blocks += 1;
                    report.new_bytes += blk.len() as u64;
                    metas.push(BlockMeta {
                        hash,
                        len: blk.len() as u32,
                        node,
                    });
                    self.collect_window(&mut pending, 2 * self.stripe())?;
                }
            }
            CaMode::Fixed => {
                for buffer in data.chunks(self.cfg.write_buffer) {
                    let blocks: Vec<&[u8]> = buffer.chunks(self.cfg.block_size).collect();
                    let th = Instant::now();
                    let digests = self.engine.direct_hash_batch(&blocks)?;
                    hash_secs += th.elapsed().as_secs_f64();
                    for (blk, digest) in blocks.iter().zip(digests) {
                        self.place_block(
                            blk,
                            digest,
                            &mut known,
                            &mut metas,
                            &mut pending,
                            &mut report,
                        )?;
                    }
                    self.collect_window(&mut pending, 2 * self.stripe())?;
                }
            }
            CaMode::Cdc => {
                let params: ChunkParams = self.cfg.chunk_params();
                let mut chunker = ContentChunker::new(params);
                let mut finished: Vec<crate::chunking::Chunk> = Vec::new();
                for buffer in data.chunks(self.cfg.write_buffer) {
                    let ext = chunker.extended(buffer);
                    let th = Instant::now();
                    let hashes = self.engine.window_hashes(&ext)?;
                    hash_secs += th.elapsed().as_secs_f64();
                    finished.extend(chunker.push_with_hashes(buffer, &hashes));
                    // Hash + ship the completed chunks of this buffer.
                    let chunk_refs: Vec<&[u8]> =
                        finished.iter().map(|c| c.data.as_slice()).collect();
                    let th = Instant::now();
                    let digests = self.engine.direct_hash_batch(&chunk_refs)?;
                    hash_secs += th.elapsed().as_secs_f64();
                    for (chunk, digest) in finished.drain(..).zip(digests) {
                        self.place_block(
                            &chunk.data,
                            digest,
                            &mut known,
                            &mut metas,
                            &mut pending,
                            &mut report,
                        )?;
                    }
                    self.collect_window(&mut pending, 2 * self.stripe())?;
                }
                if let Some(chunk) = chunker.finish() {
                    let th = Instant::now();
                    let digest = self.engine.direct_hash(&chunk.data)?;
                    hash_secs += th.elapsed().as_secs_f64();
                    self.place_block(
                        &chunk.data,
                        digest,
                        &mut known,
                        &mut metas,
                        &mut pending,
                        &mut report,
                    )?;
                }
            }
        }

        // 3. Wait for all outstanding transfers.
        self.collect_window(&mut pending, 0)?;

        // 4. Commit the new block-map (the POSIX `release` step).
        match self.manager_call(Msg::CommitBlockMap {
            file: name.into(),
            blocks: metas.clone(),
        })? {
            Msg::Ok => {}
            m => return Err(Error::Proto(format!("unexpected commit reply {m:?}"))),
        }

        report.blocks = metas.len();
        report.hash_secs = hash_secs;
        report.elapsed = t0.elapsed();
        report.similarity = if report.bytes == 0 {
            0.0
        } else {
            1.0 - report.new_bytes as f64 / report.bytes as f64
        };
        Ok(report)
    }

    /// Read a complete file and verify block integrity (CA modes).
    pub fn read_file(&self, name: &str) -> Result<Vec<u8>> {
        let (version, blocks) = self.get_block_map(name)?;
        if version == 0 {
            return Err(Error::Manager(format!("no such file: {name}")));
        }
        // Issue all fetches, then collect in order.
        let rxs: Vec<_> = blocks
            .iter()
            .map(|b| self.nodes[b.node as usize].get(b.hash))
            .collect();
        let mut out = Vec::new();
        for (meta, rx) in blocks.iter().zip(rxs) {
            let data = rx
                .recv()
                .map_err(|_| closed())??;
            if data.len() != meta.len as usize {
                return Err(Error::Node(format!(
                    "block length mismatch: got {}, expected {}",
                    data.len(),
                    meta.len
                )));
            }
            if self.cfg.ca_mode != CaMode::None {
                // Integrity check: recompute the content hash.
                let th = self.engine.direct_hash(&data)?;
                if th != meta.hash {
                    return Err(Error::Node("block integrity check failed".into()));
                }
            }
            out.extend_from_slice(&data);
        }
        Ok(out)
    }

    /// Integrity scrub: fetch every block of `name` and recompute its
    /// content hash (the paper's "traditional system that uses hashing
    /// to preserve data integrity").  Returns (ok, corrupt) counts.
    pub fn verify_file(&self, name: &str) -> Result<(usize, usize)> {
        let (version, blocks) = self.get_block_map(name)?;
        if version == 0 {
            return Err(Error::Manager(format!("no such file: {name}")));
        }
        if self.cfg.ca_mode == CaMode::None {
            return Err(Error::Config(
                "non-CA mode stores no content hashes to verify".into(),
            ));
        }
        let rxs: Vec<_> = blocks
            .iter()
            .map(|b| self.nodes[b.node as usize].get(b.hash))
            .collect();
        let mut ok = 0;
        let mut bad = 0;
        for (meta, rx) in blocks.iter().zip(rxs) {
            match rx.recv().map_err(|_| closed())? {
                Ok(data) => {
                    if data.len() == meta.len as usize
                        && self.engine.direct_hash(&data)? == meta.hash
                    {
                        ok += 1;
                    } else {
                        bad += 1;
                    }
                }
                Err(_) => bad += 1,
            }
        }
        Ok((ok, bad))
    }

    fn stripe(&self) -> usize {
        self.cfg.stripe_width.min(self.nodes.len())
    }

    /// Dedup decision + transfer for one block.
    fn place_block(
        &self,
        data: &[u8],
        digest: Digest,
        known: &mut std::collections::HashMap<Digest, u32>,
        metas: &mut Vec<BlockMeta>,
        pending: &mut Vec<Receiver<Result<()>>>,
        report: &mut WriteReport,
    ) -> Result<()> {
        if let Some(&node) = known.get(&digest) {
            report.dup_blocks += 1;
            metas.push(BlockMeta {
                hash: digest,
                len: data.len() as u32,
                node,
            });
            return Ok(());
        }
        let node = (metas.len() % self.stripe()) as u32;
        pending.push(self.nodes[node as usize].put(digest, data.to_vec()));
        known.insert(digest, node);
        report.new_blocks += 1;
        report.new_bytes += data.len() as u64;
        metas.push(BlockMeta {
            hash: digest,
            len: data.len() as u32,
            node,
        });
        Ok(())
    }

    /// Await acks until at most `max_left` puts remain outstanding.
    fn collect_window(
        &self,
        pending: &mut Vec<Receiver<Result<()>>>,
        max_left: usize,
    ) -> Result<()> {
        while pending.len() > max_left {
            let rx = pending.remove(0);
            rx.recv().map_err(|_| closed())??;
        }
        Ok(())
    }
}
