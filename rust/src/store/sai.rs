//! The client System Access Interface (SAI) — the paper's Figure 3.
//!
//! The primary API is session-based: [`Sai::create`] returns a
//! [`FileWriter`](super::FileWriter) that implements [`std::io::Write`]
//! and feeds the chunk→hash→dedup→stripe pipeline incrementally as data
//! arrives; [`Sai::open`] returns a [`FileReader`](super::FileReader)
//! that implements [`std::io::Read`] and streams blocks back with
//! integrity verification.  Whole-buffer [`Sai::write_file`] /
//! [`Sai::read_file`] are thin wrappers over the sessions.
//!
//! Control-plane v2: the client no longer chooses placement.  It
//! connects to the *manager only*, discovers the storage nodes from the
//! manager's registry ([`Msg::NodeList`]), and — per hashed batch —
//! asks the manager where blocks go ([`Msg::AllocPlacement`]).  The
//! manager answers with a replica set per block plus a freshness bit
//! (manager-side dedup); the client transfers fresh blocks to *every*
//! assigned replica and the reader fails over between replicas when a
//! node is down or a copy fails its integrity check.
//!
//! Control-plane v3 adds leases: `open` pins the opened version's
//! blocks against GC for the life of the read session, and `create`
//! holds its provisional claims under an expiring lease renewed by a
//! heartbeat, so a SIGKILL'd writer's claims lapse instead of stranding
//! blocks forever.
//!
//! Data-plane v2: each node link is a pipelined duplex
//! [`DuplexClient`](super::duplex::DuplexClient) — a writer thread and
//! a reply-reader thread over one socket, with replies matched to
//! waiters by request id — so per-node throughput is bandwidth-bound,
//! not RTT-bound.  See [`super::duplex`].
//!
//! All node links share one bandwidth [`Shaper`] — the client's NIC.

use std::io::{BufReader, BufWriter, Write as _};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::duplex::{closed, Block, DuplexClient};
use super::proto::{Assignment, BlockMeta, BlockSpec, Msg};
use super::session::{FileReader, FileWriter};
use crate::config::{CaMode, ClientConfig};
use crate::hash::Digest;
use crate::hashgpu::HashEngine;
use crate::net::{Conn, Shaper};
use crate::{Error, Result};

/// Outcome of one file write.
#[derive(Debug, Clone, Default)]
pub struct WriteReport {
    /// Total payload bytes written by the application.
    pub bytes: u64,
    /// Total blocks in the new version.
    pub blocks: usize,
    /// Blocks actually transferred to storage nodes.
    pub new_blocks: usize,
    /// Blocks deduplicated (hash already stored somewhere, per the
    /// manager's global block table).
    pub dup_blocks: usize,
    /// Bytes actually transferred, counting each replica copy once
    /// (i.e. payload bytes × that block's replica count).
    pub new_bytes: u64,
    /// Unique payload bytes behind `new_bytes` (each fresh block's
    /// length counted once, regardless of replication) — the basis of
    /// `similarity`.
    pub new_payload_bytes: u64,
    /// Replication factor observed on this write's fresh blocks
    /// (1 when no blocks were fresh).
    pub replication: usize,
    /// Wall-clock duration of the write.
    pub elapsed: Duration,
    /// Hash-engine time that stalled the write pipeline (window + direct
    /// hashing the client actually waited on).
    pub hash_secs: f64,
    /// Hash-engine time hidden behind transfers/chunking by asynchronous
    /// submission (zero for synchronous CPU/oracle engines).
    pub hash_hidden_secs: f64,
    /// Fraction of bytes deduplicated (similarity detected).
    pub similarity: f64,
    /// Device batches that served this session's direct-hash tickets.
    /// On a shared hash service a "batch" is the coalesced batch the
    /// ticket rode in (other sessions' blocks included in its depth).
    pub hash_batches: usize,
    /// Mean depth, in blocks, of those device batches (0.0 when no
    /// batched hashing happened).
    pub hash_batch_depth_mean: f64,
    /// Deepest device batch any of this session's tickets rode in.
    pub hash_batch_depth_max: usize,
    /// Time this session's submissions lingered in the shared service's
    /// coalescing queue (zero on dedicated engines) — the latency cost
    /// bought by `hash_linger_us` in exchange for deeper batches.
    pub hash_linger_secs: f64,
    /// Replica/shard transfers that failed but were absorbed by the
    /// block's redundancy budget (at least one copy — or `k` shards —
    /// still landed; the scrub loop re-creates the rest).  Non-zero
    /// means the committed file starts life under-redundant.
    pub put_failures: u64,
}

impl WriteReport {
    /// Application-observed write throughput, MB/s (0.0 if no time has
    /// elapsed).
    pub fn mbps(&self) -> f64 {
        crate::util::mbps(self.bytes, self.elapsed.as_secs_f64())
    }

    /// Total hash-engine time: exposed + hidden.
    pub fn hash_total_secs(&self) -> f64 {
        self.hash_secs + self.hash_hidden_secs
    }

    /// Fraction of hash-engine time hidden behind the rest of the
    /// pipeline (0..1; 0.0 when no hashing happened).
    pub fn overlap_fraction(&self) -> f64 {
        let total = self.hash_total_secs();
        if total <= 0.0 {
            0.0
        } else {
            self.hash_hidden_secs / total
        }
    }
}

/// `NotLeader` hints followed within one rotation round before falling
/// back to the bootstrap list (guards against redirect loops between
/// confused replicas during an election).
const MAX_REDIRECT_HOPS: usize = 4;
/// Full rotation rounds through the bootstrap list before a call gives
/// up.  Paired with [`REDIRECT_BACKOFF`] this bounds how long a client
/// rides out a leader election (~3 s) before surfacing the error.
/// Unit tests use a small bound so the exhaustion path runs fast.
#[cfg(not(test))]
const MAX_REDIRECT_ROUNDS: usize = 60;
#[cfg(test)]
const MAX_REDIRECT_ROUNDS: usize = 3;
/// Pause between rotation rounds (an election needs real time to
/// complete when every manager is answering `NotLeader`/`no quorum`).
const REDIRECT_BACKOFF: Duration = Duration::from_millis(50);

/// The SAI client.
pub struct Sai {
    pub(super) cfg: ClientConfig,
    pub(super) engine: Arc<dyn HashEngine>,
    manager: Mutex<(BufReader<Conn>, BufWriter<Conn>)>,
    /// Manager bootstrap list (the connect string, comma-split): the
    /// redirect fallback whenever no usable leader hint is available.
    bootstrap: Vec<String>,
    /// Rotation cursor over [`Sai::bootstrap`].
    bootstrap_cursor: Mutex<usize>,
    /// Address of the manager the shared connection currently points at
    /// (follows `NotLeader` redirects) — also handed to per-session
    /// helpers (the write-lease heartbeat thread) that open their own
    /// control connections without serializing behind the shared one.
    manager_addr: Mutex<String>,
    /// Node clients indexed by manager node id.  `None` = the node was
    /// unreachable when last tried (reads fail over to other replicas;
    /// puts targeting it fail the write).  Refreshed from the manager's
    /// registry when a placement names an id this client has no link
    /// for (nodes can join after the client connected).
    nodes: Mutex<Vec<Option<Arc<DuplexClient>>>>,
    /// NIC shaper applied to (re)connected node links.
    shaper: Option<Arc<Shaper>>,
    /// Throttle for registry refreshes triggered by unknown/down nodes.
    last_refresh: Mutex<Option<Instant>>,
}

impl Sai {
    /// Connect to the manager and, from its registry, to the storage
    /// nodes (control-plane v2: the manager is the single bootstrap
    /// address; under consensus, `manager_addr` may be a comma-separated
    /// list of the quorum group's members and the first reachable one is
    /// dialed — `NotLeader` redirects take it from there).  `shaper`, if
    /// given, paces ALL node links together (the client's NIC).  Nodes
    /// that are down are tolerated here and handled by replica failover
    /// at read time.
    pub fn connect(
        manager_addr: &str,
        cfg: ClientConfig,
        engine: Arc<dyn HashEngine>,
        shaper: Option<Arc<Shaper>>,
    ) -> Result<Sai> {
        cfg.validate()?;
        if cfg.ca_mode != CaMode::Cdc && cfg.write_buffer % cfg.block_size != 0 {
            return Err(Error::Config(
                "write_buffer must be a multiple of block_size".into(),
            ));
        }
        let bootstrap: Vec<String> = manager_addr
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        if bootstrap.is_empty() {
            return Err(Error::Config("empty manager address".into()));
        }
        let (conn, picked) = if bootstrap.len() == 1 {
            (Conn::connect(&bootstrap[0])?, bootstrap[0].clone())
        } else {
            let mut found = None;
            for a in &bootstrap {
                if let Ok(c) = Conn::connect_timeout(a, Duration::from_secs(1)) {
                    found = Some((c, a.clone()));
                    break;
                }
            }
            found.ok_or_else(|| {
                Error::Manager(format!("no manager reachable in \"{manager_addr}\""))
            })?
        };
        let manager = Mutex::new((BufReader::new(conn.try_clone()?), BufWriter::new(conn)));
        let sai = Sai {
            cfg,
            engine,
            manager,
            bootstrap,
            bootstrap_cursor: Mutex::new(0),
            manager_addr: Mutex::new(picked),
            nodes: Mutex::new(Vec::new()),
            shaper,
            last_refresh: Mutex::new(None),
        };
        sai.refresh_nodes()?;
        {
            let nodes = sai.nodes.lock().unwrap();
            if nodes.is_empty() {
                return Err(Error::Config(
                    "no storage nodes registered with the manager".into(),
                ));
            }
            if nodes.iter().all(Option::is_none) {
                return Err(Error::Node("no storage node is reachable".into()));
            }
        }
        Ok(sai)
    }

    /// Re-read the manager's registry and connect any node this client
    /// has no live link for (nodes may join at any time).  Connects
    /// happen OUTSIDE the nodes lock (with a bounded timeout), so a
    /// black-holed node never stalls concurrent `node()` callers — in
    /// particular read failover routing around that very node.
    fn refresh_nodes(&self) -> Result<()> {
        let entries = self.list_nodes()?;
        let missing: Vec<(usize, String)> = {
            let mut nodes = self.nodes.lock().unwrap();
            if let Some(max) = entries.iter().map(|e| e.id as usize).max() {
                if nodes.len() <= max {
                    nodes.resize_with(max + 1, || None);
                }
            }
            entries
                .iter()
                // Skip nodes the manager itself reports dead: they
                // re-qualify as soon as they heartbeat again, and
                // dialing them only buys bounded-but-real stalls.
                .filter(|e| e.alive && nodes[e.id as usize].is_none())
                .map(|e| (e.id as usize, e.addr.clone()))
                .collect()
        };
        for (idx, addr) in missing {
            if let Ok(client) =
                DuplexClient::connect(&addr, self.shaper.clone(), self.cfg.node_inflight)
            {
                let mut nodes = self.nodes.lock().unwrap();
                if nodes[idx].is_none() {
                    nodes[idx] = Some(Arc::new(client));
                }
            }
        }
        *self.last_refresh.lock().unwrap() = Some(Instant::now());
        Ok(())
    }

    /// The active configuration.
    pub fn config(&self) -> &ClientConfig {
        &self.cfg
    }

    /// The hash engine in use.
    pub fn engine(&self) -> &Arc<dyn HashEngine> {
        &self.engine
    }

    pub(super) fn manager_call(&self, msg: Msg) -> Result<Msg> {
        let mut g = self.manager.lock().unwrap();
        let (r, w) = &mut *g;
        // First try on the shared long-lived connection; note whether
        // the request was ever flushed onto the wire.
        let sent = msg.write_to(w).and_then(|()| w.flush().map_err(Error::Io));
        let reply = match &sent {
            Ok(()) => Msg::read_from(r),
            Err(_) => Ok(None),
        };
        match reply {
            // The replica we're talking to isn't the leader: follow its
            // hint (re-sending to a non-leader is always safe — it
            // applied nothing).
            Ok(Some(Msg::NotLeader { hint })) => self.redirect_call(&mut g, msg, hint),
            // A leader that couldn't commit on a quorum: the record may
            // be durable (uncommitted) on that leader, but our control
            // calls are at-least-once — rotating to another member and
            // replaying is safe for state convergence (see README,
            // "Consensus & failover") and is exactly how a writer rides
            // out a deposed/partitioned leader.
            Ok(Some(Msg::Err(e))) if e.starts_with("no quorum") => {
                self.redirect_call(&mut g, msg, String::new())
            }
            Ok(Some(m)) => m.into_result(),
            // Reconnect and replay, only when the connection itself
            // failed: the write never made it out, or the manager
            // severed the link without replying (EOF — a manager
            // crash/restart does this to every live connection).  In
            // both cases the durable manager either never saw the
            // request or recovered it from its log, so replaying the
            // idempotent control call is safe; a read that died
            // MID-reply (a non-EOF error after a successful write) is
            // NOT retried — the request may have applied and replaying
            // e.g. a commit could double-apply.
            Ok(None) => self.redirect_call(&mut g, msg, String::new()),
            Err(e) => Err(e),
        }
    }

    /// Redirect/rotation loop behind [`Sai::manager_call`]: chase at
    /// most [`MAX_REDIRECT_HOPS`] `NotLeader` hints, falling back to
    /// bootstrap-list rotation (with a short backoff between rounds, so
    /// an in-flight election has time to conclude) when a hint is
    /// missing, circular, or exhausted.  On success the fresh
    /// connection replaces the shared one and the current manager
    /// address is updated for future calls and session helpers.
    fn redirect_call(
        &self,
        g: &mut (BufReader<Conn>, BufWriter<Conn>),
        msg: Msg,
        first_hint: String,
    ) -> Result<Msg> {
        let mut target = if first_hint.is_empty() {
            self.next_bootstrap()
        } else {
            first_hint
        };
        let mut last_err = Error::Manager("manager redirect: no attempt made".into());
        for round in 0..MAX_REDIRECT_ROUNDS {
            if round > 0 {
                std::thread::sleep(REDIRECT_BACKOFF);
            }
            let mut hops = 0;
            loop {
                let conn = match Conn::connect_timeout(&target, Duration::from_secs(1)) {
                    Ok(c) => c,
                    Err(e) => {
                        last_err = e;
                        target = self.next_bootstrap();
                        break;
                    }
                };
                let rc = match conn.try_clone() {
                    Ok(c) => c,
                    Err(e) => {
                        last_err = e;
                        target = self.next_bootstrap();
                        break;
                    }
                };
                let mut r = BufReader::new(rc);
                let mut w = BufWriter::new(conn);
                if let Err(e) = msg.write_to(&mut w).and_then(|()| w.flush().map_err(Error::Io)) {
                    last_err = e;
                    target = self.next_bootstrap();
                    break;
                }
                match Msg::read_from(&mut r) {
                    Ok(Some(Msg::NotLeader { hint })) => {
                        hops += 1;
                        if hops >= MAX_REDIRECT_HOPS {
                            last_err = Error::Manager(format!(
                                "no leader found after {hops} redirects"
                            ));
                            target = self.next_bootstrap();
                            break;
                        }
                        // An empty or self-referential hint can't make
                        // progress — rotate instead of looping.
                        if hint.is_empty() || hint == target {
                            target = self.next_bootstrap();
                        } else {
                            target = hint;
                        }
                    }
                    Ok(Some(Msg::Err(e))) if e.starts_with("no quorum") => {
                        last_err = Error::Proto(format!("remote: {e}"));
                        target = self.next_bootstrap();
                        break;
                    }
                    Ok(Some(m)) => {
                        *g = (r, w);
                        *self.manager_addr.lock().unwrap() = target;
                        return m.into_result();
                    }
                    Ok(None) => {
                        last_err = closed();
                        target = self.next_bootstrap();
                        break;
                    }
                    // Died mid-reply after a successful targeted write:
                    // the request may have applied — do not replay.
                    Err(e) => return Err(e),
                }
            }
        }
        Err(last_err)
    }

    /// Next bootstrap address in rotation order.
    fn next_bootstrap(&self) -> String {
        let mut cursor = self.bootstrap_cursor.lock().unwrap();
        let a = self.bootstrap[*cursor % self.bootstrap.len()].clone();
        *cursor = cursor.wrapping_add(1);
        a
    }

    /// The client for node `id`, if it is connected.  An id beyond the
    /// known registry is provably stale client state (the manager just
    /// placed on a node that joined after we last looked) and always
    /// refreshes; reconnect attempts for known-but-down nodes are
    /// rate-limited instead.
    pub(super) fn node(&self, id: u32) -> Result<Arc<DuplexClient>> {
        let known = {
            let mut nodes = self.nodes.lock().unwrap();
            if let Some(n) = nodes.get(id as usize).and_then(Option::clone) {
                if !n.is_dead() {
                    return Ok(n);
                }
                // The worker's transport died (node crash/restart):
                // evict so the refresh below can reconnect to a healthy
                // rebirth at the same id.
                nodes[id as usize] = None;
            }
            nodes.len()
        };
        let due = id as usize >= known
            || match *self.last_refresh.lock().unwrap() {
                None => true,
                Some(t) => t.elapsed() > Duration::from_secs(1),
            };
        if due {
            self.refresh_nodes()?;
            if let Some(n) = self
                .nodes
                .lock()
                .unwrap()
                .get(id as usize)
                .and_then(Option::clone)
            {
                return Ok(n);
            }
        }
        Err(Error::Node(format!("node {id} unavailable")))
    }

    /// Fetch the manager's node registry.
    pub fn list_nodes(&self) -> Result<Vec<super::proto::NodeEntry>> {
        match self.manager_call(Msg::NodeList)? {
            Msg::Nodes { nodes } => Ok(nodes),
            m => Err(Error::Proto(format!("unexpected reply {m:?}"))),
        }
    }

    /// The manager address the client currently targets (follows
    /// `NotLeader` redirects, so session helpers start at the same
    /// member the shared connection last succeeded against).
    pub(super) fn manager_addr(&self) -> String {
        self.manager_addr.lock().unwrap().clone()
    }

    /// Open a lease: `(lease, ttl_ms, version, blocks)`.  Read leases
    /// atomically fetch-and-pin the file's current block-map; write
    /// leases register an expiring claim holder for a write session.
    pub(super) fn open_lease(
        &self,
        file: &str,
        write: bool,
    ) -> Result<(u64, u64, u64, Vec<BlockMeta>)> {
        match self.manager_call(Msg::OpenLease {
            file: file.into(),
            write,
        })? {
            Msg::LeaseGrant {
                lease,
                ttl_ms,
                version,
                blocks,
            } => Ok((lease, ttl_ms, version, blocks)),
            m => Err(Error::Proto(format!("unexpected lease reply {m:?}"))),
        }
    }

    /// Extend a lease (errs if it already lapsed manager-side).
    pub(super) fn renew_lease(&self, lease: u64) -> Result<()> {
        match self.manager_call(Msg::RenewLease { lease })? {
            Msg::Ok => Ok(()),
            m => Err(Error::Proto(format!("unexpected renew reply {m:?}"))),
        }
    }

    /// Best-effort lease release (session teardown).  Idempotent on the
    /// manager; `0` (never granted) is skipped client-side.
    pub(super) fn drop_lease(&self, lease: u64) {
        if lease != 0 {
            let _ = self.manager_call(Msg::DropLease { lease });
        }
    }

    /// Best-effort corruption report: tell the manager that `node`'s
    /// copy (or shard) of `hash` was served but failed verification, so
    /// the scrub loop re-creates it from the surviving copies.  Fire
    /// and forget — the reader has already failed over; losing the
    /// report only delays the repair until the next detection.
    pub(super) fn report_corrupt(&self, hash: Digest, node: u32) {
        let _ = self.manager_call(Msg::ReportCorrupt { hash, node });
    }

    /// Ask the manager to place a batch of blocks for `file`, claiming
    /// them under the session's write `lease`.
    pub(super) fn alloc_placement(
        &self,
        file: &str,
        lease: u64,
        blocks: Vec<BlockSpec>,
    ) -> Result<Vec<Assignment>> {
        let n = blocks.len();
        match self.manager_call(Msg::AllocPlacement {
            file: file.into(),
            lease,
            blocks,
        })? {
            Msg::Placement { assignments } if assignments.len() == n => Ok(assignments),
            Msg::Placement { assignments } => Err(Error::Manager(format!(
                "placement count mismatch: {} for {} blocks",
                assignments.len(),
                n
            ))),
            m => Err(Error::Proto(format!("unexpected reply {m:?}"))),
        }
    }

    /// Fetch a file's current block-map (version 0 = absent).
    pub fn get_block_map(&self, file: &str) -> Result<(u64, Vec<BlockMeta>)> {
        match self.manager_call(Msg::GetBlockMap { file: file.into() })? {
            Msg::BlockMap { version, blocks } => Ok((version, blocks)),
            m => Err(Error::Proto(format!("unexpected reply {m:?}"))),
        }
    }

    /// List files known to the manager, sorted by name.  The sort is
    /// applied client-side so callers never depend on a manager
    /// implementation's map iteration order.
    pub fn list_files(&self) -> Result<Vec<(String, u64)>> {
        match self.manager_call(Msg::ListFiles)? {
            Msg::Files { mut files } => {
                files.sort();
                Ok(files)
            }
            m => Err(Error::Proto(format!("unexpected reply {m:?}"))),
        }
    }

    /// Open a streaming write session: returns a [`FileWriter`] that
    /// implements [`std::io::Write`].  Data is chunked, hashed,
    /// deduplicated and placed (by the manager) as it arrives; call
    /// [`FileWriter::close`] to commit the new version (the POSIX
    /// `release` step) and obtain the [`WriteReport`].
    pub fn create(&self, name: &str) -> Result<FileWriter<'_>> {
        FileWriter::new(self, name)
    }

    /// Open a streaming read session: returns a [`FileReader`] that
    /// implements [`std::io::Read`], prefetching blocks from their
    /// replica nodes ahead of the consumer, verifying each block's
    /// integrity (CA modes), and failing over to the next replica when
    /// a node is down or a copy is corrupt.
    pub fn open(&self, name: &str) -> Result<FileReader<'_>> {
        FileReader::new(self, name)
    }

    /// Write a complete file (the paper's workloads write whole files
    /// back-to-back; `release` semantics = commit on return).  Thin
    /// wrapper over [`Sai::create`].
    pub fn write_file(&self, name: &str, data: &[u8]) -> Result<WriteReport> {
        let mut w = self.create(name)?;
        w.push_bytes(data)?;
        w.close()
    }

    /// Read a complete file and verify block integrity (CA modes).
    /// Thin wrapper over [`Sai::open`].
    pub fn read_file(&self, name: &str) -> Result<Vec<u8>> {
        let mut r = self.open(name)?;
        let mut out = Vec::with_capacity(r.len() as usize);
        while let Some(block) = r.next_block()? {
            out.extend_from_slice(&block);
        }
        Ok(out)
    }

    /// Integrity scrub: fetch every replica copy of every block of
    /// `name` and recompute its content hash (the paper's "traditional
    /// system that uses hashing to preserve data integrity").  Returns
    /// (ok, corrupt) *copy* counts — an unreachable replica counts as
    /// corrupt, since the scrub cannot vouch for it.
    pub fn verify_file(&self, name: &str) -> Result<(usize, usize)> {
        let (version, blocks) = self.get_block_map(name)?;
        if version == 0 {
            return Err(Error::Manager(format!("no such file: {name}")));
        }
        if self.cfg.ca_mode == CaMode::None {
            return Err(Error::Config(
                "non-CA mode stores no content hashes to verify".into(),
            ));
        }
        let mut ok = 0;
        let mut bad = 0;
        // (meta index, receiver) per reachable copy; unreachable copies
        // (no link, or a link that is already dead) are counted bad
        // immediately — the duplex client errs eagerly.
        let mut rxs: Vec<(usize, Receiver<Result<Block>>)> = Vec::new();
        for (i, b) in blocks.iter().enumerate() {
            if let Some((k, m)) = b.ec {
                // Erasure-coded: a "copy" is one shard.  Ground truth
                // is the block reconstructed from any k well-sized
                // shards (verified by content hash), re-encoded; each
                // held shard then either matches its expected bytes or
                // is corrupt.  An unreconstructable block vouches for
                // none of its shards.
                let (k, m) = (k as usize, m as usize);
                let n = k + m;
                if b.replicas.len() != n {
                    bad += b.replicas.len();
                    continue;
                }
                let slen = crate::ec::shard_len(b.len as usize, k);
                let got: Vec<Option<Vec<u8>>> = b
                    .replicas
                    .iter()
                    .map(|&id| {
                        self.node(id)
                            .and_then(|nl| nl.get(b.hash))
                            .and_then(|rx| rx.recv().map_err(|_| closed()).and_then(|r| r))
                            .ok()
                            .map(|d| d.as_ref().clone())
                    })
                    .collect();
                let usable: Vec<Option<Vec<u8>>> = got
                    .iter()
                    .map(|s| s.clone().filter(|d| d.len() == slen))
                    .collect();
                match crate::ec::reconstruct(k, m, &usable, b.len as usize) {
                    Ok(data) if self.engine.direct_hash(&data)? == b.hash => {
                        let truth = crate::ec::encode(k, m, &data);
                        for (s, t) in got.iter().zip(&truth) {
                            if s.as_deref() == Some(t.as_slice()) {
                                ok += 1;
                            } else {
                                bad += 1;
                            }
                        }
                    }
                    _ => bad += n,
                }
                continue;
            }
            for &id in &b.replicas {
                match self.node(id).and_then(|n| n.get(b.hash)) {
                    Ok(rx) => rxs.push((i, rx)),
                    Err(_) => bad += 1,
                }
            }
        }
        for (i, rx) in rxs {
            let meta = &blocks[i];
            match rx.recv().map_err(|_| closed())? {
                Ok(data) => {
                    if data.len() == meta.len as usize
                        && self.engine.direct_hash(&data)? == meta.hash
                    {
                        ok += 1;
                    } else {
                        bad += 1;
                    }
                }
                Err(_) => bad += 1,
            }
        }
        Ok((ok, bad))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashgpu::{CpuEngine, WindowHashMode};
    use crate::net::Listener;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// A fake manager that answers EVERY call with a `NotLeader` whose
    /// hint points back at itself — the worst-case circular redirect.
    /// `manager_call` must follow a bounded number of hints/rotations
    /// and then surface an error, never loop forever.
    #[test]
    fn manager_call_follows_bounded_redirects_then_errs() {
        let listener = Listener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let served = Arc::new(AtomicUsize::new(0));
        let hint = addr.clone();
        let count = served.clone();
        std::thread::spawn(move || loop {
            let Ok(conn) = listener.accept() else { return };
            let (hint, count) = (hint.clone(), count.clone());
            std::thread::spawn(move || {
                let Ok(rc) = conn.try_clone() else { return };
                let mut r = BufReader::new(rc);
                let mut w = BufWriter::new(conn);
                while let Ok(Some(_)) = Msg::read_from(&mut r) {
                    count.fetch_add(1, Ordering::SeqCst);
                    let reply = Msg::NotLeader { hint: hint.clone() };
                    if reply.write_to(&mut w).is_err() {
                        return;
                    }
                    let _ = w.flush();
                }
            });
        });
        let conn = Conn::connect(&addr).unwrap();
        let sai = Sai {
            cfg: ClientConfig::default(),
            engine: Arc::new(CpuEngine::new(1, 4096, WindowHashMode::Rolling)),
            manager: Mutex::new((BufReader::new(conn.try_clone().unwrap()), BufWriter::new(conn))),
            bootstrap: vec![addr.clone()],
            bootstrap_cursor: Mutex::new(0),
            manager_addr: Mutex::new(addr),
            nodes: Mutex::new(Vec::new()),
            shaper: None,
            last_refresh: Mutex::new(None),
        };
        let err = sai.manager_call(Msg::NodeList).unwrap_err();
        let msg = format!("{err}");
        assert!(
            msg.contains("redirect") || msg.contains("leader"),
            "unexpected error: {msg}"
        );
        // 1 call on the shared connection + at most HOPS per round.
        let max = 1 + MAX_REDIRECT_ROUNDS * MAX_REDIRECT_HOPS;
        let n = served.load(Ordering::SeqCst);
        assert!(n <= max, "unbounded redirect chase: {n} calls > {max}");
        assert!(n >= 2, "redirects were not followed at all: {n} calls");
    }
}
