//! Storage node: a hash-addressed block store (paper §3.2.1).  Blocks
//! are kept in memory by default (the paper's nodes are RAM-backed for
//! the evaluated workloads) with an optional spill directory.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use super::proto::Msg;
use crate::hash::Digest;
use crate::net::{Conn, Listener};
use crate::Result;

/// Node state shared across connection threads.
#[derive(Debug, Default)]
pub struct NodeState {
    blocks: Mutex<HashMap<Digest, Vec<u8>>>,
    disk_dir: Option<PathBuf>,
}

impl NodeState {
    fn disk_path(&self, hash: &Digest) -> Option<PathBuf> {
        self.disk_dir
            .as_ref()
            .map(|d| d.join(crate::util::hex(hash)))
    }

    /// Handle one request.
    pub fn handle(&self, msg: Msg) -> Msg {
        match msg {
            Msg::PutBlock { hash, data } => {
                if let Some(p) = self.disk_path(&hash) {
                    if let Err(e) = std::fs::write(&p, &data) {
                        return Msg::Err(format!("disk write: {e}"));
                    }
                }
                self.blocks.lock().unwrap().insert(hash, data);
                Msg::Ok
            }
            Msg::HasBlock { hash } => {
                Msg::Bool(self.blocks.lock().unwrap().contains_key(&hash))
            }
            Msg::GetBlock { hash } => {
                let mem = self.blocks.lock().unwrap().get(&hash).cloned();
                match mem {
                    Some(data) => Msg::Data { data },
                    None => match self.disk_path(&hash) {
                        Some(p) => match std::fs::read(&p) {
                            Ok(data) => Msg::Data { data },
                            Err(_) => Msg::Err("unknown block".into()),
                        },
                        None => Msg::Err("unknown block".into()),
                    },
                }
            }
            Msg::NodeStats => {
                let b = self.blocks.lock().unwrap();
                Msg::Stats {
                    blocks: b.len() as u64,
                    bytes: b.values().map(|v| v.len() as u64).sum(),
                }
            }
            other => Msg::Err(format!("node: unexpected message {other:?}")),
        }
    }
}

/// A running storage node server.
pub struct StorageNode {
    addr: String,
    state: Arc<NodeState>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    /// Live connections (for failure injection: `shutdown` severs them).
    conns: Arc<Mutex<Vec<Conn>>>,
}

impl StorageNode {
    /// Bind and serve on `addr` with in-memory storage.
    pub fn spawn(addr: &str) -> Result<StorageNode> {
        Self::spawn_with(addr, None)
    }

    /// Bind and serve, optionally spilling blocks to `disk_dir`.
    pub fn spawn_with(addr: &str, disk_dir: Option<PathBuf>) -> Result<StorageNode> {
        if let Some(d) = &disk_dir {
            std::fs::create_dir_all(d)?;
        }
        let listener = Listener::bind(addr)?;
        let addr = listener.local_addr()?;
        let state = Arc::new(NodeState {
            blocks: Mutex::new(HashMap::new()),
            disk_dir,
        });
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<Conn>>> = Arc::new(Mutex::new(Vec::new()));
        let (st, sp, cn) = (state.clone(), stop.clone(), conns.clone());
        let accept_thread = std::thread::Builder::new()
            .name("mosa-node".into())
            .spawn(move || accept_loop(listener, st, sp, cn))
            .map_err(crate::Error::Io)?;
        Ok(StorageNode {
            addr,
            state,
            stop,
            accept_thread: Some(accept_thread),
            conns,
        })
    }

    /// The bound address.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Direct state access for tests.
    pub fn state(&self) -> &Arc<NodeState> {
        &self.state
    }

    /// Stop accepting and sever every live connection (failure
    /// injection: in-flight client requests observe errors, not hangs).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = Conn::connect(&self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        for c in self.conns.lock().unwrap().drain(..) {
            c.shutdown();
        }
    }
}

impl Drop for StorageNode {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: Listener,
    state: Arc<NodeState>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<Conn>>>,
) {
    loop {
        let conn = match listener.accept() {
            Ok(c) => c,
            Err(_) => break,
        };
        if stop.load(Ordering::Relaxed) {
            break;
        }
        if let Ok(clone) = conn.try_clone() {
            conns.lock().unwrap().push(clone);
        }
        let st = state.clone();
        let _ = std::thread::Builder::new()
            .name("mosa-node-conn".into())
            .spawn(move || serve_conn(conn, st));
    }
}

fn serve_conn(conn: Conn, state: Arc<NodeState>) {
    let reader = match conn.try_clone() {
        Ok(c) => c,
        Err(_) => return,
    };
    let mut r = BufReader::new(reader);
    let mut w = BufWriter::new(conn);
    while let Ok(Some(msg)) = Msg::read_from(&mut r) {
        let reply = state.handle(msg);
        if reply.write_to(&mut w).is_err() {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_has_get() {
        let s = NodeState::default();
        let h = [1u8; 16];
        assert_eq!(s.handle(Msg::HasBlock { hash: h }), Msg::Bool(false));
        assert_eq!(
            s.handle(Msg::PutBlock {
                hash: h,
                data: vec![1, 2, 3]
            }),
            Msg::Ok
        );
        assert_eq!(s.handle(Msg::HasBlock { hash: h }), Msg::Bool(true));
        assert_eq!(
            s.handle(Msg::GetBlock { hash: h }),
            Msg::Data {
                data: vec![1, 2, 3]
            }
        );
    }

    #[test]
    fn get_unknown_errors() {
        let s = NodeState::default();
        assert!(matches!(
            s.handle(Msg::GetBlock { hash: [9; 16] }),
            Msg::Err(_)
        ));
    }

    #[test]
    fn stats_accumulate() {
        let s = NodeState::default();
        for i in 0..3u8 {
            s.handle(Msg::PutBlock {
                hash: [i; 16],
                data: vec![0; 100],
            });
        }
        assert_eq!(
            s.handle(Msg::NodeStats),
            Msg::Stats {
                blocks: 3,
                bytes: 300
            }
        );
    }

    #[test]
    fn put_is_idempotent_by_key() {
        let s = NodeState::default();
        let h = [2u8; 16];
        s.handle(Msg::PutBlock {
            hash: h,
            data: vec![1],
        });
        s.handle(Msg::PutBlock {
            hash: h,
            data: vec![1],
        });
        assert_eq!(
            s.handle(Msg::NodeStats),
            Msg::Stats { blocks: 1, bytes: 1 }
        );
    }

    #[test]
    fn disk_spill_roundtrip() {
        let dir = std::env::temp_dir().join(format!("gpustore-node-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut node = StorageNode::spawn_with("127.0.0.1:0", Some(dir.clone())).unwrap();
        let mut c = Conn::connect(node.addr()).unwrap();
        Msg::PutBlock {
            hash: [7; 16],
            data: vec![9; 50],
        }
        .write_to(&mut c)
        .unwrap();
        assert_eq!(Msg::read_from(&mut c).unwrap().unwrap(), Msg::Ok);
        // Block landed on disk.
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 1);
        node.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tcp_roundtrip() {
        let node = StorageNode::spawn("127.0.0.1:0").unwrap();
        let mut c = Conn::connect(node.addr()).unwrap();
        Msg::PutBlock {
            hash: [3; 16],
            data: vec![5; 10],
        }
        .write_to(&mut c)
        .unwrap();
        assert_eq!(Msg::read_from(&mut c).unwrap().unwrap(), Msg::Ok);
        Msg::GetBlock { hash: [3; 16] }.write_to(&mut c).unwrap();
        assert_eq!(
            Msg::read_from(&mut c).unwrap().unwrap(),
            Msg::Data { data: vec![5; 10] }
        );
    }
}
