//! Storage node: a hash-addressed block store (paper §3.2.1).  Blocks
//! are kept in memory by default (the paper's nodes are RAM-backed for
//! the evaluated workloads) with an optional spill directory.
//!
//! Control-plane v2: a node registers with the metadata manager on
//! spawn ([`Msg::NodeJoin`]), heartbeats it for liveness, and handles
//! [`Msg::DeleteBlock`] so the manager can reclaim unreferenced blocks.
//!
//! Serve architecture (PR 9): by default every node runs an
//! **event-driven reactor** ([`super::reactor`]) — one poll thread owns
//! all sockets and a fixed worker pool runs the handlers, so thousands
//! of connections cost a handful of threads.  The pre-PR-9
//! thread-per-connection path (request-reader loop + dedicated
//! reply-writer thread per socket) is retained behind
//! [`ServeMode::Thread`] as the benchmark baseline
//! (`cargo bench --bench sessions`).  Both paths speak the identical
//! wire protocol and preserve the pipelined
//! [`DuplexClient`](super::duplex::DuplexClient) contract: requests on
//! one connection are served FIFO and replies leave in request order.
//! Blocks are stored as shared [`Arc`] payloads and `Data` replies
//! stream straight out of the store ([`Msg::data_header`] + payload),
//! so a get never copies the block on the node.  Two optional fidelity
//! knobs for single-host experiments: a reply-side [`Shaper`] models
//! the node's NIC, and `reply_latency` models the fabric round-trip a
//! real deployment would add to every request→reply turnaround
//! (implemented as a delay line: each reply is released `reply_latency`
//! after its request arrived, so pipelined replies overlap their delays
//! exactly like real in-flight packets, while a lock-step client pays
//! the latency once per block).

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write as _};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::proto::Msg;
use super::reactor::{FrameHandler, Reactor, ReactorOpts, Replies};
use crate::config::ServeMode;
use crate::hash::Digest;
use crate::metrics::ServeGauges;
use crate::net::{Conn, Listener, Shaper};
use crate::Result;

/// Default reactor worker-pool size when `serve_threads` is 0.
const DEFAULT_SERVE_THREADS: usize = 4;

/// How often a registered node beacons the manager.
const HEARTBEAT_INTERVAL: Duration = Duration::from_millis(250);

/// Node state shared across connection threads.
#[derive(Debug, Default)]
pub struct NodeState {
    /// Shared payloads: a get clones the `Arc`, not the bytes.
    blocks: Mutex<HashMap<Digest, Arc<Vec<u8>>>>,
    disk_dir: Option<PathBuf>,
}

/// One reply travelling from the request-reader to the reply-writer
/// thread of a connection.
enum Reply {
    /// Control/ack frame, encoded at write time.
    Msg(Msg),
    /// Zero-copy block payload: header + bytes straight from the store.
    Data { req: u64, data: Arc<Vec<u8>> },
}

impl NodeState {
    fn disk_path(&self, hash: &Digest) -> Option<PathBuf> {
        self.disk_dir
            .as_ref()
            .map(|d| d.join(crate::util::hex(hash)))
    }

    /// Store one block (memory + optional disk spill).
    fn store(&self, hash: Digest, data: Vec<u8>) -> std::result::Result<(), String> {
        if let Some(p) = self.disk_path(&hash) {
            if let Err(e) = std::fs::write(&p, &data) {
                return Err(format!("disk write: {e}"));
            }
        }
        self.blocks.lock().unwrap().insert(hash, Arc::new(data));
        Ok(())
    }

    /// Fetch one block as a shared payload (memory first, then spill).
    fn fetch(&self, hash: &Digest) -> Option<Arc<Vec<u8>>> {
        if let Some(d) = self.blocks.lock().unwrap().get(hash).cloned() {
            return Some(d);
        }
        let p = self.disk_path(hash)?;
        std::fs::read(&p).ok().map(Arc::new)
    }

    /// Serve-loop dispatch: data-plane requests resolve to tagged
    /// replies (with `Data` payloads shared, not copied); everything
    /// else goes through [`NodeState::handle`].
    fn dispatch(&self, msg: Msg) -> Reply {
        match msg {
            Msg::PutBlock { req, hash, data } => match self.store(hash, data) {
                Ok(()) => Reply::Msg(Msg::OkFor { req }),
                Err(e) => Reply::Msg(Msg::ErrFor { req, msg: e }),
            },
            Msg::GetBlock { req, hash } => match self.fetch(&hash) {
                Some(data) => Reply::Data { req, data },
                None => Reply::Msg(Msg::ErrFor {
                    req,
                    msg: "unknown block".into(),
                }),
            },
            other => Reply::Msg(self.handle(other)),
        }
    }

    /// Handle one request, returning the full reply message (tests and
    /// introspection; the serve loop's hot path uses
    /// [`NodeState::dispatch`], which shares `Data` payloads instead of
    /// copying them into a `Msg`).
    pub fn handle(&self, msg: Msg) -> Msg {
        match msg {
            Msg::PutBlock { req, hash, data } => match self.store(hash, data) {
                Ok(()) => Msg::OkFor { req },
                Err(e) => Msg::ErrFor { req, msg: e },
            },
            Msg::HasBlock { hash } => {
                Msg::Bool(self.blocks.lock().unwrap().contains_key(&hash))
            }
            Msg::GetBlock { req, hash } => match self.fetch(&hash) {
                Some(data) => Msg::Data {
                    req,
                    data: data.as_ref().clone(),
                },
                None => Msg::ErrFor {
                    req,
                    msg: "unknown block".into(),
                },
            },
            Msg::DeleteBlock { hash } => {
                // Idempotent: deleting an unknown block is fine (the
                // manager's GC may race an aborted writer's puts).
                self.blocks.lock().unwrap().remove(&hash);
                if let Some(p) = self.disk_path(&hash) {
                    let _ = std::fs::remove_file(p);
                }
                Msg::Ok
            }
            Msg::NodeStats => {
                let b = self.blocks.lock().unwrap();
                Msg::Stats {
                    blocks: b.len() as u64,
                    bytes: b.values().map(|v| v.len() as u64).sum(),
                }
            }
            Msg::ListBlocks => {
                // Full inventory for the manager's anti-entropy sweep.
                // Sorted so sweeps are deterministic and two inventories
                // of the same store compare equal.
                let mut hashes: Vec<Digest> =
                    self.blocks.lock().unwrap().keys().copied().collect();
                hashes.sort_unstable();
                Msg::BlockList { hashes }
            }
            other => Msg::Err(format!("node: unexpected message {other:?}")),
        }
    }
}

/// Reactor glue: one call per request frame, single lane (node handlers
/// never block on remote calls).
struct NodeService {
    state: Arc<NodeState>,
}

impl FrameHandler for NodeService {
    fn on_frame(&self, tag: u8, body: Vec<u8>, replies: &mut Replies) {
        let msg = match Msg::decode(tag, &body) {
            Ok(m) => m,
            Err(_) => {
                // Framing/decoding violation: sever, matching the
                // threaded loop's broken read.
                replies.sever();
                return;
            }
        };
        match self.state.dispatch(msg) {
            Reply::Msg(m) => replies.frame(m.encode()),
            Reply::Data { req, data } => {
                // Copy-free get path: the header is owned, the payload
                // is the store's Arc sliced straight onto the wire.
                replies.frame_with_body(Msg::data_header(req, data.len()).to_vec(), data)
            }
        }
    }
}

/// Spawn-time options for a [`StorageNode`] beyond the bind address.
#[derive(Default)]
pub struct NodeOpts {
    /// Optional block spill directory.
    pub disk_dir: Option<PathBuf>,
    /// Manager address to register with (join + heartbeat).
    pub manager: Option<String>,
    /// Address to join the manager under (wildcard-bound nodes that are
    /// reachable at a different host:port).
    pub advertise: Option<String>,
    /// Pace this node's replies (its NIC) — single-host experiments
    /// shaping the read path like the paper's 1 Gbps fabric.
    pub reply_shaper: Option<Arc<Shaper>>,
    /// Modeled fabric round-trip residue: each reply is released this
    /// long after its request arrived (a delay line — pipelined replies
    /// overlap their delays; a lock-step client pays it per block).
    pub reply_latency: Duration,
    /// Serve architecture: event-driven reactor (default) or the legacy
    /// thread-per-connection baseline.
    pub serve_mode: ServeMode,
    /// Reactor worker threads (`0` = built-in default); ignored in
    /// [`ServeMode::Thread`].
    pub serve_threads: usize,
}

/// The node's serve path: a reactor, or the legacy thread-per-conn
/// accept loop (benchmark baseline).
enum Serve {
    Event(Option<Reactor>),
    Thread {
        stop: Arc<AtomicBool>,
        accept_thread: Option<JoinHandle<()>>,
        /// Live connections (for failure injection: `shutdown` severs
        /// them).  The reactor severs its own on shutdown.
        conns: Arc<Mutex<Vec<Conn>>>,
    },
}

/// A running storage node server.
pub struct StorageNode {
    addr: String,
    state: Arc<NodeState>,
    serve: Serve,
    /// Manager-assigned id, when registered.
    node_id: Option<u32>,
    /// Stop channel + handle of the heartbeat thread, when registered.
    heartbeat: Option<(Sender<()>, JoinHandle<()>)>,
}

impl StorageNode {
    /// Bind and serve on `addr` with in-memory storage (no manager).
    pub fn spawn(addr: &str) -> Result<StorageNode> {
        Self::spawn_with(addr, None)
    }

    /// Bind and serve, optionally spilling blocks to `disk_dir`
    /// (no manager registration).
    pub fn spawn_with(addr: &str, disk_dir: Option<PathBuf>) -> Result<StorageNode> {
        Self::spawn_full(addr, disk_dir, None)
    }

    /// Bind, serve, and — when `manager` is given — register with the
    /// metadata manager (joining under this node's bound address) and
    /// start heartbeating it.
    pub fn spawn_full(
        addr: &str,
        disk_dir: Option<PathBuf>,
        manager: Option<&str>,
    ) -> Result<StorageNode> {
        Self::spawn_opts(
            addr,
            NodeOpts {
                disk_dir,
                manager: manager.map(str::to_string),
                ..NodeOpts::default()
            },
        )
    }

    /// Like [`spawn_full`](Self::spawn_full) with a manager, but join
    /// under `advertise` (for nodes bound to wildcard addresses that
    /// are reachable at a different host:port).
    pub fn spawn_advertised(
        addr: &str,
        disk_dir: Option<PathBuf>,
        manager: &str,
        advertise: Option<&str>,
    ) -> Result<StorageNode> {
        Self::spawn_opts(
            addr,
            NodeOpts {
                disk_dir,
                manager: Some(manager.to_string()),
                advertise: advertise.map(str::to_string),
                ..NodeOpts::default()
            },
        )
    }

    /// Bind and serve with the full option set.
    pub fn spawn_opts(addr: &str, opts: NodeOpts) -> Result<StorageNode> {
        let NodeOpts {
            disk_dir,
            manager,
            advertise,
            reply_shaper,
            reply_latency,
            serve_mode,
            serve_threads,
        } = opts;
        if let Some(d) = &disk_dir {
            std::fs::create_dir_all(d)?;
        }
        let listener = Listener::bind(addr)?;
        let addr = listener.local_addr()?;
        let state = Arc::new(NodeState {
            blocks: Mutex::new(HashMap::new()),
            disk_dir,
        });
        let serve = match serve_mode {
            ServeMode::Event => {
                let workers = if serve_threads == 0 {
                    DEFAULT_SERVE_THREADS
                } else {
                    serve_threads
                };
                // Unique thread-name prefix per node (tests count live
                // serve threads by it; kernel truncates at 15 bytes).
                let port = addr.rsplit(':').next().unwrap_or("0");
                let reactor = Reactor::serve(
                    listener,
                    Arc::new(NodeService {
                        state: state.clone(),
                    }),
                    ReactorOpts {
                        name: format!("nd{port}"),
                        workers: vec![workers],
                        reply_latency,
                        reply_shaper,
                    },
                )?;
                Serve::Event(Some(reactor))
            }
            ServeMode::Thread => {
                let stop = Arc::new(AtomicBool::new(false));
                let conns: Arc<Mutex<Vec<Conn>>> = Arc::new(Mutex::new(Vec::new()));
                let (st, sp, cn) = (state.clone(), stop.clone(), conns.clone());
                let accept_thread = std::thread::Builder::new()
                    .name("mosa-node".into())
                    .spawn(move || accept_loop(listener, st, sp, cn, reply_shaper, reply_latency))
                    .map_err(crate::Error::Io)?;
                Serve::Thread {
                    stop,
                    accept_thread: Some(accept_thread),
                    conns,
                }
            }
        };
        let mut node = StorageNode {
            addr,
            state,
            serve,
            node_id: None,
            heartbeat: None,
        };
        if let Some(mgr) = manager {
            let join_as = advertise.unwrap_or_else(|| node.addr.clone());
            node.register(&mgr, join_as)?;
        }
        Ok(node)
    }

    /// Join the manager's registry (under `join_as`) and start the
    /// heartbeat thread.
    fn register(&mut self, manager_addr: &str, join_as: String) -> Result<()> {
        let mut conn = Conn::connect(manager_addr)?;
        Msg::NodeJoin {
            addr: join_as.clone(),
        }
        .write_to(&mut conn)?;
        let id = match Msg::read_from(&mut conn)?
            .ok_or_else(|| crate::Error::Manager("manager closed during join".into()))?
            .into_result()?
        {
            Msg::NodeId { id } => id,
            m => {
                return Err(crate::Error::Manager(format!(
                    "unexpected join reply {m:?}"
                )))
            }
        };
        self.node_id = Some(id);
        let mgr_addr = manager_addr.to_string();
        let (tx, rx) = mpsc::channel::<()>();
        let handle = std::thread::Builder::new()
            .name(format!("mosa-node-hb-{id}"))
            .spawn(move || {
                // Reuse the join connection; on any failure — transport
                // OR a logical Err reply (e.g. the manager restarted
                // with an empty registry and no longer knows this id) —
                // re-JOIN over a fresh connection, which re-registers
                // the node and may hand back a new id.
                let mut link = Some(conn);
                let mut my_id = id;
                loop {
                    match rx.recv_timeout(HEARTBEAT_INTERVAL) {
                        Err(RecvTimeoutError::Timeout) => {}
                        _ => break, // stop requested or node dropped
                    }
                    let beat = |c: &mut Conn, id: u32| -> Result<()> {
                        Msg::Heartbeat { node: id }.write_to(c)?;
                        match Msg::read_from(c)?.ok_or_else(|| {
                            crate::Error::Manager("manager closed".into())
                        })? {
                            Msg::Ok => Ok(()),
                            m => Err(crate::Error::Manager(format!("beat: {m:?}"))),
                        }
                    };
                    let sent = match link.as_mut() {
                        Some(c) => beat(c, my_id).is_ok(),
                        None => false,
                    };
                    if !sent {
                        link = rejoin(&mgr_addr, &join_as, &mut my_id);
                    }
                }
            })
            .map_err(crate::Error::Io)?;
        self.heartbeat = Some((tx, handle));
        Ok(())
    }

    /// The bound address.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Manager-assigned node id (None when unregistered).
    pub fn node_id(&self) -> Option<u32> {
        self.node_id
    }

    /// Direct state access for tests.
    pub fn state(&self) -> &Arc<NodeState> {
        &self.state
    }

    /// Live serve-loop gauges (None in [`ServeMode::Thread`]).
    pub fn serve_gauges(&self) -> Option<Arc<ServeGauges>> {
        match &self.serve {
            Serve::Event(Some(r)) => Some(r.gauges()),
            _ => None,
        }
    }

    /// Stop accepting and sever every live connection (failure
    /// injection: in-flight client requests observe errors, not hangs).
    /// The reactor path wakes its poll loop through the pipe and joins
    /// every serve thread — no self-connect poke.  Idempotent.
    pub fn shutdown(&mut self) {
        if let Some((tx, handle)) = self.heartbeat.take() {
            let _ = tx.send(()); // wake the heartbeat thread promptly
            let _ = handle.join();
        }
        match &mut self.serve {
            Serve::Event(reactor) => {
                if let Some(mut r) = reactor.take() {
                    r.shutdown();
                }
            }
            Serve::Thread {
                stop,
                accept_thread,
                conns,
            } => {
                if stop.swap(true, Ordering::SeqCst) {
                    return; // already shut down
                }
                // Dedicated poke path (legacy loop only): guarantees the
                // blocked accept() returns after the stop flag is set.
                let _ = Conn::connect(&self.addr);
                if let Some(t) = accept_thread.take() {
                    let _ = t.join();
                }
                for c in conns.lock().unwrap().drain(..) {
                    c.shutdown();
                }
            }
        }
    }
}

impl Drop for StorageNode {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Best-effort re-registration with the manager (fresh connection +
/// `NodeJoin`); updates `my_id` if the manager assigned a new one.
/// Bounded connect: a black-holed manager must not stall the heartbeat
/// thread (and thus `shutdown`'s join) for the OS SYN timeout.
fn rejoin(mgr_addr: &str, join_as: &str, my_id: &mut u32) -> Option<Conn> {
    let mut c = Conn::connect_timeout(mgr_addr, Duration::from_secs(1)).ok()?;
    Msg::NodeJoin {
        addr: join_as.to_string(),
    }
    .write_to(&mut c)
    .ok()?;
    match Msg::read_from(&mut c).ok()?? {
        Msg::NodeId { id } => {
            *my_id = id;
            Some(c)
        }
        _ => None,
    }
}

fn accept_loop(
    listener: Listener,
    state: Arc<NodeState>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<Conn>>>,
    reply_shaper: Option<Arc<Shaper>>,
    reply_latency: Duration,
) {
    loop {
        let conn = match listener.accept() {
            Ok(c) => c,
            Err(_) => break,
        };
        // Race fix (mirrors the manager): serve the connection even if
        // the stop flag was set while accept() was blocked — a real
        // client racing shutdown gets answered, the shutdown poke reads
        // clean EOF — then exit the loop.
        let stopping = stop.load(Ordering::SeqCst);
        if let Ok(clone) = conn.try_clone() {
            conns.lock().unwrap().push(clone);
        }
        let st = state.clone();
        let sh = reply_shaper.clone();
        let _ = std::thread::Builder::new()
            .name("mosa-node-conn".into())
            .spawn(move || serve_conn(conn, st, sh, reply_latency));
        if stopping {
            break;
        }
    }
}

/// Serve one connection, pipelined: the request-reader loop (this
/// thread) decodes and handles request N+1 while the reply-writer
/// thread drains reply N — so a stream of puts/gets is never
/// store-and-forward serialized against its own acknowledgements.
/// Replies leave in request order; the tagged protocol lets the client
/// match them to waiters regardless.
fn serve_conn(
    conn: Conn,
    state: Arc<NodeState>,
    reply_shaper: Option<Arc<Shaper>>,
    reply_latency: Duration,
) {
    let reader = match conn.try_clone() {
        Ok(c) => c,
        Err(_) => return,
    };
    let mut wconn = conn;
    if let Some(s) = reply_shaper {
        // The node's NIC: paces Data payloads on the read path the way
        // the client's shaper paces puts on the write path.
        wconn = wconn.with_shaper(s);
    }
    let (tx, rx) = mpsc::channel::<(Instant, Reply)>();
    let Ok(writer) = std::thread::Builder::new()
        .name("mosa-node-reply".into())
        .spawn(move || reply_writer(wconn, rx))
    else {
        return;
    };
    let mut r = BufReader::with_capacity(256 * 1024, reader);
    while let Ok(Some(msg)) = Msg::read_from(&mut r) {
        // The delay line stamps each reply at request arrival, so
        // overlapped requests overlap their latencies (like real
        // in-flight packets) instead of queueing them.
        let due = Instant::now() + reply_latency;
        if tx.send((due, state.dispatch(msg))).is_err() {
            break;
        }
    }
    drop(tx); // writer drains the queue, flushes, and exits
    let _ = writer.join();
}

/// Reply-writer half of a connection: releases each reply at its due
/// time, streams `Data` payloads straight from the shared store, and
/// batches flushes (one per queue drain, not one per frame).
fn reply_writer(conn: Conn, rx: mpsc::Receiver<(Instant, Reply)>) {
    let mut w = BufWriter::with_capacity(256 * 1024, conn);
    loop {
        let (due, reply) = match rx.try_recv() {
            Ok(r) => r,
            Err(TryRecvError::Empty) => {
                if w.flush().is_err() {
                    return;
                }
                match rx.recv() {
                    Ok(r) => r,
                    Err(_) => break,
                }
            }
            Err(TryRecvError::Disconnected) => break,
        };
        let now = Instant::now();
        if due > now {
            // Already-due replies must not ride out this sleep inside
            // the buffer: flush them first, THEN wait for the delay
            // line — otherwise a reply could arrive up to a full
            // `reply_latency` late.
            if w.flush().is_err() {
                return;
            }
            std::thread::sleep(due - now);
        }
        let res = match reply {
            Reply::Msg(m) => w.write_all(&m.encode()),
            Reply::Data { req, data } => w
                .write_all(&Msg::data_header(req, data.len()))
                .and_then(|()| w.write_all(&data)),
        };
        if res.is_err() {
            return;
        }
    }
    let _ = w.flush();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_has_get() {
        let s = NodeState::default();
        let h = [1u8; 16];
        assert_eq!(s.handle(Msg::HasBlock { hash: h }), Msg::Bool(false));
        assert_eq!(
            s.handle(Msg::PutBlock {
                req: 5,
                hash: h,
                data: vec![1, 2, 3]
            }),
            Msg::OkFor { req: 5 }
        );
        assert_eq!(s.handle(Msg::HasBlock { hash: h }), Msg::Bool(true));
        assert_eq!(
            s.handle(Msg::GetBlock { req: 6, hash: h }),
            Msg::Data {
                req: 6,
                data: vec![1, 2, 3]
            }
        );
    }

    #[test]
    fn get_unknown_errors() {
        let s = NodeState::default();
        assert!(matches!(
            s.handle(Msg::GetBlock {
                req: 1,
                hash: [9; 16]
            }),
            Msg::ErrFor { req: 1, .. }
        ));
    }

    #[test]
    fn delete_block_is_idempotent() {
        let s = NodeState::default();
        let h = [4u8; 16];
        s.handle(Msg::PutBlock {
            req: 1,
            hash: h,
            data: vec![1; 50],
        });
        assert_eq!(s.handle(Msg::DeleteBlock { hash: h }), Msg::Ok);
        assert_eq!(s.handle(Msg::HasBlock { hash: h }), Msg::Bool(false));
        // Deleting again (or a never-stored key) still succeeds.
        assert_eq!(s.handle(Msg::DeleteBlock { hash: h }), Msg::Ok);
        assert_eq!(s.handle(Msg::DeleteBlock { hash: [5; 16] }), Msg::Ok);
        assert_eq!(
            s.handle(Msg::NodeStats),
            Msg::Stats { blocks: 0, bytes: 0 }
        );
    }

    #[test]
    fn stats_accumulate() {
        let s = NodeState::default();
        for i in 0..3u8 {
            s.handle(Msg::PutBlock {
                req: i as u64,
                hash: [i; 16],
                data: vec![0; 100],
            });
        }
        assert_eq!(
            s.handle(Msg::NodeStats),
            Msg::Stats {
                blocks: 3,
                bytes: 300
            }
        );
    }

    #[test]
    fn put_is_idempotent_by_key() {
        let s = NodeState::default();
        let h = [2u8; 16];
        for req in [1, 2] {
            s.handle(Msg::PutBlock {
                req,
                hash: h,
                data: vec![1],
            });
        }
        assert_eq!(
            s.handle(Msg::NodeStats),
            Msg::Stats { blocks: 1, bytes: 1 }
        );
    }

    #[test]
    fn disk_spill_roundtrip() {
        let dir = std::env::temp_dir().join(format!("gpustore-node-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut node = StorageNode::spawn_with("127.0.0.1:0", Some(dir.clone())).unwrap();
        let mut c = Conn::connect(node.addr()).unwrap();
        Msg::PutBlock {
            req: 1,
            hash: [7; 16],
            data: vec![9; 50],
        }
        .write_to(&mut c)
        .unwrap();
        assert_eq!(
            Msg::read_from(&mut c).unwrap().unwrap(),
            Msg::OkFor { req: 1 }
        );
        // Block landed on disk.
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 1);
        // DeleteBlock removes the spilled copy too.
        Msg::DeleteBlock { hash: [7; 16] }.write_to(&mut c).unwrap();
        assert_eq!(Msg::read_from(&mut c).unwrap().unwrap(), Msg::Ok);
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 0);
        node.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tcp_roundtrip() {
        let node = StorageNode::spawn("127.0.0.1:0").unwrap();
        let mut c = Conn::connect(node.addr()).unwrap();
        Msg::PutBlock {
            req: 9,
            hash: [3; 16],
            data: vec![5; 10],
        }
        .write_to(&mut c)
        .unwrap();
        assert_eq!(
            Msg::read_from(&mut c).unwrap().unwrap(),
            Msg::OkFor { req: 9 }
        );
        Msg::GetBlock {
            req: 10,
            hash: [3; 16],
        }
        .write_to(&mut c)
        .unwrap();
        assert_eq!(
            Msg::read_from(&mut c).unwrap().unwrap(),
            Msg::Data {
                req: 10,
                data: vec![5; 10]
            }
        );
    }

    #[test]
    fn pipelined_requests_one_connection() {
        // Many requests written before any reply is read: the split
        // serve loop answers them all, in order, ids echoed.
        let node = StorageNode::spawn("127.0.0.1:0").unwrap();
        let mut c = Conn::connect(node.addr()).unwrap();
        for i in 0..16u64 {
            Msg::PutBlock {
                req: i,
                hash: [i as u8; 16],
                data: vec![i as u8; 100],
            }
            .write_to(&mut c)
            .unwrap();
        }
        for i in 0..16u64 {
            Msg::GetBlock {
                req: 100 + i,
                hash: [i as u8; 16],
            }
            .write_to(&mut c)
            .unwrap();
        }
        for i in 0..16u64 {
            assert_eq!(
                Msg::read_from(&mut c).unwrap().unwrap(),
                Msg::OkFor { req: i }
            );
        }
        for i in 0..16u64 {
            assert_eq!(
                Msg::read_from(&mut c).unwrap().unwrap(),
                Msg::Data {
                    req: 100 + i,
                    data: vec![i as u8; 100]
                }
            );
        }
    }

    #[test]
    fn reply_latency_is_a_delay_line() {
        // 16 pipelined requests against a 30 ms reply latency complete
        // in ~one latency window, not 16 of them — the delays overlap.
        let node = StorageNode::spawn_opts(
            "127.0.0.1:0",
            NodeOpts {
                reply_latency: Duration::from_millis(30),
                ..NodeOpts::default()
            },
        )
        .unwrap();
        let mut c = Conn::connect(node.addr()).unwrap();
        let t0 = Instant::now();
        for i in 0..16u64 {
            Msg::PutBlock {
                req: i,
                hash: [i as u8; 16],
                data: vec![0; 10],
            }
            .write_to(&mut c)
            .unwrap();
        }
        for i in 0..16u64 {
            assert_eq!(
                Msg::read_from(&mut c).unwrap().unwrap(),
                Msg::OkFor { req: i }
            );
        }
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(30), "latency applied: {dt:?}");
        assert!(
            dt < Duration::from_millis(16 * 30),
            "delays must overlap, not queue: {dt:?}"
        );
    }

    #[test]
    fn thread_mode_baseline_still_serves_pipelined() {
        // The legacy thread-per-connection path stays wire-compatible
        // (it is the sessions bench's baseline arm).
        let node = StorageNode::spawn_opts(
            "127.0.0.1:0",
            NodeOpts {
                serve_mode: ServeMode::Thread,
                ..NodeOpts::default()
            },
        )
        .unwrap();
        assert!(node.serve_gauges().is_none(), "no gauges in thread mode");
        let mut c = Conn::connect(node.addr()).unwrap();
        for i in 0..8u64 {
            Msg::PutBlock {
                req: i,
                hash: [i as u8; 16],
                data: vec![i as u8; 10],
            }
            .write_to(&mut c)
            .unwrap();
        }
        for i in 0..8u64 {
            assert_eq!(
                Msg::read_from(&mut c).unwrap().unwrap(),
                Msg::OkFor { req: i }
            );
        }
    }

    #[test]
    fn event_mode_exposes_gauges_and_leaks_no_threads() {
        let count = |prefix: &str| {
            std::fs::read_dir("/proc/self/task")
                .unwrap()
                .flatten()
                .filter(|e| {
                    std::fs::read_to_string(e.path().join("comm"))
                        .map(|n| n.trim_end().starts_with(prefix))
                        .unwrap_or(false)
                })
                .count()
        };
        let mut node = StorageNode::spawn("127.0.0.1:0").unwrap();
        let port = node.addr().rsplit(':').next().unwrap().to_string();
        let prefix = format!("nd{port}");
        assert!(count(&prefix) >= 2, "poll + worker threads running");
        let mut c = Conn::connect(node.addr()).unwrap();
        Msg::PutBlock {
            req: 1,
            hash: [1; 16],
            data: vec![1; 8],
        }
        .write_to(&mut c)
        .unwrap();
        assert_eq!(
            Msg::read_from(&mut c).unwrap().unwrap(),
            Msg::OkFor { req: 1 }
        );
        let g = node.serve_gauges().expect("event mode has gauges");
        let s = g.snapshot();
        assert_eq!(s.open_conns, 1);
        assert_eq!(s.frames_served, 1);
        assert!(s.workers_total >= 1);
        node.shutdown();
        assert_eq!(count(&prefix), 0, "serve threads must join on shutdown");
        node.shutdown(); // idempotent
    }

    #[test]
    fn registers_with_manager_and_heartbeats() {
        use super::super::manager::Manager;
        let mgr = Manager::spawn("127.0.0.1:0").unwrap();
        let node = StorageNode::spawn_full("127.0.0.1:0", None, Some(mgr.addr())).unwrap();
        assert_eq!(node.node_id(), Some(0));
        let Msg::Nodes { nodes } = mgr.state().handle(Msg::NodeList) else {
            panic!()
        };
        assert_eq!(nodes.len(), 1);
        assert_eq!(nodes[0].addr, node.addr());
        assert!(nodes[0].alive);
    }
}
