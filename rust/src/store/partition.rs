//! Deterministic in-process network partitions for the fault-injection
//! harness.
//!
//! A partition is an unordered pair of *fault ids* (we use manager
//! listen addresses) registered in a process-global table.  Every
//! manager↔manager call ([`super::manager::peer_call`]) and follower
//! poll consults the table before dialing and fails fast with a
//! `partitioned` error when the pair is cut — no timeouts, no real
//! network interference, fully deterministic and instantaneous to heal.
//!
//! Client↔manager and client↔node traffic is deliberately unaffected:
//! the scenarios under test are control-plane splits (a leader cut off
//! from its quorum while still reachable by its clients — exactly the
//! split-brain shape).

use std::collections::HashSet;
use std::sync::{Mutex, OnceLock};

fn table() -> &'static Mutex<HashSet<(String, String)>> {
    static TABLE: OnceLock<Mutex<HashSet<(String, String)>>> = OnceLock::new();
    TABLE.get_or_init(|| Mutex::new(HashSet::new()))
}

fn key(a: &str, b: &str) -> (String, String) {
    if a <= b {
        (a.to_string(), b.to_string())
    } else {
        (b.to_string(), a.to_string())
    }
}

/// Cut the link between `a` and `b` (both directions).  Idempotent.
pub fn partition(a: &str, b: &str) {
    table().lock().unwrap().insert(key(a, b));
}

/// Restore the link between `a` and `b`.  Idempotent.
pub fn heal(a: &str, b: &str) {
    table().lock().unwrap().remove(&key(a, b));
}

/// Restore every cut link (end-of-test cleanup; also used by seeded
/// nemesis schedules between scenarios).
pub fn heal_all() {
    table().lock().unwrap().clear();
}

/// True when the `a`↔`b` link is currently cut.
pub fn is_partitioned(a: &str, b: &str) -> bool {
    table().lock().unwrap().contains(&key(a, b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unordered_and_idempotent() {
        let (a, b) = ("x-part-test:1", "x-part-test:2");
        assert!(!is_partitioned(a, b));
        partition(a, b);
        partition(a, b);
        assert!(is_partitioned(a, b));
        assert!(is_partitioned(b, a));
        assert!(!is_partitioned(a, "x-part-test:3"));
        heal(b, a);
        assert!(!is_partitioned(a, b));
        partition(a, b);
        partition(a, "x-part-test:3");
        heal_all();
        assert!(!is_partitioned(a, b));
        assert!(!is_partitioned(a, "x-part-test:3"));
    }
}
