//! Pipelined duplex data-plane client (data-plane v2).
//!
//! The old `node_worker` was lock-step: one put/get on the wire per
//! node, reply awaited before the next frame was written, so per-node
//! throughput was bounded by `block_size / RTT` no matter how fast the
//! NIC or the hash engine ran.  [`DuplexClient`] splits each node link
//! into a **writer thread** and a **reply-reader thread** over one
//! socket (`Conn::try_clone`): requests stream out back-to-back while
//! replies stream back, matched to their waiters by the request id the
//! bumped wire format carries ([`Msg::PutBlock`]/[`Msg::GetBlock`] →
//! [`Msg::OkFor`]/[`Msg::Data`]/[`Msg::ErrFor`]).  Per-node throughput
//! becomes bandwidth-bound instead of RTT-bound.
//!
//! Flow control is two-level: this client admits at most
//! `max_inflight` operations onto one socket (the
//! `ClientConfig::node_inflight` knob; `1` degenerates to the old
//! lock-step behaviour and is the benchmark baseline), and the session
//! layer bounds total buffered payload with its in-flight-bytes budget
//! (`ClientConfig::inflight_budget`) so deep pipelines cannot balloon
//! memory.
//!
//! Failure semantics are preserved from the lock-step worker: a
//! transport death marks the client dead (the SAI evicts and later
//! reconnects), every outstanding waiter observes [`closed`] — never a
//! hang — and new [`put`](DuplexClient::put)/[`get`](DuplexClient::get)
//! calls fail **eagerly** instead of silently enqueueing into a dead
//! worker.  Logical errors ([`Msg::ErrFor`], e.g. "unknown block") fail
//! only their own request; the connection and every other in-flight
//! operation survive.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write as _};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::proto::Msg;
use crate::hash::Digest;
use crate::net::{Conn, Shaper};
use crate::{Error, Result};

/// Transport-level connection-death error: what every waiter on a dead
/// link observes, and what eager submission against a dead client
/// returns.
pub fn closed() -> Error {
    Error::Node("connection closed".into())
}

/// One block payload, shared without copying: the writer streams it to
/// every replica from the same allocation, the node stores it, and the
/// read path hands it back to the consumer un-copied.
pub type Block = Arc<Vec<u8>>;

/// A registered reply waiter, keyed by request id.
enum Waiter {
    Put(Sender<Result<()>>),
    Get(Sender<Result<Block>>),
}

impl Waiter {
    fn fail(self, e: Error) {
        match self {
            Waiter::Put(s) => drop(s.send(Err(e))),
            Waiter::Get(s) => drop(s.send(Err(e))),
        }
    }
}

/// A queued operation travelling from a submitting session thread to
/// the writer thread.
enum Cmd {
    Put {
        req: u64,
        hash: Digest,
        data: Block,
        done: Sender<Result<()>>,
    },
    Get {
        req: u64,
        hash: Digest,
        done: Sender<Result<Block>>,
    },
}

impl Cmd {
    fn fail(self, e: Error) {
        match self {
            Cmd::Put { done, .. } => drop(done.send(Err(e))),
            Cmd::Get { done, .. } => drop(done.send(Err(e))),
        }
    }
}

/// State shared by the writer thread, the reader thread, and the
/// submitting sessions.
struct Shared {
    /// Outstanding operations awaiting a reply, by request id.
    waiters: Mutex<HashMap<u64, Waiter>>,
    /// Signalled whenever a waiter resolves (or the link dies) so the
    /// writer's admission wait can re-check.
    space: Condvar,
    /// Latched on transport death; checked eagerly by `put`/`get`.
    dead: AtomicBool,
}

impl Shared {
    /// Mark the link dead and fail every outstanding waiter with
    /// [`closed`] — no waiter may ever hang on a dead socket.
    fn die(&self) {
        self.dead.store(true, Ordering::Relaxed);
        let drained: Vec<Waiter> = {
            let mut ws = self.waiters.lock().unwrap();
            ws.drain().map(|(_, w)| w).collect()
        };
        for w in drained {
            w.fail(closed());
        }
        self.space.notify_all();
    }
}

/// One storage node's pipelined data-plane client.  See the module
/// docs; construct with [`DuplexClient::connect`].
pub struct DuplexClient {
    tx: Sender<Cmd>,
    shared: Arc<Shared>,
    next_req: AtomicU64,
}

impl DuplexClient {
    /// Connect to a node and spawn the writer/reader pair.  `shaper`,
    /// if given, paces this link's writes (the client NIC);
    /// `max_inflight` bounds operations in flight on this socket
    /// (floored at 1; `1` = lock-step).
    pub fn connect(
        addr: &str,
        shaper: Option<Arc<Shaper>>,
        max_inflight: usize,
    ) -> Result<DuplexClient> {
        // Bounded connect: a black-holed node costs 2s, not the OS SYN
        // timeout.
        let mut conn = Conn::connect_timeout(addr, Duration::from_secs(2))?;
        if let Some(s) = shaper {
            conn = conn.with_shaper(s);
        }
        let reader_conn = conn.try_clone()?;
        let shared = Arc::new(Shared {
            waiters: Mutex::new(HashMap::new()),
            space: Condvar::new(),
            dead: AtomicBool::new(false),
        });
        let (tx, rx) = mpsc::channel();
        let cap = max_inflight.max(1);
        let sh = shared.clone();
        std::thread::Builder::new()
            .name(format!("sai-dpw-{addr}"))
            .spawn(move || writer_loop(conn, rx, sh, cap))
            .map_err(Error::Io)?;
        let sh = shared.clone();
        std::thread::Builder::new()
            .name(format!("sai-dpr-{addr}"))
            .spawn(move || reader_loop(reader_conn, sh))
            .map_err(|e| {
                // The writer is already running; poison the link so it
                // exits when the handle drops.
                shared.die();
                Error::Io(e)
            })?;
        Ok(DuplexClient {
            tx,
            shared,
            next_req: AtomicU64::new(1),
        })
    }

    /// Whether the link's transport has died (node crash/restart).  The
    /// SAI evicts dead clients so a registry refresh can reconnect.
    pub fn is_dead(&self) -> bool {
        self.shared.dead.load(Ordering::Relaxed)
    }

    /// Submit a block store.  Errs **eagerly** when the link is already
    /// dead — a caller never silently enqueues into a dead worker.  The
    /// returned receiver resolves when the node acknowledges (or the
    /// link dies: [`closed`], never a hang).
    pub fn put(&self, hash: Digest, data: Block) -> Result<Receiver<Result<()>>> {
        if self.is_dead() {
            return Err(closed());
        }
        let req = self.next_req.fetch_add(1, Ordering::Relaxed);
        let (done, rx) = mpsc::channel();
        self.tx
            .send(Cmd::Put {
                req,
                hash,
                data,
                done,
            })
            .map_err(|_| closed())?;
        Ok(rx)
    }

    /// Submit a block fetch.  Same eager-error and never-hang contract
    /// as [`put`](DuplexClient::put); resolves to the shared block
    /// payload (no copy on the client side).
    pub fn get(&self, hash: Digest) -> Result<Receiver<Result<Block>>> {
        if self.is_dead() {
            return Err(closed());
        }
        let req = self.next_req.fetch_add(1, Ordering::Relaxed);
        let (done, rx) = mpsc::channel();
        self.tx
            .send(Cmd::Get { req, hash, done })
            .map_err(|_| closed())?;
        Ok(rx)
    }
}

/// While idle, the writer wakes at this cadence to notice a link that
/// died with nothing queued — otherwise a dead link would park this
/// thread (and its socket fd) until the SAI next evicts the client.
const WRITER_IDLE_TICK: Duration = Duration::from_secs(1);

/// Outcome of waiting for the next queued command.
enum Next {
    Cmd(Cmd),
    /// Client handle dropped: graceful teardown.
    Closed,
    /// Transport died (flush failure, or the reader flagged it while
    /// the queue was idle): fatal teardown.
    Dead,
}

/// Pull the next command, flushing buffered frames before blocking on
/// an empty queue (nothing may sit unsent while we sleep) and ticking
/// the dead flag while idle.
fn next_cmd(rx: &Receiver<Cmd>, w: &mut BufWriter<Conn>, shared: &Shared) -> Next {
    match rx.try_recv() {
        Ok(c) => return Next::Cmd(c),
        Err(TryRecvError::Disconnected) => return Next::Closed,
        Err(TryRecvError::Empty) => {}
    }
    if w.flush().is_err() {
        return Next::Dead;
    }
    loop {
        match rx.recv_timeout(WRITER_IDLE_TICK) {
            Ok(c) => return Next::Cmd(c),
            Err(RecvTimeoutError::Disconnected) => return Next::Closed,
            Err(RecvTimeoutError::Timeout) => {
                if shared.dead.load(Ordering::Relaxed) {
                    return Next::Dead;
                }
            }
        }
    }
}

/// Writer thread: streams queued requests onto the socket, registering
/// each waiter *before* its frame goes out (the reply can race the
/// write), batching flushes (one flush per queue drain, not per frame)
/// and admitting at most `cap` operations in flight.
fn writer_loop(conn: Conn, rx: Receiver<Cmd>, shared: Arc<Shared>, cap: usize) {
    let mut w = BufWriter::with_capacity(256 * 1024, conn);
    let fatal = |w: &mut BufWriter<Conn>, shared: &Shared| {
        shared.die();
        // Unblock the reader (and any straggling peer read).
        w.get_ref().shutdown();
    };
    let mut graceful = true;
    loop {
        let cmd = match next_cmd(&rx, &mut w, &shared) {
            Next::Cmd(c) => c,
            Next::Closed => break, // client handle dropped
            Next::Dead => {
                fatal(&mut w, &shared);
                graceful = false;
                break;
            }
        };
        // Admission: at most `cap` ops outstanding on this socket.
        // Everything buffered must hit the wire before we block on
        // replies, or the pipeline deadlocks on its own buffer.
        if shared.waiters.lock().unwrap().len() >= cap {
            if w.flush().is_err() {
                cmd.fail(closed());
                fatal(&mut w, &shared);
                graceful = false;
                break;
            }
            let mut ws = shared.waiters.lock().unwrap();
            while ws.len() >= cap && !shared.dead.load(Ordering::Relaxed) {
                ws = shared.space.wait(ws).unwrap();
            }
        }
        if shared.dead.load(Ordering::Relaxed) {
            // Reader saw the transport die: fail this command and exit
            // (the post-loop drain fails anything else queued).  A dead
            // link must not park this thread in recv() forever — new
            // submissions already err eagerly at `put`/`get`.
            cmd.fail(closed());
            graceful = false;
            break;
        }
        let res = match cmd {
            Cmd::Put {
                req,
                hash,
                data,
                done,
            } => {
                shared
                    .waiters
                    .lock()
                    .unwrap()
                    .insert(req, Waiter::Put(done));
                // Header + payload written separately: the payload
                // streams straight from the shared Arc — no frame
                // assembly copy per replica.
                w.write_all(&Msg::put_header(req, &hash, data.len()))
                    .and_then(|()| w.write_all(&data))
            }
            Cmd::Get { req, hash, done } => {
                shared
                    .waiters
                    .lock()
                    .unwrap()
                    .insert(req, Waiter::Get(done));
                w.write_all(&Msg::GetBlock { req, hash }.encode())
            }
        };
        if res.is_err() {
            // The socket is gone mid-frame; `die` fails the waiter we
            // just registered along with every other outstanding one.
            fatal(&mut w, &shared);
            graceful = false;
            break;
        }
        // Death re-check AFTER registering: the reader may have died
        // (and drained the map) between our admission check and the
        // insert, in which case nobody else will ever fail the waiter
        // we just added.  The waiters mutex orders the insert against
        // the reader's drain, so exactly one side sees the other:
        // either the drain took our waiter, or this load observes
        // `dead` and `die` fails it here.  Never a hang.
        if shared.dead.load(Ordering::Relaxed) {
            fatal(&mut w, &shared);
            graceful = false;
            break;
        }
    }
    if graceful {
        // Handle dropped: flush what's queued and half-close so the
        // node answers everything it read; the reader drains those
        // replies and then sees a clean EOF.
        let _ = w.flush();
        w.get_ref().shutdown_write();
    }
    // Fail anything still queued behind a fatal exit.
    while let Ok(c) = rx.try_recv() {
        c.fail(closed());
    }
}

/// Reader thread: drains tagged replies off the socket and resolves
/// their waiters by request id.  Any transport error, EOF, or protocol
/// violation (unknown id, reply-kind mismatch, untagged frame) kills
/// the link: the stream can no longer be trusted to align replies with
/// requests.
fn reader_loop(conn: Conn, shared: Arc<Shared>) {
    let mut r = BufReader::with_capacity(256 * 1024, conn);
    loop {
        let msg = match Msg::read_from(&mut r) {
            Ok(Some(m)) => m,
            _ => break, // EOF or transport/frame error
        };
        let (req, outcome) = match msg {
            Msg::OkFor { req } => (req, Ok(None)),
            Msg::Data { req, data } => (req, Ok(Some(data))),
            Msg::ErrFor { req, msg } => (req, Err(Error::Node(msg))),
            _ => break, // untagged frame on the data plane
        };
        let waiter = shared.waiters.lock().unwrap().remove(&req);
        match (waiter, outcome) {
            (Some(Waiter::Put(s)), Ok(None)) => drop(s.send(Ok(()))),
            (Some(Waiter::Get(s)), Ok(Some(data))) => drop(s.send(Ok(Arc::new(data)))),
            (Some(w), Err(e)) => w.fail(e),
            // Unknown request id or reply-kind mismatch: stop trusting
            // the stream (the removed waiter, if any, resolves through
            // `die` below... its sender is gone, so it observes closed).
            _ => break,
        }
        shared.space.notify_all();
    }
    shared.die();
    // Unblock a writer stuck in a backpressured send.
    r.get_ref().shutdown();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Listener;

    /// A scripted node: reads `n` requests off one connection, then
    /// replies to ALL of them in the (possibly shuffled) order given by
    /// `order` (indices into arrival order).
    fn scripted_node(n: usize, order: Vec<usize>) -> (String, std::thread::JoinHandle<()>) {
        let l = Listener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let mut c = l.accept().unwrap();
            let mut reqs = Vec::new();
            for _ in 0..n {
                reqs.push(Msg::read_from(&mut c).unwrap().unwrap());
            }
            for &i in &order {
                let reply = match &reqs[i] {
                    Msg::PutBlock { req, .. } => Msg::OkFor { req: *req },
                    Msg::GetBlock { req, hash } => Msg::Data {
                        req: *req,
                        data: vec![hash[0]; 8],
                    },
                    m => panic!("unexpected {m:?}"),
                };
                reply.write_to(&mut c).unwrap();
            }
        });
        (addr, h)
    }

    #[test]
    fn replies_match_waiters_out_of_order() {
        let (addr, h) = scripted_node(4, vec![2, 0, 3, 1]);
        let c = DuplexClient::connect(&addr, None, 8).unwrap();
        let p0 = c.put([1; 16], Arc::new(vec![1; 32])).unwrap();
        let g1 = c.get([2; 16]).unwrap();
        let p2 = c.put([3; 16], Arc::new(vec![3; 32])).unwrap();
        let g3 = c.get([4; 16]).unwrap();
        assert!(p0.recv().unwrap().is_ok());
        assert_eq!(&*g1.recv().unwrap().unwrap(), &vec![2u8; 8]);
        assert!(p2.recv().unwrap().is_ok());
        assert_eq!(&*g3.recv().unwrap().unwrap(), &vec![4u8; 8]);
        h.join().unwrap();
    }

    #[test]
    fn dead_link_fails_eagerly_and_fails_waiters() {
        let l = Listener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let mut c = l.accept().unwrap();
            // Read one request, then slam the door.
            let _ = Msg::read_from(&mut c).unwrap();
            c.shutdown();
        });
        let c = DuplexClient::connect(&addr, None, 8).unwrap();
        let rx = c.put([1; 16], Arc::new(vec![0; 16])).unwrap();
        // The outstanding waiter observes an error, not a hang.
        assert!(rx.recv().unwrap().is_err());
        h.join().unwrap();
        // Subsequent submissions fail eagerly.
        for _ in 0..100 {
            if c.is_dead() {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(c.is_dead());
        assert!(c.put([2; 16], Arc::new(vec![0; 16])).is_err());
        assert!(c.get([2; 16]).is_err());
    }

    #[test]
    fn lock_step_cap_still_completes() {
        // cap = 1 (the lock-step baseline) must interleave cleanly with
        // a node that answers one request at a time.
        let l = Listener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let mut c = l.accept().unwrap();
            while let Ok(Some(m)) = Msg::read_from(&mut c) {
                let reply = match m {
                    Msg::PutBlock { req, .. } => Msg::OkFor { req },
                    Msg::GetBlock { req, hash } => Msg::Data {
                        req,
                        data: vec![hash[0]; 4],
                    },
                    m => panic!("unexpected {m:?}"),
                };
                if reply.write_to(&mut c).is_err() {
                    break;
                }
            }
        });
        let c = DuplexClient::connect(&addr, None, 1).unwrap();
        let rxs: Vec<_> = (0..3u8)
            .map(|i| c.put([i; 16], Arc::new(vec![i; 16])).unwrap())
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        let g = c.get([7; 16]).unwrap();
        assert_eq!(&*g.recv().unwrap().unwrap(), &vec![7u8; 4]);
        drop(c); // half-close -> the serve loop sees EOF and exits
        h.join().unwrap();
    }
}
